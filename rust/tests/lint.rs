//! `eva lint` fixture + self-check suite.
//!
//! Each rule has a fixture file under `tests/lint_fixtures/src/` laid
//! out like the real source tree (rule scopes key off the relative
//! path) and a golden expectation under `expected/` holding the
//! `{file, line, rule}` projection of every diagnostic. Messages are
//! asserted non-empty but not pinned — they are prose, and pinning
//! them would turn every wording tweak into a golden churn.
//!
//! The last test lints the real `rust/src` tree against
//! `docs/ARCHITECTURE.md` and requires zero findings: the linter's
//! own repo must be clean (CI runs the same check as a blocking job).

use std::path::{Path, PathBuf};

use eva::jsonx::Json;
use eva::lint::{self, Diagnostic, LintConfig, MetricCatalog};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("lint_fixtures")
}

fn lint_fixture(rel: &str, catalog: Option<&MetricCatalog>) -> Vec<Diagnostic> {
    let path = fixture_root().join("src").join(rel);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read fixture {}: {e}", path.display()));
    lint::lint_source(rel, &src, catalog)
}

fn golden(name: &str) -> Json {
    let path = fixture_root().join("expected").join(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read golden {}: {e}", path.display()));
    Json::parse(&text).unwrap_or_else(|e| panic!("parse golden {name}: {e}"))
}

fn fixture_catalog() -> MetricCatalog {
    let text = std::fs::read_to_string(fixture_root().join("catalog.md")).expect("catalog.md");
    MetricCatalog::parse(&text)
}

/// The `{file, line, rule}` projection compared against goldens.
fn project(diags: &[Diagnostic]) -> Json {
    Json::Arr(
        diags
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("file", Json::Str(d.file.clone())),
                    ("line", Json::Num(d.line as f64)),
                    ("rule", Json::Str(d.rule.to_string())),
                ])
            })
            .collect(),
    )
}

fn check_fixture(rel: &str, golden_name: &str, catalog: Option<&MetricCatalog>) {
    let diags = lint_fixture(rel, catalog);
    for d in &diags {
        assert!(!d.message.is_empty(), "{d:?} carries no message");
        assert_eq!(d.file, rel, "diagnostics carry the source-root-relative path");
    }
    assert_eq!(project(&diags), golden(golden_name), "got:\n{}", lint::render_text(&diags));
}

#[test]
fn l1_fma_fires_and_respects_reasoned_suppression() {
    check_fixture("simd/fma.rs", "simd__fma.json", None);
}

#[test]
fn l2_thread_spawn_fires_outside_the_allowlist() {
    check_fixture("data/loader.rs", "data__loader.json", None);
}

#[test]
fn l3_safety_comment_walkup_accepts_every_documented_form() {
    check_fixture("backend/raw.rs", "backend__raw.json", None);
}

#[test]
fn l4_hashed_collections_fire_outside_test_code() {
    check_fixture("optim/table.rs", "optim__table.json", None);
}

#[test]
fn l5_unwrap_fires_but_unwrap_or_and_tests_do_not() {
    check_fixture("serve/service.rs", "serve__service.json", None);
}

#[test]
fn l6_metric_names_check_against_the_catalog() {
    check_fixture("telemetry/counters.rs", "telemetry__counters.json", Some(&fixture_catalog()));
}

#[test]
fn l0_malformed_suppressions_fire_and_do_not_suppress() {
    check_fixture("serve/protocol.rs", "serve__protocol.json", None);
}

#[test]
fn tree_walk_aggregates_every_fixture_in_stable_order() {
    let cfg = LintConfig {
        src_root: fixture_root().join("src"),
        doc_catalog: Some(fixture_root().join("catalog.md")),
    };
    let diags = lint::lint_tree(&cfg).expect("walk the fixture tree");
    assert_eq!(diags.len(), 16, "got:\n{}", lint::render_text(&diags));
    let mut sorted = diags.clone();
    sorted.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    assert_eq!(diags, sorted, "diagnostics arrive sorted by (file, line, rule)");
}

#[test]
fn json_render_parses_and_carries_the_rule_catalog() {
    let diags = lint_fixture("serve/protocol.rs", None);
    let parsed = Json::parse(&lint::render_json(&diags)).expect("render_json emits valid JSON");
    assert_eq!(parsed.get_f64("violations"), Some(diags.len() as f64));
    let rules = parsed.get("rules").and_then(|r| r.as_arr()).expect("rules array");
    assert_eq!(rules.len(), lint::RULES.len());
    let items = parsed.get("diagnostics").and_then(|d| d.as_arr()).expect("diagnostics array");
    assert_eq!(items.len(), diags.len());
}

#[test]
fn fix_list_prints_the_suppression_recipe() {
    let diags = lint_fixture("simd/fma.rs", None);
    let s = lint::render_fix_list(&diags);
    assert!(s.contains("eva-lint: allow(L1) -- <reason>"), "{s}");
    assert_eq!(lint::render_fix_list(&[]).trim(), "nothing to fix");
}

#[test]
fn the_real_tree_is_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig {
        src_root: manifest.join("src"),
        doc_catalog: Some(manifest.join("..").join("docs").join("ARCHITECTURE.md")),
    };
    let diags = lint::lint_tree(&cfg).expect("lint the real tree");
    assert!(diags.is_empty(), "the repo must lint clean:\n{}", lint::render_text(&diags));
}
