//! Scheduler lane-count independence (ISSUE 3 acceptance, the serve
//! sibling of backend_parity.rs).
//!
//! Two guarantees, both *bit*-level:
//!
//! 1. A session run under the scheduler — concurrently with other
//!    tenants, on whatever lane carve its priority earned — produces
//!    exactly the weights it produces when stepped alone on the
//!    sequential backend.
//! 2. The carve itself doesn't matter: seq, threads:2 and threads:6
//!    all yield identical digests (the backend determinism contract
//!    composed through `split_weighted` + `with_backend`).

use std::io::{BufRead, BufReader, Write};
use std::sync::Mutex;
use std::time::Duration;

use eva::backend::{self, BackendChoice};
use eva::config::{ModelArch, TrainConfig};
use eva::serve::client::{LocalClient, ServeClient, TcpClient};
use eva::serve::{ServeConfig, Server, Service, Session};

/// Serializes tests that swap the process-global backend.
static GLOBAL_BACKEND: Mutex<()> = Mutex::new(());

fn tenant_cfg(seed: u64, optimizer: &str) -> TrainConfig {
    let mut c = TrainConfig {
        name: format!("tenant-{seed}"),
        dataset: "c10-small".into(),
        seed,
        arch: ModelArch::Classifier { hidden: vec![16] },
        epochs: 1,
        batch_size: 32,
        base_lr: 0.05,
        max_steps: Some(24),
        ..TrainConfig::default()
    };
    c.optim.algorithm = optimizer.into();
    c
}

/// Step a session to completion alone, no scheduler involved.
fn solo_digest(cfg: &TrainConfig) -> u64 {
    let mut s = Session::new(0, "solo", 1, cfg).unwrap();
    while !s.is_done() {
        assert!(s.run_quantum(16) > 0);
    }
    s.digest()
}

/// Run both tenants concurrently under a service and return their
/// digests.
fn scheduled_digests(cfgs: &[(TrainConfig, usize)], quantum: usize) -> Vec<u64> {
    let svc = Service::start(ServeConfig {
        max_sessions: cfgs.len().max(1),
        quantum_steps: quantum,
        // Durability is serve_admission.rs territory; these parity
        // tests must not write tombstones into ./checkpoints.
        checkpoint_on_shutdown: false,
        ..ServeConfig::default()
    });
    let mut client = LocalClient::new(&svc);
    let ids: Vec<u64> = cfgs
        .iter()
        .map(|(c, prio)| client.submit(c, &c.name, *prio).unwrap())
        .collect();
    for &id in &ids {
        client.wait_done(id, Duration::from_secs(300)).unwrap();
    }
    let digests = ids.iter().map(|&id| svc.model_digest(id).unwrap()).collect();
    svc.shutdown();
    digests
}

#[test]
fn concurrent_sessions_match_solo_runs_on_every_carve() {
    let _serial = GLOBAL_BACKEND.lock().unwrap_or_else(|e| e.into_inner());
    let a = tenant_cfg(31, "eva");
    let b = tenant_cfg(77, "eva-s");
    // Ground truth: each tenant alone on the sequential backend.
    let prev = backend::global();
    backend::install(&BackendChoice::Sequential);
    let solo_a = solo_digest(&a);
    let solo_b = solo_digest(&b);
    // Same tenants under the scheduler, across lane budgets and
    // priority mixes. threads:6 with weights 2:1 carves 4/2 lanes;
    // threads:2 carves 1/1 (both degrade to inline sequential); seq
    // time-slices one quantum at a time.
    for (choice, label) in [
        (BackendChoice::Sequential, "seq"),
        (BackendChoice::Threaded(2), "threads:2"),
        (BackendChoice::Threaded(6), "threads:6"),
    ] {
        backend::install(&choice);
        let digests = scheduled_digests(&[(a.clone(), 2), (b.clone(), 1)], 5);
        assert_eq!(digests[0], solo_a, "tenant A diverged under {label}");
        assert_eq!(digests[1], solo_b, "tenant B diverged under {label}");
    }
    backend::set_global(prev);
}

#[test]
fn tcp_server_speaks_the_protocol_end_to_end() {
    // Socket-level coverage: submit over TCP, read state, survive a
    // malformed line, shut the service down over the wire.
    let svc = Service::start(ServeConfig {
        max_sessions: 2,
        quantum_steps: 4,
        checkpoint_on_shutdown: false,
        ..ServeConfig::default()
    });
    let server = Server::start(svc.clone(), "127.0.0.1:0").unwrap();
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let id = client.submit(&tenant_cfg(9, "eva"), "tcp-tenant", 1).unwrap();
    let done = client.wait_done(id, Duration::from_secs(300)).unwrap();
    assert_eq!(done.get_f64("step"), Some(24.0));
    assert_eq!(done.get_str("status"), Some("done"));
    let stats = client.stats().unwrap();
    assert!(stats.get_f64("scheduler_steps").unwrap_or(0.0) >= 24.0);
    // A malformed request gets an ok:false response, not a hangup.
    {
        let mut raw = std::net::TcpStream::connect(server.addr()).unwrap();
        raw.write_all(b"this is not json\n").unwrap();
        let mut line = String::new();
        BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "{line}");
    }
    // An oversized request (no-newline flood) is bounded: the server
    // answers with an error and/or closes — it never accumulates the
    // stream indefinitely.
    {
        let mut raw = std::net::TcpStream::connect(server.addr()).unwrap();
        let chunk = vec![b'x'; 64 * 1024];
        for _ in 0..40 {
            // > MAX_LINE_BYTES in total
            if raw.write_all(&chunk).is_err() {
                break; // server already dropped us — that's a pass
            }
        }
        let _ = raw.write_all(b"\n");
        let mut line = String::new();
        let n = BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap_or(0);
        assert!(
            n == 0 || line.contains("\"ok\":false"),
            "oversized request not rejected: {line}"
        );
    }
    client.shutdown().unwrap();
    server.join();
    assert!(svc.is_stopped());
}

#[test]
fn checkpoint_resume_through_the_service_matches_uninterrupted() {
    // The full service-level loop: run → pause → checkpoint → cancel →
    // restore from the file into a *new* session → finish; digest must
    // equal the uninterrupted solo run. Exercises the protocol
    // (in-process client speaks the same wire format as TCP).
    let _serial = GLOBAL_BACKEND.lock().unwrap_or_else(|e| e.into_inner());
    let prev = backend::global();
    backend::install(&BackendChoice::Threaded(4));
    let cfg = tenant_cfg(55, "eva");
    let dir = std::env::temp_dir().join("eva-serve-parity-ck");
    let svc = Service::start(ServeConfig {
        max_sessions: 4,
        quantum_steps: 3,
        checkpoint_dir: dir.to_string_lossy().into_owned(),
        checkpoint_on_shutdown: false,
        ..ServeConfig::default()
    });
    let mut client = LocalClient::new(&svc);
    let id = client.submit(&cfg, "ck-tenant", 1).unwrap();
    // Let it make some progress, then freeze it.
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    loop {
        let st = client.status(id).unwrap();
        let step = st.get_f64("step").unwrap_or(0.0) as u64;
        let done = st.get_str("status") == Some("done");
        if step >= 6 || done {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "no progress");
        std::thread::sleep(Duration::from_millis(5));
    }
    client.pause(id).unwrap();
    let path = client.checkpoint(id).unwrap();
    client.cancel(id).unwrap();
    // Restore into a fresh session (protocol path) and finish it.
    let id2 = client.submit_checkpoint(&path, "restored", 1).unwrap();
    client.wait_done(id2, Duration::from_secs(300)).unwrap();
    let resumed = svc.model_digest(id2).unwrap();
    svc.shutdown();
    backend::install(&BackendChoice::Sequential);
    let solo = solo_digest(&cfg);
    backend::set_global(prev);
    assert_eq!(resumed, solo, "service checkpoint→restore diverged");
    let _ = std::fs::remove_dir_all(dir);
}
