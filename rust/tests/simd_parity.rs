//! SIMD parity: every routed kernel must be **bit-identical** across
//! all compiled ISA paths (`scalar`/`sse2`/`avx2`, whichever this host
//! can run) × all backends (`seq`/`threads:2`/`threads:6`).
//!
//! This is the enforcement half of the determinism contract in
//! `docs/KERNELS.md`: the fixed chunk grids come from the backend
//! layer (`tests/backend_parity.rs`), the fixed 8-lane accumulation
//! tree comes from `eva::simd` — together they make training runs and
//! checkpoints portable across ISAs, thread counts, and schedulers.

use std::sync::Mutex;

use eva::backend::{self, Backend, BackendChoice, Sequential, Threaded};
use eva::config::{ModelArch, OptimConfig, TrainConfig};
use eva::linalg;
use eva::optim::HyperParams;
use eva::simd::{self, Isa, SimdChoice};
use eva::tensor::{self, Tensor};
use eva::testing::Gen;
use eva::train::Trainer;

/// The ISA path and the global backend are process-wide; tests that
/// swap either serialize here.
static GLOBAL_KNOBS: Mutex<()> = Mutex::new(());

fn with_isa<T>(isa: Isa, f: impl FnOnce() -> T) -> T {
    simd::install(&SimdChoice::Force(isa)).unwrap();
    let out = f();
    simd::install(&SimdChoice::Auto).unwrap();
    out
}

fn with_global_backend<T>(choice: BackendChoice, f: impl FnOnce() -> T) -> T {
    let prev = backend::global();
    backend::install(&choice);
    let out = f();
    backend::set_global(prev);
    out
}

fn backends() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(Sequential) as Box<dyn Backend>,
        Box::new(Threaded::new(2)),
        Box::new(Threaded::new(6)),
    ]
}

// ---------------------------------------------------------------------------
// Slice-level micro-kernels
// ---------------------------------------------------------------------------

/// dot8/axpy8/scale8/blend8 agree bit-for-bit on every ISA path, at
/// lengths exercising the vector blocks, the odd-block arm, and the
/// scalar tail.
#[test]
fn slice_kernels_bit_identical_across_isas() {
    let _serial = GLOBAL_KNOBS.lock().unwrap_or_else(|e| e.into_inner());
    let mut g = Gen::new(2024);
    for n in [0usize, 1, 5, 8, 16, 23, 24, 1000, 8192, 8203] {
        let a = g.normal_vec(n.max(1))[..n].to_vec();
        let b = g.normal_vec(n.max(1))[..n].to_vec();
        // Row tiles: 4 k-steps over rows of length n; one coefficient
        // is exactly zero to exercise the skip arm on every path.
        let mut coeffs = g.normal_vec(4);
        coeffs[2] = 0.0;
        let bmat = g.normal_vec((4 * n).max(1))[..4 * n].to_vec();
        type KernelOut = (f32, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>);
        let runs: Vec<KernelOut> = simd::available_isas()
            .into_iter()
            .map(|isa| {
                with_isa(isa, || {
                    let d = simd::dot8(&a, &b);
                    let mut y1 = b.clone();
                    simd::axpy8(1.7, &a, &mut y1);
                    let mut y2 = a.clone();
                    simd::scale8(&mut y2, -0.3);
                    let mut y3 = b.clone();
                    simd::blend8(&mut y3, 0.95, 0.05, &a);
                    let mut y4 = a.clone();
                    simd::row_mac8(&mut y4, &coeffs, 1, &bmat);
                    let mut y5 = vec![0.0f32; 4];
                    simd::row_dots8(&mut y5, &a, &bmat);
                    (d, y1, y2, y3, y4, y5)
                })
            })
            .collect();
        for (i, r) in runs.iter().enumerate().skip(1) {
            assert_eq!(r.0.to_bits(), runs[0].0.to_bits(), "dot8 isa#{i} n={n}");
            assert_eq!(r.1, runs[0].1, "axpy8 isa#{i} n={n}");
            assert_eq!(r.2, runs[0].2, "scale8 isa#{i} n={n}");
            assert_eq!(r.3, runs[0].3, "blend8 isa#{i} n={n}");
            assert_eq!(r.4, runs[0].4, "row_mac8 isa#{i} n={n}");
            assert_eq!(r.5, runs[0].5, "row_dots8 isa#{i} n={n}");
        }
    }
}

// ---------------------------------------------------------------------------
// Routed tensor/linalg kernels: ISA × backend grid
// ---------------------------------------------------------------------------

/// Matmul variants, tmatvec/mean_rows, spd_inverse, and eigh_jacobi
/// produce the same bits under every (ISA, backend) combination —
/// sizes sit above the parallel dispatch gates so the partitioned
/// paths really run.
#[test]
fn routed_kernels_bit_identical_across_isa_backend_grid() {
    let _serial = GLOBAL_KNOBS.lock().unwrap_or_else(|e| e.into_inner());
    let mut g = Gen::new(77);
    let (m, k, n) = (130usize, 70usize, 90usize);
    let a = g.normal_tensor(m, k);
    let b = g.normal_tensor(k, n);
    let at = g.normal_tensor(k, m);
    let bt = g.normal_tensor(n, k);
    let t = g.normal_tensor(300, 300);
    let x = g.normal_vec(300);
    let spd = g.spd_tensor(96, 0.05);

    let mut reference: Option<Vec<Vec<u32>>> = None;
    for isa in simd::available_isas() {
        with_isa(isa, || {
            for bk in backends() {
                let bk = &*bk;
                let mut outs: Vec<Vec<u32>> = Vec::new();
                let bits = |t: &Tensor| t.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
                let vbits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
                outs.push(bits(&tensor::matmul_with(bk, &a, &b)));
                outs.push(bits(&tensor::matmul_at_b_with(bk, &at, &b)));
                outs.push(bits(&tensor::matmul_a_bt_with(bk, &a, &bt)));
                outs.push(vbits(&t.tmatvec_with(bk, &x)));
                outs.push(vbits(&t.mean_rows_with(bk)));
                outs.push(bits(&linalg::spd_inverse_with(bk, &spd).unwrap()));
                let (lambda, v) = linalg::eigh_jacobi_with(bk, &spd, 12);
                outs.push(vbits(&lambda));
                outs.push(bits(&v));
                if reference.is_none() {
                    reference = Some(outs);
                } else {
                    let want_all = reference.as_ref().unwrap();
                    for (ki, (got, want)) in outs.iter().zip(want_all).enumerate() {
                        assert_eq!(
                            got,
                            want,
                            "kernel #{ki} diverges at isa={} backend={}",
                            isa.name(),
                            bk.label()
                        );
                    }
                }
            }
        });
    }
}

/// The globally-dispatched reduction (`Tensor::dot` above the chunk
/// gate) agrees across the full ISA × backend grid too.
#[test]
fn global_reduction_bit_identical_across_grid() {
    let _serial = GLOBAL_KNOBS.lock().unwrap_or_else(|e| e.into_inner());
    let mut g = Gen::new(88);
    let x = g.normal_tensor(300, 300); // 90k elements: above the gate
    let y = g.normal_tensor(300, 300);
    let mut reference: Option<u32> = None;
    for isa in simd::available_isas() {
        with_isa(isa, || {
            for choice in [
                BackendChoice::Sequential,
                BackendChoice::Threaded(2),
                BackendChoice::Threaded(6),
            ] {
                let d = with_global_backend(choice.clone(), || x.dot(&y)).to_bits();
                match reference {
                    None => reference = Some(d),
                    Some(r) => assert_eq!(d, r, "dot diverges at isa={}", isa.name()),
                }
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Full train steps: weights digest per optimizer family
// ---------------------------------------------------------------------------

/// A short native training run; returns the FNV digest of the exact
/// final weight/bias bits.
fn train_digest(optimizer: &str) -> u64 {
    let mut hp = HyperParams::default();
    hp.update_interval = 2;
    hp.shampoo_block = 32;
    let cfg = TrainConfig {
        name: format!("simd-parity-{optimizer}"),
        dataset: "c10-small".into(),
        seed: 7,
        arch: ModelArch::Classifier { hidden: vec![16] },
        optim: OptimConfig { algorithm: optimizer.into(), hp },
        engine: eva::config::Engine::Native,
        epochs: 1,
        batch_size: 32,
        base_lr: 0.05,
        lr_schedule: eva::config::LrSchedule::Cosine,
        warmup_steps: 0,
        max_steps: Some(4),
        eval_every: 1,
        backend: None,
        worker_threads: None,
        simd: None,
        telemetry: None,
    };
    let mut t = Trainer::from_config(&cfg).unwrap();
    t.run().unwrap();
    eva::serve::model_digest(t.model().expect("native engine"))
}

/// One full train run per optimizer family is bit-identical with
/// `--simd scalar` vs the auto-detected best path — the end-to-end
/// statement of ISA portability (checkpoints restore to the same bits
/// on any host).
#[test]
fn train_step_digests_scalar_vs_auto() {
    let _serial = GLOBAL_KNOBS.lock().unwrap_or_else(|e| e.into_inner());
    for optimizer in ["eva", "kfac", "shampoo", "mkor", "kradagrad"] {
        let scalar = with_isa(Isa::Scalar, || train_digest(optimizer));
        let best = with_isa(simd::detect_best(), || train_digest(optimizer));
        assert_eq!(
            scalar, best,
            "{optimizer}: weights diverge between --simd scalar and the {} path",
            simd::detect_best().name()
        );
    }
}

// ---------------------------------------------------------------------------
// Selection plumbing
// ---------------------------------------------------------------------------

#[test]
fn forcing_an_unavailable_path_errors() {
    let _serial = GLOBAL_KNOBS.lock().unwrap_or_else(|e| e.into_inner());
    for isa in [Isa::Avx2, Isa::Sse2, Isa::Scalar] {
        let r = simd::install(&SimdChoice::Force(isa));
        if simd::is_available(isa) {
            assert_eq!(r.unwrap(), isa);
        } else {
            let e = r.unwrap_err();
            assert!(e.contains(isa.name()), "{e}");
        }
    }
    simd::install(&SimdChoice::Auto).unwrap();
    assert_eq!(simd::active(), simd::detect_best());
}

/// The config key installs the path through Trainer::from_config, and
/// an explicitly unavailable path fails loudly there.
#[test]
fn config_key_installs_simd_path() {
    let _serial = GLOBAL_KNOBS.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = TrainConfig::from_json(
        r#"{"name": "s", "dataset": "c10-small", "hidden": [8],
            "max_steps": 1, "simd": "scalar"}"#,
    )
    .unwrap();
    let _t = Trainer::from_config(&cfg).unwrap();
    assert_eq!(simd::active(), Isa::Scalar);
    simd::install(&SimdChoice::Auto).unwrap();
    if !simd::is_available(Isa::Avx2) {
        cfg.simd = Some("avx2".into());
        assert!(Trainer::from_config(&cfg).is_err());
    }
}
