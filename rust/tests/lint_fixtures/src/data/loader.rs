//! L2 fixture: thread creation outside the substrate allow-list
//! (`data/` is not on it).

pub fn bare() {
    std::thread::spawn(|| {}).join().ok();
}

pub fn builder_outside() {
    std::thread::Builder::new()
        .name("fixture".into())
        .spawn(|| {})
        .ok();
}

pub fn suppressed() {
    // eva-lint: allow(L2) -- fixture: pretend this is a sanctioned one-off
    std::thread::spawn(|| {}).join().ok();
}
