//! L0 fixture: malformed suppression comments — each fires L0 *and*
//! leaves the underlying violation unsuppressed.

pub fn reasonless(v: Option<u32>) -> u32 {
    v.unwrap() // eva-lint: allow(L5)
}

pub fn unknown_rule(v: Option<u32>) -> u32 {
    v.unwrap() // eva-lint: allow(L99) -- no such rule
}

pub fn empty_reason(v: Option<u32>) -> u32 {
    v.unwrap() // eva-lint: allow(L5) --
}
