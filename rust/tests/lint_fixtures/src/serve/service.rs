//! L5 fixture: panicking extractors in a request-handling path.

pub fn handle(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn handle_expect(v: Option<u32>) -> u32 {
    v.expect("fixture")
}

pub fn fine(v: Option<u32>) -> u32 {
    v.unwrap_or(0)
}

pub fn also_fine(v: Option<u32>) -> u32 {
    v.unwrap_or_else(|| 0)
}

pub fn suppressed(v: Option<u32>) -> u32 {
    v.unwrap() // eva-lint: allow(L5) -- fixture: input proven Some by the caller
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        assert_eq!(Some(3).unwrap(), 3);
    }
}
