//! L1 fixture: fused multiply-add inside a determinism-scoped module.

pub fn fused(a: f32, b: f32, c: f32) -> f32 {
    a.mul_add(b, c)
}

pub fn fused_allowed(a: f32, b: f32, c: f32) -> f32 {
    // eva-lint: allow(L1) -- fixture: demonstrates the reasoned escape hatch
    a.mul_add(b, c)
}

pub fn separate(a: f32, b: f32, c: f32) -> f32 {
    a * b + c
}

pub fn not_fma(x: f32) -> f32 {
    mul_add_estimate(x)
}

fn mul_add_estimate(x: f32) -> f32 {
    x
}

pub fn only_mentioned() -> &'static str {
    // A string or comment that mentions mul_add must not fire.
    "mul_add"
}
