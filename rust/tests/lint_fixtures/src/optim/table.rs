//! L4 fixture: hashed collections in an ordering-sensitive module.

use std::collections::HashMap;

pub fn build() -> HashMap<String, f32> {
    HashMap::new()
}

pub fn suppressed() {
    // eva-lint: allow(L4) -- fixture: insertion-only map, never iterated
    let _m: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let _m = std::collections::HashMap::<u32, u32>::new();
    }
}
