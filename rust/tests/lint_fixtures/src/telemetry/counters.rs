//! L6 fixture: metric names against the documented catalog
//! (`catalog.md` next to this fixture tree).

use crate::telemetry::{Counter, Gauge};

pub fn documented() -> Counter {
    Counter::new("fixture.requests.count")
}

pub fn documented_via_braces() -> Counter {
    Counter::new("fixture.errors.count")
}

pub fn undocumented() -> Counter {
    Counter::new("fixture.surprise.count")
}

pub fn suppressed() -> Gauge {
    // eva-lint: allow(L6) -- fixture: experimental gauge, intentionally undocumented
    Gauge::new("fixture.experimental.depth")
}
