//! L3 fixture: SAFETY discipline around `unsafe`.

pub fn missing(p: *const f32) -> f32 {
    unsafe { *p }
}

/// Reads one float.
///
/// # Safety
/// `p` must be valid for reads.
pub unsafe fn doc_safety(p: *const f32) -> f32 {
    *p
}

pub fn same_line(p: *const f32) -> f32 {
    unsafe { *p } // SAFETY: fixture — the caller checked the pointer
}

pub fn above(p: *const f32) -> f32 {
    // SAFETY: fixture — the caller checked alignment and provenance.
    unsafe { *p }
}

pub fn above_with_attr(p: *const f32) -> f32 {
    // SAFETY: fixture — the attribute between comment and keyword is skipped.
    #[allow(unused_unsafe)]
    unsafe {
        *p
    }
}

pub fn suppressed(p: *const f32) -> f32 {
    // eva-lint: allow(L3) -- fixture: contract stated in the module docs
    unsafe { *p }
}

pub fn only_mentioned() -> &'static str {
    // The word unsafe in a comment or literal must not fire.
    "unsafe"
}
