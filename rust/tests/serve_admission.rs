//! Durable admission control (ISSUE 5 acceptance).
//!
//! * Over-cap submissions **queue** (with a reported `queue_position`)
//!   instead of erroring, and are promoted FIFO-within-priority as
//!   live slots free.
//! * Per-tenant quotas bound how much of the queue one client can
//!   hold.
//! * A serve process killed after `checkpoint_every_steps` — or shut
//!   down gracefully with `checkpoint_on_shutdown` — and restarted
//!   with `resume_from_dir` finishes every session with weight
//!   digests bit-identical to an uninterrupted run (the PR 3
//!   bit-identity witness).
//! * Torn checkpoints (stray `.tmp`, truncated `.ckpt`) never shadow
//!   a good snapshot.
//! * Terminal sessions beyond `retain_terminal` are evicted and
//!   report a distinct "evicted" error.

use std::time::Duration;

use eva::config::{ModelArch, TrainConfig};
use eva::serve::client::{LocalClient, ServeClient};
use eva::serve::{ServeConfig, Service, Session, SessionState, SessionStatus};

fn tenant_cfg(seed: u64, optimizer: &str, steps: u64) -> TrainConfig {
    let mut c = TrainConfig {
        name: format!("adm-{seed}"),
        dataset: "c10-small".into(),
        seed,
        arch: ModelArch::Classifier { hidden: vec![12] },
        // Enough epochs that max_steps is always the binding budget.
        epochs: 10_000,
        batch_size: 32,
        base_lr: 0.05,
        max_steps: Some(steps),
        ..TrainConfig::default()
    };
    c.optim.algorithm = optimizer.into();
    c
}

/// Step a session to completion alone, no scheduler involved — the
/// uninterrupted ground truth every restore must reproduce bit-for-bit.
fn solo_digest(cfg: &TrainConfig) -> u64 {
    let mut s = Session::new(0, "solo", 1, cfg).unwrap();
    while !s.is_done() {
        assert!(s.run_quantum(16) > 0);
    }
    s.digest()
}

fn temp_dir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("eva-serve-admission-{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    d.to_string_lossy().into_owned()
}

fn serve_cfg(dir: &str) -> ServeConfig {
    ServeConfig {
        checkpoint_dir: dir.to_string(),
        checkpoint_on_shutdown: false,
        quantum_steps: 2,
        ..ServeConfig::default()
    }
}

fn wait_for(deadline_s: u64, what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = std::time::Instant::now() + Duration::from_secs(deadline_s);
    while !cond() {
        assert!(std::time::Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn ckpt_count(dir: &str) -> usize {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .filter(|e| {
                    e.path().file_name().and_then(|f| f.to_str()).is_some_and(|f| {
                        f.ends_with(".ckpt")
                    })
                })
                .count()
        })
        .unwrap_or(0)
}

#[test]
fn over_cap_submits_queue_and_promote_fifo_within_priority() {
    let dir = temp_dir("queue");
    let svc = Service::start(ServeConfig { max_sessions: 1, ..serve_cfg(&dir) });
    let mut client = LocalClient::new(&svc);
    // One long-running session pins the only slot.
    let blocker = svc.submit(&tenant_cfg(1, "eva", 1_000_000), "blk", 1).unwrap();
    wait_for(120, "blocker to start", || svc.status(blocker).unwrap().step > 0);
    // Over-cap submits queue — the protocol reports the position.
    let (a, a_pos) = client.submit_as(&tenant_cfg(2, "eva", 6), "a", 1, None).unwrap();
    let (b, b_pos) = client.submit_as(&tenant_cfg(3, "eva", 6), "b", 1, None).unwrap();
    let (c, c_pos) = client.submit_as(&tenant_cfg(4, "eva", 6), "c", 5, None).unwrap();
    assert_eq!(a_pos, 1, "first waiter");
    assert_eq!(b_pos, 2, "FIFO among equal priorities");
    assert_eq!(c_pos, 1, "higher priority jumps the queue");
    for (id, pos) in [(a, 2), (b, 3), (c, 1)] {
        let st = svc.status(id).unwrap();
        assert_eq!(st.status, SessionStatus::Queued, "session {id} must be parked");
        assert_eq!(st.queue_position, pos, "session {id}");
        assert_eq!(st.step, 0, "waiting sessions must not be stepped");
    }
    // Free the slot: promotion order must be c (priority), then a,
    // then b (submission order). With one slot, "x started ⇒ everyone
    // ahead of x is done" holds at every sample, whatever the poll
    // rate.
    svc.cancel(blocker).unwrap();
    let started = |st: &SessionState| {
        st.step > 0 || matches!(st.status, SessionStatus::Running | SessionStatus::Done)
    };
    wait_for(300, "all queued sessions to finish", || {
        // Read in reverse promotion order so each implication's
        // premise is sampled before its conclusion.
        let sb = svc.status(b).unwrap();
        let sa = svc.status(a).unwrap();
        let sc = svc.status(c).unwrap();
        if started(&sb) {
            assert_eq!(sa.status, SessionStatus::Done, "b ran before a finished");
        }
        if started(&sa) {
            assert_eq!(sc.status, SessionStatus::Done, "a ran before higher-priority c");
        }
        [&sa, &sb, &sc].iter().all(|st| st.status == SessionStatus::Done)
    });
    for id in [a, b, c] {
        let st = svc.status(id).unwrap();
        assert_eq!(st.step, 6);
        assert_eq!(st.queue_position, 0);
    }
    let stats = svc.stats();
    assert!(stats.promotions >= 3, "three waiters were promoted, saw {}", stats.promotions);
    assert_eq!(stats.queue_depth, 0);
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn per_tenant_quota_holds_over_the_protocol() {
    let dir = temp_dir("quota");
    let svc = Service::start(ServeConfig {
        max_sessions: 1,
        max_sessions_per_tenant: 2,
        ..serve_cfg(&dir)
    });
    let mut client = LocalClient::new(&svc);
    // Tenant from the name prefix: both live (one running, one
    // queued) count against acme's quota.
    let (j1, _) = client.submit_as(&tenant_cfg(10, "eva", 1_000_000), "acme/j1", 1, None).unwrap();
    client.submit_as(&tenant_cfg(11, "eva", 1_000_000), "acme/j2", 1, None).unwrap();
    let err = client
        .submit_as(&tenant_cfg(12, "eva", 4), "acme/j3", 1, None)
        .unwrap_err();
    assert!(err.contains("quota"), "{err}");
    // An explicit tenant field beats the name prefix.
    let err = client
        .submit_as(&tenant_cfg(13, "eva", 4), "innocuous-name", 1, Some("acme"))
        .unwrap_err();
    assert!(err.contains("acme"), "{err}");
    // Other tenants are unaffected.
    client.submit_as(&tenant_cfg(14, "eva", 1_000_000), "beta/j1", 1, None).unwrap();
    // Freeing one of acme's live sessions frees its quota.
    svc.cancel(j1).unwrap();
    client.submit_as(&tenant_cfg(15, "eva", 1_000_000), "acme/j4", 1, None).unwrap();
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn auto_checkpoint_survives_a_hard_kill_and_resumes_bit_identical() {
    let cfg = tenant_cfg(55, "eva", 24);
    let solo = solo_digest(&cfg);
    let dir = temp_dir("auto");
    // Periodic snapshots only — shutdown writes nothing, like a
    // process killed without warning (everything after the last
    // auto-checkpoint is lost).
    let svc = Service::start(ServeConfig {
        checkpoint_every_steps: 4,
        ..serve_cfg(&dir)
    });
    svc.submit(&cfg, "auto/ck", 3).unwrap();
    wait_for(300, "a periodic checkpoint to land", || ckpt_count(&dir) > 0);
    // "Kill" the process: stop without any graceful snapshot — only
    // what the periodic checkpointer already wrote survives.
    svc.shutdown();
    // Restart and re-admit the newest snapshot of the lineage.
    let svc2 = Service::start(serve_cfg(&dir));
    let ids = svc2.resume_from_dir(&dir).unwrap();
    assert_eq!(ids.len(), 1, "one lineage, one resumed session");
    let st = svc2.status(ids[0]).unwrap();
    assert_eq!(st.name, "auto/ck", "name survives the restart");
    assert_eq!(st.priority, 3, "priority survives the restart");
    assert_eq!(st.tenant, "auto", "tenant survives the restart");
    assert!(st.step >= 4, "resumed from a snapshot at least one interval in");
    wait_for(300, "resumed session to finish", || {
        svc2.status(ids[0]).unwrap().status == SessionStatus::Done
    });
    assert_eq!(
        svc2.model_digest(ids[0]).unwrap(),
        solo,
        "kill + resume diverged from the uninterrupted run"
    );
    svc2.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shutdown_checkpoint_makes_restart_transparent_even_for_waiting_sessions() {
    // a pins the only slot and can never finish; b therefore stays in
    // the admission queue at step 0 — the restart must recover both:
    // a mid-run, b still waiting.
    let cfg_a = tenant_cfg(101, "eva", 1_000_000);
    let cfg_b = tenant_cfg(202, "eva-s", 20);
    let solo_b = solo_digest(&cfg_b);
    let dir = temp_dir("shutdown");
    let svc = Service::start(ServeConfig {
        max_sessions: 1,
        checkpoint_on_shutdown: true,
        ..serve_cfg(&dir)
    });
    let a = svc.submit(&cfg_a, "alpha/a", 2).unwrap();
    let b = svc.submit(&cfg_b, "beta/b", 1).unwrap();
    // c is cancelled pre-shutdown: its *terminal* status must survive
    // the restart too (tombstone), not resurrect and train.
    let c = svc.submit(&tenant_cfg(303, "sgd", 8), "gamma/c", 1).unwrap();
    svc.cancel(c).unwrap();
    wait_for(300, "a to make progress", || svc.status(a).unwrap().step >= 4);
    let st_b = svc.status(b).unwrap();
    assert_eq!(st_b.step, 0, "b must still be waiting");
    assert_eq!(st_b.queue_position, 1);
    svc.shutdown(); // graceful: snapshots live sessions + tombstones
    assert!(ckpt_count(&dir) >= 3, "two live snapshots + one tombstone");
    let svc2 = Service::start(ServeConfig { max_sessions: 2, ..serve_cfg(&dir) });
    let ids = svc2.resume_from_dir(&dir).unwrap();
    assert_eq!(ids.len(), 3);
    let mut found = (false, false, false);
    for &id in &ids {
        let st = svc2.status(id).unwrap();
        match st.name.as_str() {
            "alpha/a" => {
                assert!(st.step >= 4, "a resumed mid-run");
                assert_eq!(st.priority, 2, "priority survives the restart");
                assert_eq!(st.tenant, "alpha");
                svc2.cancel(id).unwrap(); // never finishes; identity checked
                found.0 = true;
            }
            "beta/b" => {
                wait_for(600, "resumed b to finish", || {
                    svc2.status(id).unwrap().status == SessionStatus::Done
                });
                let st = svc2.status(id).unwrap();
                assert_eq!(st.step, 20);
                assert_eq!(
                    svc2.model_digest(id).unwrap(),
                    solo_b,
                    "waiting session b diverged across the restart"
                );
                found.1 = true;
            }
            "gamma/c" => {
                assert_eq!(
                    st.status,
                    SessionStatus::Cancelled,
                    "terminal status must survive the restart"
                );
                found.2 = true;
            }
            other => panic!("unexpected resumed session name '{other}'"),
        }
    }
    assert_eq!(found, (true, true, true), "all three lineages resumed");
    // Fresh ids never collide with ids embedded in resumed lineage
    // stems: a new same-named submit must not mint stem "alpha_a-1"
    // again and start overwriting the resumed lineage's files.
    let fresh = svc2.submit(&tenant_cfg(404, "sgd", 4), "alpha/a", 1).unwrap();
    assert!(fresh > 3, "fresh id {fresh} must exceed every id embedded in a resumed stem");
    svc2.cancel(fresh).unwrap();
    svc2.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_checkpoints_never_shadow_a_good_snapshot() {
    let cfg = tenant_cfg(7, "eva", 12);
    let solo = solo_digest(&cfg);
    let dir = temp_dir("torn");
    std::fs::create_dir_all(&dir).unwrap();
    // A genuine snapshot at step 5 via the atomic writer.
    let mut s = Session::new(9, "torn", 2, &cfg).unwrap();
    s.set_status(SessionStatus::Running);
    assert_eq!(s.run_quantum(5), 5);
    let good_path = format!("{dir}/torn-9-step5.ckpt");
    s.checkpoint().unwrap().save(&good_path).unwrap();
    let good_bytes = std::fs::read(&good_path).unwrap();
    // Torn debris a crash could leave: an interrupted atomic write
    // (`*.tmp`, ignored by suffix) and a truncated file that somehow
    // landed at a canonical name with a *newer* step (corrupt, so the
    // resume scan must fall back to the older good snapshot).
    std::fs::write(format!("{dir}/torn-9-step9.ckpt.0.tmp"), &good_bytes[..64]).unwrap();
    std::fs::write(format!("{dir}/torn-9-step8.ckpt"), &good_bytes[..good_bytes.len() / 2])
        .unwrap();
    // Boot with `resume_dir` in the config: Service::start itself
    // must perform the resume (the CLI flag is just sugar over this).
    let svc = Service::start(ServeConfig { resume_dir: Some(dir.clone()), ..serve_cfg(&dir) });
    let ids: Vec<u64> = svc.stats().sessions.iter().map(|s| s.id).collect();
    assert_eq!(ids.len(), 1, "one lineage resumes despite the debris");
    // The scheduler starts stepping immediately, so only a lower
    // bound is stable here; the digest below is the real witness that
    // the resume came from the good step-5 bytes (the torn step-8
    // file cannot even be parsed).
    assert!(svc.status(ids[0]).unwrap().step >= 5);
    wait_for(300, "resumed session to finish", || {
        svc.status(ids[0]).unwrap().status == SessionStatus::Done
    });
    assert_eq!(svc.model_digest(ids[0]).unwrap(), solo, "torn-file fallback diverged");
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn terminal_sessions_beyond_retain_cap_report_evicted() {
    let dir = temp_dir("evict");
    let svc = Service::start(ServeConfig {
        max_sessions: 4,
        retain_terminal: 1,
        ..serve_cfg(&dir)
    });
    let a = svc.submit(&tenant_cfg(31, "sgd", 4), "e1", 1).unwrap();
    wait_for(120, "e1 to finish or be evicted", || match svc.status(a) {
        Ok(st) => st.status == SessionStatus::Done,
        Err(_) => true,
    });
    let b = svc.submit(&tenant_cfg(32, "sgd", 4), "e2", 1).unwrap();
    wait_for(120, "e2 to finish or be evicted", || match svc.status(b) {
        Ok(st) => st.status == SessionStatus::Done,
        Err(_) => true,
    });
    // With two terminal sessions and a cap of one, the scheduler must
    // evict the oldest; its id then reports a distinct error.
    wait_for(120, "e1 to be evicted", || svc.status(a).is_err());
    let err = svc.status(a).unwrap_err();
    assert!(err.contains("evicted"), "want a distinct eviction error, got: {err}");
    // Unknown ids still get the plain not-found error.
    let err = svc.status(99_999).unwrap_err();
    assert!(err.contains("no session"), "{err}");
    assert!(svc.stats().evicted >= 1);
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
