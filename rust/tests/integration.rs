//! Integration tests across layers: PJRT runtime ↔ native numerics,
//! full training loops through the public API, CLI surface.
//!
//! PJRT-dependent tests require `make artifacts`; they are skipped
//! (with a notice) when the artifact directory is missing so `cargo
//! test` stays green on a fresh checkout.

use eva::config::{Engine, LrSchedule, ModelArch, OptimConfig, TrainConfig};
use eva::optim::HyperParams;
use eva::runtime::Runtime;
use eva::train::Trainer;

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_present() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn manifest_covers_all_expected_artifacts() {
    require_artifacts!();
    let rt = Runtime::open_default().unwrap();
    for model in ["quickstart", "ae-small", "e2e"] {
        for graph in ["eva_step", "sgd_step", "fwdbwd_kv", "predict"] {
            assert!(
                rt.manifest().artifacts.contains_key(&format!("{model}.{graph}")),
                "{model}.{graph} missing"
            );
        }
    }
    for probe in ["kernel.eva_precond", "kernel.eva_f_precond", "kernel.eva_s_precond"] {
        assert!(rt.manifest().artifacts.contains_key(probe), "{probe} missing");
    }
}

#[test]
fn pallas_kernel_probes_match_native() {
    require_artifacts!();
    let mut rt = Runtime::open_default().unwrap();
    eva::exp::validate::kernel_probes(&mut rt).unwrap();
}

#[test]
fn pjrt_fwdbwd_matches_native_model() {
    require_artifacts!();
    let mut rt = Runtime::open_default().unwrap();
    eva::exp::validate::fwdbwd_cross_check(&mut rt).unwrap();
}

#[test]
fn fused_eva_step_reduces_loss() {
    require_artifacts!();
    let mut rt = Runtime::open_default().unwrap();
    eva::exp::validate::fused_step_trains(&mut rt).unwrap();
}

#[test]
fn pjrt_trainer_end_to_end() {
    require_artifacts!();
    let cfg = TrainConfig {
        name: "it-pjrt".into(),
        dataset: "c10-small".into(),
        seed: 5,
        arch: ModelArch::Classifier { hidden: vec![128, 64] }, // unused by pjrt
        optim: OptimConfig { algorithm: "eva".into(), hp: HyperParams::default() },
        engine: Engine::Pjrt { model: "quickstart".into() },
        epochs: 2,
        batch_size: 64,
        base_lr: 0.05,
        lr_schedule: LrSchedule::Cosine,
        warmup_steps: 0,
        max_steps: Some(50),
        eval_every: 1,
        backend: None,
        worker_threads: None,
        simd: None,
        telemetry: None,
    };
    let mut t = Trainer::from_config(&cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.best_val_acc > 0.3, "pjrt eva acc {}", r.best_val_acc);
    assert!(r.final_loss.is_finite());
}

#[test]
fn native_and_pjrt_agree_on_learnability() {
    require_artifacts!();
    // Same task, same optimizer family: both engines must clear the
    // same quality bar (they share dataset + loss semantics).
    let mk = |engine: Engine| TrainConfig {
        name: "it-agree".into(),
        dataset: "c10-small".into(),
        seed: 9,
        arch: ModelArch::Classifier { hidden: vec![128, 64] },
        optim: OptimConfig { algorithm: "eva".into(), hp: HyperParams::default() },
        engine,
        epochs: 2,
        batch_size: 64,
        base_lr: 0.05,
        lr_schedule: LrSchedule::Cosine,
        warmup_steps: 0,
        max_steps: Some(60),
        eval_every: 1,
        backend: None,
        worker_threads: None,
        simd: None,
        telemetry: None,
    };
    let mut native = Trainer::from_config(&mk(Engine::Native)).unwrap();
    let rn = native.run().unwrap();
    let mut pjrt =
        Trainer::from_config(&mk(Engine::Pjrt { model: "quickstart".into() })).unwrap();
    let rp = pjrt.run().unwrap();
    assert!(rn.best_val_acc > 0.4, "native {}", rn.best_val_acc);
    assert!(rp.best_val_acc > 0.4, "pjrt {}", rp.best_val_acc);
}

#[test]
fn config_file_roundtrip_drives_training() {
    let dir = std::env::temp_dir().join("eva-it-config");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.json");
    std::fs::write(
        &path,
        r#"{"name": "from-file", "dataset": "c10-small", "optimizer": "eva-f",
            "hidden": [32], "epochs": 1, "base_lr": 0.05, "max_steps": 12}"#,
    )
    .unwrap();
    let cfg = TrainConfig::from_file(path.to_str().unwrap()).unwrap();
    let mut t = Trainer::from_config(&cfg).unwrap();
    let r = t.run().unwrap();
    assert_eq!(r.steps, 12);
    assert_eq!(r.optimizer, "eva-f");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn experiment_registry_lists_every_paper_item() {
    for id in ["table1", "table4", "table5", "table8", "fig4", "fig7", "table10"] {
        assert!(eva::exp::ALL.contains(&id), "{id} not registered");
    }
}
