//! Backend parity: `Threaded` must reproduce `Sequential` numerics on
//! every routed hot path.
//!
//! The backend contract is stronger than a tolerance — kernels keep
//! per-element arithmetic order backend-invariant and reductions use a
//! size-derived chunk grid, so results are bit-identical. These tests
//! assert the satellite requirement (≤ 1e-6) on top of exercising the
//! parallel code paths with sizes above the dispatch thresholds.

use std::sync::Mutex;

use eva::backend::{self, Backend, BackendChoice, Sequential, Threaded};
use eva::linalg;
use eva::nn::LayerStats;
use eva::optim::{Eva, HyperParams, Kfac, Optimizer, StepCtx};
use eva::tensor::{self, Tensor};
use eva::testing::Gen;

/// Tests that swap the process-global backend serialize here so their
/// install/restore windows don't interleave. (Numerics are
/// backend-invariant, so this is hygiene, not correctness.)
static GLOBAL_BACKEND: Mutex<()> = Mutex::new(());

const TOL: f32 = 1e-6;

fn threaded() -> Threaded {
    Threaded::new(4)
}

// ---------------------------------------------------------------------------
// Kernel parity (explicit backend handles; no global state touched)
// ---------------------------------------------------------------------------

#[test]
fn matmul_variants_parity() {
    let mut g = Gen::new(101);
    let thr = threaded();
    // Odd sizes above the parallel threshold (≥ 2^18 MACs) so row
    // partitioning actually engages, plus a small below-threshold case.
    for &(m, k, n) in &[(130usize, 70usize, 90usize), (9, 11, 7)] {
        let a = g.normal_tensor(m, k);
        let b = g.normal_tensor(k, n);
        let seq = tensor::matmul_with(&Sequential, &a, &b);
        let par = tensor::matmul_with(&thr, &a, &b);
        assert!(seq.max_abs_diff(&par) <= TOL, "matmul {m}x{k}x{n}");

        let at = g.normal_tensor(k, m); // (k, m) for Aᵀ·B
        let seq = tensor::matmul_at_b_with(&Sequential, &at, &b);
        let par = tensor::matmul_at_b_with(&thr, &at, &b);
        assert!(seq.max_abs_diff(&par) <= TOL, "matmul_at_b {m}x{k}x{n}");

        let bt = g.normal_tensor(n, k); // (n, k) for A·Bᵀ
        let seq = tensor::matmul_a_bt_with(&Sequential, &a, &bt);
        let par = tensor::matmul_a_bt_with(&thr, &a, &bt);
        assert!(seq.max_abs_diff(&par) <= TOL, "matmul_a_bt {m}x{k}x{n}");
    }
}

#[test]
fn matmul_against_naive_reference_under_threads() {
    // Not just self-consistency: the threaded result is the right
    // product.
    let mut g = Gen::new(7);
    let (m, k, n) = (80usize, 65usize, 75usize);
    let a = g.normal_tensor(m, k);
    let b = g.normal_tensor(k, n);
    let par = tensor::matmul_with(&threaded(), &a, &b);
    for i in [0usize, m / 2, m - 1] {
        for j in [0usize, n / 2, n - 1] {
            let expect: f32 = (0..k).map(|kk| a.at(i, kk) * b.at(kk, j)).sum();
            assert!((par.at(i, j) - expect).abs() < 1e-3, "({i},{j})");
        }
    }
}

#[test]
fn spd_inverse_parity_and_correctness() {
    let mut g = Gen::new(33);
    let thr = threaded();
    for n in [8usize, 96] {
        // 96 crosses the column-solve dispatch gate; 8 stays inline.
        let m = g.spd_tensor(n, 0.05);
        let seq = linalg::spd_inverse_with(&Sequential, &m).unwrap();
        let par = linalg::spd_inverse_with(&thr, &m).unwrap();
        assert!(seq.max_abs_diff(&par) <= TOL, "spd_inverse n={n}");
        let prod = tensor::matmul_with(&thr, &m, &par);
        assert!(prod.max_abs_diff(&Tensor::eye(n)) < 1e-2, "M·M⁻¹ ≉ I at n={n}");
    }
}

#[test]
fn eigh_jacobi_bit_identical_across_backends() {
    // n = 96 is above the Jacobi dispatch gate, so the threaded runs
    // really fan the round-robin phases out; the two-phase schedule
    // fixes per-element arithmetic, so results are *bit*-equal.
    let mut g = Gen::new(71);
    let m = g.spd_tensor(96, 0.05);
    let (l_seq, v_seq) = linalg::eigh_jacobi_with(&Sequential, &m, 20);
    for lanes in [2usize, 4, 7] {
        let thr = Threaded::new(lanes);
        let (l_par, v_par) = linalg::eigh_jacobi_with(&thr, &m, 20);
        assert_eq!(l_seq, l_par, "eigenvalues diverge at threads:{lanes}");
        assert_eq!(v_seq, v_par, "eigenvectors diverge at threads:{lanes}");
    }
    // And the decomposition is correct: M V ≈ V diag(λ).
    for j in [0usize, 47, 95] {
        let col: Vec<f32> = (0..96).map(|i| v_seq.at(i, j)).collect();
        let mv = m.matvec(&col);
        for i in 0..96 {
            assert!((mv[i] - l_seq[j] * col[i]).abs() < 5e-2, "({i},{j})");
        }
    }
}

#[test]
fn tmatvec_and_mean_rows_bit_identical_across_backends() {
    // 300×300 = 90k elements — above the reduction gate, so the
    // fixed row-chunk grid engages under every backend.
    let mut g = Gen::new(72);
    let t = g.normal_tensor(300, 300);
    let x = g.normal_vec(300);
    let y_seq = t.tmatvec_with(&Sequential, &x);
    let m_seq = t.mean_rows_with(&Sequential);
    for lanes in [2usize, 4] {
        let thr = Threaded::new(lanes);
        assert_eq!(y_seq, t.tmatvec_with(&thr, &x), "tmatvec threads:{lanes}");
        assert_eq!(m_seq, t.mean_rows_with(&thr), "mean_rows threads:{lanes}");
    }
    // Against the naive reference — not just self-consistency.
    for j in [0usize, 150, 299] {
        let expect: f32 = (0..300).map(|i| x[i] * t.at(i, j)).sum();
        assert!((y_seq[j] - expect).abs() < 1e-2, "tmatvec[{j}]");
        let expect: f32 = (0..300).map(|i| t.at(i, j)).sum::<f32>() / 300.0;
        assert!((m_seq[j] - expect).abs() < 1e-3, "mean_rows[{j}]");
    }
}

// ---------------------------------------------------------------------------
// Linalg edge cases
// ---------------------------------------------------------------------------

#[test]
fn one_by_one_edge_cases() {
    let thr = threaded();
    let a = Tensor::from_rows(&[&[3.0]]);
    let b = Tensor::from_rows(&[&[4.0]]);
    assert_eq!(tensor::matmul_with(&thr, &a, &b).at(0, 0), 12.0);
    let inv = linalg::spd_inverse_with(&thr, &b).unwrap();
    assert!((inv.at(0, 0) - 0.25).abs() < 1e-6);
    let l = linalg::cholesky(&b).unwrap();
    let x = linalg::cholesky_solve(&l, &[8.0]);
    assert!((x[0] - 2.0).abs() < 1e-6);
}

#[test]
fn empty_product_does_not_panic() {
    let a = Tensor::zeros(0, 0);
    let c = tensor::matmul_with(&threaded(), &a, &a);
    assert_eq!(c.shape(), (0, 0));
}

#[test]
fn non_pd_error_path_is_backend_invariant() {
    // eig(−1, 3): not positive definite.
    let m = Tensor::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
    let seq = linalg::spd_inverse_with(&Sequential, &m);
    let par = linalg::spd_inverse_with(&threaded(), &m);
    assert!(seq.is_err() && par.is_err());
    assert_eq!(seq.unwrap_err(), par.unwrap_err());
    assert!(linalg::cholesky(&m).is_err());
}

// ---------------------------------------------------------------------------
// Full optimizer steps through the global dispatcher
// ---------------------------------------------------------------------------

fn with_global<T>(choice: BackendChoice, f: impl FnOnce() -> T) -> T {
    let prev = backend::global();
    backend::install(&choice);
    let out = f();
    backend::set_global(prev);
    out
}

/// One Eva step on a layer big enough (256×512) to cross the
/// elementwise/reduction dispatch thresholds.
fn eva_step_deltas() -> (Tensor, Vec<f32>) {
    let mut g = Gen::new(1234);
    let (d_out, d_in) = (256usize, 512usize);
    let params = vec![Tensor::zeros(d_out, d_in)];
    let grads = vec![g.normal_tensor(d_out, d_in)];
    let bias = vec![vec![0.01; d_out]];
    let stats = vec![LayerStats {
        a_mean: g.normal_vec(d_in),
        b_mean: g.normal_vec(d_out),
        aat: None,
        bbt: None,
    }];
    let ctx = StepCtx {
        params: &params,
        grads: &grads,
        bias_grads: &bias,
        stats: &stats,
        lr: 0.1,
        step: 0,
    };
    let mut opt = Eva::new(HyperParams::default());
    let u = opt.step(&ctx);
    (u.deltas[0].clone(), u.bias_deltas[0].clone())
}

#[test]
fn full_eva_step_parity() {
    let _serial = GLOBAL_BACKEND.lock().unwrap_or_else(|e| e.into_inner());
    let (dw_seq, db_seq) = with_global(BackendChoice::Sequential, eva_step_deltas);
    let (dw_par, db_par) = with_global(BackendChoice::Threaded(4), eva_step_deltas);
    assert!(dw_seq.max_abs_diff(&dw_par) <= TOL, "eva weight deltas diverge");
    for (a, b) in db_seq.iter().zip(&db_par) {
        assert!((a - b).abs() <= TOL, "eva bias deltas diverge");
    }
    assert!(dw_seq.all_finite());
}

/// One K-FAC step with full Kronecker factors (two layers so the
/// per-layer par_map fan-out has more than one unit of work).
fn kfac_step_deltas() -> Vec<Tensor> {
    let mut g = Gen::new(987);
    let dims = [(96usize, 160usize), (48, 96)];
    let params: Vec<Tensor> = dims.iter().map(|&(o, i)| Tensor::zeros(o, i)).collect();
    let grads: Vec<Tensor> = dims.iter().map(|&(o, i)| g.normal_tensor(o, i)).collect();
    let bias: Vec<Vec<f32>> = dims.iter().map(|&(o, _)| vec![0.0; o]).collect();
    let stats: Vec<LayerStats> = dims
        .iter()
        .map(|&(o, i)| LayerStats {
            a_mean: g.normal_vec(i),
            b_mean: g.normal_vec(o),
            aat: Some(g.spd_tensor(i, 0.01)),
            bbt: Some(g.spd_tensor(o, 0.01)),
        })
        .collect();
    let ctx = StepCtx {
        params: &params,
        grads: &grads,
        bias_grads: &bias,
        stats: &stats,
        lr: 0.05,
        step: 0,
    };
    let mut opt = Kfac::new(HyperParams::default());
    opt.step(&ctx).deltas
}

#[test]
fn full_kfac_step_parity() {
    let _serial = GLOBAL_BACKEND.lock().unwrap_or_else(|e| e.into_inner());
    let seq = with_global(BackendChoice::Sequential, kfac_step_deltas);
    let par = with_global(BackendChoice::Threaded(4), kfac_step_deltas);
    assert_eq!(seq.len(), par.len());
    for (l, (a, b)) in seq.iter().zip(&par).enumerate() {
        assert!(a.max_abs_diff(b) <= TOL, "kfac layer {l} deltas diverge");
        assert!(a.all_finite(), "kfac layer {l} non-finite");
    }
}

/// A short native training run under the installed global backend;
/// returns the FNV digest of the exact final weight/bias bits. Same
/// recipe as `tests/simd_parity.rs` so the two parity suites pin the
/// identical trajectory from both axes of the determinism contract.
fn train_digest(optimizer: &str) -> u64 {
    use eva::config::{ModelArch, OptimConfig, TrainConfig};
    use eva::train::Trainer;
    let mut hp = HyperParams::default();
    hp.update_interval = 2;
    hp.shampoo_block = 32;
    let cfg = TrainConfig {
        name: format!("backend-parity-{optimizer}"),
        dataset: "c10-small".into(),
        seed: 7,
        arch: ModelArch::Classifier { hidden: vec![16] },
        optim: OptimConfig { algorithm: optimizer.into(), hp },
        engine: eva::config::Engine::Native,
        epochs: 1,
        batch_size: 32,
        base_lr: 0.05,
        lr_schedule: eva::config::LrSchedule::Cosine,
        warmup_steps: 0,
        max_steps: Some(4),
        eval_every: 1,
        backend: None,
        worker_threads: None,
        simd: None,
        telemetry: None,
    };
    let mut t = Trainer::from_config(&cfg).unwrap();
    t.run().unwrap();
    eva::serve::model_digest(t.model().expect("native engine"))
}

/// A full train run per optimizer family — including the
/// vectorized-approximation cousins mkor and kradagrad — produces
/// bit-identical weights under seq, threads:2 and threads:6.
#[test]
fn full_train_digests_bit_identical_across_backends() {
    let _serial = GLOBAL_BACKEND.lock().unwrap_or_else(|e| e.into_inner());
    for optimizer in ["eva", "kfac", "shampoo", "mkor", "kradagrad"] {
        let seq = with_global(BackendChoice::Sequential, || train_digest(optimizer));
        for lanes in [2usize, 6] {
            let par = with_global(BackendChoice::Threaded(lanes), || train_digest(optimizer));
            assert_eq!(
                seq, par,
                "{optimizer}: weights diverge between seq and threads:{lanes}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Elementwise / reduction parity through the global dispatcher
// ---------------------------------------------------------------------------

#[test]
fn elementwise_and_reduction_parity() {
    let _serial = GLOBAL_BACKEND.lock().unwrap_or_else(|e| e.into_inner());
    let run = || {
        let mut g = Gen::new(555);
        // 300×300 = 90k elements: above the elementwise + reduction gates.
        let mut x = g.normal_tensor(300, 300);
        let y = g.normal_tensor(300, 300);
        x.axpy(0.5, &y);
        x.blend(0.9, 0.1, &y);
        x.scale(1.25);
        x.map_inplace(|v| v.tanh());
        let d = x.dot(&y);
        let n = x.norm();
        let mv = x.matvec(&vec![0.5f32; 300]);
        (x, d, n, mv)
    };
    let (xs, ds, ns, mvs) = with_global(BackendChoice::Sequential, run);
    let (xp, dp, np, mvp) = with_global(BackendChoice::Threaded(4), run);
    assert!(xs.max_abs_diff(&xp) <= TOL);
    assert!((ds - dp).abs() <= TOL * ds.abs().max(1.0));
    assert!((ns - np).abs() <= TOL * ns.abs().max(1.0));
    for (a, b) in mvs.iter().zip(&mvp) {
        assert!((a - b).abs() <= TOL * a.abs().max(1.0));
    }
}

// ---------------------------------------------------------------------------
// Data-parallel coordinator through per-worker backend handles
// ---------------------------------------------------------------------------

/// A short data-parallel run; returns the per-layer weight bits of the
/// canonical replica plus the final loss bits.
fn dp_run_digest(workers: usize, steps: u64) -> (Vec<Vec<u32>>, u32) {
    use eva::config::ModelArch;
    use eva::coordinator::{DataParallelCfg, DataParallelTrainer};
    let mut cfg = DataParallelCfg::new(workers, "eva");
    cfg.steps = steps;
    cfg.arch = ModelArch::Classifier { hidden: vec![48] };
    cfg.hp.weight_decay = 0.0;
    cfg.worker_threads = None; // carve from the installed global backend
    let mut t = DataParallelTrainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    let weights = t
        .model()
        .weights
        .iter()
        .map(|w| w.data().iter().map(|v| v.to_bits()).collect())
        .collect();
    (weights, r.final_loss.to_bits())
}

#[test]
fn full_data_parallel_step_parity() {
    // The whole §3.3 path — sharded batches, per-worker handle compute,
    // fused ring all-reduce, leader precondition — must be
    // bit-identical whether the dispatch layer is sequential or a
    // threaded pool carved into per-worker sub-pools. 8 lanes over 4
    // workers carve to threads:2 handles, so the nested sub-pool
    // kernel path really runs threaded (4 lanes would degrade every
    // handle to seq and only test the fan-out).
    let _serial = GLOBAL_BACKEND.lock().unwrap_or_else(|e| e.into_inner());
    let (w_seq, loss_seq) = with_global(BackendChoice::Sequential, || dp_run_digest(4, 3));
    let (w_par, loss_par) = with_global(BackendChoice::Threaded(8), || dp_run_digest(4, 3));
    assert_eq!(loss_seq, loss_par, "dp final loss diverges across backends");
    assert_eq!(w_seq, w_par, "dp replica weights diverge across backends");
}

#[test]
fn dp_worker_handles_are_carved_from_the_dispatch_backend() {
    use eva::coordinator::{DataParallelCfg, DataParallelTrainer};
    let _serial = GLOBAL_BACKEND.lock().unwrap_or_else(|e| e.into_inner());
    let labels = with_global(BackendChoice::Threaded(8), || {
        let mut cfg = DataParallelCfg::new(4, "sgd");
        cfg.worker_threads = None;
        DataParallelTrainer::new(cfg).unwrap().worker_handle_labels()
    });
    assert_eq!(labels, vec!["threads:2"; 4]);
}

#[test]
fn backend_labels_and_threads() {
    assert_eq!(Sequential.label(), "seq");
    assert_eq!(Sequential.threads(), 1);
    let t = Threaded::new(3);
    assert_eq!(t.label(), "threads:3");
    assert_eq!(t.threads(), 3);
}
