//! Cluster fault-injection suite (ISSUE 7 acceptance).
//!
//! An in-process cluster — real `Service` instances behind real TCP
//! servers, a real router in front — driven through real failures:
//!
//! * Kill one host mid-training: the router's probes detect it, the
//!   session is rescued from its newest auto-checkpoint onto the
//!   surviving host, and its final weights digest is **bit-identical**
//!   to an uninterrupted single-host run.
//! * Protocol adversarial cases at the router boundary: malformed
//!   ndjson, unknown commands, a `watch` that spans a live migration
//!   (must end with a clean redirect line, never hang), and a host
//!   that accepts TCP but never replies (probe-timeout path).
//! * Rendezvous placement properties over a few hundred synthetic
//!   stems: deterministic, and removing one host remaps only the
//!   sessions that lived there.

use std::io::{BufRead, BufReader, Write};
use std::time::{Duration, Instant};

use eva::cluster::{rendezvous, ClusterConfig, HostHealth, HostSpec, Router, RouterServer};
use eva::config::{ModelArch, TrainConfig};
use eva::jsonx::Json;
use eva::serve::client::{ServeClient, TcpClient};
use eva::serve::{ServeConfig, Server, Service, Session};

fn train_cfg(seed: u64, steps: u64) -> TrainConfig {
    let mut c = TrainConfig {
        name: format!("clu-{seed}"),
        dataset: "c10-small".into(),
        seed,
        arch: ModelArch::Classifier { hidden: vec![12] },
        // Enough epochs that max_steps is always the binding budget.
        epochs: 10_000,
        batch_size: 32,
        base_lr: 0.05,
        max_steps: Some(steps),
        ..TrainConfig::default()
    };
    c.optim.algorithm = "eva".into();
    c
}

/// Step the config to completion alone — the uninterrupted ground
/// truth a migrated session must reproduce bit-for-bit.
fn solo_digest(cfg: &TrainConfig) -> u64 {
    let mut s = Session::new(0, "solo", 1, cfg).unwrap();
    while !s.is_done() {
        assert!(s.run_quantum(16) > 0);
    }
    s.digest()
}

fn temp_dir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("eva-cluster-{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    d.to_string_lossy().into_owned()
}

fn wait_for(deadline_s: u64, what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(deadline_s);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// One backend host: a service with fast auto-checkpoints behind a
/// real TCP server on an ephemeral port.
fn start_host(dir: &str) -> (Service, Server) {
    let svc = Service::start(ServeConfig {
        checkpoint_dir: dir.to_string(),
        // A "kill" must lose the un-snapshotted tail, like a real
        // crash — rescue has to come from the periodic checkpoints.
        checkpoint_on_shutdown: false,
        checkpoint_every_steps: 4,
        quantum_steps: 2,
        ..ServeConfig::default()
    });
    let server = Server::start(svc.clone(), "127.0.0.1:0").unwrap();
    (svc, server)
}

/// A router (manual probing — tests drive `probe_once` so failure
/// detection is deterministic) over the given hosts, plus its TCP
/// front door.
fn start_router(hosts: Vec<(&str, String)>) -> (Router, RouterServer) {
    let cfg = ClusterConfig {
        router_addr: "127.0.0.1:0".into(),
        hosts: hosts
            .into_iter()
            .map(|(addr, dir)| HostSpec { addr: addr.into(), checkpoint_dir: dir })
            .collect(),
        probe_interval_ms: 0,
        probe_timeout_ms: 250,
        probe_fails_down: 2,
        request_timeout_ms: 5000,
        auto_migrate: true,
    };
    let router = Router::start(cfg);
    let server = RouterServer::start(router.clone(), "127.0.0.1:0").unwrap();
    (router, server)
}

#[test]
fn kill_one_host_migrates_from_newest_checkpoint_bit_identical() {
    const TARGET: u64 = 40;
    let (dir_a, dir_b) = (temp_dir("kill-a"), temp_dir("kill-b"));
    let (svc_a, srv_a) = start_host(&dir_a);
    let (svc_b, srv_b) = start_host(&dir_b);
    let (addr_a, addr_b) = (srv_a.addr().to_string(), srv_b.addr().to_string());
    let (router, front) =
        start_router(vec![(addr_a.as_str(), dir_a.clone()), (addr_b.as_str(), dir_b.clone())]);
    let mut client = TcpClient::connect(front.addr()).unwrap();

    let cfg = train_cfg(11, TARGET);
    let want = solo_digest(&cfg);
    let (cid, _) = client.submit_as(&cfg, "victim", 1, None).unwrap();
    let placed = router.placement(cid).expect("routed session has a placement");
    assert!(!placed.stem.is_empty(), "router must learn the lineage stem");

    // Train past the first auto-checkpoint (every 4 steps), and make
    // sure the snapshot file itself has landed — that file is the
    // only thing the rescue can use.
    wait_for(120, "some progress", || {
        client.status(cid).unwrap().get_f64("step").unwrap_or(0.0) >= 6.0
    });
    let victim_dir = if placed.host == 0 { &dir_a } else { &dir_b };
    wait_for(120, "an auto-checkpoint on the victim host", || {
        std::fs::read_dir(victim_dir)
            .map(|rd| {
                rd.flatten().any(|e| {
                    e.file_name().to_string_lossy().ends_with(".ckpt")
                })
            })
            .unwrap_or(false)
    });

    // Kill the host the session lives on — hard stop, no shutdown
    // snapshot, listener gone.
    let survivor_idx = if placed.host == 0 {
        svc_a.shutdown();
        1
    } else {
        svc_b.shutdown();
        0
    };

    // The router notices (2 consecutive failed probes → Down) and
    // rescues the session onto the survivor.
    wait_for(60, "probes to mark the host down and rescue the session", || {
        router.probe_once();
        router.placement(cid).is_some_and(|p| p.host == survivor_idx && !p.migrating)
    });
    assert_eq!(router.hosts()[placed.host].health, HostHealth::Down);
    assert!(router.migrations() >= 1, "rescue counts as a migration");

    // The client keeps using the same cluster id, oblivious.
    let st = client.wait_done(cid, Duration::from_secs(240)).unwrap();
    assert_eq!(st.get_f64("step"), Some(TARGET as f64));
    assert_eq!(
        st.get_str("host"),
        Some(if survivor_idx == 0 { addr_a.as_str() } else { addr_b.as_str() }),
        "status reports the new home"
    );

    // Bit-identity: the migrated run's final weights equal an
    // uninterrupted run's, exactly.
    let survivor_svc = if survivor_idx == 0 { &svc_a } else { &svc_b };
    let remote = router.placement(cid).unwrap().remote_id;
    assert_eq!(
        survivor_svc.model_digest(remote).unwrap(),
        want,
        "weights after kill + rescue must be bit-identical to an uninterrupted run"
    );

    // Cluster stats still account for the session under its cluster id.
    let stats = client.stats().unwrap();
    let sessions = stats.get("sessions").and_then(|s| s.as_arr()).unwrap().clone();
    assert!(
        sessions.iter().any(|s| s.get_f64("id") == Some(cid as f64)
            && s.get_str("status") == Some("done")),
        "{stats:?}"
    );

    router.shutdown();
    front.join();
    svc_a.shutdown();
    svc_b.shutdown();
    srv_a.join();
    srv_b.join();
}

#[test]
fn drain_migrates_live_sessions_and_undrain_readmits() {
    let (dir_a, dir_b) = (temp_dir("drain-a"), temp_dir("drain-b"));
    let (svc_a, srv_a) = start_host(&dir_a);
    let (svc_b, srv_b) = start_host(&dir_b);
    let (addr_a, addr_b) = (srv_a.addr().to_string(), srv_b.addr().to_string());
    let (router, front) =
        start_router(vec![(addr_a.as_str(), dir_a.clone()), (addr_b.as_str(), dir_b.clone())]);
    let mut client = TcpClient::connect(front.addr()).unwrap();

    // A long-running session we can drain mid-flight.
    let (cid, _) = client.submit_as(&train_cfg(21, 1_000_000), "drainee", 1, None).unwrap();
    wait_for(120, "session to start", || {
        client.status(cid).unwrap().get_f64("step").unwrap_or(0.0) > 0.0
    });
    let src = router.placement(cid).unwrap().host;
    let src_addr = if src == 0 { &addr_a } else { &addr_b };
    let dst = 1 - src;

    // Rolling-restart shape: admit-stop + migrate...
    let resp = client.drain(src_addr).unwrap();
    assert_eq!(resp.get_f64("migrated"), Some(1.0), "{resp:?}");
    assert_eq!(resp.get_f64("failed"), Some(0.0), "{resp:?}");
    let p = router.placement(cid).unwrap();
    assert_eq!(p.host, dst, "session moved to the peer");
    assert!(!p.migrating);
    // ...verify it kept stepping where it left off...
    let step_after = client.status(cid).unwrap().get_f64("step").unwrap();
    wait_for(120, "migrated session to keep stepping", || {
        client.status(cid).unwrap().get_f64("step").unwrap() > step_after
    });
    // ...while the drained host takes no new work...
    let hosts = client.hosts().unwrap();
    let drained = hosts.iter().find(|h| h.get_str("addr") == Some(src_addr)).unwrap();
    assert_eq!(drained.get("draining"), Some(&Json::Bool(true)));
    let (other_cid, _) = client.submit_as(&train_cfg(22, 4), "filler", 1, None).unwrap();
    assert_eq!(router.placement(other_cid).unwrap().host, dst, "drained host gets nothing");
    // ...and re-admit.
    client.undrain(src_addr).unwrap();
    let hosts = client.hosts().unwrap();
    let readmitted = hosts.iter().find(|h| h.get_str("addr") == Some(src_addr)).unwrap();
    assert_eq!(readmitted.get("draining"), Some(&Json::Bool(false)));

    client.cancel(cid).unwrap();
    router.shutdown();
    front.join();
    svc_a.shutdown();
    svc_b.shutdown();
    srv_a.join();
    srv_b.join();
}

#[test]
fn watch_across_a_migration_ends_with_a_clean_redirect_line() {
    let (dir_a, dir_b) = (temp_dir("watch-a"), temp_dir("watch-b"));
    let (svc_a, srv_a) = start_host(&dir_a);
    let (svc_b, srv_b) = start_host(&dir_b);
    let (addr_a, addr_b) = (srv_a.addr().to_string(), srv_b.addr().to_string());
    let (router, front) =
        start_router(vec![(addr_a.as_str(), dir_a.clone()), (addr_b.as_str(), dir_b.clone())]);
    let mut client = TcpClient::connect(front.addr()).unwrap();

    let (cid, _) = client.submit_as(&train_cfg(31, 1_000_000), "watched", 1, None).unwrap();
    wait_for(120, "session to start", || {
        client.status(cid).unwrap().get_f64("step").unwrap_or(0.0) > 0.0
    });
    let src = router.placement(cid).unwrap().host;
    let src_addr = if src == 0 { addr_a.clone() } else { addr_b.clone() };

    // Watch on a second connection; the stream must terminate with a
    // redirect once the session migrates out from under it — a
    // blocking relay that never notices would hang this thread (and
    // the channel timeout below would catch it).
    let front_addr = front.addr();
    let (tx, rx) = std::sync::mpsc::channel();
    let watcher = std::thread::spawn(move || {
        let mut wc = TcpClient::connect(front_addr).unwrap();
        let mut steps = 0usize;
        let fin = wc.watch(cid, &mut |_| steps += 1);
        let _ = tx.send((steps, fin));
    });
    // Give the watcher a moment to attach, then migrate the session.
    wait_for(60, "watcher to see a step", || {
        client.status(cid).unwrap().get_f64("step").unwrap_or(0.0) > 4.0
    });
    router.migrate(cid).unwrap();
    let (_steps, fin) = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("watch stream must terminate after the migration, not hang");
    let fin = fin.expect("clean final line, not a transport error");
    assert_eq!(fin.get_str("event"), Some("end"));
    assert_eq!(
        fin.get_str("status"),
        Some("migrating"),
        "a migration-cancel must read as a redirect, not a user cancel: {fin:?}"
    );
    watcher.join().unwrap();

    // Re-issuing the watch follows the session to its new host.
    let mut wc = TcpClient::connect(front.addr()).unwrap();
    let seen = std::sync::atomic::AtomicUsize::new(0);
    let cancel_at = 3;
    let router2 = router.clone();
    let fin = wc.watch(cid, &mut |_| {
        // Cancel through the router once the new stream proves live.
        if seen.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1 == cancel_at {
            let r = router2.dispatch(&Json::obj(vec![
                ("cmd", Json::Str("cancel".into())),
                ("session", Json::Num(cid as f64)),
            ]));
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
        }
    });
    let fin = fin.unwrap();
    assert_eq!(fin.get_str("event"), Some("end"));
    assert_eq!(fin.get_str("status"), Some("cancelled"), "{fin:?}");

    router.shutdown();
    front.join();
    svc_a.shutdown();
    svc_b.shutdown();
    srv_a.join();
    srv_b.join();
}

#[test]
fn router_boundary_rejects_malformed_and_unknown_requests() {
    let dir = temp_dir("adv");
    let (svc, srv) = start_host(&dir);
    let addr = srv.addr().to_string();
    let (router, front) = start_router(vec![(addr.as_str(), dir.clone())]);

    // Raw socket: drive the framing layer directly.
    let stream = std::net::TcpStream::connect(front.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut roundtrip = |line: &str| -> Json {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    };

    // Malformed ndjson → clean per-line error, connection stays up.
    let r = roundtrip("{not json");
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert!(r.get_str("error").unwrap().contains("bad request"), "{r:?}");
    // Unknown command.
    let r = roundtrip(r#"{"cmd":"frobnicate"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert!(r.get_str("error").unwrap().contains("unknown command"), "{r:?}");
    // Missing cmd.
    let r = roundtrip("{}");
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    // Session-addressed command for a session that was never placed.
    let r = roundtrip(r#"{"cmd":"status","session":404,"id":7}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(r.get("id"), Some(&Json::Num(7.0)), "id echoed on errors");
    // Watch on an unknown session: one clean error line, no stream.
    let r = roundtrip(r#"{"cmd":"watch","session":404}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    // The connection survived all of the above.
    let r = roundtrip(r#"{"cmd":"hosts"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    assert_eq!(r.get("hosts").and_then(|h| h.as_arr()).map(|a| a.len()), Some(1));

    router.shutdown();
    front.join();
    svc.shutdown();
    srv.join();
}

#[test]
fn host_that_accepts_but_never_replies_fails_probes_within_budget() {
    // A listener that accepts connections and then says nothing —
    // the nastiest failure mode for anything without read deadlines.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop_accept = stop.clone();
    let hold = std::thread::spawn(move || {
        let mut held = Vec::new();
        // Keep accepted sockets open (never reply) until told to stop.
        listener.set_nonblocking(true).unwrap();
        while !stop_accept.load(std::sync::atomic::Ordering::Relaxed) {
            if let Ok((s, _)) = listener.accept() {
                held.push(s);
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    });

    let (router, front) = start_router(vec![(addr.as_str(), String::new())]);
    let t0 = Instant::now();
    router.probe_once();
    router.probe_once();
    let elapsed = t0.elapsed();
    assert_eq!(router.hosts()[0].health, HostHealth::Down);
    assert_eq!(router.failed_probes(), 2);
    // Each probe is bounded by probe_timeout_ms (250) — two passes
    // must come in way under the 10s a blocking reader would burn.
    assert!(elapsed < Duration::from_secs(5), "probe hung on a silent host: {elapsed:?}");

    // Submitting with every host down is a clean error, not a hang.
    let mut client = TcpClient::connect(front.addr()).unwrap();
    let err = client.submit_as(&train_cfg(41, 4), "nope", 1, None).unwrap_err();
    assert!(err.contains("no live host"), "{err}");

    router.shutdown();
    front.join();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    hold.join().unwrap();
}

#[test]
fn rendezvous_same_stem_same_host_and_minimal_disruption() {
    // Integration-level restatement of the routing properties over a
    // few hundred synthetic lineage stems, phrased exactly as the
    // operational guarantees we rely on during drains.
    let hosts = ["10.0.0.1:7931", "10.0.0.2:7931", "10.0.0.3:7931"];
    let stems: Vec<String> = (0..400).map(|i| format!("tenant{}/job{i}-{i}", i % 7)).collect();
    // Same stem → same host, every time.
    for s in &stems {
        assert_eq!(rendezvous(s, &hosts), rendezvous(s, &hosts));
    }
    let before: Vec<usize> = stems.iter().map(|s| rendezvous(s, &hosts).unwrap()).collect();
    // Kill the middle host: only its sessions move.
    let survivors = ["10.0.0.1:7931", "10.0.0.3:7931"];
    let mut moved = 0usize;
    for (s, &was) in stems.iter().zip(&before) {
        let now = [0usize, 2][rendezvous(s, &survivors).unwrap()];
        if was == 1 {
            moved += 1;
            assert_ne!(now, 1);
        } else {
            assert_eq!(now, was, "stem {s} moved although its host survived");
        }
    }
    // The dead host actually owned a meaningful share.
    assert!(moved > 60, "suspiciously few stems on the dead host: {moved}");
}
