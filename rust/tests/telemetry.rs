//! Telemetry integration: the streaming `watch` protocol over real
//! TCP, the `metrics` command, and the load-bearing guarantee that
//! instrumentation never touches numerics — training digests are
//! bit-identical with telemetry on and off.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

use eva::config::{ModelArch, OptimConfig, TrainConfig};
use eva::jsonx::Json;
use eva::optim::HyperParams;
use eva::serve::{ServeClient, Server, ServeConfig, Service, TcpClient};
use eva::telemetry::{self, TelemetryChoice};
use eva::train::Trainer;

/// The telemetry switch is process-wide; tests in this binary that
/// flip it (or depend on its value) serialize here.
static GLOBAL_KNOBS: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_KNOBS.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny(steps: u64) -> TrainConfig {
    TrainConfig {
        name: "telem".into(),
        dataset: "c10-small".into(),
        arch: ModelArch::Classifier { hidden: vec![8] },
        max_steps: Some(steps),
        epochs: 10_000, // max_steps is always the binding budget
        batch_size: 32,
        ..TrainConfig::default()
    }
}

fn test_cfg(tag: &str) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: 4,
        quantum_steps: 2,
        checkpoint_on_shutdown: false,
        checkpoint_dir: std::env::temp_dir()
            .join(format!("eva-telemetry-{tag}"))
            .to_string_lossy()
            .into_owned(),
        ..ServeConfig::default()
    }
}

#[test]
fn watch_streams_steps_over_tcp_until_done() {
    let _serial = lock();
    telemetry::install(&TelemetryChoice::On);
    let svc = Service::start(test_cfg("watch"));
    let server = Server::start(svc.clone(), "127.0.0.1:0").unwrap();
    let mut client = TcpClient::connect(server.addr()).unwrap();

    let id = client.submit(&tiny(12), "w", 1).unwrap();
    let mut events: Vec<Json> = Vec::new();
    let end = client.watch(id, &mut |ev| events.push(ev.clone())).unwrap();
    assert_eq!(end.get_str("event"), Some("end"));
    assert_eq!(end.get_str("status"), Some("done"), "{end:?}");

    // The ring (cap 256) held every event of a 12-step run, whether
    // the watch attached before or after the steps ran.
    assert_eq!(events.len(), 12, "one event per optimizer step");
    let seqs: Vec<f64> = events.iter().map(|e| e.get_f64("seq").unwrap()).collect();
    assert!(seqs.windows(2).all(|w| w[1] > w[0]), "seq must strictly increase: {seqs:?}");
    assert_eq!(events.last().unwrap().get_f64("step"), Some(12.0));
    for ev in &events {
        assert_eq!(ev.get_str("event"), Some("step"));
        assert!(ev.get_f64("loss").unwrap().is_finite());
        assert!(ev.get_f64("step_ms").unwrap() >= 0.0);
        // Telemetry is on: the native step phases must be present.
        let phases = ev.get("phases").and_then(|p| p.as_obj()).unwrap();
        assert!(phases.contains_key("forward_backward"), "{phases:?}");
    }

    // The connection survives a completed stream: ordinary commands
    // keep working on it.
    let stats = client.stats().unwrap();
    assert!(stats.get_f64("scheduler_steps").unwrap() >= 12.0);

    // The metrics command dumps the live registry over the same wire.
    let metrics = client.metrics().unwrap();
    assert_eq!(metrics.get_str("telemetry"), Some("on"));
    let counters = metrics.get("counters").and_then(|c| c.as_obj()).unwrap();
    assert!(counters.get("train.steps").and_then(|v| v.as_f64()).unwrap() >= 12.0);

    // Watching a bogus id is an ordinary error, not a broken stream.
    let err = client.watch(9999, &mut |_| {}).unwrap_err();
    assert!(err.contains("9999"), "{err}");

    svc.shutdown();
    server.join();
    let _ = std::fs::remove_dir_all(std::env::temp_dir().join("eva-telemetry-watch"));
}

#[test]
fn watch_ends_when_session_cancelled_midstream() {
    let _serial = lock();
    let svc = Service::start(test_cfg("cancel"));
    let server = Server::start(svc.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr();
    let mut ctl = TcpClient::connect(addr).unwrap();
    let id = ctl.submit(&tiny(1_000_000), "long", 1).unwrap();

    let watcher = std::thread::spawn(move || {
        let mut client = TcpClient::connect(addr).unwrap();
        let mut n = 0usize;
        let end = client.watch(id, &mut |_| n += 1).unwrap();
        (n, end)
    });
    // Wait until real steps exist (they are in the ring, so the
    // watcher sees them even if it attached late), then terminate the
    // session under the live stream.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    while ctl.status(id).unwrap().get_f64("step").unwrap() < 4.0 {
        assert!(std::time::Instant::now() < deadline, "session never stepped");
        std::thread::sleep(Duration::from_millis(10));
    }
    ctl.cancel(id).unwrap();
    let (n, end) = watcher.join().unwrap();
    assert_eq!(end.get_str("status"), Some("cancelled"), "{end:?}");
    assert!(n > 0, "watcher saw no events before the cancel");

    svc.shutdown();
    server.join();
    let _ = std::fs::remove_dir_all(std::env::temp_dir().join("eva-telemetry-cancel"));
}

#[test]
fn unread_watcher_never_stalls_the_scheduler() {
    let _serial = lock();
    let svc = Service::start(test_cfg("slow"));
    let server = Server::start(svc.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr();
    let mut ctl = TcpClient::connect(addr).unwrap();
    let long = ctl.submit(&tiny(1_000_000), "long", 1).unwrap();

    // A watcher that sends the request and then never reads a byte:
    // its stream backs up in kernel buffers and the session's event
    // ring drops oldest — neither may block stepping.
    let mut dead = TcpStream::connect(addr).unwrap();
    let req = format!("{}\n", Json::obj(vec![
        ("cmd", Json::Str("watch".into())),
        ("session", Json::Num(long as f64)),
    ]).dump());
    dead.write_all(req.as_bytes()).unwrap();
    dead.flush().unwrap();

    // Other work proceeds at full speed while the dead watcher hangs.
    let quick = ctl.submit(&tiny(20), "quick", 1).unwrap();
    ctl.wait_done(quick, Duration::from_secs(120)).unwrap();
    // And the watched session itself keeps stepping.
    let before = ctl.status(long).unwrap().get_f64("step").unwrap();
    std::thread::sleep(Duration::from_millis(200));
    let after = ctl.status(long).unwrap().get_f64("step").unwrap();
    assert!(after > before, "watched session stalled at step {after}");

    ctl.cancel(long).unwrap();
    drop(dead);
    svc.shutdown();
    server.join();
    let _ = std::fs::remove_dir_all(std::env::temp_dir().join("eva-telemetry-slow"));
}

/// A short native training run; returns the FNV digest of the exact
/// final weight/bias bits (same recipe as `tests/simd_parity.rs`).
fn train_digest(optimizer: &str) -> u64 {
    let mut hp = HyperParams::default();
    hp.update_interval = 2;
    hp.shampoo_block = 32;
    let cfg = TrainConfig {
        name: format!("telemetry-parity-{optimizer}"),
        dataset: "c10-small".into(),
        seed: 7,
        arch: ModelArch::Classifier { hidden: vec![16] },
        optim: OptimConfig { algorithm: optimizer.into(), hp },
        epochs: 1,
        batch_size: 32,
        base_lr: 0.05,
        lr_schedule: eva::config::LrSchedule::Cosine,
        max_steps: Some(4),
        eval_every: 1,
        ..TrainConfig::default()
    };
    let mut t = Trainer::from_config(&cfg).unwrap();
    t.run().unwrap();
    eva::serve::model_digest(t.model().expect("native engine"))
}

/// The determinism contract extends to observability: spans and
/// counters only ever *read the clock and bump atomics* — flipping
/// telemetry must not move a single weight bit for any optimizer
/// family.
#[test]
fn training_digests_identical_with_telemetry_on_and_off() {
    let _serial = lock();
    for optimizer in ["eva", "kfac", "shampoo", "mkor", "kradagrad"] {
        telemetry::install(&TelemetryChoice::On);
        let on = train_digest(optimizer);
        telemetry::install(&TelemetryChoice::Off);
        let off = train_digest(optimizer);
        telemetry::install(&TelemetryChoice::On);
        assert_eq!(
            on, off,
            "{optimizer}: weights diverge between telemetry on and off"
        );
    }
}

/// Health probes are read-only by construction (they recompute
/// diagnostics from state the step already produced), so the digest
/// contract must hold across every probe cadence — off (0), every
/// step (1), the default (10) — and with telemetry itself off.
#[test]
fn training_digests_identical_across_health_cadences() {
    use eva::telemetry::health;
    let _serial = lock();
    let prev_every = health::every();
    for optimizer in ["eva", "kfac", "shampoo", "mkor", "kradagrad"] {
        telemetry::install(&TelemetryChoice::On);
        health::set_every(0);
        let off = train_digest(optimizer);
        health::set_every(1);
        let every_step = train_digest(optimizer);
        health::set_every(10);
        let sampled = train_digest(optimizer);
        telemetry::install(&TelemetryChoice::Off);
        let no_telemetry = train_digest(optimizer);
        telemetry::install(&TelemetryChoice::On);
        assert_eq!(off, every_step, "{optimizer}: cadence 1 changed the weights");
        assert_eq!(off, sampled, "{optimizer}: cadence 10 changed the weights");
        assert_eq!(off, no_telemetry, "{optimizer}: telemetry off changed the weights");
    }
    health::set_every(prev_every);
    // Cadence-1 runs filled the thread-local and global buffers with
    // real samples; leave a clean slate for other tests.
    health::clear_thread();
    health::reset_global();
    assert!(
        health::with_global(|s| s.is_empty()),
        "global health store must reset clean"
    );
}
