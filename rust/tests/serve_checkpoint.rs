//! Checkpoint/restore bit-identity, per optimizer.
//!
//! The contract (ISSUE 3 acceptance): run K steps → snapshot →
//! serialize → deserialize into a fresh session ("fresh process
//! state": nothing survives but the bytes) → run K more steps, and
//! the weights digest must equal a 2K-step uninterrupted run — for
//! **every** optimizer in the zoo, including the interval-based ones
//! snapshotted mid-interval with stale cached inverses.

use eva::config::{ModelArch, TrainConfig};
use eva::serve::{Checkpoint, Session};

fn cfg(optimizer: &str, total_steps: u64, interval: usize) -> TrainConfig {
    let mut c = TrainConfig {
        name: format!("ckpt-{optimizer}"),
        dataset: "c10-small".into(),
        seed: 23,
        arch: ModelArch::Classifier { hidden: vec![10] },
        epochs: 1,
        batch_size: 32,
        base_lr: 0.05,
        max_steps: Some(total_steps),
        ..TrainConfig::default()
    };
    c.optim.algorithm = optimizer.into();
    c.optim.hp.update_interval = interval;
    c.optim.hp.mfac_history = 6;
    c
}

fn run_to_completion(s: &mut Session) {
    while !s.is_done() {
        assert!(s.run_quantum(64) > 0, "session stalled");
    }
}

/// Digest of an uninterrupted `total` -step run.
fn digest_uninterrupted(c: &TrainConfig) -> u64 {
    let mut s = Session::new(100, "uninterrupted", 1, c).unwrap();
    run_to_completion(&mut s);
    s.digest()
}

/// Digest of a run snapshotted at step `k`, round-tripped through the
/// binary format, restored into a fresh session and finished.
fn digest_resumed(c: &TrainConfig, k: usize) -> u64 {
    let mut s = Session::new(200, "interrupted", 1, c).unwrap();
    let mut left = k;
    while left > 0 {
        let took = s.run_quantum(left);
        assert!(took > 0, "session stalled before snapshot point");
        left -= took;
    }
    assert_eq!(s.state().step, k as u64);
    let bytes = s.checkpoint().unwrap().to_bytes();
    drop(s); // nothing of the original session survives but the bytes
    let ck = Checkpoint::from_bytes(&bytes).unwrap();
    let mut r = Session::from_checkpoint(201, "resumed", 1, &ck).unwrap();
    assert_eq!(r.state().step, k as u64, "restored session lost its cursor");
    run_to_completion(&mut r);
    assert_eq!(r.state().step, c.max_steps.unwrap());
    r.digest()
}

#[test]
fn checkpoint_roundtrip_is_bit_identical_for_every_optimizer() {
    for optimizer in [
        "sgd", "adam", "adagrad", "kfac", "foof", "shampoo", "mfac", "eva", "eva-f", "eva-s",
        "mkor", "kradagrad",
    ] {
        let c = cfg(optimizer, 10, 1);
        let full = digest_uninterrupted(&c);
        // Snapshot both mid-run points: right after a step and right
        // before the budget ends.
        for k in [4usize, 7] {
            let resumed = digest_resumed(&c, k);
            assert_eq!(
                resumed, full,
                "{optimizer}: resume-at-{k} diverged from uninterrupted run"
            );
        }
    }
}

#[test]
fn checkpoint_mid_interval_preserves_stale_preconditioners() {
    // Interval-based optimizers cache inverses/roots between refreshes;
    // a snapshot taken mid-interval must carry the *stale* cache, not
    // recompute it, or the resumed trajectory diverges.
    // mkor refreshes its inverse Kronecker factors and kradagrad its
    // cached inverse roots on the same interval schedule — both must
    // survive a mid-interval snapshot with the stale state intact.
    for optimizer in ["kfac", "foof", "shampoo", "mkor", "kradagrad"] {
        let c = cfg(optimizer, 9, 4); // refreshes at steps 0, 4, 8
        let full = digest_uninterrupted(&c);
        for k in [2usize, 5, 6] {
            let resumed = digest_resumed(&c, k);
            assert_eq!(
                resumed, full,
                "{optimizer}@4: resume-at-{k} diverged (stale cache lost?)"
            );
        }
    }
}

#[test]
fn checkpoint_across_epoch_boundary_preserves_batcher_stream() {
    // Cross an epoch boundary (per-epoch = ceil(2000/32) = 63): the
    // restored batcher must continue the *second* epoch's shuffled
    // order from its RNG state, not restart.
    let mut c = cfg("eva", 70, 1);
    c.epochs = 2;
    let full = digest_uninterrupted(&c);
    for k in [62usize, 63, 65] {
        let resumed = digest_resumed(&c, k);
        assert_eq!(resumed, full, "epoch-boundary resume-at-{k} diverged");
    }
}

#[test]
fn restore_rejects_wrong_algorithm_and_corrupt_bytes() {
    let c = cfg("eva", 6, 1);
    let mut s = Session::new(1, "x", 1, &c).unwrap();
    s.run_quantum(3);
    let mut ck = s.checkpoint().unwrap();
    // Rewrite the config to a different optimizer: the state bag's
    // algorithm tag must catch the mismatch.
    ck.config.optim.algorithm = "sgd".into();
    let err = Session::from_checkpoint(2, "y", 1, &ck).unwrap_err();
    assert!(err.contains("eva"), "{err}");
}
