//! L3 distributed coordinator: data-parallel training with KV/KF
//! communication, tensor fusion and a simulated interconnect.
//!
//! Reproduces the paper's §3.3 distributed design points:
//!
//! * Workers compute gradients + curvature statistics on their shard
//!   in parallel. Worker compute routes through the same dispatch
//!   layer as the kernels ([`crate::backend`]): the worker loop is one
//!   parallel-for over the coordinator's dispatch backend, and each
//!   simulated worker's kernels run on a per-worker *sub-pool handle*
//!   carved from that backend's lane budget
//!   ([`crate::backend::split`] + [`crate::backend::with_backend`];
//!   see [`dp`]).
//! * Gradients and statistics are combined with a **ring all-reduce**
//!   ([`allreduce`]) over a **simulated network** ([`network`]) whose
//!   bandwidth/latency model provides the paper's communication-time
//!   accounting (the testbed has no 32-GPU cluster; DESIGN.md §3).
//! * Small KVs are **tensor-fused** into one message
//!   ([`fusion`]) — the Horovod trick the paper leans on; the same
//!   fusion applied to K-FAC's d² factors is what makes KF traffic
//!   dominate.
//! * Distributed K-FAC spreads layer inversions across workers (the
//!   Osawa/Pauloski scheme): [`dp`]'s simulated clock divides the
//!   leader-side inverse cost by the worker count on K-FAC refresh
//!   steps — the setup the paper contrasts with Eva's "every worker
//!   preconditions everything cheaply".

#![warn(missing_docs)]

pub mod allreduce;
pub mod dp;
pub mod fusion;
pub mod network;

pub use dp::{DataParallelCfg, DataParallelTrainer, DpReport};
pub use network::SimNetwork;

/// Bytes of gradient traffic per step for a model (all-reduce payload).
pub fn gradient_bytes(layer_sizes: &[(usize, usize)]) -> usize {
    4 * layer_sizes.iter().map(|(r, c)| r * c + r).sum::<usize>()
}

/// Bytes of Eva KV traffic per step (ā + b̄ per layer) — sublinear.
pub fn kv_bytes(layer_sizes: &[(usize, usize)]) -> usize {
    4 * layer_sizes.iter().map(|(r, c)| r + c).sum::<usize>()
}

/// Bytes of K-FAC KF traffic per refresh (Q + R per layer) — quadratic.
pub fn kf_bytes(layer_sizes: &[(usize, usize)]) -> usize {
    4 * layer_sizes.iter().map(|(r, c)| r * r + c * c).sum::<usize>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_ordering_kv_lt_grad_lt_kf() {
        // The paper's communication argument: |KV| ≪ |grad| ≪ |KF|.
        let layers = [(512usize, 1024usize), (256, 512), (10, 256)];
        let kv = kv_bytes(&layers);
        let g = gradient_bytes(&layers);
        let kf = kf_bytes(&layers);
        assert!(kv * 10 < g, "kv {kv} vs grad {g}");
        assert!(g < kf, "grad {g} vs kf {kf}");
    }
}
