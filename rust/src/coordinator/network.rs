//! Simulated interconnect: an α–β (latency–bandwidth) cost model.
//!
//! The paper's ImageNet runs use 32 GPUs over 100 Gb/s interconnect;
//! that hardware is substituted (DESIGN.md §3) by this analytic model,
//! which provides the *time accounting* for all-reduce traffic while
//! the numerics run on real threads. The α–β model is the standard
//! collective-communication cost form: `T(bytes) = α + bytes/β`.

/// A symmetric full-duplex network between `workers` peers.
#[derive(Clone, Copy, Debug)]
pub struct SimNetwork {
    /// Per-message latency α in seconds.
    pub latency_s: f64,
    /// Bandwidth β in bytes/second.
    pub bandwidth_bps: f64,
    /// Number of ring participants.
    pub workers: usize,
}

impl SimNetwork {
    /// 100 Gb/s, 20 µs — datacenter RDMA-ish defaults (paper testbed).
    pub fn datacenter(workers: usize) -> Self {
        SimNetwork { latency_s: 20e-6, bandwidth_bps: 100e9 / 8.0, workers }
    }

    /// 10 Gb/s, 50 µs — commodity Ethernet.
    pub fn commodity(workers: usize) -> Self {
        SimNetwork { latency_s: 50e-6, bandwidth_bps: 10e9 / 8.0, workers }
    }

    /// Point-to-point transfer time for a message.
    pub fn p2p_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Ring all-reduce time: 2(W−1) phases each moving `bytes/W`.
    pub fn ring_allreduce_time(&self, bytes: usize) -> f64 {
        if self.workers <= 1 {
            return 0.0;
        }
        let w = self.workers as f64;
        2.0 * (w - 1.0) * (self.latency_s + (bytes as f64 / w) / self.bandwidth_bps)
    }

    /// Broadcast (binary tree) time.
    pub fn broadcast_time(&self, bytes: usize) -> f64 {
        if self.workers <= 1 {
            return 0.0;
        }
        (self.workers as f64).log2().ceil() * self.p2p_time(bytes)
    }

    /// All-reduce time for `messages` separate buffers (un-fused): the
    /// latency term is paid per message — what tensor fusion removes.
    pub fn ring_allreduce_multi(&self, message_bytes: &[usize]) -> f64 {
        message_bytes.iter().map(|&b| self.ring_allreduce_time(b)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_scales_with_bytes_not_workers() {
        // Classic property: ring all-reduce bandwidth term is ~2·bytes/β
        // independent of W (for large messages).
        let big = 1usize << 30;
        let t8 = SimNetwork::datacenter(8).ring_allreduce_time(big);
        let t32 = SimNetwork::datacenter(32).ring_allreduce_time(big);
        assert!((t8 / t32 - 1.0).abs() < 0.15, "{t8} vs {t32}");
    }

    #[test]
    fn fusion_beats_many_small_messages() {
        let net = SimNetwork::datacenter(16);
        let msgs: Vec<usize> = vec![4 * 1024; 64];
        let fused: usize = msgs.iter().sum();
        assert!(net.ring_allreduce_time(fused) < net.ring_allreduce_multi(&msgs) / 10.0);
    }

    #[test]
    fn single_worker_is_free() {
        assert_eq!(SimNetwork::datacenter(1).ring_allreduce_time(1 << 20), 0.0);
    }
}
