//! Tensor fusion: pack many small buffers into few fixed-size fusion
//! buffers before communication (the Horovod technique §3.3 cites; it
//! is what makes Eva's many tiny KV vectors cheap to all-reduce).

/// A fusion plan: which input buffers land in which fused message.
#[derive(Clone, Debug)]
pub struct FusionPlan {
    /// For each fused message: (input index, offset within message).
    pub messages: Vec<Vec<(usize, usize)>>,
    /// Payload size of each fused message, in bytes.
    pub message_bytes: Vec<usize>,
}

impl FusionPlan {
    /// Greedy first-fit packing of `sizes` (element counts) into
    /// messages of at most `budget_bytes` (f32 elements = 4 bytes).
    /// Buffers larger than the budget get their own message.
    pub fn build(sizes: &[usize], budget_bytes: usize) -> Self {
        let mut messages: Vec<Vec<(usize, usize)>> = Vec::new();
        let mut message_bytes: Vec<usize> = Vec::new();
        for (i, &n) in sizes.iter().enumerate() {
            let bytes = 4 * n;
            let slot = message_bytes
                .iter()
                .position(|&used| used + bytes <= budget_bytes)
                .filter(|_| bytes <= budget_bytes);
            match slot {
                Some(s) => {
                    messages[s].push((i, message_bytes[s] / 4));
                    message_bytes[s] += bytes;
                }
                None => {
                    messages.push(vec![(i, 0)]);
                    message_bytes.push(bytes);
                }
            }
        }
        FusionPlan { messages, message_bytes }
    }

    /// Number of fused messages the plan produces.
    pub fn num_messages(&self) -> usize {
        self.messages.len()
    }

    /// Total payload across all fused messages, in bytes.
    pub fn total_bytes(&self) -> usize {
        self.message_bytes.iter().sum()
    }

    /// Scatter input buffers into fused messages.
    pub fn pack(&self, inputs: &[&[f32]]) -> Vec<Vec<f32>> {
        self.messages
            .iter()
            .zip(&self.message_bytes)
            .map(|(entries, &bytes)| {
                let mut msg = vec![0.0f32; bytes / 4];
                for &(idx, off) in entries {
                    msg[off..off + inputs[idx].len()].copy_from_slice(inputs[idx]);
                }
                msg
            })
            .collect()
    }

    /// Gather fused messages back into per-buffer vectors.
    pub fn unpack(&self, messages: &[Vec<f32>], sizes: &[usize]) -> Vec<Vec<f32>> {
        let mut out: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0.0; n]).collect();
        for (m, entries) in messages.iter().zip(&self.messages) {
            for &(idx, off) in entries {
                let n = sizes[idx];
                out[idx].copy_from_slice(&m[off..off + n]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn packs_within_budget() {
        let sizes = [10usize, 20, 30, 1000, 5];
        let plan = FusionPlan::build(&sizes, 256); // 64 f32s per message
        assert!(plan.num_messages() < sizes.len());
        assert_eq!(plan.total_bytes() / 4, 10 + 20 + 30 + 1000 + 5);
        for (m, &bytes) in plan.messages.iter().zip(&plan.message_bytes) {
            if m.len() > 1 {
                assert!(bytes <= 256);
            }
        }
    }

    #[test]
    fn prop_pack_unpack_roundtrip() {
        check("fusion roundtrip", 20, |g| {
            let k = g.usize_in(1, 12);
            let sizes: Vec<usize> = (0..k).map(|_| g.usize_in(1, 40)).collect();
            let bufs: Vec<Vec<f32>> = sizes.iter().map(|&n| g.normal_vec(n)).collect();
            let plan = FusionPlan::build(&sizes, g.usize_in(16, 200) * 4);
            let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
            let packed = plan.pack(&refs);
            let unpacked = plan.unpack(&packed, &sizes);
            if unpacked == bufs {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }

    #[test]
    fn oversized_buffer_gets_own_message() {
        let plan = FusionPlan::build(&[1000, 2, 3], 64);
        assert_eq!(plan.messages[0].len(), 1);
        assert_eq!(plan.messages[1].len(), 2);
    }
}
