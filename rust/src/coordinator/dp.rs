//! Data-parallel training: per-worker backend handles + ring
//! all-reduce + the simulated interconnect — the paper's §3.3 /
//! Table 8 setup.
//!
//! Replicas stay bit-identical (same init, same averaged update), so a
//! single canonical model is stored; simulated workers compute
//! gradients and curvature statistics on *disjoint shards* in parallel
//! (real compute), statistics are combined with the real ring
//! all-reduce, and the step's wall-clock is *accounted* under the
//! simulated network: `max(worker compute) + comm(fused payload) +
//! leader preconditioning`.
//!
//! Worker compute goes through **one dispatch layer**: the worker loop
//! is a single [`crate::backend::par_map`] over the coordinator's
//! dispatch backend (no raw `std::thread` spawns), and each worker's
//! kernels run under [`crate::backend::with_backend`] on its own
//! sub-pool handle carved from the dispatch backend's lane budget by
//! [`crate::backend::split`]. When a worker's handle is exhausted
//! (one lane), its nested dispatch inlines — the degenerate case is
//! exactly the sequential path, so results are bit-identical for every
//! backend and worker-lane assignment. On the untouched boot default
//! (no backend chosen anywhere) the coordinator falls back to one lane
//! per hardware thread, preserving the real parallelism the seed's
//! raw-thread workers had; an *explicit* `seq` choice is honored.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::backend::Backend;
use crate::config::ModelArch;
use crate::coordinator::fusion::FusionPlan;
use crate::coordinator::network::SimNetwork;
use crate::coordinator::{allreduce, gradient_bytes, kf_bytes, kv_bytes};
use crate::data::{by_name, Batcher, Dataset};
use crate::nn::{BackwardResult, Mlp, StatsMode};
use crate::optim::{by_name as optim_by_name, HyperParams, Optimizer, StepCtx};
use crate::tensor::Tensor;

/// Process-wide default for [`DataParallelCfg::worker_threads`]
/// (0 encodes "unset"). Set from the CLI (`--worker-threads`) or a
/// train config; read by [`DataParallelCfg::new`].
static DEFAULT_WORKER_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide default per-worker lane budget picked up by
/// every subsequently built [`DataParallelCfg`] (`None` restores the
/// carve-from-global default).
pub fn set_default_worker_threads(n: Option<usize>) {
    DEFAULT_WORKER_THREADS.store(n.unwrap_or(0), Ordering::Relaxed);
}

/// The process-wide default per-worker lane budget, if one was set.
pub fn default_worker_threads() -> Option<usize> {
    match DEFAULT_WORKER_THREADS.load(Ordering::Relaxed) {
        0 => None,
        n => Some(n),
    }
}

/// Configuration for a data-parallel run.
#[derive(Clone, Debug)]
pub struct DataParallelCfg {
    /// Number of simulated workers (ring participants).
    pub workers: usize,
    /// Dataset name, resolved via [`crate::data::by_name`].
    pub dataset: String,
    /// Model architecture trained by every replica.
    pub arch: ModelArch,
    /// Optimizer algorithm name ([`crate::optim::by_name`]).
    pub optimizer: String,
    /// Optimizer hyper-parameters.
    pub hp: HyperParams,
    /// Samples per worker per step (global batch = workers × this).
    pub per_worker_batch: usize,
    /// Number of optimizer steps to run.
    pub steps: u64,
    /// Base learning rate.
    pub base_lr: f32,
    /// Seed for data generation, sharding and model init.
    pub seed: u64,
    /// Simulated interconnect used for communication accounting.
    pub network: SimNetwork,
    /// Horovod-style fusion buffer budget.
    pub fusion_budget_bytes: usize,
    /// Per-worker compute-lane budget. `None` carves the dispatch
    /// backend's lanes evenly across workers
    /// ([`crate::backend::split`]); `Some(k)` gives every worker
    /// exactly `k` lanes (`k ≤ 1` means inline/sequential compute).
    /// Defaults to [`default_worker_threads`].
    pub worker_threads: Option<usize>,
}

impl DataParallelCfg {
    /// Defaults for `workers` ring participants running `optimizer`.
    pub fn new(workers: usize, optimizer: &str) -> Self {
        DataParallelCfg {
            workers,
            dataset: "c10-small".into(),
            arch: ModelArch::Classifier { hidden: vec![128, 64] },
            optimizer: optimizer.into(),
            hp: HyperParams::default(),
            per_worker_batch: 32,
            steps: 30,
            base_lr: 0.05,
            seed: 17,
            network: SimNetwork::datacenter(workers),
            fusion_budget_bytes: 64 << 20,
            worker_threads: default_worker_threads(),
        }
    }

    /// Total samples consumed per step across all workers.
    pub fn global_batch(&self) -> usize {
        self.workers * self.per_worker_batch
    }
}

/// Per-step and aggregate accounting.
#[derive(Clone, Debug)]
pub struct DpReport {
    /// Mean training loss of the last step.
    pub final_loss: f32,
    /// Steps actually run.
    pub steps: u64,
    /// Real wall-clock of the whole run.
    pub wall_time_s: f64,
    /// Simulated per-step time: compute + comm + precondition.
    pub sim_step_time_s: f64,
    /// Simulated per-step compute time (max over workers).
    pub sim_compute_s: f64,
    /// Simulated per-step all-reduce time under the network model.
    pub sim_comm_s: f64,
    /// Simulated per-step leader preconditioning time.
    pub sim_precond_s: f64,
    /// Global samples/second under the simulated clock (Table 8).
    pub throughput: f64,
    /// All-reduced payload per step (gradients + statistics), bytes.
    pub comm_bytes_per_step: usize,
    /// Fused message count per step.
    pub messages_per_step: usize,
}

/// The coordinator.
pub struct DataParallelTrainer {
    cfg: DataParallelCfg,
    dataset: Dataset,
    model: Mlp,
    optimizer: Box<dyn Optimizer>,
    batchers: Vec<Batcher>,
    /// Fan-out backend: the per-step worker loop runs as one
    /// parallel-for here, and the leader optimizer step runs under it
    /// as a scoped handle ([`crate::backend::with_backend`]).
    dispatch: Arc<dyn Backend>,
    /// Per-worker compute handles — sub-pools carved from `dispatch`'s
    /// lane budget (or fixed-size pools under
    /// [`DataParallelCfg::worker_threads`]).
    worker_handles: Vec<Arc<dyn Backend>>,
}

impl DataParallelTrainer {
    /// Build the coordinator: dataset, canonical model, per-worker
    /// shards and per-worker backend handles.
    pub fn new(cfg: DataParallelCfg) -> Result<Self, String> {
        let dataset = by_name(&cfg.dataset, cfg.seed)?;
        let spec = cfg.arch.to_spec(dataset.input_dim(), dataset.num_classes);
        let model = Mlp::init(spec, cfg.seed.wrapping_add(1));
        let optimizer = optim_by_name(&cfg.optimizer, &cfg.hp)?;
        // Each worker shards the training set by stride and owns an
        // independent shuffling stream.
        let n = dataset.train.len();
        let shard = n / cfg.workers;
        let batchers = (0..cfg.workers)
            .map(|w| Batcher::new(shard.max(1), cfg.per_worker_batch, cfg.seed ^ (w as u64)))
            .collect();
        // Dispatch backend for the worker fan-out. An explicitly
        // chosen backend — global (CLI/config/install) or scoped
        // (`with_backend`) — is honored as-is, including `seq` for
        // single-threaded debugging. Only on the untouched boot
        // default does the coordinator fall back to one lane per
        // hardware thread, so the simulated workers really compute in
        // parallel like the seed's raw-thread workers; numerics are
        // identical either way (bit-identical backend contract).
        let dispatch = {
            let cur = crate::backend::current();
            let untouched_default = cur.threads() == 1
                && crate::backend::global_is_default()
                && !crate::backend::scoped_override_active()
                && !crate::backend::in_pool();
            if untouched_default {
                crate::backend::handle_with_lanes(crate::backend::default_threads())
            } else {
                cur
            }
        };
        let worker_handles = match cfg.worker_threads {
            Some(lanes) => {
                (0..cfg.workers).map(|_| crate::backend::handle_with_lanes(lanes)).collect()
            }
            None => crate::backend::split(&*dispatch, cfg.workers),
        };
        Ok(DataParallelTrainer {
            cfg,
            dataset,
            model,
            optimizer,
            batchers,
            dispatch,
            worker_handles,
        })
    }

    /// The canonical replica (all replicas are bit-identical).
    pub fn model(&self) -> &Mlp {
        &self.model
    }

    /// Labels of the per-worker backend handles (diagnostics/tests).
    pub fn worker_handle_labels(&self) -> Vec<String> {
        self.worker_handles.iter().map(|h| h.label()).collect()
    }

    /// Worker w's global index for local index i (stride sharding).
    fn global_index(&self, w: usize, local: usize) -> usize {
        (local * self.cfg.workers + w) % self.dataset.train.len()
    }

    /// Run the configured number of steps.
    pub fn run(&mut self) -> Result<DpReport, String> {
        let w = self.cfg.workers;
        let start = Instant::now();
        let mut final_loss = 0.0f32;
        let (mut sim_compute, mut sim_comm, mut sim_precond) = (0.0f64, 0.0f64, 0.0f64);
        let (mut bytes_acc, mut msgs_acc) = (0usize, 0usize);
        for step in 0..self.cfg.steps {
            let mode = self.optimizer.stats_mode_at(step);
            // ---- parallel worker compute (one dispatch layer) -------------
            let batches: Vec<(Tensor, Vec<usize>)> = (0..w)
                .map(|wi| {
                    let idx: Vec<usize> = self.batchers[wi]
                        .next_indices()
                        .to_vec()
                        .into_iter()
                        .map(|i| self.global_index(wi, i))
                        .collect();
                    self.dataset.train.gather(&idx)
                })
                .collect();
            let model = &self.model;
            let handles = &self.worker_handles;
            // One parallel-for over workers on the dispatch backend;
            // each worker's kernels dispatch through its own sub-pool
            // handle. Results land in worker order (par_map), so the
            // combine below is schedule-independent.
            let results: Vec<(BackwardResult, f64)> =
                crate::backend::par_map(&*self.dispatch, w, |wi| {
                    let (x, y) = &batches[wi];
                    let t0 = Instant::now();
                    let r = crate::backend::with_backend(Arc::clone(&handles[wi]), || {
                        model.forward_backward(x, y, mode)
                    });
                    (r, t0.elapsed().as_secs_f64())
                });
            let compute_time =
                results.iter().map(|(_, t)| *t).fold(0.0f64, f64::max);
            final_loss =
                results.iter().map(|(r, _)| r.loss).sum::<f32>() / w as f32;

            // ---- all-reduce gradients (+ statistics) ----------------------
            let (avg, payload_bytes, messages) = self.combine(&results, mode);
            let comm_time = {
                // fused ring all-reduce under the simulated interconnect
                let plan_sizes: Vec<usize> = messages.clone();
                self.cfg.network.ring_allreduce_multi(&plan_sizes)
            };
            bytes_acc += payload_bytes;
            msgs_acc += messages.len();

            // ---- leader optimizer step ------------------------------------
            let t0 = Instant::now();
            let ctx = StepCtx {
                params: &self.model.weights,
                grads: &avg.grads,
                bias_grads: &avg.bias_grads,
                stats: &avg.stats,
                lr: self.cfg.base_lr,
                step,
            };
            // Leader preconditioning runs under the same dispatch
            // backend as the workers (K-FAC's O(d³) inverses et al.
            // would otherwise fall back to the global default, which
            // in the boot-default case is still sequential).
            let update = crate::backend::with_backend(Arc::clone(&self.dispatch), || {
                self.optimizer.step(&ctx)
            });
            let mut precond_time = t0.elapsed().as_secs_f64();
            if self.cfg.optimizer == "kfac" && mode == StatsMode::Full {
                // Distributed K-FAC assigns layer inversions across
                // workers (Osawa/Pauloski): leader-side inverse cost is
                // divided by W in the simulated clock.
                precond_time /= w as f64;
            }
            self.model.apply_update(&update.deltas, &update.bias_deltas);

            sim_compute += compute_time;
            sim_comm += comm_time;
            sim_precond += precond_time;
        }
        let steps = self.cfg.steps.max(1) as f64;
        let sim_step = (sim_compute + sim_comm + sim_precond) / steps;
        Ok(DpReport {
            final_loss,
            steps: self.cfg.steps,
            wall_time_s: start.elapsed().as_secs_f64(),
            sim_step_time_s: sim_step,
            sim_compute_s: sim_compute / steps,
            sim_comm_s: sim_comm / steps,
            sim_precond_s: sim_precond / steps,
            throughput: self.cfg.global_batch() as f64 / sim_step,
            comm_bytes_per_step: bytes_acc / self.cfg.steps.max(1) as usize,
            messages_per_step: msgs_acc / self.cfg.steps.max(1) as usize,
        })
    }

    /// Average gradients/statistics across workers with the real ring
    /// all-reduce; returns the combined result + payload accounting.
    fn combine(
        &self,
        results: &[(BackwardResult, f64)],
        mode: StatsMode,
    ) -> (BackwardResult, usize, Vec<usize>) {
        let w = results.len();
        let ll = self.model.num_layers();
        // Flatten per-worker payloads: grads + bias grads (+ KVs).
        let mut sizes: Vec<usize> = Vec::new();
        for l in 0..ll {
            sizes.push(results[0].0.grads[l].len());
            sizes.push(results[0].0.bias_grads[l].len());
        }
        if mode != StatsMode::None {
            for l in 0..ll {
                sizes.push(results[0].0.stats[l].a_mean.len());
                sizes.push(results[0].0.stats[l].b_mean.len());
            }
        }
        if mode == StatsMode::Full {
            for l in 0..ll {
                sizes.push(results[0].0.stats[l].aat.as_ref().unwrap().len());
                sizes.push(results[0].0.stats[l].bbt.as_ref().unwrap().len());
            }
        }
        let plan = FusionPlan::build(&sizes, self.cfg.fusion_budget_bytes);
        // Pack each worker's buffers.
        let mut fused: Vec<Vec<Vec<f32>>> = results
            .iter()
            .map(|(r, _)| {
                let mut bufs: Vec<&[f32]> = Vec::with_capacity(sizes.len());
                for l in 0..ll {
                    bufs.push(r.grads[l].data());
                    bufs.push(&r.bias_grads[l]);
                }
                if mode != StatsMode::None {
                    for l in 0..ll {
                        bufs.push(&r.stats[l].a_mean);
                        bufs.push(&r.stats[l].b_mean);
                    }
                }
                if mode == StatsMode::Full {
                    for l in 0..ll {
                        bufs.push(r.stats[l].aat.as_ref().unwrap().data());
                        bufs.push(r.stats[l].bbt.as_ref().unwrap().data());
                    }
                }
                plan.pack(&bufs)
            })
            .collect();
        // Real ring all-reduce per fused message, then mean.
        for m in 0..plan.num_messages() {
            let mut msg_bufs: Vec<Vec<f32>> =
                fused.iter().map(|worker| worker[m].clone()).collect();
            allreduce::ring_allreduce_mean(&mut msg_bufs);
            fused[0][m] = msg_bufs.into_iter().next().unwrap();
            let _ = w;
        }
        let averaged = plan.unpack(&fused[0], &sizes);
        // Rebuild a BackwardResult from the averaged buffers.
        let mut it = averaged.into_iter();
        let mut grads = Vec::with_capacity(ll);
        let mut bias_grads = Vec::with_capacity(ll);
        for l in 0..ll {
            let (r, c) = results[0].0.grads[l].shape();
            grads.push(Tensor::from_vec(r, c, it.next().unwrap()));
            bias_grads.push(it.next().unwrap());
            let _ = l;
        }
        let mut stats = Vec::with_capacity(ll);
        if mode != StatsMode::None {
            let mut kv: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(ll);
            for _ in 0..ll {
                let a = it.next().unwrap();
                let b = it.next().unwrap();
                kv.push((a, b));
            }
            let mut full: Vec<(Option<Tensor>, Option<Tensor>)> = vec![(None, None); ll];
            if mode == StatsMode::Full {
                for item in full.iter_mut() {
                    let aat_data = it.next().unwrap();
                    let bbt_data = it.next().unwrap();
                    let da = (aat_data.len() as f64).sqrt() as usize;
                    let db = (bbt_data.len() as f64).sqrt() as usize;
                    *item = (
                        Some(Tensor::from_vec(da, da, aat_data)),
                        Some(Tensor::from_vec(db, db, bbt_data)),
                    );
                }
            }
            for (l, ((a, b), (aat, bbt))) in kv.into_iter().zip(full).enumerate() {
                stats.push(crate::nn::LayerStats { a_mean: a, b_mean: b, aat, bbt });
                let _ = l;
            }
        }
        let payload = 4 * sizes.iter().sum::<usize>();
        let combined = BackwardResult {
            loss: results.iter().map(|(r, _)| r.loss).sum::<f32>() / w as f32,
            grads,
            bias_grads,
            stats,
            correct: 0,
        };
        (combined, payload, plan.message_bytes.clone())
    }

    /// Validation accuracy of the canonical replica.
    pub fn val_accuracy(&self) -> f32 {
        self.model.accuracy(&self.dataset.val.inputs, &self.dataset.val.labels, 256)
    }

    /// Communication volumes per step for this model under each scheme
    /// (grad-only SGD, Eva grad+KV, K-FAC grad+KF on refresh).
    pub fn traffic_summary(&self) -> (usize, usize, usize) {
        let shapes: Vec<(usize, usize)> =
            self.model.weights.iter().map(|t| t.shape()).collect();
        (gradient_bytes(&shapes), kv_bytes(&shapes), kf_bytes(&shapes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(workers: usize, optimizer: &str, steps: u64) -> DataParallelCfg {
        let mut c = DataParallelCfg::new(workers, optimizer);
        c.steps = steps;
        c.hp.weight_decay = 0.0;
        c.arch = ModelArch::Classifier { hidden: vec![32] };
        c
    }

    #[test]
    fn dp_eva_learns_and_accounts() {
        let mut t = DataParallelTrainer::new(quick_cfg(4, "eva", 25)).unwrap();
        let r = t.run().unwrap();
        assert!(r.final_loss.is_finite());
        assert!(t.val_accuracy() > 0.3, "acc {}", t.val_accuracy());
        assert!(r.throughput > 0.0);
        assert!(r.comm_bytes_per_step > 0);
        assert!(r.sim_comm_s > 0.0);
    }

    #[test]
    fn dp_matches_single_worker_gradients() {
        // With W workers on disjoint shards and the same model, the
        // averaged gradient equals a single pass over the union batch.
        let cfg = quick_cfg(2, "sgd", 1);
        let t = DataParallelTrainer::new(cfg).unwrap();
        let (x0, y0) = t.dataset.train.gather(&[0, 2, 4, 6]);
        let (x1, y1) = t.dataset.train.gather(&[1, 3, 5, 7]);
        let r0 = t.model.forward_backward(&x0, &y0, StatsMode::None);
        let r1 = t.model.forward_backward(&x1, &y1, StatsMode::None);
        let results = vec![(r0, 0.0), (r1, 0.0)];
        let (avg, _, _) = t.combine(&results, StatsMode::None);
        let (xu, yu) = t.dataset.train.gather(&[0, 2, 4, 6, 1, 3, 5, 7]);
        let ru = t.model.forward_backward(&xu, &yu, StatsMode::None);
        for l in 0..t.model.num_layers() {
            assert!(
                avg.grads[l].max_abs_diff(&ru.grads[l]) < 1e-4,
                "layer {l} mismatch"
            );
        }
    }

    #[test]
    fn worker_threads_knob_controls_handles() {
        let mut cfg = quick_cfg(3, "sgd", 1);
        cfg.worker_threads = Some(1);
        let t = DataParallelTrainer::new(cfg).unwrap();
        assert_eq!(t.worker_handle_labels(), vec!["seq"; 3]);
        let mut cfg = quick_cfg(2, "sgd", 1);
        cfg.worker_threads = Some(2);
        let t = DataParallelTrainer::new(cfg).unwrap();
        assert_eq!(t.worker_handle_labels(), vec!["threads:2"; 2]);
    }

    #[test]
    fn default_worker_threads_flows_into_new_cfgs() {
        // Some(1) keeps any concurrently-built test cfg on the inline
        // path if the window overlaps — behavior, not numerics, so the
        // transient is harmless.
        set_default_worker_threads(Some(1));
        assert_eq!(default_worker_threads(), Some(1));
        assert_eq!(DataParallelCfg::new(2, "sgd").worker_threads, Some(1));
        set_default_worker_threads(None);
        assert_eq!(default_worker_threads(), None);
        assert_eq!(DataParallelCfg::new(2, "sgd").worker_threads, None);
    }

    #[test]
    fn handles_split_from_dispatch_backend_when_unset() {
        // Under a 4-lane scoped dispatch backend, 2 workers get 2
        // lanes each; the knob is None so the carve applies.
        let four: std::sync::Arc<dyn Backend> =
            std::sync::Arc::new(crate::backend::Threaded::new(4));
        let mut cfg = quick_cfg(2, "sgd", 1);
        cfg.worker_threads = None;
        let t = crate::backend::with_backend(four, || DataParallelTrainer::new(cfg).unwrap());
        assert_eq!(t.worker_handle_labels(), vec!["threads:2"; 2]);
    }

    #[test]
    fn kfac_refresh_steps_carry_kf_traffic() {
        let mut cfg = quick_cfg(2, "kfac", 2);
        cfg.hp.update_interval = 2;
        let mut t = DataParallelTrainer::new(cfg).unwrap();
        let r = t.run().unwrap();
        // Step 0 (refresh) moves KFs, step 1 only grads → the average
        // payload must exceed the pure-gradient volume.
        let (grad_b, _kv_b, _kf_b) = t.traffic_summary();
        assert!(r.comm_bytes_per_step > grad_b, "{} vs {grad_b}", r.comm_bytes_per_step);
    }
}
