//! Ring all-reduce over in-process workers (correctness path).
//!
//! The numerics run for real — each worker contributes a buffer, the
//! reduce-scatter + all-gather phases exchange actual chunks — so tests
//! can assert bit-level agreement with a sequential sum. Wall-clock
//! accounting for the simulated interconnect happens separately via
//! [`super::network::SimNetwork`].

/// Reduce (sum) `buffers` across workers with a ring schedule; every
/// buffer ends up holding the elementwise sum. Panics if buffer lengths
/// differ.
pub fn ring_allreduce(buffers: &mut [Vec<f32>]) {
    let w = buffers.len();
    if w <= 1 {
        return;
    }
    let n = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == n), "buffer length mismatch");
    if n == 0 {
        return;
    }
    // Chunk boundaries (W chunks, last absorbs the remainder).
    let chunk = n.div_ceil(w);
    // Clamp both ends: when n < w some tail chunks are empty.
    let bounds: Vec<(usize, usize)> =
        (0..w).map(|c| ((c * chunk).min(n), ((c + 1) * chunk).min(n))).collect();
    // Reduce-scatter: step s, worker i sends chunk (i - s) to worker i+1.
    for s in 0..w - 1 {
        // Gather the chunks to send first (borrow discipline), then add.
        let mut sends: Vec<(usize, usize, Vec<f32>)> = Vec::with_capacity(w);
        for i in 0..w {
            let c = (i + w - s) % w;
            let (lo, hi) = bounds[c];
            sends.push(((i + 1) % w, c, buffers[i][lo..hi].to_vec()));
        }
        for (dst, c, data) in sends {
            let (lo, hi) = bounds[c];
            for (d, v) in buffers[dst][lo..hi].iter_mut().zip(data) {
                *d += v;
            }
        }
    }
    // All-gather: worker i owns the fully-reduced chunk (i+1) mod w.
    for s in 0..w - 1 {
        let mut sends: Vec<(usize, usize, Vec<f32>)> = Vec::with_capacity(w);
        for i in 0..w {
            let c = (i + 1 + w - s) % w;
            let (lo, hi) = bounds[c];
            sends.push(((i + 1) % w, c, buffers[i][lo..hi].to_vec()));
        }
        for (dst, c, data) in sends {
            let (lo, hi) = bounds[c];
            buffers[dst][lo..hi].copy_from_slice(&data);
        }
    }
}

/// Average (all-reduce then scale by 1/W).
pub fn ring_allreduce_mean(buffers: &mut [Vec<f32>]) {
    let w = buffers.len().max(1) as f32;
    ring_allreduce(buffers);
    for b in buffers.iter_mut() {
        for v in b.iter_mut() {
            *v /= w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn prop_matches_sequential_sum() {
        check("ring == seq sum", 25, |g| {
            let w = g.usize_in(1, 9);
            let n = g.usize_in(1, 57);
            let buffers: Vec<Vec<f32>> = (0..w).map(|_| g.normal_vec(n)).collect();
            let mut expect = vec![0.0f32; n];
            for b in &buffers {
                for (e, &v) in expect.iter_mut().zip(b) {
                    *e += v;
                }
            }
            let mut bufs = buffers.clone();
            ring_allreduce(&mut bufs);
            for (wi, b) in bufs.iter().enumerate() {
                for (j, (&got, &want)) in b.iter().zip(&expect).enumerate() {
                    if (got - want).abs() > 1e-3 * (1.0 + want.abs()) {
                        return Err(format!("worker {wi} elem {j}: {got} vs {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mean_divides() {
        let mut bufs = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        ring_allreduce_mean(&mut bufs);
        assert_eq!(bufs[0], vec![2.0, 4.0]);
        assert_eq!(bufs[1], vec![2.0, 4.0]);
    }

    #[test]
    fn single_worker_noop() {
        let mut bufs = vec![vec![1.0f32, 2.0]];
        ring_allreduce(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }
}
