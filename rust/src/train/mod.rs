//! The training driver: ties datasets, models, optimizers and engines
//! together, with metrics and CSV logging.
//!
//! [`Trainer`] is the single-process path used by every experiment in
//! `exp/` (native engine) and by the quickstart (either engine).
//! Multi-worker data parallelism lives in `coordinator`.

mod metrics;

pub use metrics::{Metrics, StepTimer};

use anyhow::{anyhow, Result};

use crate::config::{Engine, TrainConfig};
use crate::data::{by_name, Batcher, BatcherSnapshot, Dataset, Task};
use crate::nn::{Mlp, StatsMode};
use crate::optim::{by_name as optim_by_name, Optimizer, StepCtx};
use crate::runtime::{HostArray, Runtime, StepDriver, StepHp, StepKind};
use crate::tensor::Tensor;

/// Per-epoch record.
#[derive(Clone, Debug)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub train_loss: f32,
    pub val_metric: f32, // accuracy for classification, loss for AE
    pub wall_time_s: f64,
    pub mean_step_ms: f64,
}

/// Final run report.
#[derive(Clone, Debug)]
pub struct Report {
    pub config_name: String,
    pub optimizer: String,
    pub final_loss: f32,
    /// Best validation accuracy (classification) — 0 for AE runs.
    pub best_val_acc: f32,
    /// Best (lowest) validation loss (AE) — f32::MAX for classification.
    pub best_val_loss: f32,
    pub history: Vec<EpochMetrics>,
    pub total_time_s: f64,
    pub mean_step_ms: f64,
    pub optimizer_state_bytes: usize,
    pub steps: u64,
}

impl Report {
    /// First epoch at which validation accuracy reached `target`
    /// (classification), with the cumulative wall-clock time.
    pub fn time_to_accuracy(&self, target: f32) -> Option<(usize, f64)> {
        let mut t = 0.0;
        for e in &self.history {
            t += e.wall_time_s;
            if e.val_metric >= target {
                return Some((e.epoch, t));
            }
        }
        None
    }
}

/// Single-process trainer.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub dataset: Dataset,
    engine: EngineState,
}

enum EngineState {
    Native { model: Mlp, optimizer: Box<dyn Optimizer> },
    Pjrt { driver: StepDriver },
}

impl Trainer {
    pub fn from_config(cfg: &TrainConfig) -> Result<Self> {
        if let Some(spec) = &cfg.backend {
            let choice =
                crate::backend::BackendChoice::parse(spec).map_err(|e| anyhow!(e))?;
            crate::backend::install(&choice);
        }
        if cfg.worker_threads.is_some() {
            // Flows into every DataParallelCfg built afterwards (the
            // coordinator runs in-process; see coordinator::dp).
            crate::coordinator::dp::set_default_worker_threads(cfg.worker_threads);
        }
        if let Some(spec) = &cfg.simd {
            // Like `backend`, a process-wide knob: forcing a path the
            // host lacks fails here, loudly, not mid-step. Numerics are
            // bit-identical across paths (see crate::simd).
            let choice = crate::simd::SimdChoice::parse(spec).map_err(|e| anyhow!(e))?;
            crate::simd::install(&choice).map_err(|e| anyhow!(e))?;
        }
        if let Some(spec) = &cfg.telemetry {
            // Also process-wide. Instrumentation never touches numerics
            // (see crate::telemetry), so flipping it cannot change a
            // run's bits — only whether counters/histograms move.
            let choice =
                crate::telemetry::TelemetryChoice::parse(spec).map_err(|e| anyhow!(e))?;
            crate::telemetry::install(&choice);
        }
        let dataset = by_name(&cfg.dataset, cfg.seed).map_err(|e| anyhow!(e))?;
        let engine = match &cfg.engine {
            Engine::Native => {
                let spec = cfg.arch.to_spec(dataset.input_dim(), dataset.num_classes);
                let model = Mlp::init(spec, cfg.seed.wrapping_add(1));
                let optimizer =
                    optim_by_name(&cfg.optim.algorithm, &cfg.optim.hp).map_err(|e| anyhow!(e))?;
                EngineState::Native { model, optimizer }
            }
            Engine::Pjrt { model } => {
                let mut rt = Runtime::open_default()?;
                let kind = match cfg.optim.algorithm.as_str() {
                    "eva" => StepKind::Eva,
                    "sgd" => StepKind::Sgd,
                    other => {
                        return Err(anyhow!("pjrt engine supports eva|sgd, not '{other}'"))
                    }
                };
                let hp = StepHp {
                    lr: cfg.base_lr,
                    gamma: cfg.optim.hp.damping,
                    xi: cfg.optim.hp.running_avg,
                    kappa: cfg.optim.hp.kl_clip,
                    momentum: cfg.optim.hp.momentum,
                    weight_decay: cfg.optim.hp.weight_decay,
                };
                let driver = StepDriver::new(&mut rt, model, kind, hp, cfg.seed)?;
                // The runtime must outlive the driver's executables; the
                // executables are Rc-shared, and the client lives inside
                // them via PJRT refcounting, so dropping `rt` is fine.
                EngineState::Pjrt { driver }
            }
        };
        Ok(Trainer { cfg: cfg.clone(), dataset, engine })
    }

    /// The model (native engine only).
    pub fn model(&self) -> Option<&Mlp> {
        match &self.engine {
            EngineState::Native { model, .. } => Some(model),
            _ => None,
        }
    }

    /// Replace the optimizer (ablation studies swap configured variants).
    pub fn set_optimizer(&mut self, opt: Box<dyn Optimizer>) {
        if let EngineState::Native { optimizer, .. } = &mut self.engine {
            *optimizer = opt;
        }
    }

    /// The optimizer (native engine only).
    pub fn optimizer(&self) -> Option<&dyn Optimizer> {
        match &self.engine {
            EngineState::Native { optimizer, .. } => Some(optimizer.as_ref()),
            _ => None,
        }
    }

    /// Mutable optimizer access (native engine only) — checkpoint
    /// restore imports exported state through this.
    pub fn optimizer_mut(&mut self) -> Option<&mut dyn Optimizer> {
        match &mut self.engine {
            EngineState::Native { optimizer, .. } => Some(optimizer.as_mut()),
            _ => None,
        }
    }

    /// Replace the native model (finetuning warm starts). No-op on the
    /// PJRT engine.
    pub fn set_model(&mut self, m: Mlp) {
        if let EngineState::Native { model, .. } = &mut self.engine {
            *model = m;
        }
    }

    /// Total optimizer steps this config will take.
    pub fn total_steps(&self) -> u64 {
        let per_epoch = self.dataset.train.len().div_ceil(self.cfg.batch_size) as u64;
        let by_epochs = per_epoch * self.cfg.epochs as u64;
        self.cfg.max_steps.map_or(by_epochs, |m| m.min(by_epochs).max(1))
    }

    /// Run the full training loop (a thin driver over [`LoopState`] —
    /// the resumable decomposition the `serve` session layer steps
    /// one quantum at a time).
    pub fn run(&mut self) -> Result<Report> {
        let mut lp = LoopState::new(self);
        while !lp.is_done() {
            lp.step_once(self)?;
            // This loop owns its steps, so it drains the health
            // samples the step buffered (the serve session layer does
            // the same for its quanta) into the process-global rings —
            // `eva train` feeds the scrape endpoint without a session.
            let samples = crate::telemetry::health::take_samples();
            crate::telemetry::health::record_global(lp.step(), &samples);
        }
        Ok(lp.report(self))
    }

    /// One optimizer step over the given sample indices.
    fn train_step(&mut self, idx: &[usize], lr: f32, step: u64) -> Result<f32> {
        use crate::telemetry as tm;
        let (x, labels) =
            tm::time_phase("data", &tm::TRAIN_DATA_US, || self.dataset.train.gather(idx));
        match &mut self.engine {
            EngineState::Native { model, optimizer } => {
                let mode = optimizer.stats_mode_at(step);
                let res = tm::time_phase("forward_backward", &tm::TRAIN_FORWARD_BACKWARD_US, || {
                    model.forward_backward(&x, &labels, mode)
                });
                let ctx = StepCtx {
                    params: &model.weights,
                    grads: &res.grads,
                    bias_grads: &res.bias_grads,
                    stats: &res.stats,
                    lr,
                    step,
                };
                let update =
                    tm::time_phase("optimizer", &tm::TRAIN_OPTIMIZER_US, || optimizer.step(&ctx));
                tm::time_phase("apply", &tm::TRAIN_APPLY_US, || {
                    model.apply_update(&update.deltas, &update.bias_deltas)
                });
                Ok(res.loss)
            }
            EngineState::Pjrt { driver } => {
                // Fused artifacts bake the batch size; pad the tail batch
                // by repeating samples (same expectation).
                let b = driver.meta.batch;
                let (xb, yb) = pjrt_batch(&x, &labels, b, driver.meta.dims[driver.meta.dims.len() - 1]);
                driver.hp.lr = lr;
                driver.step(&xb, &yb)
            }
        }
    }

    /// Validation metric: accuracy (classification) or loss (AE).
    pub fn evaluate(&mut self) -> Result<f32> {
        match (&mut self.engine, self.dataset.task) {
            (EngineState::Native { model, .. }, Task::Classification) => {
                Ok(model.accuracy(&self.dataset.val.inputs, &self.dataset.val.labels, 256))
            }
            (EngineState::Native { model, .. }, Task::Autoencoding) => {
                Ok(model.reconstruction_loss(&self.dataset.val.inputs, 256))
            }
            (EngineState::Pjrt { driver }, Task::Classification) => {
                driver.accuracy(&self.dataset.val.inputs, &self.dataset.val.labels)
            }
            (EngineState::Pjrt { .. }, Task::Autoencoding) => {
                Err(anyhow!("pjrt AE evaluation not wired; use native engine"))
            }
        }
    }

    pub fn optimizer_state_bytes(&self) -> usize {
        match &self.engine {
            EngineState::Native { optimizer, .. } => optimizer.state_bytes(),
            EngineState::Pjrt { driver } => driver.optimizer_state_bytes(),
        }
    }
}

// ---------------------------------------------------------------------------
// Resumable loop state
// ---------------------------------------------------------------------------

/// What one [`LoopState::step_once`] call did.
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// Global step count *after* this step.
    pub step: u64,
    /// Training loss of this step's batch.
    pub loss: f32,
    /// `Some(epoch)` when this step closed an epoch (validation ran
    /// and a history entry was recorded).
    pub epoch_closed: Option<usize>,
    /// The epoch's validation metric, when `epoch_closed`.
    pub val_metric: Option<f32>,
    /// True when the run is complete after this step.
    pub done: bool,
}

/// The resumable decomposition of the training loop.
///
/// [`Trainer::run`] used to own a monolithic epoch loop; the loop's
/// entire mutable state now lives here so a run can be advanced one
/// step at a time ([`LoopState::step_once`]), paused between steps,
/// snapshotted ([`LoopState::snapshot`]) and restored
/// ([`LoopState::restore`]) — the substrate of `serve`'s time-sliced
/// sessions. Stepping to completion is **bit-identical** to the old
/// all-at-once loop: batch order, learning rates and epoch boundaries
/// are pure functions of this state.
///
/// `LoopState` deliberately does not own the [`Trainer`]; every method
/// takes it explicitly, so a session can keep the two side by side and
/// checkpoint them together.
pub struct LoopState {
    batcher: Batcher,
    total_steps: u64,
    per_epoch: usize,
    epochs: usize,
    step: u64,
    epoch: usize,
    nsteps_in_epoch: usize,
    loss_sum: f64,
    final_loss: f32,
    best_acc: f32,
    best_loss: f32,
    history: Vec<EpochMetrics>,
    epoch_timer: StepTimer,
    /// Active wall-clock accumulated in the current epoch (pauses
    /// between `step_once` calls are excluded by construction).
    epoch_wall_s: f64,
    total_wall_s: f64,
    done: bool,
}

impl LoopState {
    /// Fresh loop state for `trainer` (step 0, epoch 0).
    pub fn new(trainer: &Trainer) -> Self {
        let total_steps = trainer.total_steps();
        let per_epoch = trainer.dataset.train.len().div_ceil(trainer.cfg.batch_size);
        let batcher = Batcher::new(
            trainer.dataset.train.len(),
            trainer.cfg.batch_size,
            trainer.cfg.seed ^ 0xbeef,
        );
        let epochs = trainer.cfg.epochs;
        LoopState {
            batcher,
            total_steps,
            per_epoch,
            epochs,
            step: 0,
            epoch: 0,
            nsteps_in_epoch: 0,
            loss_sum: 0.0,
            final_loss: f32::NAN,
            best_acc: 0.0,
            best_loss: f32::MAX,
            history: Vec::new(),
            epoch_timer: StepTimer::new(),
            epoch_wall_s: 0.0,
            total_wall_s: 0.0,
            done: total_steps == 0 || epochs == 0,
        }
    }

    /// True once every step has been taken (further `step_once` calls
    /// error).
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Global step counter (steps taken so far).
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Total steps this run will take.
    pub fn total_steps(&self) -> u64 {
        self.total_steps
    }

    /// Current epoch index (0-based; the epoch the *next* step belongs
    /// to).
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Completed-epoch records so far.
    pub fn history(&self) -> &[EpochMetrics] {
        &self.history
    }

    /// Take exactly one optimizer step, closing the epoch (validation +
    /// history entry) when it is the epoch's last.
    pub fn step_once(&mut self, trainer: &mut Trainer) -> Result<StepOutcome> {
        if self.done {
            return Err(anyhow!("training loop already finished"));
        }
        crate::telemetry::begin_step();
        let wall0 = std::time::Instant::now();
        let lr = trainer.cfg.lr_schedule.lr_at(
            trainer.cfg.base_lr,
            self.step,
            self.total_steps,
            trainer.cfg.warmup_steps,
        );
        let idx = self.batcher.next_indices().to_vec();
        let t0 = std::time::Instant::now();
        let loss = trainer.train_step(&idx, lr, self.step)?;
        self.epoch_timer.record(t0.elapsed());
        if crate::telemetry::health::due(self.step) {
            // Sampled loss series for the spike-anomaly rule
            // (read-only; numerics untouched).
            crate::telemetry::health::sample("train", "loss", loss as f64);
        }
        self.loss_sum += loss as f64;
        self.nsteps_in_epoch += 1;
        self.step += 1;
        self.final_loss = loss;
        let mut outcome = StepOutcome {
            step: self.step,
            loss,
            epoch_closed: None,
            val_metric: None,
            done: false,
        };
        if self.nsteps_in_epoch >= self.per_epoch || self.step >= self.total_steps {
            let val_metric = crate::telemetry::time_phase(
                "eval",
                &crate::telemetry::TRAIN_EVAL_US,
                || trainer.evaluate(),
            )?;
            match trainer.dataset.task {
                Task::Classification => self.best_acc = self.best_acc.max(val_metric),
                Task::Autoencoding => self.best_loss = self.best_loss.min(val_metric),
            }
            let epoch_wall = self.epoch_wall_s + wall0.elapsed().as_secs_f64();
            self.history.push(EpochMetrics {
                epoch: self.epoch,
                train_loss: (self.loss_sum / self.nsteps_in_epoch.max(1) as f64) as f32,
                val_metric,
                wall_time_s: epoch_wall,
                mean_step_ms: self.epoch_timer.mean_ms(),
            });
            outcome.epoch_closed = Some(self.epoch);
            outcome.val_metric = Some(val_metric);
            self.epoch += 1;
            self.nsteps_in_epoch = 0;
            self.loss_sum = 0.0;
            self.epoch_timer = StepTimer::new();
            self.epoch_wall_s = 0.0;
            if self.step >= self.total_steps || self.epoch >= self.epochs {
                self.done = true;
                outcome.done = true;
            }
        } else {
            self.epoch_wall_s += wall0.elapsed().as_secs_f64();
        }
        let wall = wall0.elapsed();
        self.total_wall_s += wall.as_secs_f64();
        if crate::telemetry::enabled() {
            crate::telemetry::TRAIN_STEPS.add(1);
            crate::telemetry::TRAIN_STEP_US.record_us(wall.as_micros() as u64);
        }
        Ok(outcome)
    }

    /// Build the final [`Report`] (valid at any point; `steps` and
    /// `history` reflect progress so far).
    pub fn report(&self, trainer: &Trainer) -> Report {
        let mean_step_ms = if self.history.is_empty() {
            0.0
        } else {
            self.history.iter().map(|h| h.mean_step_ms).sum::<f64>() / self.history.len() as f64
        };
        Report {
            config_name: trainer.cfg.name.clone(),
            optimizer: trainer.cfg.optim.algorithm.clone(),
            final_loss: self.final_loss,
            best_val_acc: self.best_acc,
            best_val_loss: self.best_loss,
            history: self.history.clone(),
            total_time_s: self.total_wall_s,
            mean_step_ms,
            optimizer_state_bytes: trainer.optimizer_state_bytes(),
            steps: self.step,
        }
    }

    /// Capture the loop's exact state for checkpointing. The restored
    /// loop replays the identical batch/LR stream; only the in-flight
    /// epoch's timing samples are dropped (timing is informational).
    pub fn snapshot(&self) -> LoopSnapshot {
        LoopSnapshot {
            batcher: self.batcher.snapshot(),
            step: self.step,
            epoch: self.epoch as u64,
            nsteps_in_epoch: self.nsteps_in_epoch as u64,
            loss_sum: self.loss_sum,
            final_loss: self.final_loss,
            best_acc: self.best_acc,
            best_loss: self.best_loss,
            epoch_wall_s: self.epoch_wall_s,
            total_wall_s: self.total_wall_s,
            history: self.history.clone(),
        }
    }

    /// Rebuild loop state from a snapshot taken against an equivalently
    /// configured trainer (inverse of [`LoopState::snapshot`]).
    pub fn restore(trainer: &Trainer, s: &LoopSnapshot) -> Result<Self, String> {
        let fresh = LoopState::new(trainer);
        if s.step > fresh.total_steps {
            return Err(format!(
                "loop snapshot at step {} exceeds configured total {}",
                s.step, fresh.total_steps
            ));
        }
        let epoch = s.epoch as usize;
        if epoch > fresh.epochs {
            return Err(format!("loop snapshot at epoch {epoch} exceeds {}", fresh.epochs));
        }
        let done = s.step >= fresh.total_steps || epoch >= fresh.epochs;
        Ok(LoopState {
            batcher: Batcher::restore(&s.batcher)?,
            total_steps: fresh.total_steps,
            per_epoch: fresh.per_epoch,
            epochs: fresh.epochs,
            step: s.step,
            epoch,
            nsteps_in_epoch: s.nsteps_in_epoch as usize,
            loss_sum: s.loss_sum,
            final_loss: s.final_loss,
            best_acc: s.best_acc,
            best_loss: s.best_loss,
            history: s.history.clone(),
            epoch_timer: StepTimer::new(),
            epoch_wall_s: s.epoch_wall_s,
            total_wall_s: s.total_wall_s,
            done,
        })
    }
}

/// Serializable [`LoopState`] (see [`LoopState::snapshot`]).
#[derive(Clone, Debug)]
pub struct LoopSnapshot {
    /// Mini-batch iterator state.
    pub batcher: BatcherSnapshot,
    /// Global step counter.
    pub step: u64,
    /// Current epoch index.
    pub epoch: u64,
    /// Steps taken inside the current epoch.
    pub nsteps_in_epoch: u64,
    /// Running loss sum of the current epoch.
    pub loss_sum: f64,
    /// Loss of the most recent step.
    pub final_loss: f32,
    /// Best validation accuracy so far (classification).
    pub best_acc: f32,
    /// Best (lowest) validation loss so far (autoencoding).
    pub best_loss: f32,
    /// Active wall-clock accumulated in the current epoch.
    pub epoch_wall_s: f64,
    /// Active wall-clock accumulated over the whole run.
    pub total_wall_s: f64,
    /// Completed-epoch records.
    pub history: Vec<EpochMetrics>,
}

/// Pack a (possibly short) batch into the fixed PJRT batch size with
/// one-hot labels.
fn pjrt_batch(x: &Tensor, labels: &[usize], batch: usize, classes: usize) -> (HostArray, HostArray) {
    let d = x.cols();
    let mut xb = vec![0.0f32; batch * d];
    let mut yb = vec![0.0f32; batch * classes];
    for r in 0..batch {
        let src = r % x.rows();
        xb[r * d..(r + 1) * d].copy_from_slice(x.row(src));
        let c = labels[src].min(classes - 1);
        yb[r * classes + c] = 1.0;
    }
    (HostArray::new(vec![batch, d], xb), HostArray::new(vec![batch, classes], yb))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LrSchedule, ModelArch};

    fn tiny_cfg(optimizer: &str) -> TrainConfig {
        TrainConfig {
            name: format!("test-{optimizer}"),
            dataset: "c10-small".into(),
            seed: 7,
            arch: ModelArch::Classifier { hidden: vec![32] },
            optim: crate::config::OptimConfig {
                algorithm: optimizer.into(),
                hp: crate::optim::HyperParams {
                    weight_decay: 0.0,
                    ..Default::default()
                },
            },
            engine: Engine::Native,
            epochs: 2,
            batch_size: 64,
            base_lr: if optimizer == "sgd" { 0.1 } else { 0.05 },
            lr_schedule: LrSchedule::Cosine,
            warmup_steps: 0,
            max_steps: Some(40),
            eval_every: 1,
            backend: None,
            worker_threads: None,
            simd: None,
            telemetry: None,
        }
    }

    #[test]
    fn native_training_learns_all_optimizers() {
        // Every optimizer must beat chance (10%) within 40 steps on the
        // easy synthetic task — integration over data+nn+optim+train.
        for opt in ["sgd", "eva", "eva-f", "eva-s", "kfac", "foof", "shampoo", "adam"] {
            let mut t = Trainer::from_config(&tiny_cfg(opt)).unwrap();
            let report = t.run().unwrap();
            assert!(
                report.best_val_acc > 0.3,
                "{opt}: acc {} loss {}",
                report.best_val_acc,
                report.final_loss
            );
            assert!(report.steps == 40);
            assert!(report.optimizer_state_bytes > 0 || opt == "sgd");
        }
    }

    #[test]
    fn step_once_matches_monolithic_run_exactly() {
        // Driving the loop one step at a time must reproduce run()
        // bit-for-bit: same weights, same history, same step count.
        let cfg = tiny_cfg("eva");
        let mut a = Trainer::from_config(&cfg).unwrap();
        let ra = a.run().unwrap();
        let mut b = Trainer::from_config(&cfg).unwrap();
        let mut lp = LoopState::new(&b);
        let mut outcomes = 0;
        while !lp.is_done() {
            let o = lp.step_once(&mut b).unwrap();
            assert_eq!(o.step, lp.step());
            outcomes += 1;
        }
        assert!(lp.step_once(&mut b).is_err(), "done loop must refuse to step");
        let rb = lp.report(&b);
        assert_eq!(outcomes as u64, ra.steps);
        assert_eq!(rb.steps, ra.steps);
        assert_eq!(rb.history.len(), ra.history.len());
        for (ha, hb) in ra.history.iter().zip(&rb.history) {
            assert_eq!(ha.epoch, hb.epoch);
            assert_eq!(ha.train_loss.to_bits(), hb.train_loss.to_bits());
            assert_eq!(ha.val_metric.to_bits(), hb.val_metric.to_bits());
        }
        let (wa, wb) = (a.model().unwrap(), b.model().unwrap());
        for (ta, tb) in wa.weights.iter().zip(&wb.weights) {
            assert_eq!(ta.data(), tb.data());
        }
    }

    #[test]
    fn loop_snapshot_restore_resumes_identically() {
        let cfg = tiny_cfg("sgd");
        let mut a = Trainer::from_config(&cfg).unwrap();
        let mut lp = LoopState::new(&a);
        for _ in 0..17 {
            lp.step_once(&mut a).unwrap();
        }
        let snap = lp.snapshot();
        let restored = LoopState::restore(&a, &snap).unwrap();
        assert_eq!(restored.step(), 17);
        assert_eq!(restored.epoch(), lp.epoch());
        assert!(!restored.is_done());
        // A snapshot past the configured budget is rejected.
        let mut bad = snap.clone();
        bad.step = 10_000;
        assert!(LoopState::restore(&a, &bad).is_err());
    }

    #[test]
    fn autoencoder_loss_decreases() {
        let mut cfg = tiny_cfg("eva");
        cfg.dataset = "curves".into();
        cfg.arch = ModelArch::AutoencoderSmall;
        cfg.max_steps = Some(30);
        cfg.base_lr = 0.03;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let r = t.run().unwrap();
        assert!(r.history.len() >= 1);
        assert!(r.best_val_loss < f32::MAX);
        assert!(r.final_loss.is_finite());
    }

    #[test]
    fn time_to_accuracy_reports_cumulative() {
        let h = |e, acc, t| EpochMetrics {
            epoch: e,
            train_loss: 1.0,
            val_metric: acc,
            wall_time_s: t,
            mean_step_ms: 1.0,
        };
        let r = Report {
            config_name: "x".into(),
            optimizer: "sgd".into(),
            final_loss: 0.5,
            best_val_acc: 0.8,
            best_val_loss: f32::MAX,
            history: vec![h(0, 0.5, 1.0), h(1, 0.7, 1.0), h(2, 0.9, 1.0)],
            total_time_s: 3.0,
            mean_step_ms: 1.0,
            optimizer_state_bytes: 0,
            steps: 3,
        };
        assert_eq!(r.time_to_accuracy(0.7).unwrap().0, 1);
        assert!((r.time_to_accuracy(0.9).unwrap().1 - 3.0).abs() < 1e-9);
        assert!(r.time_to_accuracy(0.99).is_none());
    }
}
