//! The training driver: ties datasets, models, optimizers and engines
//! together, with metrics and CSV logging.
//!
//! [`Trainer`] is the single-process path used by every experiment in
//! `exp/` (native engine) and by the quickstart (either engine).
//! Multi-worker data parallelism lives in `coordinator`.

mod metrics;

pub use metrics::{Metrics, StepTimer};

use anyhow::{anyhow, Result};

use crate::config::{Engine, TrainConfig};
use crate::data::{by_name, Batcher, Dataset, Task};
use crate::nn::{Mlp, StatsMode};
use crate::optim::{by_name as optim_by_name, Optimizer, StepCtx};
use crate::runtime::{HostArray, Runtime, StepDriver, StepHp, StepKind};
use crate::tensor::Tensor;

/// Per-epoch record.
#[derive(Clone, Debug)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub train_loss: f32,
    pub val_metric: f32, // accuracy for classification, loss for AE
    pub wall_time_s: f64,
    pub mean_step_ms: f64,
}

/// Final run report.
#[derive(Clone, Debug)]
pub struct Report {
    pub config_name: String,
    pub optimizer: String,
    pub final_loss: f32,
    /// Best validation accuracy (classification) — 0 for AE runs.
    pub best_val_acc: f32,
    /// Best (lowest) validation loss (AE) — f32::MAX for classification.
    pub best_val_loss: f32,
    pub history: Vec<EpochMetrics>,
    pub total_time_s: f64,
    pub mean_step_ms: f64,
    pub optimizer_state_bytes: usize,
    pub steps: u64,
}

impl Report {
    /// First epoch at which validation accuracy reached `target`
    /// (classification), with the cumulative wall-clock time.
    pub fn time_to_accuracy(&self, target: f32) -> Option<(usize, f64)> {
        let mut t = 0.0;
        for e in &self.history {
            t += e.wall_time_s;
            if e.val_metric >= target {
                return Some((e.epoch, t));
            }
        }
        None
    }
}

/// Single-process trainer.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub dataset: Dataset,
    engine: EngineState,
}

enum EngineState {
    Native { model: Mlp, optimizer: Box<dyn Optimizer> },
    Pjrt { driver: StepDriver },
}

impl Trainer {
    pub fn from_config(cfg: &TrainConfig) -> Result<Self> {
        if let Some(spec) = &cfg.backend {
            let choice =
                crate::backend::BackendChoice::parse(spec).map_err(|e| anyhow!(e))?;
            crate::backend::install(&choice);
        }
        if cfg.worker_threads.is_some() {
            // Flows into every DataParallelCfg built afterwards (the
            // coordinator runs in-process; see coordinator::dp).
            crate::coordinator::dp::set_default_worker_threads(cfg.worker_threads);
        }
        let dataset = by_name(&cfg.dataset, cfg.seed).map_err(|e| anyhow!(e))?;
        let engine = match &cfg.engine {
            Engine::Native => {
                let spec = cfg.arch.to_spec(dataset.input_dim(), dataset.num_classes);
                let model = Mlp::init(spec, cfg.seed.wrapping_add(1));
                let optimizer =
                    optim_by_name(&cfg.optim.algorithm, &cfg.optim.hp).map_err(|e| anyhow!(e))?;
                EngineState::Native { model, optimizer }
            }
            Engine::Pjrt { model } => {
                let mut rt = Runtime::open_default()?;
                let kind = match cfg.optim.algorithm.as_str() {
                    "eva" => StepKind::Eva,
                    "sgd" => StepKind::Sgd,
                    other => {
                        return Err(anyhow!("pjrt engine supports eva|sgd, not '{other}'"))
                    }
                };
                let hp = StepHp {
                    lr: cfg.base_lr,
                    gamma: cfg.optim.hp.damping,
                    xi: cfg.optim.hp.running_avg,
                    kappa: cfg.optim.hp.kl_clip,
                    momentum: cfg.optim.hp.momentum,
                    weight_decay: cfg.optim.hp.weight_decay,
                };
                let driver = StepDriver::new(&mut rt, model, kind, hp, cfg.seed)?;
                // The runtime must outlive the driver's executables; the
                // executables are Rc-shared, and the client lives inside
                // them via PJRT refcounting, so dropping `rt` is fine.
                EngineState::Pjrt { driver }
            }
        };
        Ok(Trainer { cfg: cfg.clone(), dataset, engine })
    }

    /// The model (native engine only).
    pub fn model(&self) -> Option<&Mlp> {
        match &self.engine {
            EngineState::Native { model, .. } => Some(model),
            _ => None,
        }
    }

    /// Replace the optimizer (ablation studies swap configured variants).
    pub fn set_optimizer(&mut self, opt: Box<dyn Optimizer>) {
        if let EngineState::Native { optimizer, .. } = &mut self.engine {
            *optimizer = opt;
        }
    }

    /// Replace the native model (finetuning warm starts). No-op on the
    /// PJRT engine.
    pub fn set_model(&mut self, m: Mlp) {
        if let EngineState::Native { model, .. } = &mut self.engine {
            *model = m;
        }
    }

    /// Total optimizer steps this config will take.
    pub fn total_steps(&self) -> u64 {
        let per_epoch = self.dataset.train.len().div_ceil(self.cfg.batch_size) as u64;
        let by_epochs = per_epoch * self.cfg.epochs as u64;
        self.cfg.max_steps.map_or(by_epochs, |m| m.min(by_epochs).max(1))
    }

    /// Run the full training loop.
    pub fn run(&mut self) -> Result<Report> {
        let total_steps = self.total_steps();
        let per_epoch = self.dataset.train.len().div_ceil(self.cfg.batch_size);
        let mut batcher =
            Batcher::new(self.dataset.train.len(), self.cfg.batch_size, self.cfg.seed ^ 0xbeef);
        let mut history = Vec::new();
        let mut step: u64 = 0;
        let mut final_loss = f32::NAN;
        let (mut best_acc, mut best_loss) = (0.0f32, f32::MAX);
        let run_start = std::time::Instant::now();
        for epoch in 0..self.cfg.epochs {
            let epoch_start = std::time::Instant::now();
            let mut loss_sum = 0.0f64;
            let mut nsteps = 0usize;
            let mut step_timer = StepTimer::new();
            let budget_hit = loop {
                if nsteps >= per_epoch {
                    break false;
                }
                if step >= total_steps {
                    break true;
                }
                let lr = self.cfg.lr_schedule.lr_at(
                    self.cfg.base_lr,
                    step,
                    total_steps,
                    self.cfg.warmup_steps,
                );
                let idx = batcher.next_indices().to_vec();
                let t0 = std::time::Instant::now();
                let loss = self.train_step(&idx, lr, step)?;
                step_timer.record(t0.elapsed());
                loss_sum += loss as f64;
                nsteps += 1;
                step += 1;
                final_loss = loss;
            };
            // Record the epoch (including a partial epoch cut short by
            // max_steps) so reports always carry at least one entry.
            if nsteps > 0 || !budget_hit {
                let val_metric = self.evaluate()?;
                match self.dataset.task {
                    Task::Classification => best_acc = best_acc.max(val_metric),
                    Task::Autoencoding => best_loss = best_loss.min(val_metric),
                }
                history.push(EpochMetrics {
                    epoch,
                    train_loss: (loss_sum / nsteps.max(1) as f64) as f32,
                    val_metric,
                    wall_time_s: epoch_start.elapsed().as_secs_f64(),
                    mean_step_ms: step_timer.mean_ms(),
                });
            }
            if budget_hit {
                break;
            }
        }
        let mean_step_ms = if history.is_empty() {
            0.0
        } else {
            history.iter().map(|h| h.mean_step_ms).sum::<f64>() / history.len() as f64
        };
        Ok(Report {
            config_name: self.cfg.name.clone(),
            optimizer: self.cfg.optim.algorithm.clone(),
            final_loss,
            best_val_acc: best_acc,
            best_val_loss: best_loss,
            history,
            total_time_s: run_start.elapsed().as_secs_f64(),
            mean_step_ms,
            optimizer_state_bytes: self.optimizer_state_bytes(),
            steps: step,
        })
    }

    /// One optimizer step over the given sample indices.
    fn train_step(&mut self, idx: &[usize], lr: f32, step: u64) -> Result<f32> {
        let (x, labels) = self.dataset.train.gather(idx);
        match &mut self.engine {
            EngineState::Native { model, optimizer } => {
                let mode = optimizer.stats_mode_at(step);
                let res = model.forward_backward(&x, &labels, mode);
                let ctx = StepCtx {
                    params: &model.weights,
                    grads: &res.grads,
                    bias_grads: &res.bias_grads,
                    stats: &res.stats,
                    lr,
                    step,
                };
                let update = optimizer.step(&ctx);
                model.apply_update(&update.deltas, &update.bias_deltas);
                Ok(res.loss)
            }
            EngineState::Pjrt { driver } => {
                // Fused artifacts bake the batch size; pad the tail batch
                // by repeating samples (same expectation).
                let b = driver.meta.batch;
                let (xb, yb) = pjrt_batch(&x, &labels, b, driver.meta.dims[driver.meta.dims.len() - 1]);
                driver.hp.lr = lr;
                driver.step(&xb, &yb)
            }
        }
    }

    /// Validation metric: accuracy (classification) or loss (AE).
    pub fn evaluate(&mut self) -> Result<f32> {
        match (&mut self.engine, self.dataset.task) {
            (EngineState::Native { model, .. }, Task::Classification) => {
                Ok(model.accuracy(&self.dataset.val.inputs, &self.dataset.val.labels, 256))
            }
            (EngineState::Native { model, .. }, Task::Autoencoding) => {
                Ok(model.reconstruction_loss(&self.dataset.val.inputs, 256))
            }
            (EngineState::Pjrt { driver }, Task::Classification) => {
                driver.accuracy(&self.dataset.val.inputs, &self.dataset.val.labels)
            }
            (EngineState::Pjrt { .. }, Task::Autoencoding) => {
                Err(anyhow!("pjrt AE evaluation not wired; use native engine"))
            }
        }
    }

    pub fn optimizer_state_bytes(&self) -> usize {
        match &self.engine {
            EngineState::Native { optimizer, .. } => optimizer.state_bytes(),
            EngineState::Pjrt { driver } => driver.optimizer_state_bytes(),
        }
    }
}

/// Pack a (possibly short) batch into the fixed PJRT batch size with
/// one-hot labels.
fn pjrt_batch(x: &Tensor, labels: &[usize], batch: usize, classes: usize) -> (HostArray, HostArray) {
    let d = x.cols();
    let mut xb = vec![0.0f32; batch * d];
    let mut yb = vec![0.0f32; batch * classes];
    for r in 0..batch {
        let src = r % x.rows();
        xb[r * d..(r + 1) * d].copy_from_slice(x.row(src));
        let c = labels[src].min(classes - 1);
        yb[r * classes + c] = 1.0;
    }
    (HostArray::new(vec![batch, d], xb), HostArray::new(vec![batch, classes], yb))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LrSchedule, ModelArch};

    fn tiny_cfg(optimizer: &str) -> TrainConfig {
        TrainConfig {
            name: format!("test-{optimizer}"),
            dataset: "c10-small".into(),
            seed: 7,
            arch: ModelArch::Classifier { hidden: vec![32] },
            optim: crate::config::OptimConfig {
                algorithm: optimizer.into(),
                hp: crate::optim::HyperParams {
                    weight_decay: 0.0,
                    ..Default::default()
                },
            },
            engine: Engine::Native,
            epochs: 2,
            batch_size: 64,
            base_lr: if optimizer == "sgd" { 0.1 } else { 0.05 },
            lr_schedule: LrSchedule::Cosine,
            warmup_steps: 0,
            max_steps: Some(40),
            eval_every: 1,
            backend: None,
            worker_threads: None,
        }
    }

    #[test]
    fn native_training_learns_all_optimizers() {
        // Every optimizer must beat chance (10%) within 40 steps on the
        // easy synthetic task — integration over data+nn+optim+train.
        for opt in ["sgd", "eva", "eva-f", "eva-s", "kfac", "foof", "shampoo", "adam"] {
            let mut t = Trainer::from_config(&tiny_cfg(opt)).unwrap();
            let report = t.run().unwrap();
            assert!(
                report.best_val_acc > 0.3,
                "{opt}: acc {} loss {}",
                report.best_val_acc,
                report.final_loss
            );
            assert!(report.steps == 40);
            assert!(report.optimizer_state_bytes > 0 || opt == "sgd");
        }
    }

    #[test]
    fn autoencoder_loss_decreases() {
        let mut cfg = tiny_cfg("eva");
        cfg.dataset = "curves".into();
        cfg.arch = ModelArch::AutoencoderSmall;
        cfg.max_steps = Some(30);
        cfg.base_lr = 0.03;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let r = t.run().unwrap();
        assert!(r.history.len() >= 1);
        assert!(r.best_val_loss < f32::MAX);
        assert!(r.final_loss.is_finite());
    }

    #[test]
    fn time_to_accuracy_reports_cumulative() {
        let h = |e, acc, t| EpochMetrics {
            epoch: e,
            train_loss: 1.0,
            val_metric: acc,
            wall_time_s: t,
            mean_step_ms: 1.0,
        };
        let r = Report {
            config_name: "x".into(),
            optimizer: "sgd".into(),
            final_loss: 0.5,
            best_val_acc: 0.8,
            best_val_loss: f32::MAX,
            history: vec![h(0, 0.5, 1.0), h(1, 0.7, 1.0), h(2, 0.9, 1.0)],
            total_time_s: 3.0,
            mean_step_ms: 1.0,
            optimizer_state_bytes: 0,
            steps: 3,
        };
        assert_eq!(r.time_to_accuracy(0.7).unwrap().0, 1);
        assert!((r.time_to_accuracy(0.9).unwrap().1 - 3.0).abs() < 1e-9);
        assert!(r.time_to_accuracy(0.99).is_none());
    }
}
