//! Step timing + CSV metric sinks.

use std::time::Duration;

/// Collects per-step wall times and reports summary statistics.
#[derive(Clone, Debug, Default)]
pub struct StepTimer {
    samples_us: Vec<u64>,
}

impl StepTimer {
    pub fn new() -> Self {
        StepTimer { samples_us: Vec::new() }
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64 / 1000.0
    }

    /// p-th percentile in milliseconds (p in [0, 100]).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_us.clone();
        s.sort_unstable();
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)] as f64 / 1000.0
    }

    /// Fold another timer's samples into this one (the serve stats
    /// endpoint aggregates per-session timers this way).
    pub fn merge(&mut self, other: &StepTimer) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    /// Mean excluding the first `k` warmup samples (JIT/caches).
    pub fn steady_mean_ms(&self, k: usize) -> f64 {
        if self.samples_us.len() <= k {
            return self.mean_ms();
        }
        let s = &self.samples_us[k..];
        s.iter().sum::<u64>() as f64 / s.len() as f64 / 1000.0
    }
}

/// Minimal CSV writer for experiment outputs (plotted offline).
pub struct Metrics {
    path: std::path::PathBuf,
    rows: Vec<String>,
    header: String,
}

impl Metrics {
    pub fn new(path: impl Into<std::path::PathBuf>, header: &str) -> Self {
        Metrics { path: path.into(), rows: Vec::new(), header: header.to_string() }
    }

    pub fn row(&mut self, values: &[String]) {
        self.rows.push(values.join(","));
    }

    pub fn rowf(&mut self, values: &[f64]) {
        self.rows
            .push(values.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(","));
    }

    /// Write the CSV to disk (creates parent dirs).
    pub fn flush(&self) -> std::io::Result<()> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::with_capacity(self.rows.len() * 32);
        out.push_str(&self.header);
        out.push('\n');
        for r in &self.rows {
            out.push_str(r);
            out.push('\n');
        }
        std::fs::write(&self.path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_stats() {
        let mut t = StepTimer::new();
        for ms in [1u64, 2, 3, 4, 100] {
            t.record(Duration::from_millis(ms));
        }
        assert_eq!(t.count(), 5);
        assert!((t.mean_ms() - 22.0).abs() < 0.5);
        assert!(t.percentile_ms(50.0) <= 4.0);
        // Excluding the 1ms warmup sample.
        assert!(t.steady_mean_ms(1) > t.percentile_ms(50.0));
    }

    #[test]
    fn csv_writes() {
        let dir = std::env::temp_dir().join("eva-test-metrics");
        let path = dir.join("m.csv");
        let mut m = Metrics::new(&path, "a,b");
        m.rowf(&[1.0, 2.0]);
        m.row(&["x".into(), "y".into()]);
        m.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\nx,y\n");
        let _ = std::fs::remove_dir_all(dir);
    }
}
