//! Step timing + CSV metric sinks.

use std::time::Duration;

/// The first `WARM_CAP` samples are kept verbatim so
/// [`StepTimer::steady_mean_ms`] can exclude warmup exactly.
const WARM_CAP: usize = 64;

/// At most this many recent samples back the percentile estimates.
const RING_CAP: usize = 512;

/// Collects per-step wall times and reports summary statistics.
///
/// Memory is **bounded** regardless of how long the run is (a
/// long-lived serve session records one sample per step forever):
/// the exact sample `count` and sum (hence an exact [`mean_ms`])
/// are kept as scalars, the first [`WARM_CAP`] samples are retained
/// verbatim for warmup-exclusion, and percentiles come from a ring
/// of the most recent [`RING_CAP`] samples — so
/// [`percentile_ms`](StepTimer::percentile_ms) reflects *current*
/// step latency, is O([`RING_CAP`] log [`RING_CAP`]) to compute, and
/// is exact whenever the timer holds at most [`RING_CAP`] samples.
///
/// [`mean_ms`]: StepTimer::mean_ms
#[derive(Clone, Debug, Default)]
pub struct StepTimer {
    count: u64,
    sum_us: u64,
    /// First `WARM_CAP` samples ever recorded (exact warmup record).
    warm: Vec<u64>,
    /// Most recent `RING_CAP` samples; wraps at `pos` once full.
    ring: Vec<u64>,
    pos: usize,
}

impl StepTimer {
    pub fn new() -> Self {
        StepTimer::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    fn record_us(&mut self, us: u64) {
        self.count += 1;
        self.sum_us += us;
        if self.warm.len() < WARM_CAP {
            self.warm.push(us);
        }
        if self.ring.len() < RING_CAP {
            self.ring.push(us);
        } else {
            self.ring[self.pos] = us;
            self.pos = (self.pos + 1) % RING_CAP;
        }
    }

    /// Exact number of samples ever recorded.
    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Number of samples currently retained for percentile estimates
    /// (bounded by the ring capacity).
    pub fn retained(&self) -> usize {
        self.ring.len()
    }

    /// The ring capacity: percentiles are exact up to this many
    /// samples, then reflect the most recent window of this size.
    pub const fn sample_capacity() -> usize {
        RING_CAP
    }

    /// Exact mean over *all* recorded samples, in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64 / 1000.0
    }

    /// p-th percentile in milliseconds (p in [0, 100]) over the
    /// retained recent-sample window — exact while at most
    /// [`StepTimer::sample_capacity`] samples were recorded,
    /// an approximation of recent latency afterwards.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.ring.is_empty() {
            return 0.0;
        }
        let mut s = self.ring.clone();
        s.sort_unstable();
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)] as f64 / 1000.0
    }

    /// Fold another timer into this one (the serve stats endpoint
    /// aggregates per-session timers this way). Count and mean stay
    /// exact; the percentile windows combine by an even-stride
    /// subsample when the merged window overflows the ring, so both
    /// sides stay represented.
    pub fn merge(&mut self, other: &StepTimer) {
        self.count += other.count;
        self.sum_us += other.sum_us;
        for &us in &other.warm {
            if self.warm.len() >= WARM_CAP {
                break;
            }
            self.warm.push(us);
        }
        if other.ring.is_empty() {
            return;
        }
        let mut combined = self.window();
        combined.extend(other.window());
        if combined.len() > RING_CAP {
            combined = (0..RING_CAP)
                .map(|i| combined[i * combined.len() / RING_CAP])
                .collect();
        }
        self.ring = combined;
        self.pos = 0;
    }

    /// The retained samples in chronological order.
    fn window(&self) -> Vec<u64> {
        if self.ring.len() < RING_CAP {
            self.ring.clone()
        } else {
            let mut w = Vec::with_capacity(RING_CAP);
            w.extend_from_slice(&self.ring[self.pos..]);
            w.extend_from_slice(&self.ring[..self.pos]);
            w
        }
    }

    /// Mean excluding the first `k` warmup samples (JIT/caches).
    /// Exact for `k` up to the retained warmup record (the first 64
    /// samples); larger `k` clamps to that record.
    pub fn steady_mean_ms(&self, k: usize) -> f64 {
        if self.count as usize <= k {
            return self.mean_ms();
        }
        let k = k.min(self.warm.len());
        let warm_sum: u64 = self.warm[..k].iter().sum();
        (self.sum_us - warm_sum) as f64 / (self.count - k as u64) as f64 / 1000.0
    }
}

/// Minimal CSV writer for experiment outputs (plotted offline).
pub struct Metrics {
    path: std::path::PathBuf,
    rows: Vec<String>,
    header: String,
}

impl Metrics {
    pub fn new(path: impl Into<std::path::PathBuf>, header: &str) -> Self {
        Metrics { path: path.into(), rows: Vec::new(), header: header.to_string() }
    }

    pub fn row(&mut self, values: &[String]) {
        self.rows.push(values.join(","));
    }

    pub fn rowf(&mut self, values: &[f64]) {
        self.rows
            .push(values.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(","));
    }

    /// Write the CSV to disk (creates parent dirs).
    pub fn flush(&self) -> std::io::Result<()> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::with_capacity(self.rows.len() * 32);
        out.push_str(&self.header);
        out.push('\n');
        for r in &self.rows {
            out.push_str(r);
            out.push('\n');
        }
        std::fs::write(&self.path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_stats() {
        let mut t = StepTimer::new();
        for ms in [1u64, 2, 3, 4, 100] {
            t.record(Duration::from_millis(ms));
        }
        assert_eq!(t.count(), 5);
        assert!((t.mean_ms() - 22.0).abs() < 0.5);
        assert!(t.percentile_ms(50.0) <= 4.0);
        // Excluding the 1ms warmup sample.
        assert!(t.steady_mean_ms(1) > t.percentile_ms(50.0));
    }

    #[test]
    fn timer_empty_and_single_sample() {
        let t = StepTimer::new();
        assert_eq!(t.count(), 0);
        assert_eq!(t.mean_ms(), 0.0);
        assert_eq!(t.percentile_ms(50.0), 0.0);
        assert_eq!(t.steady_mean_ms(3), 0.0);
        let mut t = StepTimer::new();
        t.record(Duration::from_millis(7));
        assert_eq!(t.count(), 1);
        assert!((t.mean_ms() - 7.0).abs() < 1e-9);
        for p in [0.0, 50.0, 100.0] {
            assert!((t.percentile_ms(p) - 7.0).abs() < 1e-9, "p{p}");
        }
        // k >= count falls back to the overall mean.
        assert!((t.steady_mean_ms(1) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn timer_percentiles_stay_within_min_max() {
        let mut t = StepTimer::new();
        for ms in [5u64, 1, 9, 3, 7] {
            t.record(Duration::from_millis(ms));
        }
        assert!((t.percentile_ms(0.0) - 1.0).abs() < 1e-9);
        assert!((t.percentile_ms(100.0) - 9.0).abs() < 1e-9);
        for p in [10.0, 25.0, 50.0, 75.0, 90.0] {
            let v = t.percentile_ms(p);
            assert!((1.0..=9.0).contains(&v), "p{p} = {v}");
        }
    }

    #[test]
    fn timer_memory_is_bounded_and_stats_stay_exact() {
        let mut t = StepTimer::new();
        let n = 10_000u64;
        for i in 0..n {
            t.record(Duration::from_micros(i));
        }
        assert_eq!(t.count(), n as usize);
        assert!(t.retained() <= StepTimer::sample_capacity());
        // Exact mean over all n samples: (n-1)/2 µs.
        let want = (n - 1) as f64 / 2.0 / 1000.0;
        assert!((t.mean_ms() - want).abs() < 1e-9);
        // Percentiles reflect the most recent window.
        let p50 = t.percentile_ms(50.0);
        let lo = (n as f64 - StepTimer::sample_capacity() as f64) / 1000.0;
        let hi = n as f64 / 1000.0;
        assert!((lo..=hi).contains(&p50), "recent-window p50 = {p50}");
    }

    #[test]
    fn timer_merge_keeps_count_mean_and_both_windows() {
        let mut a = StepTimer::new();
        let mut b = StepTimer::new();
        for _ in 0..600 {
            a.record(Duration::from_millis(1));
        }
        for _ in 0..600 {
            b.record(Duration::from_millis(9));
        }
        a.merge(&b);
        assert_eq!(a.count(), 1200);
        assert!((a.mean_ms() - 5.0).abs() < 1e-9);
        // Both sides survive the bounded merge: the extremes are both
        // present in the combined window.
        assert!((a.percentile_ms(0.0) - 1.0).abs() < 1e-9);
        assert!((a.percentile_ms(100.0) - 9.0).abs() < 1e-9);
        assert!(a.retained() <= StepTimer::sample_capacity());
        // Merging an empty timer is a no-op on the stats.
        let before = a.percentile_ms(50.0);
        a.merge(&StepTimer::new());
        assert_eq!(a.count(), 1200);
        assert_eq!(a.percentile_ms(50.0), before);
    }

    #[test]
    fn csv_writes() {
        let dir = std::env::temp_dir().join("eva-test-metrics");
        let path = dir.join("m.csv");
        let mut m = Metrics::new(&path, "a,b");
        m.rowf(&[1.0, 2.0]);
        m.row(&["x".into(), "y".into()]);
        m.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\nx,y\n");
        let _ = std::fs::remove_dir_all(dir);
    }
}
