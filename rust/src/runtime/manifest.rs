//! Artifact manifest parsing (written by `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::jsonx::Json;

/// One named array in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct ArraySpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ArraySpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact: HLO file + ordered input/output signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<ArraySpec>,
    pub outputs: Vec<ArraySpec>,
}

/// Model metadata block (mirrors `ModelCfg` in model.py).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub dims: Vec<usize>,
    pub loss: String,
    pub hidden_act: String,
    pub output_act: String,
    pub batch: usize,
    pub num_params: usize,
}

impl ModelMeta {
    pub fn num_layers(&self) -> usize {
        self.dims.len() - 1
    }
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelMeta>,
}

fn parse_arrays(v: &Json) -> Result<Vec<ArraySpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of specs"))?
        .iter()
        .map(|a| {
            let name = a.get_str("name").ok_or_else(|| anyhow!("spec missing name"))?.to_string();
            let shape = a
                .get("shape")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("spec missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            Ok(ArraySpec { name, shape })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let mut artifacts = BTreeMap::new();
        for (k, a) in v.get("artifacts").and_then(|x| x.as_obj()).into_iter().flatten() {
            artifacts.insert(
                k.clone(),
                ArtifactSpec {
                    file: a
                        .get_str("file")
                        .ok_or_else(|| anyhow!("artifact {k} missing file"))?
                        .to_string(),
                    inputs: parse_arrays(a.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
                    outputs: parse_arrays(a.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
                },
            );
        }
        let mut models = BTreeMap::new();
        for (k, m) in v.get("models").and_then(|x| x.as_obj()).into_iter().flatten() {
            models.insert(
                k.clone(),
                ModelMeta {
                    dims: m
                        .get("dims")
                        .and_then(|d| d.as_arr())
                        .ok_or_else(|| anyhow!("model {k} missing dims"))?
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect(),
                    loss: m.get_str("loss").unwrap_or("ce").to_string(),
                    hidden_act: m.get_str("hidden_act").unwrap_or("relu").to_string(),
                    output_act: m.get_str("output_act").unwrap_or("identity").to_string(),
                    batch: m.get_usize("batch").unwrap_or(64),
                    num_params: m.get_usize("num_params").unwrap_or(0),
                },
            );
        }
        Ok(Manifest { artifacts, models })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "m.predict": {
          "file": "m.predict.hlo.txt",
          "inputs": [{"name": "w0", "shape": [4, 3]}, {"name": "x", "shape": [8, 3]}],
          "outputs": [{"name": "out", "shape": [8, 4]}]
        }
      },
      "models": {
        "m": {"dims": [3, 4], "loss": "ce", "hidden_act": "relu",
               "output_act": "identity", "batch": 8, "num_params": 16}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["m.predict"];
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![4, 3]);
        assert_eq!(a.outputs[0].numel(), 32);
        assert_eq!(m.models["m"].dims, vec![3, 4]);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Integration-ish: when artifacts were built, validate the file.
        let p = Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.artifacts.contains_key("quickstart.eva_step"));
            assert!(m.models.contains_key("quickstart"));
            let spec = &m.artifacts["quickstart.eva_step"];
            // params(2L) + momentum(2L) + kv(2L) + x, y, hp
            let ll = m.models["quickstart"].num_layers();
            assert_eq!(spec.inputs.len(), 6 * ll + 3);
            assert_eq!(spec.outputs.len(), 6 * ll + 1);
        }
    }
}
