//! Stateful fused-step driver: the optimized training hot path.
//!
//! Owns parameters, momentum and KV state for one model and advances
//! one optimizer step per [`StepDriver::step`] call by executing the
//! fused `<model>.eva_step` (or `<model>.sgd_step`) artifact — forward,
//! backward, Pallas preconditioning, KL clip, momentum and update all
//! inside a single XLA computation.

use std::rc::Rc;

use anyhow::{anyhow, Result};

use super::{Executable, HostArray, ModelMeta, Runtime};
use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// Which fused step graph to drive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    Eva,
    Sgd,
}

impl StepKind {
    fn graph(&self) -> &'static str {
        match self {
            StepKind::Eva => "eva_step",
            StepKind::Sgd => "sgd_step",
        }
    }
}

/// Hyper-parameters packed as the artifact's `hp` input
/// `[lr, gamma, xi, kappa, momentum, weight_decay]`.
#[derive(Clone, Copy, Debug)]
pub struct StepHp {
    pub lr: f32,
    pub gamma: f32,
    pub xi: f32,
    pub kappa: f32,
    pub momentum: f32,
    pub weight_decay: f32,
}

impl Default for StepHp {
    fn default() -> Self {
        StepHp {
            lr: 0.1,
            gamma: 0.03,
            xi: 0.95,
            kappa: 1e-3,
            momentum: 0.9,
            weight_decay: 5e-4,
        }
    }
}

/// Stateful driver over a fused step artifact.
pub struct StepDriver {
    step_exe: Rc<Executable>,
    predict_exe: Rc<Executable>,
    pub meta: ModelMeta,
    pub kind: StepKind,
    pub hp: StepHp,
    /// weights, biases, momentum_w, momentum_b (+ a_bars, b_bars for Eva),
    /// in artifact input order.
    weights: Vec<HostArray>,
    biases: Vec<HostArray>,
    mom_w: Vec<HostArray>,
    mom_b: Vec<HostArray>,
    a_bars: Vec<HostArray>,
    b_bars: Vec<HostArray>,
    pub steps_taken: u64,
}

impl StepDriver {
    /// Build for a manifest model (`"quickstart"`, `"ae-small"`, `"e2e"`),
    /// initializing parameters with the same scheme as `Mlp::init`.
    pub fn new(rt: &mut Runtime, model: &str, kind: StepKind, hp: StepHp, seed: u64) -> Result<Self> {
        let meta = rt
            .manifest()
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model '{model}' not in manifest"))?
            .clone();
        let step_exe = rt.load(&format!("{model}.{}", kind.graph()))?;
        let predict_exe = rt.load(&format!("{model}.predict"))?;
        let mut rng = Pcg64::new(seed, 0x3317);
        let ll = meta.num_layers();
        let relu = meta.hidden_act == "relu";
        let mut weights = Vec::with_capacity(ll);
        let mut biases = Vec::with_capacity(ll);
        for l in 0..ll {
            let (d_in, d_out) = (meta.dims[l], meta.dims[l + 1]);
            let std = if relu { (2.0 / d_in as f32).sqrt() } else { (1.0 / d_in as f32).sqrt() };
            let mut w = vec![0.0f32; d_out * d_in];
            rng.fill_normal(&mut w, std);
            weights.push(HostArray::new(vec![d_out, d_in], w));
            biases.push(HostArray::zeros(&[d_out]));
        }
        let mom_w = weights.iter().map(|w| HostArray::zeros(&w.shape)).collect();
        let mom_b = biases.iter().map(|b| HostArray::zeros(&b.shape)).collect();
        let a_bars = (0..ll).map(|l| HostArray::zeros(&[meta.dims[l]])).collect();
        let b_bars = (0..ll).map(|l| HostArray::zeros(&[meta.dims[l + 1]])).collect();
        Ok(StepDriver {
            step_exe,
            predict_exe,
            meta,
            kind,
            hp,
            weights,
            biases,
            mom_w,
            mom_b,
            a_bars,
            b_bars,
            steps_taken: 0,
        })
    }

    fn hp_array(&self) -> HostArray {
        HostArray::from_vec1(vec![
            self.hp.lr,
            self.hp.gamma,
            self.hp.xi,
            self.hp.kappa,
            self.hp.momentum,
            self.hp.weight_decay,
        ])
    }

    /// One fused training step. `x` is `(batch, d0)`, `y_onehot`
    /// `(batch, d_last)` (ignored by MSE models). Returns the loss.
    pub fn step(&mut self, x: &HostArray, y_onehot: &HostArray) -> Result<f32> {
        let mut inputs: Vec<HostArray> = Vec::new();
        inputs.extend(self.weights.iter().cloned());
        inputs.extend(self.biases.iter().cloned());
        inputs.extend(self.mom_w.iter().cloned());
        inputs.extend(self.mom_b.iter().cloned());
        if self.kind == StepKind::Eva {
            inputs.extend(self.a_bars.iter().cloned());
            inputs.extend(self.b_bars.iter().cloned());
        }
        inputs.push(x.clone());
        inputs.push(y_onehot.clone());
        inputs.push(self.hp_array());
        let mut out = self.step_exe.run(&inputs)?;
        let loss = out.pop().expect("loss output").scalar_value();
        let ll = self.meta.num_layers();
        // Outputs: w', b', mw', mb' (+ abar', bbar' for Eva).
        let mut it = out.into_iter();
        self.weights = (&mut it).take(ll).collect();
        self.biases = (&mut it).take(ll).collect();
        self.mom_w = (&mut it).take(ll).collect();
        self.mom_b = (&mut it).take(ll).collect();
        if self.kind == StepKind::Eva {
            self.a_bars = (&mut it).take(ll).collect();
            self.b_bars = (&mut it).take(ll).collect();
        }
        self.steps_taken += 1;
        Ok(loss)
    }

    /// Run the predict artifact on one batch.
    pub fn predict(&self, x: &HostArray) -> Result<HostArray> {
        let mut inputs: Vec<HostArray> = Vec::new();
        inputs.extend(self.weights.iter().cloned());
        inputs.extend(self.biases.iter().cloned());
        inputs.push(x.clone());
        Ok(self.predict_exe.run(&inputs)?.pop().expect("predict output"))
    }

    /// Batched top-1 accuracy over a labeled split (classification).
    pub fn accuracy(&self, inputs: &Tensor, labels: &[usize]) -> Result<f32> {
        let batch = self.meta.batch;
        let n = inputs.rows();
        let d = inputs.cols();
        let mut correct = 0usize;
        let mut counted = 0usize;
        let mut i = 0;
        while i + batch <= n {
            let mut xb = vec![0.0f32; batch * d];
            for r in 0..batch {
                xb[r * d..(r + 1) * d].copy_from_slice(inputs.row(i + r));
            }
            let out = self.predict(&HostArray::new(vec![batch, d], xb))?;
            let classes = *out.shape.last().unwrap();
            for r in 0..batch {
                let row = &out.data[r * classes..(r + 1) * classes];
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap();
                if argmax == labels[i + r] {
                    correct += 1;
                }
            }
            counted += batch;
            i += batch;
        }
        Ok(correct as f32 / counted.max(1) as f32)
    }

    /// Export current parameters as tensors (weights only).
    pub fn weights_as_tensors(&self) -> Vec<Tensor> {
        self.weights.iter().map(|w| w.to_tensor()).collect()
    }

    /// Bytes of optimizer state (momentum + KVs) — Table 5 accounting
    /// for the fused path.
    pub fn optimizer_state_bytes(&self) -> usize {
        let mom: usize =
            self.mom_w.iter().chain(&self.mom_b).map(|a| a.data.len()).sum();
        let kv: usize = if self.kind == StepKind::Eva {
            self.a_bars.iter().chain(&self.b_bars).map(|a| a.data.len()).sum()
        } else {
            0
        };
        4 * (mom + kv)
    }
}
