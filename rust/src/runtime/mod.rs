//! PJRT runtime: load and execute the AOT artifacts from the hot path.
//!
//! `make artifacts` (Python, build-time only) writes `artifacts/*.hlo.txt`
//! plus `manifest.json`; this module is everything the self-contained
//! Rust binary needs to run them:
//!
//! * [`Manifest`] — parsed artifact index (names, input/output specs,
//!   model metadata).
//! * [`Runtime`] — a `PjRtClient::cpu()` plus an executable cache:
//!   `HloModuleProto::from_text_file` → `XlaComputation` → `compile`.
//! * [`Executable::run`] — marshals [`HostArray`]s to literals, executes,
//!   and unwraps the result (tuple root only when the graph has >1
//!   output — see `aot.py`).
//! * [`StepDriver`] — stateful wrapper around the fused
//!   `<model>.eva_step` / `<model>.sgd_step` artifacts: owns parameters,
//!   momentum and KV state and advances one optimizer step per call.
//!   This is the paper's optimized hot path: one XLA computation per
//!   training step, Python nowhere in sight.

mod driver;
mod manifest;

pub use driver::{StepDriver, StepHp, StepKind};
pub use manifest::{ArraySpec, ArtifactSpec, Manifest, ModelMeta};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// A host-side array: f32 data + shape (0-, 1- or 2-d in practice).
#[derive(Clone, Debug, PartialEq)]
pub struct HostArray {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostArray {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostArray { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        HostArray { shape: vec![], data: vec![v] }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostArray { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_tensor(t: &crate::tensor::Tensor) -> Self {
        HostArray { shape: vec![t.rows(), t.cols()], data: t.data().to_vec() }
    }

    pub fn from_vec1(v: Vec<f32>) -> Self {
        HostArray { shape: vec![v.len()], data: v }
    }

    /// View as a 2-d tensor (0-/1-d arrays become a single row).
    pub fn to_tensor(&self) -> crate::tensor::Tensor {
        match self.shape.len() {
            0 => crate::tensor::Tensor::from_vec(1, 1, self.data.clone()),
            1 => crate::tensor::Tensor::from_vec(1, self.shape[0], self.data.clone()),
            2 => crate::tensor::Tensor::from_vec(self.shape[0], self.shape[1], self.data.clone()),
            _ => panic!("HostArray rank {} unsupported", self.shape.len()),
        }
    }

    pub fn scalar_value(&self) -> f32 {
        self.data[0]
    }

    /// Reinterpret with an explicit shape (asserts element count).
    pub fn reshaped(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }
}

/// The PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Executable>>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, dir, cache: HashMap::new() })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        Self::open("artifacts")
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load (or fetch from cache) a compiled artifact by manifest key,
    /// e.g. `"quickstart.eva_step"`.
    pub fn load(&mut self, key: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(key) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!("artifact '{key}' not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {key}: {e:?}"))?;
        let exec = std::rc::Rc::new(Executable { exe, spec, key: key.to_string() });
        self.cache.insert(key.to_string(), exec.clone());
        Ok(exec)
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
    key: String,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with host inputs; returns outputs in manifest order.
    pub fn run(&self, inputs: &[HostArray]) -> Result<Vec<HostArray>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.key,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (arr, ispec) in inputs.iter().zip(&self.spec.inputs) {
            if arr.shape != ispec.shape {
                bail!(
                    "{}: input '{}' shape {:?} != expected {:?}",
                    self.key,
                    ispec.name,
                    arr.shape,
                    ispec.shape
                );
            }
            let dims: Vec<i64> = arr.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&arr.data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input '{}': {e:?}", ispec.name))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.key))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {}: {e:?}", self.key))?;
        let outs: Vec<xla::Literal> = if self.spec.outputs.len() > 1 {
            root.to_tuple().map_err(|e| anyhow!("tuple decompose: {e:?}"))?
        } else {
            vec![root]
        };
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.key,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        let mut arrays = Vec::with_capacity(outs.len());
        for (lit, ospec) in outs.iter().zip(&self.spec.outputs) {
            let data: Vec<f32> =
                lit.to_vec().map_err(|e| anyhow!("output '{}': {e:?}", ospec.name))?;
            arrays.push(HostArray::new(ospec.shape.clone(), data));
        }
        Ok(arrays)
    }
}
