//! Dense row-major f32 tensors (substrate).
//!
//! A deliberately small tensor library: just what the native training
//! path, the optimizer zoo, and the linear-algebra substrate need.
//! Matrices are row-major `(rows, cols)`. The matmul family is written
//! as blocked kernels over contiguous rows whose inner loops run on
//! the explicit `f32x8` micro-kernels ([`crate::simd`]) — AVX2/SSE2
//! tiles with a bit-identical scalar fallback; see
//! `rust/benches/simd_kernels.rs` and `docs/KERNELS.md`.
//!
//! Large operations dispatch through [`crate::backend`] (resolved per
//! thread via [`crate::backend::current`]): matmuls and row-wise ops
//! are row-partitioned, elementwise ops are range-partitioned, and
//! reductions ([`dot`], [`Tensor::norm_sq`], [`Tensor::tmatvec`],
//! [`Tensor::mean_rows`]) use a *size-derived* fixed chunk grid so the
//! result is bit-identical under every backend and thread count — and,
//! because every chunk body runs the same fixed 8-lane accumulation
//! tree, under every ISA path too. Small operands always run inline —
//! dispatch overhead is gated by size thresholds, not flags.

#![warn(missing_docs)]

mod matmul;
pub use matmul::{
    matmul, matmul_a_bt, matmul_a_bt_with, matmul_at_b, matmul_at_b_with, matmul_into,
    matmul_into_with, matmul_with,
};

use std::ops::Range;

use crate::backend::{Backend, SendPtr};

/// Elementwise ops below this many elements run inline.
const PAR_ELEM_MIN: usize = 1 << 16;

/// Minimum elements per parallel elementwise chunk.
const ELEM_GRAIN: usize = 4096;

/// Fixed reduction chunk: reductions over `n` elements always use
/// `ceil(n / REDUCE_CHUNK)` partials combined in index order,
/// regardless of backend — the determinism contract.
const REDUCE_CHUNK: usize = 8192;

/// Reductions below this length skip the chunked path entirely.
const PAR_REDUCE_MIN: usize = 1 << 16;

/// Upper bound on partials in the column-reduction grid
/// (`weighted_col_sum_with`): bounds the temporary buffer to
/// `MAX_COL_PARTS · cols` for wide matrices while keeping the grid a
/// pure function of the shape (never of the backend).
const MAX_COL_PARTS: usize = 64;

/// Apply `f` to matching chunk-disjoint sub-slices of `y` and `x`.
fn par_binary(y: &mut [f32], x: &[f32], f: impl Fn(&mut [f32], &[f32]) + Sync) {
    debug_assert_eq!(y.len(), x.len());
    let n = y.len();
    if n < PAR_ELEM_MIN {
        f(y, x);
        return;
    }
    let bk = crate::backend::current();
    let yp = SendPtr(y.as_mut_ptr());
    crate::backend::par_ranges(&*bk, n, ELEM_GRAIN, &|r: Range<usize>| {
        // SAFETY: ranges from par_ranges are disjoint.
        let ys = unsafe { std::slice::from_raw_parts_mut(yp.0.add(r.start), r.len()) };
        f(ys, &x[r]);
    });
}

/// Apply `f` to chunk-disjoint sub-slices of `y`.
fn par_unary(y: &mut [f32], f: impl Fn(&mut [f32]) + Sync) {
    let n = y.len();
    if n < PAR_ELEM_MIN {
        f(y);
        return;
    }
    let bk = crate::backend::current();
    let yp = SendPtr(y.as_mut_ptr());
    crate::backend::par_ranges(&*bk, n, ELEM_GRAIN, &|r: Range<usize>| {
        // SAFETY: ranges from par_ranges are disjoint.
        let ys = unsafe { std::slice::from_raw_parts_mut(yp.0.add(r.start), r.len()) };
        f(ys);
    });
}

/// A dense, row-major matrix of `f32`.
///
/// The name `Tensor` is kept for parity with the paper's notation; all
/// per-layer quantities in Eva/K-FAC are matrices (order-2) after
/// `mat_i` reshaping, which is how Shampoo's tensor case is handled too.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled `(rows, cols)` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Tensor { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(n, n);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// Build from an existing buffer. `data.len()` must equal `rows*cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Tensor { rows, cols, data }
    }

    /// Build from a row-major slice of slices (tests/fixtures).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Tensor { rows: r, cols: c, data }
    }

    /// A column vector from a slice.
    pub fn col_vec(xs: &[f32]) -> Self {
        Tensor { rows: xs.len(), cols: 1, data: xs.to_vec() }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }
    /// True when the matrix holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    /// The row-major element buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    /// Mutable access to the row-major element buffer.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    /// Consume the matrix, returning its row-major buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Immutable row slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Element access (row, col).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Mutable element access (row, col).
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    /// Reinterpret as a `(rows, cols)` matrix with the same element count.
    pub fn reshaped(mut self, rows: usize, cols: usize) -> Self {
        assert_eq!(rows * cols, self.data.len(), "reshape element mismatch");
        self.rows = rows;
        self.cols = cols;
        self
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Self {
        let mut t = Tensor::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise in-place map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32 + Sync) {
        par_unary(&mut self.data, |ys| {
            for v in ys {
                *v = f(*v);
            }
        });
    }

    /// self += alpha * other (same shape).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape(), other.shape());
        par_binary(&mut self.data, &other.data, |ys, xs| {
            crate::simd::axpy8(alpha, xs, ys);
        });
    }

    /// self = beta*self + alpha*other (running averages).
    pub fn blend(&mut self, beta: f32, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape(), other.shape());
        par_binary(&mut self.data, &other.data, |ys, xs| {
            crate::simd::blend8(ys, beta, alpha, xs);
        });
    }

    /// Scale all elements in place.
    pub fn scale(&mut self, s: f32) {
        par_unary(&mut self.data, |ys| {
            crate::simd::scale8(ys, s);
        });
    }

    /// Frobenius inner product <self, other>.
    pub fn dot(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape());
        dot(&self.data, &other.data)
    }

    /// Squared Frobenius norm.
    pub fn norm_sq(&self) -> f32 {
        dot(&self.data, &self.data)
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.norm_sq().sqrt()
    }

    /// Mean over columns: returns a length-`rows` vector (the paper's
    /// `mean-col` used to build KVs from batched activations of shape
    /// `(d, n)`).
    pub fn mean_cols(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows];
        let op = SendPtr(out.as_mut_ptr());
        let body = |range: Range<usize>| {
            for i in range {
                let r = self.row(i);
                // SAFETY: one writer per row index.
                unsafe { *op.0.add(i) = r.iter().sum::<f32>() / self.cols as f32 };
            }
        };
        if self.data.len() >= PAR_ELEM_MIN {
            let bk = crate::backend::current();
            crate::backend::par_ranges(&*bk, self.rows, 16, &body);
        } else {
            body(0..self.rows);
        }
        out
    }

    /// Mean over rows: returns a length-`cols` vector.
    ///
    /// Long inputs reduce over the same size-derived row-chunk grid as
    /// [`tmatvec`](Tensor::tmatvec), dispatched through the thread's
    /// current backend — results are bit-identical across backends.
    ///
    /// # Examples
    ///
    /// ```
    /// use eva::tensor::Tensor;
    ///
    /// let t = Tensor::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
    /// assert_eq!(t.mean_rows(), vec![2.5, 3.5, 4.5]);
    /// ```
    pub fn mean_rows(&self) -> Vec<f32> {
        self.mean_rows_with(&*crate::backend::current())
    }

    /// [`mean_rows`](Tensor::mean_rows) with an explicit backend.
    pub fn mean_rows_with(&self, bk: &dyn Backend) -> Vec<f32> {
        let mut out = weighted_col_sum_with(bk, self, None);
        let inv = 1.0 / self.rows as f32;
        for o in &mut out {
            *o *= inv;
        }
        out
    }

    /// Rank-one update: self += alpha * u vᵀ.
    pub fn add_outer(&mut self, alpha: f32, u: &[f32], v: &[f32]) {
        assert_eq!(u.len(), self.rows);
        assert_eq!(v.len(), self.cols);
        let (rows, cols) = (self.rows, self.cols);
        let dp = SendPtr(self.data.as_mut_ptr());
        let body = |range: Range<usize>| {
            for i in range {
                let ui = alpha * u[i];
                // SAFETY: row blocks from disjoint ranges never overlap.
                let row = unsafe { std::slice::from_raw_parts_mut(dp.0.add(i * cols), cols) };
                crate::simd::axpy8(ui, v, row);
            }
        };
        if rows * cols >= PAR_ELEM_MIN {
            let bk = crate::backend::current();
            crate::backend::par_ranges(&*bk, rows, 16, &body);
        } else {
            body(0..rows);
        }
    }

    /// y = self · x for a vector x of length `cols`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        let yp = SendPtr(y.as_mut_ptr());
        let body = |range: Range<usize>| {
            for i in range {
                // SAFETY: one writer per row index.
                unsafe { *yp.0.add(i) = dot(self.row(i), x) };
            }
        };
        if self.data.len() >= PAR_ELEM_MIN {
            let bk = crate::backend::current();
            crate::backend::par_ranges(&*bk, self.rows, 16, &body);
        } else {
            body(0..self.rows);
        }
        y
    }

    /// y = selfᵀ · x for a vector x of length `rows`.
    ///
    /// The column accumulation is a reduction over rows; long inputs
    /// split the rows into a *size-derived* fixed chunk grid (the same
    /// contract as [`dot`]) whose partials combine in index order, so
    /// `seq` and `threads:N` produce bit-identical results.
    ///
    /// # Examples
    ///
    /// ```
    /// use eva::tensor::Tensor;
    ///
    /// let t = Tensor::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
    /// // [1, 1] · T gives the column sums.
    /// assert_eq!(t.tmatvec(&[1.0, 1.0]), vec![4.0, 6.0]);
    /// ```
    pub fn tmatvec(&self, x: &[f32]) -> Vec<f32> {
        self.tmatvec_with(&*crate::backend::current(), x)
    }

    /// [`tmatvec`](Tensor::tmatvec) with an explicit backend.
    pub fn tmatvec_with(&self, bk: &dyn Backend, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows);
        if crate::telemetry::enabled() {
            crate::telemetry::TENSOR_TMATVEC_CALLS.add(1);
            crate::telemetry::TENSOR_TMATVEC_FLOPS.add(2 * (self.rows * self.cols) as u64);
        }
        weighted_col_sum_with(bk, self, Some(x))
    }

    /// Add `gamma` to the diagonal in place (damping).
    pub fn add_diag(&mut self, gamma: f32) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += gamma;
        }
    }

    /// Copy of the sub-matrix rows `r0..r1`, cols `c0..c1`.
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Tensor {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols);
        let mut out = Tensor::zeros(r1 - r0, c1 - c0);
        for (oi, i) in (r0..r1).enumerate() {
            out.row_mut(oi).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Paste `block` into this matrix with its top-left at `(r0, c0)`.
    pub fn paste(&mut self, r0: usize, c0: usize, block: &Tensor) {
        assert!(r0 + block.rows() <= self.rows && c0 + block.cols() <= self.cols);
        for i in 0..block.rows() {
            self.row_mut(r0 + i)[c0..c0 + block.cols()].copy_from_slice(block.row(i));
        }
    }

    /// Maximum absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// True if all entries are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

/// Shared column-reduction kernel behind [`Tensor::tmatvec`] and
/// [`Tensor::mean_rows`]: `out[j] = Σ_i w_i · t[i, j]` (`w_i = 1`
/// when `weights` is `None`).
///
/// Determinism contract (same as [`dot`]): rows group into chunks of
/// `~REDUCE_CHUNK / cols` rows (at least 1, and large enough that the
/// chunk count never exceeds `MAX_COL_PARTS`) — a grid derived only
/// from the matrix shape, never from the backend — each chunk
/// accumulates its partial row-by-row, and partials combine in
/// ascending chunk order. The arithmetic structure is identical under
/// every backend, so results are bit-identical; only the chunk
/// *scheduling* differs.
fn weighted_col_sum_with(bk: &dyn Backend, t: &Tensor, weights: Option<&[f32]>) -> Vec<f32> {
    let (rows, cols) = t.shape();
    let mut out = vec![0.0f32; cols];
    if rows == 0 || cols == 0 {
        return out;
    }
    let acc_rows = |acc: &mut [f32], r: Range<usize>| {
        for i in r {
            let wi = weights.map_or(1.0, |w| w[i]);
            // acc += wi · row — the 8×-wide elementwise tile; identical
            // arithmetic to the plain loop on every ISA path.
            crate::simd::axpy8(wi, t.row(i), acc);
        }
    };
    let rows_per = (REDUCE_CHUNK / cols).max(rows.div_ceil(MAX_COL_PARTS)).max(1);
    let parts = rows.div_ceil(rows_per);
    if parts == 1 || t.len() < PAR_REDUCE_MIN {
        // Size-derived gate: every backend takes this branch (or none
        // does), and one chunk is exactly the plain accumulation.
        acc_rows(&mut out, 0..rows);
        return out;
    }
    let mut partials = vec![0.0f32; parts * cols];
    let pp = SendPtr(partials.as_mut_ptr());
    bk.par_for(parts, &|p| {
        let lo = p * rows_per;
        let hi = (lo + rows_per).min(rows);
        // SAFETY: each chunk index owns its disjoint partial slice.
        let acc = unsafe { std::slice::from_raw_parts_mut(pp.0.add(p * cols), cols) };
        acc_rows(acc, lo..hi);
    });
    for chunk in partials.chunks_exact(cols) {
        for (o, &v) in out.iter_mut().zip(chunk) {
            *o += v;
        }
    }
    out
}

/// Dense dot product over f32 slices. Long inputs reduce over the
/// fixed `REDUCE_CHUNK` grid through the thread's *current* backend
/// (bit-identical for every backend — the grid depends only on the
/// length); short inputs run the straight-line micro-kernel directly.
/// Every chunk body is [`crate::simd::dot8`]'s fixed 8-lane tree, so
/// the result is also bit-identical across ISA paths. Kernels that
/// take an explicit backend handle must not call this in their inner
/// loops — use the crate-private `dot_seq`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    if a.len() >= PAR_REDUCE_MIN {
        let bk = crate::backend::current();
        return crate::backend::par_reduce_sum(&*bk, a.len(), REDUCE_CHUNK, &|r: Range<usize>| {
            dot_seq(&a[r.clone()], &b[r])
        });
    }
    dot_seq(a, b)
}

/// The straight-line chunk-body dot kernel: [`crate::simd::dot8`]'s
/// fixed 8-lane accumulation tree (the ISA path is process-global and
/// bit-identical everywhere). Kernels taking an explicit backend use
/// this directly so their only *backend* dispatch surface is the
/// handle they were given.
#[inline]
pub(crate) fn dot_seq(a: &[f32], b: &[f32]) -> f32 {
    crate::simd::dot8(a, b)
}

/// axpy over raw slices: y += alpha * x (the `f32x8` elementwise tile;
/// bit-identical to the plain loop).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    crate::simd::axpy8(alpha, x, y);
}

/// Euclidean norm of a slice.
pub fn norm(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(t.shape(), (2, 2));
        assert_eq!(t.at(1, 0), 3.0);
        assert_eq!(t.row(0), &[1.0, 2.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let tt = t.transpose();
        assert_eq!(tt.shape(), (3, 2));
        assert_eq!(tt.at(2, 1), 6.0);
        assert_eq!(tt.transpose(), t);
    }

    #[test]
    fn mean_cols_matches_manual() {
        // (d, n) = (2, 3): rows are feature dims, columns are samples.
        let a = Tensor::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.mean_cols(), vec![2.0, 5.0]);
        assert_eq!(a.mean_rows(), vec![2.5, 3.5, 4.5]);
    }

    #[test]
    fn outer_and_matvec() {
        let mut t = Tensor::zeros(2, 3);
        t.add_outer(2.0, &[1.0, 2.0], &[1.0, 0.0, 1.0]);
        assert_eq!(t.row(1), &[4.0, 0.0, 4.0]);
        assert_eq!(t.matvec(&[1.0, 1.0, 1.0]), vec![4.0, 8.0]);
        assert_eq!(t.tmatvec(&[1.0, 0.0]), vec![2.0, 0.0, 2.0]);
    }

    #[test]
    fn blend_running_average() {
        let mut a = Tensor::full(1, 2, 1.0);
        let b = Tensor::full(1, 2, 3.0);
        a.blend(0.5, 0.5, &b);
        assert_eq!(a.data(), &[2.0, 2.0]);
    }

    #[test]
    fn add_diag_damps() {
        let mut t = Tensor::zeros(3, 3);
        t.add_diag(0.25);
        assert_eq!(t.at(1, 1), 0.25);
        assert_eq!(t.at(0, 1), 0.0);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }
}
