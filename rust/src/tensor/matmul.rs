//! Blocked matrix-multiply kernels, dispatched through the compute
//! backend.
//!
//! Three variants cover every product the training stack needs without
//! materializing transposes:
//!
//! * [`matmul`]      — `C = A · B`
//! * [`matmul_at_b`] — `C = Aᵀ · B` (e.g. `AAᵀ` KF construction works on
//!   `(n, d)` layouts; gradients `G = Bᵀ·? `)
//! * [`matmul_a_bt`] — `C = A · Bᵀ` (e.g. `G = δᵀX` partners)
//!
//! All kernels walk the output row-contiguously and accumulate with an
//! i-k-j loop order so each output row is one 8×-wide `f32x8` tile:
//! `matmul`/`matmul_at_b` build a C row with [`crate::simd::row_mac8`]
//! (`crow += a[i,k] · brow` over all k, 8 output columns per vector
//! op, one ISA dispatch per row) and `matmul_a_bt` with
//! [`crate::simd::row_dots8`] (each element one fixed-tree dot).
//! Large products are **row-partitioned** across
//! the backend ([`crate::backend`]): each lane owns a disjoint block of
//! output rows, and per-element accumulation order (k ascending) is
//! identical in the sequential and partitioned paths, so every backend
//! — and every ISA path, see `docs/KERNELS.md` — produces bit-identical
//! results. The `*_with` variants take an explicit backend (benches,
//! parity tests); the plain names resolve the thread's
//! scoped-or-global backend via [`crate::backend::current`].

use std::ops::Range;

use super::Tensor;
use crate::backend::{self, Backend, SendPtr};

/// Below this many fused multiply-adds a product runs inline — pool
/// dispatch would cost more than it buys (64³ sits at the boundary).
const PAR_FLOP_MIN: usize = 1 << 18;

/// Minimum output rows per parallel chunk.
const ROW_GRAIN: usize = 8;

#[inline]
fn par_worthwhile(bk: &dyn Backend, macs: usize) -> bool {
    macs >= PAR_FLOP_MIN && bk.threads() > 1
}

/// C = A(m,k) · B(k,n).
///
/// # Examples
///
/// ```
/// use eva::tensor::{matmul, Tensor};
///
/// let a = Tensor::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
/// let b = Tensor::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
/// let c = matmul(&a, &b);
/// assert_eq!(c.row(0), &[19.0, 22.0]);
/// assert_eq!(matmul(&a, &Tensor::eye(2)), a);
/// ```
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_with(&*backend::current(), a, b)
}

/// [`matmul`] with an explicit backend.
pub fn matmul_with(bk: &dyn Backend, a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols(), b.rows(), "matmul inner-dim mismatch");
    let mut c = Tensor::zeros(a.rows(), b.cols());
    matmul_into_with(bk, a, b, &mut c);
    c
}

/// C = A · B written into an existing output buffer (hot path: avoids
/// reallocating per step).
pub fn matmul_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    matmul_into_with(&*backend::current(), a, b, c);
}

/// [`matmul_into`] with an explicit backend.
pub fn matmul_into_with(bk: &dyn Backend, a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (m, kk) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(kk, kb, "matmul inner-dim mismatch");
    assert_eq!(c.shape(), (m, n), "matmul output shape mismatch");
    if crate::telemetry::enabled() {
        crate::telemetry::TENSOR_MATMUL_CALLS.add(1);
        crate::telemetry::TENSOR_MATMUL_FLOPS.add(2 * (m * n * kk) as u64);
    }
    c.data_mut().fill(0.0);
    let (ad, bd) = (a.data(), b.data());
    let cd = SendPtr(c.data_mut().as_mut_ptr());
    // i-k-j: C[i,:] += A[i,k] * B[k,:]; each output row is one f32x8
    // row-mac tile (the whole k-sweep runs in a single ISA dispatch),
    // contiguous in both B and C.
    let rows = |r: Range<usize>| {
        for i in r {
            // SAFETY: row blocks from disjoint ranges never overlap.
            let crow = unsafe { std::slice::from_raw_parts_mut(cd.0.add(i * n), n) };
            crate::simd::row_mac8(crow, &ad[i * kk..(i + 1) * kk], 1, bd);
        }
    };
    if par_worthwhile(bk, m.saturating_mul(n).saturating_mul(kk)) {
        backend::par_ranges(bk, m, ROW_GRAIN, &rows);
    } else {
        rows(0..m);
    }
}

/// C = Aᵀ(k,m)ᵀ is (m,k): computes C(m,n) = Aᵀ · B where A is (k,m),
/// B is (k,n).
pub fn matmul_at_b(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_at_b_with(&*backend::current(), a, b)
}

/// [`matmul_at_b`] with an explicit backend.
pub fn matmul_at_b_with(bk: &dyn Backend, a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "matmul_at_b inner-dim mismatch");
    if crate::telemetry::enabled() {
        crate::telemetry::TENSOR_MATMUL_AT_B_CALLS.add(1);
        crate::telemetry::TENSOR_MATMUL_AT_B_FLOPS.add(2 * (m * n * k) as u64);
    }
    let mut c = Tensor::zeros(m, n);
    if k == 0 {
        return c; // empty inner dim: the product is all zeros
    }
    let (ad, bd) = (a.data(), b.data());
    // Row-partitioned when parallel: lane-local C rows; A is read with
    // stride m inside the row-mac tile (the whole k-sweep is a single
    // ISA dispatch per output row), amortized over the contiguous
    // length-n row updates. Per element the accumulation is
    // k-ascending in both branches, hence bit-equal results.
    let cd = SendPtr(c.data_mut().as_mut_ptr());
    let rows = |r: Range<usize>| {
        for i in r {
            // SAFETY: row blocks from disjoint ranges never overlap.
            let crow = unsafe { std::slice::from_raw_parts_mut(cd.0.add(i * n), n) };
            crate::simd::row_mac8(crow, &ad[i..], m, bd);
        }
    };
    if par_worthwhile(bk, m.saturating_mul(n).saturating_mul(k)) {
        backend::par_ranges(bk, m, ROW_GRAIN, &rows);
    } else {
        rows(0..m);
    }
    c
}

/// C(m,n) = A(m,k) · Bᵀ where B is (n,k).
pub fn matmul_a_bt(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_a_bt_with(&*backend::current(), a, b)
}

/// [`matmul_a_bt`] with an explicit backend.
pub fn matmul_a_bt_with(bk: &dyn Backend, a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(k, kb, "matmul_a_bt inner-dim mismatch");
    if crate::telemetry::enabled() {
        crate::telemetry::TENSOR_MATMUL_A_BT_CALLS.add(1);
        crate::telemetry::TENSOR_MATMUL_A_BT_FLOPS.add(2 * (m * n * k) as u64);
    }
    let mut c = Tensor::zeros(m, n);
    let (ad, bd) = (a.data(), b.data());
    let cd = SendPtr(c.data_mut().as_mut_ptr());
    // Rows of A against rows of B: each output element is one dot of
    // two contiguous slices, all n of them fused into one row-dots
    // tile (a single ISA dispatch per output row, each dot on dot8's
    // fixed tree). The tile never touches the backend layer, so the
    // explicit `bk` is the only backend this function dispatches
    // through (`super::dot` would route huge inner dims via the
    // global).
    let rows = |r: Range<usize>| {
        for i in r {
            let arow = &ad[i * k..(i + 1) * k];
            // SAFETY: row blocks from disjoint ranges never overlap.
            let crow = unsafe { std::slice::from_raw_parts_mut(cd.0.add(i * n), n) };
            crate::simd::row_dots8(crow, arow, bd);
        }
    };
    if par_worthwhile(bk, m.saturating_mul(n).saturating_mul(k)) {
        backend::par_ranges(bk, m, ROW_GRAIN, &rows);
    } else {
        rows(0..m);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Sequential, Threaded};
    use crate::rng::Pcg64;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Tensor::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    fn random(rng: &mut Pcg64, r: usize, c: usize) -> Tensor {
        let mut t = Tensor::zeros(r, c);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seeded(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 9, 13), (32, 32, 32)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-4, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Pcg64::seeded(12);
        let a = random(&mut rng, 7, 5); // (k, m) with k=7
        let b = random(&mut rng, 7, 6);
        let c = matmul_at_b(&a, &b);
        let expect = matmul(&a.transpose(), &b);
        assert!(c.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Pcg64::seeded(13);
        let a = random(&mut rng, 4, 9);
        let b = random(&mut rng, 6, 9); // (n, k)
        let c = matmul_a_bt(&a, &b);
        let expect = matmul(&a, &b.transpose());
        assert!(c.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seeded(14);
        let a = random(&mut rng, 8, 8);
        let i = Tensor::eye(8);
        assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-6);
    }

    /// Threaded results are bit-identical to sequential for all three
    /// kernels — sizes chosen above the parallel dispatch threshold
    /// with uneven row counts.
    #[test]
    fn threaded_is_bit_identical_to_sequential() {
        let mut rng = Pcg64::seeded(15);
        let thr = Threaded::new(4);
        let (m, k, n) = (67, 129, 61);
        let a = random(&mut rng, m, k);
        let b = random(&mut rng, k, n);
        assert_eq!(matmul_with(&Sequential, &a, &b), matmul_with(&thr, &a, &b));
        let at = random(&mut rng, k, m); // (k, m)
        assert_eq!(
            matmul_at_b_with(&Sequential, &at, &b),
            matmul_at_b_with(&thr, &at, &b)
        );
        let bt = random(&mut rng, n, k); // (n, k)
        assert_eq!(
            matmul_a_bt_with(&Sequential, &a, &bt),
            matmul_a_bt_with(&thr, &a, &bt)
        );
    }
}
