//! Blocked matrix-multiply kernels.
//!
//! Three variants cover every product the training stack needs without
//! materializing transposes:
//!
//! * [`matmul`]      — `C = A · B`
//! * [`matmul_at_b`] — `C = Aᵀ · B` (e.g. `AAᵀ` KF construction works on
//!   `(n, d)` layouts; gradients `G = Bᵀ·? `)
//! * [`matmul_a_bt`] — `C = A · Bᵀ` (e.g. `G = δᵀX` partners)
//!
//! All kernels walk the output row-contiguously and accumulate with an
//! i-k-j loop order so the inner loop is a pure FMA stream the compiler
//! vectorizes. Measured ~2-6 GFLOP/s single-thread on this CPU (see
//! `rust/benches/linalg_micro.rs`), flat with size, which is enough to
//! keep L3 off the critical path (the PJRT artifact does model math).

use super::Tensor;

/// C = A(m,k) · B(k,n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols(), b.rows(), "matmul inner-dim mismatch");
    let mut c = Tensor::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// C = A · B written into an existing output buffer (hot path: avoids
/// reallocating per step).
pub fn matmul_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (m, kk) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(kk, kb, "matmul inner-dim mismatch");
    assert_eq!(c.shape(), (m, n), "matmul output shape mismatch");
    c.data_mut().fill(0.0);
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    // i-k-j: C[i,:] += A[i,k] * B[k,:]; inner loop is contiguous in both
    // B and C.
    for i in 0..m {
        let crow = &mut cd[i * n..(i + 1) * n];
        for k in 0..kk {
            let aik = ad[i * kk + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &bd[k * n..(k + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// C = Aᵀ(k,m)ᵀ is (m,k): computes C(m,n) = Aᵀ · B where A is (k,m),
/// B is (k,n).
pub fn matmul_at_b(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "matmul_at_b inner-dim mismatch");
    let mut c = Tensor::zeros(m, n);
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    // k-i-j order: stream over A and B rows; C row update contiguous.
    for kk in 0..k {
        let arow = &ad[kk * m..(kk + 1) * m];
        let brow = &bd[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aik = arow[i];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// C(m,n) = A(m,k) · Bᵀ where B is (n,k).
pub fn matmul_a_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(k, kb, "matmul_a_bt inner-dim mismatch");
    let mut c = Tensor::zeros(m, n);
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    // Rows of A against rows of B: each output element is one dot of two
    // contiguous slices.
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut cd[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &bd[j * k..(j + 1) * k];
            *cv = super::dot(arow, brow);
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Tensor::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    fn random(rng: &mut Pcg64, r: usize, c: usize) -> Tensor {
        let mut t = Tensor::zeros(r, c);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seeded(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 9, 13), (32, 32, 32)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-4, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Pcg64::seeded(12);
        let a = random(&mut rng, 7, 5); // (k, m) with k=7
        let b = random(&mut rng, 7, 6);
        let c = matmul_at_b(&a, &b);
        let expect = matmul(&a.transpose(), &b);
        assert!(c.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Pcg64::seeded(13);
        let a = random(&mut rng, 4, 9);
        let b = random(&mut rng, 6, 9); // (n, k)
        let c = matmul_a_bt(&a, &b);
        let expect = matmul(&a, &b.transpose());
        assert!(c.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seeded(14);
        let a = random(&mut rng, 8, 8);
        let i = Tensor::eye(8);
        assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-6);
    }
}
