//! Typed configuration + presets + JSON loading (the "config system").
//!
//! A [`TrainConfig`] fully determines a training run: dataset, model
//! architecture, optimizer + hyper-parameters, schedule, engine
//! (native Rust fwd/bwd or the fused PJRT artifact), and seed. Configs
//! load from JSON files (`eva train --config cfg.json`), from named
//! presets, or are built programmatically; every experiment in
//! `exp/` is expressed as a set of `TrainConfig`s.

use crate::jsonx::Json;
use crate::nn::MlpSpec;
use crate::optim::HyperParams;

/// Model architecture selection.
#[derive(Clone, Debug)]
pub enum ModelArch {
    /// ReLU classifier with the given hidden dims.
    Classifier { hidden: Vec<usize> },
    /// The paper's §5.1 autoencoder (hidden [1000,500,250,30,…]).
    Autoencoder,
    /// Reduced autoencoder for fast experiments.
    AutoencoderSmall,
}

impl ModelArch {
    /// Resolve to a concrete spec given the dataset's shape.
    pub fn to_spec(&self, input_dim: usize, num_classes: usize) -> MlpSpec {
        match self {
            ModelArch::Classifier { hidden } => {
                let mut dims = vec![input_dim];
                dims.extend_from_slice(hidden);
                dims.push(num_classes);
                MlpSpec::classifier(dims)
            }
            ModelArch::Autoencoder => MlpSpec::autoencoder(input_dim),
            ModelArch::AutoencoderSmall => MlpSpec::autoencoder_small(input_dim),
        }
    }
}

/// Optimizer selection + hyper-parameters.
#[derive(Clone, Debug)]
pub struct OptimConfig {
    /// One of the `optim::by_name` algorithms.
    pub algorithm: String,
    pub hp: HyperParams,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig { algorithm: "eva".into(), hp: HyperParams::default() }
    }
}

/// Learning-rate schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LrSchedule {
    Constant,
    /// Cosine decay to zero over the run.
    Cosine,
    /// Linear decay to zero (the paper's autoencoder setup).
    Linear,
    /// Step decay ×0.1 at 50% and 75% (the paper's Cifar setup).
    Step,
}

impl LrSchedule {
    /// Canonical config-string for this schedule (inverse of
    /// [`LrSchedule::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            LrSchedule::Constant => "constant",
            LrSchedule::Cosine => "cosine",
            LrSchedule::Linear => "linear",
            LrSchedule::Step => "step",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "constant" | "const" => Ok(LrSchedule::Constant),
            "cosine" => Ok(LrSchedule::Cosine),
            "linear" => Ok(LrSchedule::Linear),
            "step" => Ok(LrSchedule::Step),
            other => Err(format!("unknown lr schedule '{other}'")),
        }
    }

    /// LR at `step` of `total` with `warmup` steps of linear ramp.
    pub fn lr_at(&self, base: f32, step: u64, total: u64, warmup: u64) -> f32 {
        if warmup > 0 && step < warmup {
            return base * (step + 1) as f32 / warmup as f32;
        }
        let t = ((step.saturating_sub(warmup)) as f32
            / (total.saturating_sub(warmup)).max(1) as f32)
            .clamp(0.0, 1.0);
        match self {
            LrSchedule::Constant => base,
            LrSchedule::Cosine => base * 0.5 * (1.0 + (std::f32::consts::PI * t).cos()),
            LrSchedule::Linear => base * (1.0 - t),
            LrSchedule::Step => {
                if t < 0.5 {
                    base
                } else if t < 0.75 {
                    base * 0.1
                } else {
                    base * 0.01
                }
            }
        }
    }
}

/// Which execution engine drives fwd/bwd.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Native Rust fwd/bwd + the optimizer zoo (works for every
    /// algorithm; used by the experiment harness).
    Native,
    /// Fused PJRT artifact (`eva_step`/`sgd_step`) — the optimized hot
    /// path; `model` is the manifest model name.
    Pjrt { model: String },
}

/// A fully-specified training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub name: String,
    pub dataset: String,
    pub seed: u64,
    pub arch: ModelArch,
    pub optim: OptimConfig,
    pub engine: Engine,
    pub epochs: usize,
    pub batch_size: usize,
    pub base_lr: f32,
    pub lr_schedule: LrSchedule,
    pub warmup_steps: u64,
    /// Optional hard cap on optimizer steps (overrides epochs if set).
    pub max_steps: Option<u64>,
    /// Evaluate on the validation split every N epochs (0 = only at end).
    pub eval_every: usize,
    /// Compute backend selection (`seq` | `threads` | `threads:N`).
    /// `None` inherits whatever backend is already installed
    /// process-wide (CLI `--backend`, a previous config, or the
    /// sequential default) — see [`crate::backend`].
    pub backend: Option<String>,
    /// Per-worker lane budget for data-parallel coordinator runs
    /// (`Some(k)` = every simulated worker computes on its own k-lane
    /// sub-pool, installed as the process-wide dp default). `None`
    /// inherits whatever default is already set (CLI
    /// `--worker-threads`, a previous config, or the
    /// carve-evenly-from-the-backend fallback) — see
    /// [`crate::coordinator::dp`].
    pub worker_threads: Option<usize>,
    /// ISA path for the `f32x8` micro-kernels
    /// (`auto` | `avx2` | `sse2` | `scalar`). `None` inherits the
    /// process-wide path (CLI `--simd`, the `EVA_SIMD` env var, or the
    /// auto-detected best) — see [`crate::simd`]. Numerics are
    /// bit-identical across paths, so this is a pure performance knob.
    pub simd: Option<String>,
    /// Telemetry recording (`on` | `off`). `None` inherits the
    /// process-wide mode (CLI `--telemetry`, the `EVA_TELEMETRY` env
    /// var, or the on-by-default boot state) — see
    /// [`crate::telemetry`]. Telemetry never touches numerics, so this
    /// is a pure observability knob.
    pub telemetry: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            name: "run".into(),
            dataset: "c10-small".into(),
            seed: 42,
            arch: ModelArch::Classifier { hidden: vec![128, 64] },
            optim: OptimConfig::default(),
            engine: Engine::Native,
            epochs: 10,
            batch_size: 64,
            base_lr: 0.1,
            lr_schedule: LrSchedule::Cosine,
            warmup_steps: 0,
            max_steps: None,
            eval_every: 1,
            backend: None,
            worker_threads: None,
            simd: None,
            telemetry: None,
        }
    }
}

/// JSON encoding for u64 config fields (seed, step counters): f64
/// holds integers exactly only up to 2^53, so larger values are
/// emitted as decimal strings — otherwise a checkpointed config would
/// silently round its seed and resume on different data.
fn u64_to_json(v: u64) -> Json {
    if v <= (1u64 << 53) {
        Json::Num(v as f64)
    } else {
        Json::Str(v.to_string())
    }
}

/// Accept a u64 config field as either a JSON number or a decimal
/// string (inverse of [`u64_to_json`]).
fn json_to_u64(v: &Json) -> Option<u64> {
    match v {
        Json::Num(n) if *n >= 0.0 => Some(*n as u64),
        Json::Str(s) => s.parse().ok(),
        _ => None,
    }
}

impl TrainConfig {
    /// Named presets used by examples and docs.
    pub fn preset(name: &str) -> Self {
        let mut c = TrainConfig { name: name.into(), ..TrainConfig::default() };
        match name {
            "quickstart" => {
                c.epochs = 6;
                c.base_lr = 0.05;
            }
            "ae-quick" => {
                c.dataset = "mnist-like".into();
                c.arch = ModelArch::AutoencoderSmall;
                c.epochs = 4;
                c.base_lr = 0.05;
                c.lr_schedule = LrSchedule::Linear;
                c.optim.hp.weight_decay = 0.0;
            }
            "c100-bench" => {
                c.dataset = "c100-small".into();
                c.arch = ModelArch::Classifier { hidden: vec![256, 128, 64] };
                c.epochs = 20;
            }
            _ => {}
        }
        c
    }

    /// Parse a JSON config. Unknown fields are rejected to catch typos.
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = Json::parse(text)?;
        let obj = v.as_obj().ok_or("config must be an object")?;
        let mut c = TrainConfig::default();
        for (k, val) in obj {
            match k.as_str() {
                "name" => c.name = val.as_str().ok_or("name: string")?.to_string(),
                "dataset" => c.dataset = val.as_str().ok_or("dataset: string")?.to_string(),
                "seed" => c.seed = json_to_u64(val).ok_or("seed: number")?,
                "epochs" => c.epochs = val.as_usize().ok_or("epochs: number")?,
                "batch_size" => c.batch_size = val.as_usize().ok_or("batch_size: number")?,
                "base_lr" => c.base_lr = val.as_f64().ok_or("base_lr: number")? as f32,
                "warmup_steps" => c.warmup_steps = json_to_u64(val).ok_or("warmup")?,
                "max_steps" => c.max_steps = Some(json_to_u64(val).ok_or("max_steps")?),
                "eval_every" => c.eval_every = val.as_usize().ok_or("eval_every")?,
                "lr_schedule" => {
                    c.lr_schedule = LrSchedule::parse(val.as_str().ok_or("lr_schedule")?)?
                }
                "engine" => match val.as_str().ok_or("engine: string")? {
                    "native" => c.engine = Engine::Native,
                    s if s.starts_with("pjrt:") => {
                        c.engine = Engine::Pjrt { model: s[5..].to_string() }
                    }
                    other => return Err(format!("unknown engine '{other}'")),
                },
                "arch" => {
                    let s = val.as_str().ok_or("arch: string")?;
                    c.arch = match s {
                        "autoencoder" => ModelArch::Autoencoder,
                        "autoencoder-small" => ModelArch::AutoencoderSmall,
                        _ => return Err(format!("unknown arch '{s}' (use 'hidden' for classifiers)")),
                    };
                }
                "hidden" => {
                    let dims = val
                        .as_arr()
                        .ok_or("hidden: array")?
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect();
                    c.arch = ModelArch::Classifier { hidden: dims };
                }
                "backend" => {
                    let s = val.as_str().ok_or("backend: string")?;
                    // Validate eagerly so config typos fail at load time.
                    crate::backend::BackendChoice::parse(s)?;
                    c.backend = Some(s.to_string());
                }
                "worker_threads" => {
                    let n = val.as_usize().ok_or("worker_threads: number")?;
                    if n == 0 {
                        return Err("worker_threads must be ≥ 1".into());
                    }
                    c.worker_threads = Some(n);
                }
                "simd" => {
                    let s = val.as_str().ok_or("simd: string")?;
                    // Validate the spelling eagerly; availability is
                    // checked at install time (a config written on an
                    // AVX2 host must still *parse* elsewhere).
                    crate::simd::SimdChoice::parse(s)?;
                    c.simd = Some(s.to_string());
                }
                "telemetry" => {
                    let s = val.as_str().ok_or("telemetry: string")?;
                    // Validate eagerly so config typos fail at load time.
                    crate::telemetry::TelemetryChoice::parse(s)?;
                    c.telemetry = Some(s.to_string());
                }
                "optimizer" => c.optim.algorithm = val.as_str().ok_or("optimizer")?.to_string(),
                "momentum" => c.optim.hp.momentum = val.as_f64().ok_or("momentum")? as f32,
                "weight_decay" => c.optim.hp.weight_decay = val.as_f64().ok_or("wd")? as f32,
                "damping" => c.optim.hp.damping = val.as_f64().ok_or("damping")? as f32,
                "running_avg" => c.optim.hp.running_avg = val.as_f64().ok_or("ra")? as f32,
                "kl_clip" => c.optim.hp.kl_clip = val.as_f64().ok_or("kl_clip")? as f32,
                "update_interval" => {
                    c.optim.hp.update_interval = val.as_usize().ok_or("interval")?
                }
                "mfac_history" => c.optim.hp.mfac_history = val.as_usize().ok_or("mfac")?,
                "shampoo_block" => {
                    c.optim.hp.shampoo_block = val.as_usize().ok_or("shampoo_block")?
                }
                "beta1" => c.optim.hp.beta1 = val.as_f64().ok_or("beta1")? as f32,
                "beta2" => c.optim.hp.beta2 = val.as_f64().ok_or("beta2")? as f32,
                "eps" => c.optim.hp.eps = val.as_f64().ok_or("eps")? as f32,
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&text)
    }

    /// Serialize to the JSON object [`TrainConfig::from_json`] accepts
    /// (used by checkpoints so a snapshot is self-describing). Every
    /// emitted key round-trips; `decoupled_wd` is implied by the
    /// `adamw` optimizer name, mirroring the parser.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("name", Json::Str(self.name.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("seed", u64_to_json(self.seed)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("base_lr", Json::Num(self.base_lr as f64)),
            ("warmup_steps", u64_to_json(self.warmup_steps)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("lr_schedule", Json::Str(self.lr_schedule.name().into())),
            ("optimizer", Json::Str(self.optim.algorithm.clone())),
            ("momentum", Json::Num(self.optim.hp.momentum as f64)),
            ("weight_decay", Json::Num(self.optim.hp.weight_decay as f64)),
            ("damping", Json::Num(self.optim.hp.damping as f64)),
            ("running_avg", Json::Num(self.optim.hp.running_avg as f64)),
            ("kl_clip", Json::Num(self.optim.hp.kl_clip as f64)),
            ("update_interval", Json::Num(self.optim.hp.update_interval as f64)),
            ("mfac_history", Json::Num(self.optim.hp.mfac_history as f64)),
            ("shampoo_block", Json::Num(self.optim.hp.shampoo_block as f64)),
            ("beta1", Json::Num(self.optim.hp.beta1 as f64)),
            ("beta2", Json::Num(self.optim.hp.beta2 as f64)),
            ("eps", Json::Num(self.optim.hp.eps as f64)),
        ];
        match &self.engine {
            Engine::Native => pairs.push(("engine", Json::Str("native".into()))),
            Engine::Pjrt { model } => {
                pairs.push(("engine", Json::Str(format!("pjrt:{model}"))))
            }
        }
        match &self.arch {
            ModelArch::Classifier { hidden } => {
                pairs.push(("hidden", Json::arr_usize(hidden)))
            }
            ModelArch::Autoencoder => pairs.push(("arch", Json::Str("autoencoder".into()))),
            ModelArch::AutoencoderSmall => {
                pairs.push(("arch", Json::Str("autoencoder-small".into())))
            }
        }
        if let Some(m) = self.max_steps {
            pairs.push(("max_steps", u64_to_json(m)));
        }
        if let Some(b) = &self.backend {
            pairs.push(("backend", Json::Str(b.clone())));
        }
        if let Some(w) = self.worker_threads {
            pairs.push(("worker_threads", Json::Num(w as f64)));
        }
        if let Some(s) = &self.simd {
            pairs.push(("simd", Json::Str(s.clone())));
        }
        if let Some(t) = &self.telemetry {
            pairs.push(("telemetry", Json::Str(t.clone())));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for p in ["quickstart", "ae-quick", "c100-bench"] {
            let c = TrainConfig::preset(p);
            assert_eq!(c.name, p);
        }
    }

    #[test]
    fn json_roundtrip_core_fields() {
        let c = TrainConfig::from_json(
            r#"{"name": "t", "dataset": "c10-small", "optimizer": "kfac",
                "epochs": 3, "base_lr": 0.2, "lr_schedule": "step",
                "hidden": [32, 16], "update_interval": 10,
                "engine": "pjrt:quickstart"}"#,
        )
        .unwrap();
        assert_eq!(c.optim.algorithm, "kfac");
        assert_eq!(c.optim.hp.update_interval, 10);
        assert_eq!(c.lr_schedule, LrSchedule::Step);
        assert!(matches!(c.engine, Engine::Pjrt { ref model } if model == "quickstart"));
        assert!(matches!(c.arch, ModelArch::Classifier { ref hidden } if hidden == &[32, 16]));
    }

    #[test]
    fn to_json_roundtrips_through_from_json() {
        let mut c = TrainConfig::preset("c100-bench");
        c.optim.algorithm = "kfac".into();
        c.optim.hp.update_interval = 10;
        c.max_steps = Some(123);
        c.backend = Some("threads:2".into());
        c.worker_threads = Some(3);
        c.simd = Some("scalar".into());
        c.telemetry = Some("off".into());
        c.lr_schedule = LrSchedule::Step;
        let back = TrainConfig::from_json(&c.to_json().dump()).unwrap();
        assert_eq!(back.name, c.name);
        assert_eq!(back.dataset, c.dataset);
        assert_eq!(back.seed, c.seed);
        assert_eq!(back.optim.algorithm, "kfac");
        assert_eq!(back.optim.hp.update_interval, 10);
        assert_eq!(back.optim.hp.damping.to_bits(), c.optim.hp.damping.to_bits());
        assert_eq!(back.base_lr.to_bits(), c.base_lr.to_bits());
        assert_eq!(back.max_steps, Some(123));
        assert_eq!(back.backend.as_deref(), Some("threads:2"));
        assert_eq!(back.worker_threads, Some(3));
        assert_eq!(back.simd.as_deref(), Some("scalar"));
        assert_eq!(back.telemetry.as_deref(), Some("off"));
        assert_eq!(back.lr_schedule, LrSchedule::Step);
        assert!(matches!(back.arch, ModelArch::Classifier { ref hidden } if hidden == &[256, 128, 64]));
        // Autoencoder arch round-trips via the "arch" key.
        c.arch = ModelArch::AutoencoderSmall;
        let back = TrainConfig::from_json(&c.to_json().dump()).unwrap();
        assert!(matches!(back.arch, ModelArch::AutoencoderSmall));
    }

    #[test]
    fn u64_fields_above_2_pow_53_roundtrip_exactly() {
        // f64 would round these; the string fallback must not (a
        // checkpointed config resuming on a rounded seed would train
        // on different data).
        let mut c = TrainConfig::default();
        c.seed = (1u64 << 60) | 1;
        c.max_steps = Some(u64::MAX - 7);
        let back = TrainConfig::from_json(&c.to_json().dump()).unwrap();
        assert_eq!(back.seed, (1u64 << 60) | 1);
        assert_eq!(back.max_steps, Some(u64::MAX - 7));
        // Plain numbers still parse.
        assert_eq!(TrainConfig::from_json(r#"{"seed": 42}"#).unwrap().seed, 42);
        assert_eq!(
            TrainConfig::from_json(r#"{"seed": "99"}"#).unwrap().seed,
            99
        );
        assert!(TrainConfig::from_json(r#"{"seed": "nope"}"#).is_err());
        assert!(TrainConfig::from_json(r#"{"seed": -3}"#).is_err());
    }

    #[test]
    fn json_rejects_unknown_keys() {
        assert!(TrainConfig::from_json(r#"{"learning_rate": 0.1}"#).is_err());
    }

    #[test]
    fn backend_key_parses_and_validates() {
        let c = TrainConfig::from_json(r#"{"backend": "threads:2"}"#).unwrap();
        assert_eq!(c.backend.as_deref(), Some("threads:2"));
        assert!(TrainConfig::from_json(r#"{"backend": "gpu"}"#).is_err());
    }

    #[test]
    fn worker_threads_key_parses_and_validates() {
        let c = TrainConfig::from_json(r#"{"worker_threads": 2}"#).unwrap();
        assert_eq!(c.worker_threads, Some(2));
        assert!(TrainConfig::from_json(r#"{"worker_threads": 0}"#).is_err());
    }

    #[test]
    fn simd_key_parses_and_validates() {
        // All spellings parse, even paths this host can't run —
        // availability is an install-time check, not a parse error.
        for s in ["auto", "avx2", "sse2", "scalar"] {
            let c = TrainConfig::from_json(&format!(r#"{{"simd": "{s}"}}"#)).unwrap();
            assert_eq!(c.simd.as_deref(), Some(s));
        }
        assert!(TrainConfig::from_json(r#"{"simd": "neon"}"#).is_err());
        assert!(TrainConfig::from_json(r#"{"simd": 2}"#).is_err());
    }

    #[test]
    fn telemetry_key_parses_and_validates() {
        for s in ["on", "off"] {
            let c = TrainConfig::from_json(&format!(r#"{{"telemetry": "{s}"}}"#)).unwrap();
            assert_eq!(c.telemetry.as_deref(), Some(s));
        }
        assert!(TrainConfig::from_json(r#"{"telemetry": "loud"}"#).is_err());
        assert!(TrainConfig::from_json(r#"{"telemetry": 1}"#).is_err());
    }

    #[test]
    fn schedules_shapes() {
        let base = 1.0;
        assert_eq!(LrSchedule::Constant.lr_at(base, 50, 100, 0), 1.0);
        assert!(LrSchedule::Cosine.lr_at(base, 99, 100, 0) < 0.01);
        assert!((LrSchedule::Linear.lr_at(base, 50, 100, 0) - 0.5).abs() < 0.02);
        assert_eq!(LrSchedule::Step.lr_at(base, 10, 100, 0), 1.0);
        assert!((LrSchedule::Step.lr_at(base, 60, 100, 0) - 0.1).abs() < 1e-6);
        // Warmup ramps from base/warmup.
        let w = LrSchedule::Cosine.lr_at(base, 0, 100, 10);
        assert!((w - 0.1).abs() < 1e-6);
    }
}
