//! Typed configuration + presets + JSON loading (the "config system").
//!
//! A [`TrainConfig`] fully determines a training run: dataset, model
//! architecture, optimizer + hyper-parameters, schedule, engine
//! (native Rust fwd/bwd or the fused PJRT artifact), and seed. Configs
//! load from JSON files (`eva train --config cfg.json`), from named
//! presets, or are built programmatically; every experiment in
//! `exp/` is expressed as a set of `TrainConfig`s.

use crate::jsonx::Json;
use crate::nn::MlpSpec;
use crate::optim::HyperParams;

/// Model architecture selection.
#[derive(Clone, Debug)]
pub enum ModelArch {
    /// ReLU classifier with the given hidden dims.
    Classifier { hidden: Vec<usize> },
    /// The paper's §5.1 autoencoder (hidden [1000,500,250,30,…]).
    Autoencoder,
    /// Reduced autoencoder for fast experiments.
    AutoencoderSmall,
}

impl ModelArch {
    /// Resolve to a concrete spec given the dataset's shape.
    pub fn to_spec(&self, input_dim: usize, num_classes: usize) -> MlpSpec {
        match self {
            ModelArch::Classifier { hidden } => {
                let mut dims = vec![input_dim];
                dims.extend_from_slice(hidden);
                dims.push(num_classes);
                MlpSpec::classifier(dims)
            }
            ModelArch::Autoencoder => MlpSpec::autoencoder(input_dim),
            ModelArch::AutoencoderSmall => MlpSpec::autoencoder_small(input_dim),
        }
    }
}

/// Optimizer selection + hyper-parameters.
#[derive(Clone, Debug)]
pub struct OptimConfig {
    /// One of the `optim::by_name` algorithms.
    pub algorithm: String,
    pub hp: HyperParams,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig { algorithm: "eva".into(), hp: HyperParams::default() }
    }
}

/// Learning-rate schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LrSchedule {
    Constant,
    /// Cosine decay to zero over the run.
    Cosine,
    /// Linear decay to zero (the paper's autoencoder setup).
    Linear,
    /// Step decay ×0.1 at 50% and 75% (the paper's Cifar setup).
    Step,
}

impl LrSchedule {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "constant" | "const" => Ok(LrSchedule::Constant),
            "cosine" => Ok(LrSchedule::Cosine),
            "linear" => Ok(LrSchedule::Linear),
            "step" => Ok(LrSchedule::Step),
            other => Err(format!("unknown lr schedule '{other}'")),
        }
    }

    /// LR at `step` of `total` with `warmup` steps of linear ramp.
    pub fn lr_at(&self, base: f32, step: u64, total: u64, warmup: u64) -> f32 {
        if warmup > 0 && step < warmup {
            return base * (step + 1) as f32 / warmup as f32;
        }
        let t = ((step.saturating_sub(warmup)) as f32
            / (total.saturating_sub(warmup)).max(1) as f32)
            .clamp(0.0, 1.0);
        match self {
            LrSchedule::Constant => base,
            LrSchedule::Cosine => base * 0.5 * (1.0 + (std::f32::consts::PI * t).cos()),
            LrSchedule::Linear => base * (1.0 - t),
            LrSchedule::Step => {
                if t < 0.5 {
                    base
                } else if t < 0.75 {
                    base * 0.1
                } else {
                    base * 0.01
                }
            }
        }
    }
}

/// Which execution engine drives fwd/bwd.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Native Rust fwd/bwd + the optimizer zoo (works for every
    /// algorithm; used by the experiment harness).
    Native,
    /// Fused PJRT artifact (`eva_step`/`sgd_step`) — the optimized hot
    /// path; `model` is the manifest model name.
    Pjrt { model: String },
}

/// A fully-specified training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub name: String,
    pub dataset: String,
    pub seed: u64,
    pub arch: ModelArch,
    pub optim: OptimConfig,
    pub engine: Engine,
    pub epochs: usize,
    pub batch_size: usize,
    pub base_lr: f32,
    pub lr_schedule: LrSchedule,
    pub warmup_steps: u64,
    /// Optional hard cap on optimizer steps (overrides epochs if set).
    pub max_steps: Option<u64>,
    /// Evaluate on the validation split every N epochs (0 = only at end).
    pub eval_every: usize,
    /// Compute backend selection (`seq` | `threads` | `threads:N`).
    /// `None` inherits whatever backend is already installed
    /// process-wide (CLI `--backend`, a previous config, or the
    /// sequential default) — see [`crate::backend`].
    pub backend: Option<String>,
    /// Per-worker lane budget for data-parallel coordinator runs
    /// (`Some(k)` = every simulated worker computes on its own k-lane
    /// sub-pool, installed as the process-wide dp default). `None`
    /// inherits whatever default is already set (CLI
    /// `--worker-threads`, a previous config, or the
    /// carve-evenly-from-the-backend fallback) — see
    /// [`crate::coordinator::dp`].
    pub worker_threads: Option<usize>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            name: "run".into(),
            dataset: "c10-small".into(),
            seed: 42,
            arch: ModelArch::Classifier { hidden: vec![128, 64] },
            optim: OptimConfig::default(),
            engine: Engine::Native,
            epochs: 10,
            batch_size: 64,
            base_lr: 0.1,
            lr_schedule: LrSchedule::Cosine,
            warmup_steps: 0,
            max_steps: None,
            eval_every: 1,
            backend: None,
            worker_threads: None,
        }
    }
}

impl TrainConfig {
    /// Named presets used by examples and docs.
    pub fn preset(name: &str) -> Self {
        let mut c = TrainConfig { name: name.into(), ..TrainConfig::default() };
        match name {
            "quickstart" => {
                c.epochs = 6;
                c.base_lr = 0.05;
            }
            "ae-quick" => {
                c.dataset = "mnist-like".into();
                c.arch = ModelArch::AutoencoderSmall;
                c.epochs = 4;
                c.base_lr = 0.05;
                c.lr_schedule = LrSchedule::Linear;
                c.optim.hp.weight_decay = 0.0;
            }
            "c100-bench" => {
                c.dataset = "c100-small".into();
                c.arch = ModelArch::Classifier { hidden: vec![256, 128, 64] };
                c.epochs = 20;
            }
            _ => {}
        }
        c
    }

    /// Parse a JSON config. Unknown fields are rejected to catch typos.
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = Json::parse(text)?;
        let obj = v.as_obj().ok_or("config must be an object")?;
        let mut c = TrainConfig::default();
        for (k, val) in obj {
            match k.as_str() {
                "name" => c.name = val.as_str().ok_or("name: string")?.to_string(),
                "dataset" => c.dataset = val.as_str().ok_or("dataset: string")?.to_string(),
                "seed" => c.seed = val.as_f64().ok_or("seed: number")? as u64,
                "epochs" => c.epochs = val.as_usize().ok_or("epochs: number")?,
                "batch_size" => c.batch_size = val.as_usize().ok_or("batch_size: number")?,
                "base_lr" => c.base_lr = val.as_f64().ok_or("base_lr: number")? as f32,
                "warmup_steps" => c.warmup_steps = val.as_f64().ok_or("warmup")? as u64,
                "max_steps" => c.max_steps = Some(val.as_f64().ok_or("max_steps")? as u64),
                "eval_every" => c.eval_every = val.as_usize().ok_or("eval_every")?,
                "lr_schedule" => {
                    c.lr_schedule = LrSchedule::parse(val.as_str().ok_or("lr_schedule")?)?
                }
                "engine" => match val.as_str().ok_or("engine: string")? {
                    "native" => c.engine = Engine::Native,
                    s if s.starts_with("pjrt:") => {
                        c.engine = Engine::Pjrt { model: s[5..].to_string() }
                    }
                    other => return Err(format!("unknown engine '{other}'")),
                },
                "arch" => {
                    let s = val.as_str().ok_or("arch: string")?;
                    c.arch = match s {
                        "autoencoder" => ModelArch::Autoencoder,
                        "autoencoder-small" => ModelArch::AutoencoderSmall,
                        _ => return Err(format!("unknown arch '{s}' (use 'hidden' for classifiers)")),
                    };
                }
                "hidden" => {
                    let dims = val
                        .as_arr()
                        .ok_or("hidden: array")?
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect();
                    c.arch = ModelArch::Classifier { hidden: dims };
                }
                "backend" => {
                    let s = val.as_str().ok_or("backend: string")?;
                    // Validate eagerly so config typos fail at load time.
                    crate::backend::BackendChoice::parse(s)?;
                    c.backend = Some(s.to_string());
                }
                "worker_threads" => {
                    let n = val.as_usize().ok_or("worker_threads: number")?;
                    if n == 0 {
                        return Err("worker_threads must be ≥ 1".into());
                    }
                    c.worker_threads = Some(n);
                }
                "optimizer" => c.optim.algorithm = val.as_str().ok_or("optimizer")?.to_string(),
                "momentum" => c.optim.hp.momentum = val.as_f64().ok_or("momentum")? as f32,
                "weight_decay" => c.optim.hp.weight_decay = val.as_f64().ok_or("wd")? as f32,
                "damping" => c.optim.hp.damping = val.as_f64().ok_or("damping")? as f32,
                "running_avg" => c.optim.hp.running_avg = val.as_f64().ok_or("ra")? as f32,
                "kl_clip" => c.optim.hp.kl_clip = val.as_f64().ok_or("kl_clip")? as f32,
                "update_interval" => {
                    c.optim.hp.update_interval = val.as_usize().ok_or("interval")?
                }
                "mfac_history" => c.optim.hp.mfac_history = val.as_usize().ok_or("mfac")?,
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for p in ["quickstart", "ae-quick", "c100-bench"] {
            let c = TrainConfig::preset(p);
            assert_eq!(c.name, p);
        }
    }

    #[test]
    fn json_roundtrip_core_fields() {
        let c = TrainConfig::from_json(
            r#"{"name": "t", "dataset": "c10-small", "optimizer": "kfac",
                "epochs": 3, "base_lr": 0.2, "lr_schedule": "step",
                "hidden": [32, 16], "update_interval": 10,
                "engine": "pjrt:quickstart"}"#,
        )
        .unwrap();
        assert_eq!(c.optim.algorithm, "kfac");
        assert_eq!(c.optim.hp.update_interval, 10);
        assert_eq!(c.lr_schedule, LrSchedule::Step);
        assert!(matches!(c.engine, Engine::Pjrt { ref model } if model == "quickstart"));
        assert!(matches!(c.arch, ModelArch::Classifier { ref hidden } if hidden == &[32, 16]));
    }

    #[test]
    fn json_rejects_unknown_keys() {
        assert!(TrainConfig::from_json(r#"{"learning_rate": 0.1}"#).is_err());
    }

    #[test]
    fn backend_key_parses_and_validates() {
        let c = TrainConfig::from_json(r#"{"backend": "threads:2"}"#).unwrap();
        assert_eq!(c.backend.as_deref(), Some("threads:2"));
        assert!(TrainConfig::from_json(r#"{"backend": "gpu"}"#).is_err());
    }

    #[test]
    fn worker_threads_key_parses_and_validates() {
        let c = TrainConfig::from_json(r#"{"worker_threads": 2}"#).unwrap();
        assert_eq!(c.worker_threads, Some(2));
        assert!(TrainConfig::from_json(r#"{"worker_threads": 0}"#).is_err());
    }

    #[test]
    fn schedules_shapes() {
        let base = 1.0;
        assert_eq!(LrSchedule::Constant.lr_at(base, 50, 100, 0), 1.0);
        assert!(LrSchedule::Cosine.lr_at(base, 99, 100, 0) < 0.01);
        assert!((LrSchedule::Linear.lr_at(base, 50, 100, 0) - 0.5).abs() < 0.02);
        assert_eq!(LrSchedule::Step.lr_at(base, 10, 100, 0), 1.0);
        assert!((LrSchedule::Step.lr_at(base, 60, 100, 0) - 0.1).abs() < 1e-6);
        // Warmup ramps from base/warmup.
        let w = LrSchedule::Cosine.lr_at(base, 0, 100, 10);
        assert!((w - 0.1).abs() < 1e-6);
    }
}
