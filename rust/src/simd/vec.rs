//! The 8-lane `f32` value: portable reference semantics plus the
//! per-ISA vector implementations behind them.
//!
//! [`F32x8`] is the *semantic model* of every SIMD path: a plain
//! `[f32; 8]` with lane-wise `add`/`mul` and one canonical horizontal
//! sum. The SSE2 and AVX2 implementations in [`x86`] reproduce its
//! arithmetic exactly — same lane ops, same reduction bracketing, no
//! fused multiply-add — so every path is bit-identical (see
//! `docs/KERNELS.md` for the contract and `tests/simd_parity.rs` for
//! the enforcement).

/// A portable 8-lane `f32` value — the reference semantics every ISA
/// path must reproduce bit-for-bit.
///
/// All operations are lane-wise IEEE-754 single precision with one
/// rounding per multiply and per add (multiplies are never fused into
/// adds: SSE2 has no FMA, so fusing on AVX2 would break cross-ISA
/// bit-identity). The horizontal sum uses one fixed bracketing — see
/// [`F32x8::hsum`].
///
/// # Examples
///
/// ```
/// use eva::simd::F32x8;
///
/// let x = F32x8::from_array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
/// let y = F32x8::splat(2.0);
/// // Lane-wise multiply, then the canonical horizontal sum.
/// assert_eq!(x.mul(y).hsum(), 72.0);
/// assert_eq!(x.add(y).to_array()[7], 10.0);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct F32x8(pub(crate) [f32; 8]);

impl F32x8 {
    /// Number of lanes.
    pub const LANES: usize = 8;

    /// All lanes zero.
    pub fn zero() -> Self {
        F32x8([0.0; 8])
    }

    /// All lanes set to `v`.
    pub fn splat(v: f32) -> Self {
        F32x8([v; 8])
    }

    /// Build from an array of 8 lanes.
    pub fn from_array(a: [f32; 8]) -> Self {
        F32x8(a)
    }

    /// Load the first 8 elements of `s` (panics if `s` is shorter).
    pub fn from_slice(s: &[f32]) -> Self {
        let mut a = [0.0f32; 8];
        a.copy_from_slice(&s[..8]);
        F32x8(a)
    }

    /// The lanes as an array.
    pub fn to_array(self) -> [f32; 8] {
        self.0
    }

    /// Lane-wise addition.
    pub fn add(self, o: Self) -> Self {
        let mut r = [0.0f32; 8];
        for i in 0..8 {
            r[i] = self.0[i] + o.0[i];
        }
        F32x8(r)
    }

    /// Lane-wise multiplication (never fused into a following add).
    pub fn mul(self, o: Self) -> Self {
        let mut r = [0.0f32; 8];
        for i in 0..8 {
            r[i] = self.0[i] * o.0[i];
        }
        F32x8(r)
    }

    /// The canonical horizontal sum — the one bracketing every ISA
    /// path uses:
    ///
    /// ```text
    /// h_j = l_j + l_{j+4}            (fold 8 lanes to 4)
    /// s   = ((h0 + h2) + (h1 + h3))  (fold 4 lanes to 1)
    /// ```
    ///
    /// This is the natural AVX2 tree (`vextractf128` + add, then the
    /// SSE `movehl`/`shuffle` fold); the scalar and SSE2 paths
    /// replicate it exactly rather than summing lanes left-to-right.
    pub fn hsum(self) -> f32 {
        let l = self.0;
        let h = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
        (h[0] + h[2]) + (h[1] + h[3])
    }
}

/// The internal 8-lane vector contract the generic kernel bodies are
/// written against. Implementations must be lane-exact against
/// [`F32x8`]: same per-lane IEEE ops, same [`F32x8::hsum`] bracketing,
/// and **no** FMA contraction.
///
/// All methods are `unsafe` because the x86 implementations may only
/// run when the corresponding ISA was detected — the dispatchers in
/// `kernels.rs` uphold that via [`crate::simd::active`].
pub(crate) trait SimdVec: Copy {
    /// All lanes zero.
    ///
    /// # Safety
    /// The implementing ISA must be active (see the trait docs).
    unsafe fn zero() -> Self;
    /// All lanes set to `v`.
    ///
    /// # Safety
    /// The implementing ISA must be active (see the trait docs).
    unsafe fn splat(v: f32) -> Self;
    /// Unaligned load of 8 consecutive `f32`s starting at `p`.
    ///
    /// # Safety
    /// ISA active, and `p` must be valid for reading 8 `f32`s.
    unsafe fn load(p: *const f32) -> Self;
    /// Unaligned store of the 8 lanes starting at `p`.
    ///
    /// # Safety
    /// ISA active, and `p` must be valid for writing 8 `f32`s.
    unsafe fn store(self, p: *mut f32);
    /// Lane-wise addition.
    ///
    /// # Safety
    /// The implementing ISA must be active (see the trait docs).
    unsafe fn add(self, o: Self) -> Self;
    /// Lane-wise multiplication.
    ///
    /// # Safety
    /// The implementing ISA must be active (see the trait docs).
    unsafe fn mul(self, o: Self) -> Self;
    /// The canonical horizontal sum (same bracketing as [`F32x8::hsum`]).
    ///
    /// # Safety
    /// The implementing ISA must be active (see the trait docs).
    unsafe fn hsum(self) -> f32;
}

/// The scalar fallback *is* the reference value.
impl SimdVec for F32x8 {
    // SAFETY: plain scalar code with no ISA requirement; `unsafe` only
    // to match the trait signature.
    #[inline(always)]
    unsafe fn zero() -> Self {
        F32x8::zero()
    }

    // SAFETY: plain scalar code with no ISA requirement.
    #[inline(always)]
    unsafe fn splat(v: f32) -> Self {
        F32x8::splat(v)
    }

    // SAFETY: the trait contract makes the caller pass a pointer valid
    // for reading 8 f32s; no ISA requirement.
    #[inline(always)]
    unsafe fn load(p: *const f32) -> Self {
        let mut a = [0.0f32; 8];
        std::ptr::copy_nonoverlapping(p, a.as_mut_ptr(), 8);
        F32x8(a)
    }

    // SAFETY: the trait contract makes the caller pass a pointer valid
    // for writing 8 f32s; no ISA requirement.
    #[inline(always)]
    unsafe fn store(self, p: *mut f32) {
        std::ptr::copy_nonoverlapping(self.0.as_ptr(), p, 8);
    }

    // SAFETY: plain scalar code with no ISA requirement.
    #[inline(always)]
    unsafe fn add(self, o: Self) -> Self {
        F32x8::add(self, o)
    }

    // SAFETY: plain scalar code with no ISA requirement.
    #[inline(always)]
    unsafe fn mul(self, o: Self) -> Self {
        F32x8::mul(self, o)
    }

    // SAFETY: plain scalar code with no ISA requirement.
    #[inline(always)]
    unsafe fn hsum(self) -> f32 {
        F32x8::hsum(self)
    }
}

/// x86_64 vector implementations (SSE2 half-pairs and AVX2).
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use super::SimdVec;
    use core::arch::x86_64::*;

    /// Fold a 4-lane `__m128` to one `f32` with the canonical
    /// bracketing `(h0 + h2) + (h1 + h3)` — shared by the SSE2 and
    /// AVX2 [`SimdVec::hsum`] implementations so both match
    /// [`super::F32x8::hsum`] exactly.
    ///
    /// # Safety
    /// SSE2 only (baseline on x86_64).
    #[inline(always)]
    pub(crate) unsafe fn hsum128(h: __m128) -> f32 {
        // [h2, h3, h2, h3]
        let swapped = _mm_movehl_ps(h, h);
        // [h0+h2, h1+h3, _, _]
        let folded = _mm_add_ps(h, swapped);
        // lane 0 of `folded` + lane 1 of `folded`
        let s = _mm_add_ss(folded, _mm_shuffle_ps(folded, folded, 0b01));
        _mm_cvtss_f32(s)
    }

    /// Two SSE2 128-bit halves: `.0` holds lanes 0–3, `.1` lanes 4–7.
    #[derive(Clone, Copy)]
    pub(crate) struct Sse2Vec(__m128, __m128);

    impl SimdVec for Sse2Vec {
        // SAFETY: SSE2 is unconditionally available on x86_64.
        #[inline(always)]
        unsafe fn zero() -> Self {
            Sse2Vec(_mm_setzero_ps(), _mm_setzero_ps())
        }

        // SAFETY: SSE2 is unconditionally available on x86_64.
        #[inline(always)]
        unsafe fn splat(v: f32) -> Self {
            let s = _mm_set1_ps(v);
            Sse2Vec(s, s)
        }

        // SAFETY: SSE2 is baseline; the trait contract makes the
        // caller pass a pointer valid for reading 8 f32s.
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            Sse2Vec(_mm_loadu_ps(p), _mm_loadu_ps(p.add(4)))
        }

        // SAFETY: SSE2 is baseline; the trait contract makes the
        // caller pass a pointer valid for writing 8 f32s.
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            _mm_storeu_ps(p, self.0);
            _mm_storeu_ps(p.add(4), self.1);
        }

        // SAFETY: SSE2 is unconditionally available on x86_64.
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            Sse2Vec(_mm_add_ps(self.0, o.0), _mm_add_ps(self.1, o.1))
        }

        // SAFETY: SSE2 is unconditionally available on x86_64.
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            Sse2Vec(_mm_mul_ps(self.0, o.0), _mm_mul_ps(self.1, o.1))
        }

        // SAFETY: SSE2 is unconditionally available on x86_64.
        #[inline(always)]
        unsafe fn hsum(self) -> f32 {
            // l_j + l_{j+4}, then the shared 4-lane fold.
            hsum128(_mm_add_ps(self.0, self.1))
        }
    }

    /// One AVX 256-bit register (dispatched behind the `avx2` probe;
    /// the f32 ops used here are AVX, which AVX2 implies).
    #[derive(Clone, Copy)]
    pub(crate) struct Avx2Vec(__m256);

    impl SimdVec for Avx2Vec {
        // SAFETY: per the trait contract the caller (the kernels.rs
        // dispatcher) proved AVX2 via the runtime probe.
        #[inline(always)]
        unsafe fn zero() -> Self {
            Avx2Vec(_mm256_setzero_ps())
        }

        // SAFETY: AVX2 proved by the caller (trait contract).
        #[inline(always)]
        unsafe fn splat(v: f32) -> Self {
            Avx2Vec(_mm256_set1_ps(v))
        }

        // SAFETY: AVX2 proved by the caller; the trait contract makes
        // it pass a pointer valid for reading 8 f32s.
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            Avx2Vec(_mm256_loadu_ps(p))
        }

        // SAFETY: AVX2 proved by the caller; the trait contract makes
        // it pass a pointer valid for writing 8 f32s.
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            _mm256_storeu_ps(p, self.0);
        }

        // SAFETY: AVX2 proved by the caller (trait contract).
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            Avx2Vec(_mm256_add_ps(self.0, o.0))
        }

        // SAFETY: AVX2 proved by the caller (trait contract).
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            // Deliberately not _mm256_fmadd_ps anywhere: fusing would
            // break bit-identity with the SSE2 and scalar paths.
            Avx2Vec(_mm256_mul_ps(self.0, o.0))
        }

        // SAFETY: AVX2 proved by the caller (trait contract).
        #[inline(always)]
        unsafe fn hsum(self) -> f32 {
            let lo = _mm256_castps256_ps128(self.0);
            let hi = _mm256_extractf128_ps(self.0, 1);
            hsum128(_mm_add_ps(lo, hi))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_lane_ops() {
        let x = F32x8::from_array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(x.add(F32x8::splat(1.0)).to_array()[0], 2.0);
        assert_eq!(x.mul(x).to_array()[3], 16.0);
        assert_eq!(F32x8::zero().hsum(), 0.0);
        assert_eq!(x.hsum(), 36.0);
        assert_eq!(F32x8::from_slice(&[2.0; 9]).hsum(), 16.0);
    }

    /// hsum follows the documented bracketing, not left-to-right
    /// summation — assert with values where the two differ.
    #[test]
    fn hsum_uses_the_canonical_tree() {
        let l = [1e8f32, 1.0, -1e8, 1.0, 0.5, 0.0, 0.25, 0.0];
        let v = F32x8::from_array(l);
        let h = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
        let expect = (h[0] + h[2]) + (h[1] + h[3]);
        assert_eq!(v.hsum().to_bits(), expect.to_bits());
    }

    /// The x86 vector types are lane-exact against the reference.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn x86_paths_match_reference_bitwise() {
        let a: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin() * 1e3).collect();
        let b: Vec<f32> = (0..8).map(|i| (i as f32 * 0.91).cos() + 0.1).collect();
        let ra = F32x8::from_slice(&a);
        let rb = F32x8::from_slice(&b);
        let reference = ra.mul(rb).add(F32x8::splat(0.5));
        let ref_sum = reference.hsum();

        // SAFETY: SSE2 is baseline on x86_64 — always safe to run.
        unsafe {
            let va = x86::Sse2Vec::load(a.as_ptr());
            let vb = x86::Sse2Vec::load(b.as_ptr());
            let v = va.mul(vb).add(x86::Sse2Vec::splat(0.5));
            let mut out = [0.0f32; 8];
            v.store(out.as_mut_ptr());
            assert_eq!(out, reference.to_array());
            assert_eq!(v.hsum().to_bits(), ref_sum.to_bits());
        }
        if crate::simd::is_available(crate::simd::Isa::Avx2) {
            // SAFETY: the probe on the line above proved AVX2.
            unsafe {
                let va = x86::Avx2Vec::load(a.as_ptr());
                let vb = x86::Avx2Vec::load(b.as_ptr());
                let v = va.mul(vb).add(x86::Avx2Vec::splat(0.5));
                let mut out = [0.0f32; 8];
                v.store(out.as_mut_ptr());
                assert_eq!(out, reference.to_array());
                assert_eq!(v.hsum().to_bits(), ref_sum.to_bits());
            }
        }
    }
}
