//! Explicit `f32x8` SIMD micro-kernels under the dispatch layer.
//!
//! The tensor/linalg hot loops — matmul row tiles, dot-product chunk
//! bodies, elementwise axpy/scale/blend — run on an 8-lane `f32`
//! abstraction with three runtime-selected implementations:
//!
//! * **avx2** — one 256-bit register per tile (x86_64, detected via
//!   `is_x86_feature_detected!("avx2")`);
//! * **sse2** — two 128-bit halves (x86_64 baseline);
//! * **scalar** — a portable `[f32; 8]` computing the *same 8-lane
//!   accumulation tree*, so it is the reference semantics, not an
//!   approximation.
//!
//! **Determinism contract** (full statement in `docs/KERNELS.md`):
//! every path performs identical per-lane IEEE-754 operations — no FMA
//! contraction (SSE2 has none, so fusing on AVX2 would break parity),
//! one canonical horizontal-sum bracketing ([`F32x8::hsum`]), and
//! reduction trees derived only from operand sizes. Combined with the
//! backend layer's fixed chunk grids ([`crate::backend`]), results are
//! **bit-identical** across `scalar`/`sse2`/`avx2` × `seq`/`threads:N`
//! (`tests/simd_parity.rs`), so checkpoints and training runs are
//! ISA-portable.
//!
//! **Selection.** The process-wide path defaults to the best available
//! ISA; override with the CLI flag `--simd auto|avx2|sse2|scalar`
//! (every command that accepts `--backend`), the config key `"simd"`,
//! the `EVA_SIMD` environment variable, or [`install`]. Because the
//! paths are bit-identical, the knob is a pure performance/debugging
//! control — switching it never changes a training run.

#![warn(missing_docs)]

mod kernels;
mod vec;

pub use kernels::{axpy8, blend8, dot8, row_dots8, row_mac8, scale8};
pub use vec::F32x8;

use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set path for the `f32x8` micro-kernels, best first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// 256-bit AVX2 tiles (x86_64, runtime-probed).
    Avx2,
    /// Paired 128-bit SSE2 tiles (x86_64 baseline).
    Sse2,
    /// Portable scalar fallback computing the same 8-lane tree.
    Scalar,
}

impl Isa {
    /// The CLI/config spelling: `avx2` | `sse2` | `scalar`.
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Avx2 => "avx2",
            Isa::Sse2 => "sse2",
            Isa::Scalar => "scalar",
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            Isa::Avx2 => 0,
            Isa::Sse2 => 1,
            Isa::Scalar => 2,
        }
    }

    fn from_u8(v: u8) -> Option<Isa> {
        match v {
            0 => Some(Isa::Avx2),
            1 => Some(Isa::Sse2),
            2 => Some(Isa::Scalar),
            _ => None,
        }
    }
}

/// True when `isa` can run on this host (scalar always can; the x86
/// paths need an x86_64 build, and AVX2 additionally needs the CPU
/// probe to pass).
pub fn is_available(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => is_x86_feature_detected!("avx2"),
        #[cfg(not(target_arch = "x86_64"))]
        _ => false,
    }
}

/// The best ISA path available on this host.
pub fn detect_best() -> Isa {
    if is_available(Isa::Avx2) {
        Isa::Avx2
    } else if is_available(Isa::Sse2) {
        Isa::Sse2
    } else {
        Isa::Scalar
    }
}

/// Every ISA path runnable on this host, best first (always ends with
/// [`Isa::Scalar`]). Parity tests iterate this.
pub fn available_isas() -> Vec<Isa> {
    [Isa::Avx2, Isa::Sse2, Isa::Scalar]
        .into_iter()
        .filter(|&isa| is_available(isa))
        .collect()
}

/// Parsed `--simd` / `"simd"` selection (config/CLI layer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdChoice {
    /// Pick the best available path at install time.
    Auto,
    /// Force one specific path (install fails if the host lacks it).
    Force(Isa),
}

impl SimdChoice {
    /// Parse `auto | avx2 | sse2 | scalar`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(SimdChoice::Auto),
            "avx2" => Ok(SimdChoice::Force(Isa::Avx2)),
            "sse2" => Ok(SimdChoice::Force(Isa::Sse2)),
            "scalar" => Ok(SimdChoice::Force(Isa::Scalar)),
            other => Err(format!(
                "unknown simd path '{other}' (use auto | avx2 | sse2 | scalar)"
            )),
        }
    }

    /// Canonical config-string (inverse of [`SimdChoice::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            SimdChoice::Auto => "auto",
            SimdChoice::Force(isa) => isa.name(),
        }
    }
}

/// `u8::MAX` = not yet resolved; first read resolves the boot default.
const UNSET: u8 = u8::MAX;

static ACTIVE: AtomicU8 = AtomicU8::new(UNSET);

/// The ISA path kernels dispatch on. Resolved lazily on first use:
/// the `EVA_SIMD` environment variable if set, otherwise
/// [`detect_best`]; [`install`] overrides it at any time. Like every
/// other selection surface (`--simd`, the config key), an `EVA_SIMD`
/// value that is misspelled or not runnable on this host is a hard
/// error (panic at first kernel use), never a silent downgrade — a
/// perf harness that forces a path must get that path or fail.
/// One relaxed atomic load on the hot path.
#[inline]
pub fn active() -> Isa {
    match Isa::from_u8(ACTIVE.load(Ordering::Relaxed)) {
        Some(isa) => isa,
        None => boot_default(),
    }
}

#[cold]
fn boot_default() -> Isa {
    let isa = match std::env::var("EVA_SIMD") {
        Ok(v) => match SimdChoice::parse(&v) {
            // Resolve without storing: an explicit install() racing
            // this boot path must win, so only the CAS below may write.
            Ok(choice) => resolve(&choice).unwrap_or_else(|e| panic!("EVA_SIMD={v}: {e}")),
            Err(e) => panic!("EVA_SIMD: {e}"),
        },
        Err(_) => detect_best(),
    };
    // First resolution wins, but never clobber a concurrent install().
    let _ = ACTIVE.compare_exchange(UNSET, isa.to_u8(), Ordering::Relaxed, Ordering::Relaxed);
    Isa::from_u8(ACTIVE.load(Ordering::Relaxed)).unwrap_or(Isa::Scalar)
}

/// Validate `choice` against this host without touching the global.
fn resolve(choice: &SimdChoice) -> Result<Isa, String> {
    match *choice {
        SimdChoice::Auto => Ok(detect_best()),
        SimdChoice::Force(isa) => {
            if !is_available(isa) {
                return Err(format!(
                    "simd path '{}' is not available on this host (best available: {})",
                    isa.name(),
                    detect_best().name()
                ));
            }
            Ok(isa)
        }
    }
}

/// Make `choice` the process-wide ISA path; returns the resolved
/// [`Isa`]. Forcing a path the host cannot run is an error (kernels
/// would fault), so config typos and wrong-host checkpoints fail
/// loudly instead of crashing mid-step.
pub fn install(choice: &SimdChoice) -> Result<Isa, String> {
    let isa = resolve(choice)?;
    ACTIVE.store(isa.to_u8(), Ordering::Relaxed);
    Ok(isa)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parses_and_labels() {
        assert_eq!(SimdChoice::parse("auto").unwrap(), SimdChoice::Auto);
        assert_eq!(
            SimdChoice::parse("scalar").unwrap(),
            SimdChoice::Force(Isa::Scalar)
        );
        assert_eq!(SimdChoice::parse("avx2").unwrap().label(), "avx2");
        assert_eq!(SimdChoice::parse("sse2").unwrap().label(), "sse2");
        assert!(SimdChoice::parse("neon").is_err());
        for isa in [Isa::Avx2, Isa::Sse2, Isa::Scalar] {
            assert_eq!(SimdChoice::parse(isa.name()).unwrap(), SimdChoice::Force(isa));
        }
    }

    #[test]
    fn scalar_is_always_available_and_best_is_sane() {
        assert!(is_available(Isa::Scalar));
        let best = detect_best();
        assert!(is_available(best));
        let all = available_isas();
        assert_eq!(all.first().copied(), Some(best));
        assert_eq!(all.last().copied(), Some(Isa::Scalar));
    }

    #[test]
    fn install_switches_and_rejects_unavailable() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let prev = active();
        assert_eq!(install(&SimdChoice::Force(Isa::Scalar)).unwrap(), Isa::Scalar);
        assert_eq!(active(), Isa::Scalar);
        assert_eq!(install(&SimdChoice::Auto).unwrap(), detect_best());
        if !is_available(Isa::Avx2) {
            assert!(install(&SimdChoice::Force(Isa::Avx2)).is_err());
        }
        install(&SimdChoice::Force(prev)).unwrap();
    }
}
