//! The routed micro-kernels: generic 8-lane bodies, monomorphized per
//! ISA and dispatched on [`crate::simd::active`].
//!
//! Each kernel is one `#[inline(always)]` body written against
//! [`SimdVec`], instantiated three times (AVX2 / SSE2 / scalar). The
//! AVX2 instantiations sit inside `#[target_feature(enable = "avx2")]`
//! functions so the intrinsics inline; they are only reachable when the
//! runtime probe confirmed AVX2 (see `mod.rs`). Remainder elements
//! (`len % 8`) always run the same plain scalar tail, identical on
//! every path.

use super::vec::{F32x8, SimdVec};
#[cfg(target_arch = "x86_64")]
use super::vec::x86::{Avx2Vec, Sse2Vec};
use super::{active, Isa};

// ---------------------------------------------------------------------------
// Generic bodies
// ---------------------------------------------------------------------------

/// Dot product: two interleaved 8-lane accumulators (lane `l` of
/// accumulator `p` sums `a[16k + 8p + l]·b[16k + 8p + l]` in ascending
/// `k`), combined lane-wise, then folded with the canonical
/// [`F32x8::hsum`] bracketing; the `< 8` remainder accumulates
/// left-to-right on the scalar tail. The tree is a pure function of
/// the length — never of the ISA, backend, or thread count.
// SAFETY: the caller instantiates `V` only for an ISA it has proved
// active (SimdVec contract); loads stay inside both slices (blocks come from the min length).
#[inline(always)]
unsafe fn dot_body<V: SimdVec>(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let blocks = n / 8;
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = V::zero();
    let mut acc1 = V::zero();
    for k in 0..blocks / 2 {
        let i = 16 * k;
        acc0 = acc0.add(V::load(ap.add(i)).mul(V::load(bp.add(i))));
        acc1 = acc1.add(V::load(ap.add(i + 8)).mul(V::load(bp.add(i + 8))));
    }
    if blocks % 2 == 1 {
        let i = 8 * (blocks - 1);
        acc0 = acc0.add(V::load(ap.add(i)).mul(V::load(bp.add(i))));
    }
    let mut s = acc0.add(acc1).hsum();
    for i in 8 * blocks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y[i] += alpha * x[i]` — elementwise, so any blocking is
/// arithmetic-neutral; vectorization never changes a bit.
// SAFETY: the caller instantiates `V` only for an ISA it has proved
// active (SimdVec contract); loads/stores stay inside both slices (blocks come from the min length).
#[inline(always)]
unsafe fn axpy_body<V: SimdVec>(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = y.len().min(x.len());
    let blocks = n / 8;
    let va = V::splat(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for k in 0..blocks {
        let i = 8 * k;
        V::load(yp.add(i)).add(va.mul(V::load(xp.add(i)))).store(yp.add(i));
    }
    for i in 8 * blocks..n {
        y[i] += alpha * x[i];
    }
}

/// `y[i] *= s` — elementwise.
// SAFETY: the caller instantiates `V` only for an ISA it has proved
// active (SimdVec contract); loads/stores stay inside `y` (blocks come from its length).
#[inline(always)]
unsafe fn scale_body<V: SimdVec>(y: &mut [f32], s: f32) {
    let n = y.len();
    let blocks = n / 8;
    let vs = V::splat(s);
    let yp = y.as_mut_ptr();
    for k in 0..blocks {
        let i = 8 * k;
        V::load(yp.add(i)).mul(vs).store(yp.add(i));
    }
    for i in 8 * blocks..n {
        y[i] *= s;
    }
}

/// One whole matmul output row: `crow += Σ_k a[k·astride] · b_k` with
/// `b_k = b[k·n..(k+1)·n]`, `n = crow.len()`, `kk = b.len()/n`. The
/// entire k-sweep runs inside a single ISA dispatch (one call per
/// output row, not per (row, k) pair). Exactly-zero `a` coefficients
/// skip their sweep on every path alike. Elementwise per `(k, j)` with
/// k ascending per element — bit-identical to the repeated-axpy loop
/// it fuses, on every path.
// SAFETY: the caller instantiates `V` only for an ISA it has proved
// active (SimdVec contract); row pointers stay inside `crow`/`b` (blocks and `kk` come from their lengths).
#[inline(always)]
unsafe fn row_mac_body<V: SimdVec>(crow: &mut [f32], a: &[f32], astride: usize, b: &[f32]) {
    let n = crow.len();
    if n == 0 {
        return;
    }
    let kk = b.len() / n;
    let blocks = n / 8;
    let yp = crow.as_mut_ptr();
    for k in 0..kk {
        let aik = a[k * astride];
        if aik == 0.0 {
            continue;
        }
        let bp = b.as_ptr().add(k * n);
        let va = V::splat(aik);
        for blk in 0..blocks {
            let i = 8 * blk;
            V::load(yp.add(i)).add(va.mul(V::load(bp.add(i)))).store(yp.add(i));
        }
        for i in 8 * blocks..n {
            *yp.add(i) += aik * *bp.add(i);
        }
    }
}

/// One whole `A·Bᵀ` output row: `crow[j] = dot(arow, bt_j)` with
/// `bt_j = bt[j·k..(j+1)·k]`, `k = arow.len()` — every dot runs
/// [`dot_body`]'s fixed tree, all `crow.len()` of them inside a single
/// ISA dispatch.
// SAFETY: the caller instantiates `V` only for an ISA it has proved
// active (SimdVec contract); each dot runs over in-bounds subslices of `bt`.
#[inline(always)]
unsafe fn row_dots_body<V: SimdVec>(crow: &mut [f32], arow: &[f32], bt: &[f32]) {
    let k = arow.len();
    for (j, cv) in crow.iter_mut().enumerate() {
        *cv = dot_body::<V>(arow, &bt[j * k..(j + 1) * k]);
    }
}

/// `y[i] = beta*y[i] + alpha*x[i]` — elementwise, two independent
/// rounded multiplies then one rounded add on every path.
// SAFETY: the caller instantiates `V` only for an ISA it has proved
// active (SimdVec contract); loads/stores stay inside both slices (blocks come from the min length).
#[inline(always)]
unsafe fn blend_body<V: SimdVec>(y: &mut [f32], beta: f32, alpha: f32, x: &[f32]) {
    let n = y.len().min(x.len());
    let blocks = n / 8;
    let vb = V::splat(beta);
    let va = V::splat(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for k in 0..blocks {
        let i = 8 * k;
        let vy = vb.mul(V::load(yp.add(i))).add(va.mul(V::load(xp.add(i))));
        vy.store(yp.add(i));
    }
    for i in 8 * blocks..n {
        y[i] = beta * y[i] + alpha * x[i];
    }
}

// ---------------------------------------------------------------------------
// Per-ISA instantiations
// ---------------------------------------------------------------------------

macro_rules! avx2_entry {
    ($name:ident, ($($arg:ident : $ty:ty),*) -> $ret:ty, $body:ident) => {
        // SAFETY: callable only from the dispatch arms below, which
        // take it only when active() returned Avx2 (runtime probe).
        #[cfg(target_arch = "x86_64")]
        #[target_feature(enable = "avx2")]
        unsafe fn $name($($arg: $ty),*) -> $ret {
            $body::<Avx2Vec>($($arg),*)
        }
    };
}

avx2_entry!(dot_avx2, (a: &[f32], b: &[f32]) -> f32, dot_body);
avx2_entry!(axpy_avx2, (alpha: f32, x: &[f32], y: &mut [f32]) -> (), axpy_body);
avx2_entry!(scale_avx2, (y: &mut [f32], s: f32) -> (), scale_body);
avx2_entry!(blend_avx2, (y: &mut [f32], beta: f32, alpha: f32, x: &[f32]) -> (), blend_body);
avx2_entry!(
    row_mac_avx2,
    (crow: &mut [f32], a: &[f32], astride: usize, b: &[f32]) -> (),
    row_mac_body
);
avx2_entry!(row_dots_avx2, (crow: &mut [f32], arow: &[f32], bt: &[f32]) -> (), row_dots_body);

// SSE2 is baseline on x86_64 — no target_feature gate needed.
// SAFETY: SSE2 is unconditionally available on x86_64.
#[cfg(target_arch = "x86_64")]
unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    dot_body::<Sse2Vec>(a, b)
}
// SAFETY: SSE2 is unconditionally available on x86_64.
#[cfg(target_arch = "x86_64")]
unsafe fn axpy_sse2(alpha: f32, x: &[f32], y: &mut [f32]) {
    axpy_body::<Sse2Vec>(alpha, x, y)
}
// SAFETY: SSE2 is unconditionally available on x86_64.
#[cfg(target_arch = "x86_64")]
unsafe fn scale_sse2(y: &mut [f32], s: f32) {
    scale_body::<Sse2Vec>(y, s)
}
// SAFETY: SSE2 is unconditionally available on x86_64.
#[cfg(target_arch = "x86_64")]
unsafe fn blend_sse2(y: &mut [f32], beta: f32, alpha: f32, x: &[f32]) {
    blend_body::<Sse2Vec>(y, beta, alpha, x)
}
// SAFETY: SSE2 is unconditionally available on x86_64.
#[cfg(target_arch = "x86_64")]
unsafe fn row_mac_sse2(crow: &mut [f32], a: &[f32], astride: usize, b: &[f32]) {
    row_mac_body::<Sse2Vec>(crow, a, astride, b)
}
// SAFETY: SSE2 is unconditionally available on x86_64.
#[cfg(target_arch = "x86_64")]
unsafe fn row_dots_sse2(crow: &mut [f32], arow: &[f32], bt: &[f32]) {
    row_dots_body::<Sse2Vec>(crow, arow, bt)
}

// ---------------------------------------------------------------------------
// Dispatched entrypoints
// ---------------------------------------------------------------------------

/// Dot product over two equal-length slices on the fixed 8-lane
/// accumulation tree (see `docs/KERNELS.md`): the micro-kernel behind
/// [`crate::tensor::dot`]'s chunk bodies and the `matmul_a_bt` row
/// tiles. Bit-identical on every ISA path.
///
/// # Examples
///
/// ```
/// let a = [1.0f32; 16];
/// let b: Vec<f32> = (0..16).map(|i| i as f32).collect();
/// assert_eq!(eva::simd::dot8(&a, &b), 120.0);
/// ```
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    if crate::telemetry::enabled() {
        crate::telemetry::SIMD_DOT8_CALLS.add(1);
        crate::telemetry::SIMD_DOT8_FLOPS.add(2 * a.len() as u64);
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2 only after the runtime probe.
        Isa::Avx2 => unsafe { dot_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is unconditionally available on x86_64.
        Isa::Sse2 => unsafe { dot_sse2(a, b) },
        // SAFETY: the scalar body has no ISA requirement.
        _ => unsafe { dot_body::<F32x8>(a, b) },
    }
}

/// `y += alpha · x` over slices — the 8×-wide elementwise tile behind
/// `tmatvec`/`mean_rows` row accumulation, `Tensor::axpy`/`add_outer`,
/// and the triangular-solve sweeps (matmul rows use the fused
/// [`row_mac8`] so a whole k-sweep costs one dispatch). Elementwise,
/// so it is bit-identical on every ISA path *and* to the plain scalar
/// loop it replaced.
///
/// # Examples
///
/// ```
/// // One k-step of a row accumulation: acc += w_i * row.
/// let mut acc = vec![1.0f32; 10];
/// let row = vec![0.5f32; 10];
/// eva::simd::axpy8(2.0, &row, &mut acc);
/// assert!(acc.iter().all(|&v| v == 2.0));
/// ```
#[inline]
pub fn axpy8(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    if crate::telemetry::enabled() {
        crate::telemetry::SIMD_AXPY8_CALLS.add(1);
        crate::telemetry::SIMD_AXPY8_FLOPS.add(2 * x.len() as u64);
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2 only after the runtime probe.
        Isa::Avx2 => unsafe { axpy_avx2(alpha, x, y) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is unconditionally available on x86_64.
        Isa::Sse2 => unsafe { axpy_sse2(alpha, x, y) },
        // SAFETY: the scalar body has no ISA requirement.
        _ => unsafe { axpy_body::<F32x8>(alpha, x, y) },
    }
}

/// `y *= s` over a slice. Elementwise; bit-identical on every path.
#[inline]
pub fn scale8(y: &mut [f32], s: f32) {
    if crate::telemetry::enabled() {
        crate::telemetry::SIMD_SCALE8_CALLS.add(1);
        crate::telemetry::SIMD_SCALE8_FLOPS.add(y.len() as u64);
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2 only after the runtime probe.
        Isa::Avx2 => unsafe { scale_avx2(y, s) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is unconditionally available on x86_64.
        Isa::Sse2 => unsafe { scale_sse2(y, s) },
        // SAFETY: the scalar body has no ISA requirement.
        _ => unsafe { scale_body::<F32x8>(y, s) },
    }
}

/// The matmul row-tile entrypoint: one whole output row
/// `crow += Σ_k a[k·astride] · b[k·n..(k+1)·n]` (`n = crow.len()`,
/// `k` ranging over `b.len()/n`) in a single ISA dispatch. `astride`
/// is 1 when the A coefficients for this row are contiguous
/// (`matmul`), or the A column stride for transpose-free `Aᵀ·B`
/// (`matmul_at_b`). Per-element accumulation is k-ascending on every
/// path — bit-identical across ISAs *and* to the scalar loop nest it
/// replaces.
///
/// # Examples
///
/// ```
/// // One 1×2·2×3 product row: C[0,:] = 2·B[0,:] + 3·B[1,:].
/// let b = [1.0f32, 10.0, 100.0, 2.0, 20.0, 200.0];
/// let mut crow = [0.0f32; 3];
/// eva::simd::row_mac8(&mut crow, &[2.0, 3.0], 1, &b);
/// assert_eq!(crow, [8.0, 80.0, 800.0]);
/// ```
#[inline]
pub fn row_mac8(crow: &mut [f32], a: &[f32], astride: usize, b: &[f32]) {
    if crate::telemetry::enabled() {
        crate::telemetry::SIMD_ROW_MAC8_CALLS.add(1);
        crate::telemetry::SIMD_ROW_MAC8_FLOPS.add(2 * b.len() as u64);
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2 only after the runtime probe.
        Isa::Avx2 => unsafe { row_mac_avx2(crow, a, astride, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is unconditionally available on x86_64.
        Isa::Sse2 => unsafe { row_mac_sse2(crow, a, astride, b) },
        // SAFETY: the scalar body has no ISA requirement.
        _ => unsafe { row_mac_body::<F32x8>(crow, a, astride, b) },
    }
}

/// The `A·Bᵀ` row-tile entrypoint: `crow[j] = dot(arow, bt[j·k..])`
/// for every `j` (`k = arow.len()`) in a single ISA dispatch, each dot
/// on [`dot8`]'s fixed tree. Bit-identical on every path.
#[inline]
pub fn row_dots8(crow: &mut [f32], arow: &[f32], bt: &[f32]) {
    debug_assert_eq!(bt.len(), arow.len() * crow.len());
    if crate::telemetry::enabled() {
        crate::telemetry::SIMD_ROW_DOTS8_CALLS.add(1);
        crate::telemetry::SIMD_ROW_DOTS8_FLOPS.add(2 * bt.len() as u64);
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2 only after the runtime probe.
        Isa::Avx2 => unsafe { row_dots_avx2(crow, arow, bt) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is unconditionally available on x86_64.
        Isa::Sse2 => unsafe { row_dots_sse2(crow, arow, bt) },
        // SAFETY: the scalar body has no ISA requirement.
        _ => unsafe { row_dots_body::<F32x8>(crow, arow, bt) },
    }
}

/// `y = beta·y + alpha·x` over slices — running averages (Eva's KV
/// blends, Eq. 14–15; the K-FAC/FOOF factor blends via
/// [`crate::tensor::Tensor::blend`]). Elementwise; bit-identical on
/// every path.
#[inline]
pub fn blend8(y: &mut [f32], beta: f32, alpha: f32, x: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    if crate::telemetry::enabled() {
        crate::telemetry::SIMD_BLEND8_CALLS.add(1);
        crate::telemetry::SIMD_BLEND8_FLOPS.add(3 * x.len() as u64);
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2 only after the runtime probe.
        Isa::Avx2 => unsafe { blend_avx2(y, beta, alpha, x) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is unconditionally available on x86_64.
        Isa::Sse2 => unsafe { blend_sse2(y, beta, alpha, x) },
        // SAFETY: the scalar body has no ISA requirement.
        _ => unsafe { blend_body::<F32x8>(y, beta, alpha, x) },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::simd::{install, is_available, SimdChoice};

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seeded(seed);
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        (a, b)
    }

    /// Every available ISA path reproduces the scalar reference
    /// bit-for-bit on every kernel, including tail lengths.
    #[test]
    fn isa_paths_match_scalar_reference_bitwise() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let prev = crate::simd::active();
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 64, 1000, 8195] {
            let (a, b) = vecs(n, 42 + n as u64);
            install(&SimdChoice::Force(Isa::Scalar)).unwrap();
            let dot_ref = dot8(&a, &b);
            let mut axpy_ref = b.clone();
            axpy8(0.37, &a, &mut axpy_ref);
            let mut scale_ref = a.clone();
            scale8(&mut scale_ref, -1.25);
            let mut blend_ref = b.clone();
            blend8(&mut blend_ref, 0.95, 0.05, &a);
            // Row tiles: 3 "k-steps" over rows of length n (a carries
            // a zero to exercise the skip arm on every path).
            let coeffs = [0.6f32, 0.0, -1.1];
            let bmat: Vec<f32> = (0..3 * n).map(|i| (i as f32 * 0.11).sin()).collect();
            let mut mac_ref = a.clone();
            row_mac8(&mut mac_ref, &coeffs, 1, &bmat);
            let mut dots_ref = vec![0.0f32; 3];
            row_dots8(&mut dots_ref, &a, &bmat);
            for isa in [Isa::Sse2, Isa::Avx2] {
                if !is_available(isa) {
                    continue;
                }
                install(&SimdChoice::Force(isa)).unwrap();
                assert_eq!(dot8(&a, &b).to_bits(), dot_ref.to_bits(), "dot8 {isa:?} n={n}");
                let mut y = b.clone();
                axpy8(0.37, &a, &mut y);
                assert_eq!(y, axpy_ref, "axpy8 {isa:?} n={n}");
                let mut y = a.clone();
                scale8(&mut y, -1.25);
                assert_eq!(y, scale_ref, "scale8 {isa:?} n={n}");
                let mut y = b.clone();
                blend8(&mut y, 0.95, 0.05, &a);
                assert_eq!(y, blend_ref, "blend8 {isa:?} n={n}");
                let mut y = a.clone();
                row_mac8(&mut y, &coeffs, 1, &bmat);
                assert_eq!(y, mac_ref, "row_mac8 {isa:?} n={n}");
                let mut y = vec![0.0f32; 3];
                row_dots8(&mut y, &a, &bmat);
                assert_eq!(y, dots_ref, "row_dots8 {isa:?} n={n}");
            }
        }
        install(&SimdChoice::Force(prev)).unwrap();
    }

    /// The kernels compute the right values, not just consistent ones.
    #[test]
    fn kernels_match_naive_math() {
        let (a, b) = vecs(37, 7);
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot8(&a, &b) - naive).abs() < 1e-4);
        let mut y = b.clone();
        axpy8(2.0, &a, &mut y);
        for i in 0..37 {
            assert_eq!(y[i].to_bits(), (b[i] + 2.0 * a[i]).to_bits());
        }
        let mut y = a.clone();
        scale8(&mut y, 0.5);
        for i in 0..37 {
            assert_eq!(y[i].to_bits(), (a[i] * 0.5).to_bits());
        }
        let mut y = b.clone();
        blend8(&mut y, 0.25, 0.75, &a);
        for i in 0..37 {
            assert_eq!(y[i].to_bits(), (0.25 * b[i] + 0.75 * a[i]).to_bits());
        }
    }

    #[test]
    fn empty_slices_are_fine() {
        assert_eq!(dot8(&[], &[]), 0.0);
        let mut y: Vec<f32> = Vec::new();
        axpy8(1.0, &[], &mut y);
        scale8(&mut y, 2.0);
        blend8(&mut y, 0.5, 0.5, &[]);
        assert!(y.is_empty());
    }
}
