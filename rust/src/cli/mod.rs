//! Command-line parsing (substrate; no clap offline).
//!
//! Grammar: `eva <command> [positional] [--key value | --flag]`.

use std::collections::BTreeMap;

/// Parsed invocation.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Cli {
    /// Parse an argv (without the program name).
    pub fn parse(args: &[String]) -> Result<Cli, String> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        if let Some(cmd) = it.next() {
            cli.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    cli.options.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    cli.flags.push(name.to_string());
                }
            } else {
                cli.positional.push(a.clone());
            }
        }
        Ok(cli)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_f32(&self, key: &str) -> Result<Option<f32>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s.parse().map(Some).map_err(|_| format!("--{key}: bad number '{s}'")),
        }
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s.parse().map(Some).map_err(|_| format!("--{key}: bad integer '{s}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

pub const USAGE: &str = "\
eva — vectorized second-order optimization (paper reproduction)

USAGE:
  eva train [--config FILE | --preset NAME] [--optimizer ALG] [--dataset D]
            [--epochs N] [--lr F] [--batch N] [--seed N] [--engine native|pjrt:MODEL]
            [--interval N] [--damping F] [--max-steps N] [--backend seq|threads[:N]]
            [--worker-threads N]
  eva experiment <id|all>     regenerate a paper table/figure (see DESIGN.md §5)
  eva validate                cross-check PJRT artifacts vs native numerics
  eva list                    list datasets, optimizers, experiments, artifacts
  eva info                    runtime + manifest summary

OPTIONS:
  --backend seq|threads[:N]   compute backend for tensor/linalg hot paths
                              (seq = single-threaded; threads = one lane per
                              hardware thread; threads:N = N lanes). Applies
                              to every command; numerics are identical.
  --worker-threads N          data-parallel runs only: give every simulated
                              worker its own N-lane sub-pool instead of
                              carving the --backend lane budget evenly
                              across workers. Numerics are identical.

EXAMPLES:
  eva train --preset quickstart --optimizer eva
  eva train --dataset c100-small --optimizer kfac --interval 10 --epochs 8
  eva train --engine pjrt:quickstart --optimizer eva --epochs 4
  eva train --preset c100-bench --optimizer shampoo --backend threads:8
  eva experiment table5 --backend threads
  eva experiment table8 --backend threads:8 --worker-threads 2
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_command_options_flags() {
        // NOTE: a trailing non-dashed token after `--name` binds as its
        // value (option-vs-flag is positional, like most getopt-style
        // parsers) — so positionals come before flags here.
        let c = Cli::parse(&argv("train pos1 --optimizer eva --epochs 3 --verbose")).unwrap();
        assert_eq!(c.command, "train");
        assert_eq!(c.opt("optimizer"), Some("eva"));
        assert_eq!(c.opt_usize("epochs").unwrap(), Some(3));
        assert!(c.has_flag("verbose"));
        assert_eq!(c.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let c = Cli::parse(&argv("train --lr=0.05")).unwrap();
        assert_eq!(c.opt_f32("lr").unwrap(), Some(0.05));
    }

    #[test]
    fn bad_number_is_error() {
        let c = Cli::parse(&argv("train --lr abc")).unwrap();
        assert!(c.opt_f32("lr").is_err());
    }

    #[test]
    fn empty_args() {
        let c = Cli::parse(&[]).unwrap();
        assert_eq!(c.command, "");
    }
}
