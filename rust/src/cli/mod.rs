//! Command-line parsing (substrate; no clap offline).
//!
//! Grammar: `eva <command> [positional] [--key value | --flag]`.

use std::collections::BTreeMap;

/// Parsed invocation.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Cli {
    /// Parse an argv (without the program name).
    pub fn parse(args: &[String]) -> Result<Cli, String> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        if let Some(cmd) = it.next() {
            cli.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    cli.options.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    cli.flags.push(name.to_string());
                }
            } else {
                cli.positional.push(a.clone());
            }
        }
        Ok(cli)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_f32(&self, key: &str) -> Result<Option<f32>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s.parse().map(Some).map_err(|_| format!("--{key}: bad number '{s}'")),
        }
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s.parse().map(Some).map_err(|_| format!("--{key}: bad integer '{s}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Reject options/flags the command doesn't recognize (typos used
    /// to be silently ignored — `--epcohs 3` would happily train with
    /// the default). Commands not in [`known_options`] are passed
    /// through; the command dispatcher reports those itself.
    pub fn reject_unknown(&self) -> Result<(), String> {
        let Some(spec) = known_options(&self.command) else {
            return Ok(());
        };
        let accepts = |k: &str| spec.options.contains(&k) || GLOBAL_OPTIONS.contains(&k);
        for k in self.options.keys() {
            if !accepts(k.as_str()) {
                return Err(format!(
                    "unknown option '--{k}' for '{}' (see `eva help`)",
                    self.command
                ));
            }
        }
        for f in &self.flags {
            if accepts(f.as_str()) {
                // A value-taking option given last with no value parses
                // as a flag; make the mistake explicit.
                return Err(format!("option '--{f}' needs a value"));
            }
            if !spec.flags.contains(&f.as_str()) {
                return Err(format!(
                    "unknown flag '--{f}' for '{}' (see `eva help`)",
                    self.command
                ));
            }
        }
        Ok(())
    }
}

/// Options every command accepts (process-wide knobs). `--simd` rides
/// with `--backend` everywhere: both select a compute path whose
/// numerics are bit-identical, so they apply uniformly to every
/// subcommand.
pub const GLOBAL_OPTIONS: &[&str] = &["backend", "worker-threads", "simd", "telemetry"];

/// Every command registered in [`known_options`] (canonical names
/// only; the parser also accepts `""`/`--help`/`-h` as `help`). Tests
/// iterate this to keep [`USAGE`] and [`Cli::reject_unknown`] in sync
/// instead of hand-maintaining a second list.
pub const KNOWN_COMMANDS: &[&str] = &[
    "train",
    "serve",
    "router",
    "health",
    "lint",
    "experiment",
    "validate",
    "list",
    "info",
    "help",
];

/// Per-command accepted options and flags.
pub struct CommandSpec {
    /// Options that take a value (`--name value` / `--name=value`).
    pub options: &'static [&'static str],
    /// Boolean flags.
    pub flags: &'static [&'static str],
}

/// The option/flag vocabulary of each built-in command, used by
/// [`Cli::reject_unknown`]. Returns `None` for commands this registry
/// doesn't know (the dispatcher errors on those separately).
pub fn known_options(command: &str) -> Option<CommandSpec> {
    fn spec(
        options: &'static [&'static str],
        flags: &'static [&'static str],
    ) -> Option<CommandSpec> {
        Some(CommandSpec { options, flags })
    }
    match command {
        "train" => spec(
            &[
                "config",
                "preset",
                "optimizer",
                "dataset",
                "epochs",
                "lr",
                "batch",
                "seed",
                "interval",
                "damping",
                "max-steps",
                "schedule",
                "hidden",
                "engine",
            ],
            &[],
        ),
        "serve" => spec(
            &[
                "config",
                "addr",
                "max-sessions",
                "max-per-tenant",
                "checkpoint-dir",
                "checkpoint-every",
                "retain-terminal",
                "retain-snapshots",
                "resume-dir",
                "quantum",
                "metrics-addr",
                "trace-out",
                "health-every",
            ],
            &[],
        ),
        "router" => spec(
            &[
                "config",
                "addr",
                "hosts",
                "checkpoint-dirs",
                "probe-interval-ms",
                "probe-timeout-ms",
                "probe-fails",
                "request-timeout-ms",
                "auto-migrate",
            ],
            &[],
        ),
        "health" => spec(&["addr", "session"], &[]),
        "lint" => spec(&["format"], &["fix-list"]),
        "experiment" | "validate" | "list" | "info" => spec(&[], &[]),
        "" | "help" | "--help" | "-h" => spec(&[], &[]),
        _ => None,
    }
}

pub const USAGE: &str = "\
eva — vectorized second-order optimization (paper reproduction)

USAGE:
  eva train [--config FILE | --preset NAME] [--optimizer ALG] [--dataset D]
            [--epochs N] [--lr F] [--batch N] [--seed N] [--engine native|pjrt:MODEL]
            [--interval N] [--damping F] [--max-steps N] [--schedule NAME]
            [--hidden D1,D2,...] [--backend seq|threads[:N]]
            [--worker-threads N] [--simd auto|avx2|sse2|scalar]
  eva serve [--config FILE] [--addr HOST:PORT] [--max-sessions N]
            [--max-per-tenant N] [--checkpoint-dir DIR]
            [--checkpoint-every N] [--retain-terminal N]
            [--retain-snapshots N] [--resume-dir DIR] [--quantum N]
            [--metrics-addr HOST:PORT] [--trace-out FILE]
            [--health-every N]
  eva router [--config FILE] [--addr HOST:PORT] [--hosts A1,A2,...]
            [--checkpoint-dirs D1,D2,...] [--probe-interval-ms N]
            [--probe-timeout-ms N] [--probe-fails N]
            [--request-timeout-ms N] [--auto-migrate on|off]
  eva health [--addr HOST:PORT] [--session ID]
                              optimizer-health report from a serve/router
                              control plane: per-layer second-order
                              diagnostics + anomaly flags
  eva lint [PATHS...] [--fix-list] [--format text|json]
                              repo-invariant static analysis (rules L1-L6,
                              see docs/LINTS.md); exits nonzero on violations
  eva experiment <id|all>     regenerate a paper table/figure (see DESIGN.md §5)
  eva validate                cross-check PJRT artifacts vs native numerics
  eva list                    list datasets, optimizers, experiments, artifacts
  eva info                    runtime + manifest summary

Unknown --options are rejected (typos used to be silently ignored).

OPTIONS:
  --optimizer ALG             training algorithm, one of: sgd adagrad adam
                              adamw eva eva-f eva-s kfac foof foof-rank1
                              shampoo mfac mkor kradagrad
                              (the same registry `eva list` prints)
  --backend seq|threads[:N]   compute backend for tensor/linalg hot paths
                              (seq = single-threaded; threads = one lane per
                              hardware thread; threads:N = N lanes). Applies
                              to every command; numerics are identical.
  --worker-threads N          data-parallel runs only: give every simulated
                              worker its own N-lane sub-pool instead of
                              carving the --backend lane budget evenly
                              across workers. Numerics are identical.
  --simd auto|avx2|sse2|scalar
                              ISA path for the f32x8 micro-kernels (auto =
                              best available; forcing an unavailable path is
                              an error). Applies to every command; numerics
                              are bit-identical across paths — see
                              docs/KERNELS.md.
  --telemetry on|off          metrics registry + tracing spans (default on;
                              env EVA_TELEMETRY overrides the default).
                              Instrumentation never touches numerics: runs
                              are bit-identical either way. `eva serve`
                              exposes the registry via the `metrics` and
                              streaming `watch` protocol commands.

SERVE OPTIONS (multi-tenant training-session service):
  --addr HOST:PORT            control-plane listen address (newline-delimited
                              JSON; default 127.0.0.1:7931, port 0 = ephemeral)
  --max-sessions N            cap on concurrently *admitted* sessions
                              (default 8); submits past it queue (reported
                              queue_position) and are promoted FIFO within
                              priority as slots free — never rejected
  --max-per-tenant N          cap on live sessions per tenant (explicit
                              submit `tenant` field, else the session-name
                              prefix before the first '/'); 0 = unlimited
  --checkpoint-dir DIR        where checkpoint snapshots are written
                              (default ./checkpoints; writes are atomic
                              tmp + rename)
  --checkpoint-every N        auto-checkpoint each session every N steps
                              (default 0 = off); live sessions are also
                              snapshotted on shutdown/SIGTERM
  --retain-terminal N         keep at most N terminal sessions for status
                              queries (default 64); older ones are evicted
  --retain-snapshots N        keep only the newest N loadable snapshots per
                              checkpoint lineage, pruning older ones after
                              each write (default 0 = unlimited; terminal
                              tombstones are never pruned)
  --resume-dir DIR            on boot, re-admit the newest checkpoint per
                              session lineage found in DIR (restart-
                              transparent serving)
  --quantum N                 steps per scheduler time-slice (default 8)
  --metrics-addr HOST:PORT    serve a Prometheus text-exposition scrape
                              endpoint (HTTP GET) on a separate listener;
                              port 0 = ephemeral (off by default)
  --trace-out FILE            write a Chrome trace-event JSON of per-step
                              phase spans at shutdown — open in Perfetto
                              (ui.perfetto.dev) or chrome://tracing
  --health-every N            sample per-layer optimizer-health diagnostics
                              every Nth step (default 10; 0 = off). Purely
                              observational: numerics are bit-identical at
                              any cadence. Query via `eva health` or the
                              `health` protocol command
  --config FILE               JSON file with serve_addr / max_sessions /
                              max_sessions_per_tenant / checkpoint_dir /
                              checkpoint_every_steps / checkpoint_on_shutdown /
                              retain_terminal / retain_snapshots / resume_dir /
                              quantum_steps / metrics_addr / trace_out /
                              health_every_steps keys (flags override the file)

ROUTER OPTIONS (multi-host cluster front door; see docs/ARCHITECTURE.md):
  --addr HOST:PORT            router listen address (same ndjson protocol as
                              serve; default 127.0.0.1:7940, port 0 = ephemeral)
  --hosts A1,A2,...           backend serve addresses, comma-separated;
                              sessions are placed by rendezvous hashing on
                              their checkpoint lineage stem
  --checkpoint-dirs D1,D2,... each host's checkpoint_dir as the *router* sees
                              it (same order as --hosts); needed to rescue
                              sessions off a host that dies without warning
  --probe-interval-ms N       health-probe period (default 1000; the probe is
                              the ordinary `stats` command)
  --probe-timeout-ms N        per-host probe budget (default 500); a host that
                              accepts TCP but never replies counts as failed
  --probe-fails N             consecutive failed probes before a host is down
                              and its sessions are rescued (default 3; fewer
                              failures mark it suspect = no new placements)
  --request-timeout-ms N      proxied client-request budget (default 5000)
  --auto-migrate on|off       rescue sessions off down hosts from their newest
                              loadable checkpoint (default on)

LINT OPTIONS (static analysis; the CI `lint` job runs this blocking):
  PATHS...                    files/directories to lint (default: the whole
                              rust/src tree)
  --format text|json          report format (default text; json is what CI
                              uploads as an artifact on failure)
  --fix-list                  print a per-finding worklist with the exact
                              `// eva-lint: allow(<rule>) -- <reason>`
                              suppression syntax (reason mandatory)

HEALTH OPTIONS (optimizer-health report; speaks to serve or router):
  --addr HOST:PORT            control plane to query (default 127.0.0.1:7931)
  --session ID                report one session's per-layer rings instead of
                              the service/fleet aggregate

EXAMPLES:
  eva train --preset quickstart --optimizer eva
  eva train --dataset c100-small --optimizer kfac --interval 10 --epochs 8
  eva train --engine pjrt:quickstart --optimizer eva --epochs 4
  eva train --preset c100-bench --optimizer shampoo --backend threads:8
  eva train --preset quickstart --optimizer eva --simd scalar   # same bits, slower
  eva train --preset quickstart --optimizer mkor --interval 5
  eva train --preset quickstart --optimizer kradagrad
  eva serve --backend threads:8 --max-sessions 4 --checkpoint-dir /tmp/ck
  eva experiment table5 --backend threads
  eva experiment table8 --backend threads:8 --worker-threads 2
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_command_options_flags() {
        // NOTE: a trailing non-dashed token after `--name` binds as its
        // value (option-vs-flag is positional, like most getopt-style
        // parsers) — so positionals come before flags here.
        let c = Cli::parse(&argv("train pos1 --optimizer eva --epochs 3 --verbose")).unwrap();
        assert_eq!(c.command, "train");
        assert_eq!(c.opt("optimizer"), Some("eva"));
        assert_eq!(c.opt_usize("epochs").unwrap(), Some(3));
        assert!(c.has_flag("verbose"));
        assert_eq!(c.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let c = Cli::parse(&argv("train --lr=0.05")).unwrap();
        assert_eq!(c.opt_f32("lr").unwrap(), Some(0.05));
    }

    #[test]
    fn bad_number_is_error() {
        let c = Cli::parse(&argv("train --lr abc")).unwrap();
        assert!(c.opt_f32("lr").is_err());
    }

    #[test]
    fn empty_args() {
        let c = Cli::parse(&[]).unwrap();
        assert_eq!(c.command, "");
    }

    #[test]
    fn unknown_options_are_rejected() {
        // Typo'd option: error instead of silent ignore.
        let c = Cli::parse(&argv("train --epcohs 3")).unwrap();
        let e = c.reject_unknown().unwrap_err();
        assert!(e.contains("--epcohs"), "{e}");
        // Unknown flag too.
        let c = Cli::parse(&argv("train --preset quickstart --verbose")).unwrap();
        assert!(c.reject_unknown().is_err());
        // Valid invocations pass, including global options everywhere.
        for ok in [
            "train --preset quickstart --optimizer eva --backend threads:2",
            "train --preset quickstart --simd scalar",
            "serve --addr 127.0.0.1:0 --max-sessions 2 --checkpoint-dir /tmp/x",
            "experiment table5 --backend threads",
            "experiment table5 --simd avx2",
            "list",
        ] {
            let c = Cli::parse(&argv(ok)).unwrap();
            c.reject_unknown().unwrap_or_else(|e| panic!("{ok}: {e}"));
        }
        // A value option left dangling reads as a flag → explicit error.
        let c = Cli::parse(&argv("serve --max-sessions")).unwrap();
        let e = c.reject_unknown().unwrap_err();
        assert!(e.contains("needs a value"), "{e}");
        // Unknown commands pass through (dispatcher reports them).
        let c = Cli::parse(&argv("frobnicate --whatever x")).unwrap();
        assert!(c.reject_unknown().is_ok());
    }

    #[test]
    fn usage_covers_serve() {
        assert!(USAGE.contains("eva serve"));
        assert!(USAGE.contains("--checkpoint-dir"));
        assert!(USAGE.contains("--max-sessions"));
    }

    /// USAGE and `reject_unknown` stay in sync by construction: walk
    /// the registry ([`KNOWN_COMMANDS`] × [`known_options`] +
    /// [`GLOBAL_OPTIONS`]) instead of a hand-maintained list — every
    /// registered option must appear in USAGE, and every one must be
    /// accepted by `reject_unknown` on its command.
    #[test]
    fn usage_and_registry_stay_in_sync() {
        for cmd in KNOWN_COMMANDS {
            let spec = known_options(cmd).unwrap_or_else(|| {
                panic!("'{cmd}' listed in KNOWN_COMMANDS but not in known_options")
            });
            for opt in spec.options.iter().chain(GLOBAL_OPTIONS) {
                assert!(
                    USAGE.contains(&format!("--{opt}")),
                    "USAGE is missing --{opt} (accepted by '{cmd}')"
                );
                let c = Cli::parse(&[cmd.to_string(), format!("--{opt}"), "x".into()]).unwrap();
                c.reject_unknown()
                    .unwrap_or_else(|e| panic!("'{cmd} --{opt} x' rejected: {e}"));
            }
            for flag in spec.flags {
                assert!(
                    USAGE.contains(&format!("--{flag}")),
                    "USAGE is missing --{flag} (accepted by '{cmd}')"
                );
                let c = Cli::parse(&[cmd.to_string(), format!("--{flag}")]).unwrap();
                c.reject_unknown()
                    .unwrap_or_else(|e| panic!("'{cmd} --{flag}' rejected: {e}"));
            }
        }
        // And every command name itself shows up in USAGE (help is the
        // USAGE text).
        for cmd in KNOWN_COMMANDS.iter().filter(|c| **c != "help") {
            assert!(USAGE.contains(&format!("eva {cmd}")), "USAGE missing 'eva {cmd}'");
        }
    }

    /// `eva list`, the USAGE enumeration, and the optimizer registry
    /// cannot drift: `list` prints `OPTIMIZER_NAMES` directly, and this
    /// test pins the USAGE `--optimizer` enumeration to exactly that
    /// constant (no missing names, no stale ones) with every entry
    /// buildable through `by_name`.
    #[test]
    fn optimizer_registry_usage_and_list_stay_in_sync() {
        use crate::optim::{by_name, HyperParams, OPTIMIZER_NAMES};
        let hp = HyperParams::default();
        let start = USAGE.find("one of:").expect("USAGE must enumerate --optimizer ALG");
        let rel_end = USAGE[start..]
            .find('(')
            .expect("the --optimizer enumeration must close with a parenthetical");
        let tokens: Vec<&str> =
            USAGE[start + "one of:".len()..start + rel_end].split_whitespace().collect();
        assert_eq!(
            tokens.len(),
            OPTIMIZER_NAMES.len(),
            "USAGE enumerates {} optimizers, registry has {}",
            tokens.len(),
            OPTIMIZER_NAMES.len()
        );
        for t in &tokens {
            assert!(OPTIMIZER_NAMES.contains(t), "USAGE lists '{t}' but the registry doesn't");
        }
        for n in OPTIMIZER_NAMES {
            assert!(tokens.contains(n), "USAGE enumeration is missing '{n}'");
            by_name(n, &hp).unwrap_or_else(|e| panic!("registry name '{n}' doesn't build: {e}"));
        }
    }
}
