//! Persistent worker pool (substrate; no rayon offline).
//!
//! One pool = N−1 parked worker threads plus the injecting thread,
//! cooperating on a single chunked job at a time. Jobs are borrowed
//! closures (`&dyn Fn(usize) + Sync`): the injector publishes the
//! closure with its lifetime erased, workers pull chunk indices from a
//! shared counter, and the injector does not return until every chunk
//! has finished — which is exactly what makes the lifetime erasure
//! sound (the borrow strictly outlives all uses).
//!
//! Design notes:
//! * **One job at a time (per pool).** A second injector blocks on
//!   `inject` until the current job drains. Dispatch epochs guard
//!   against stale workers claiming chunks of a newer job.
//! * **Ancestor nesting runs inline; sibling nesting fans out.** Every
//!   pool has a unique id, and every job carries the chain of pool ids
//!   it is (transitively) running under — its injector's chain plus
//!   the publishing pool — which chunk executors push for the duration
//!   of each chunk (`serving`). A chunk body that calls
//!   [`WorkerPool::run`] on any pool in its chain (same-pool nesting,
//!   e.g. a parallel layer loop whose per-layer work calls a parallel
//!   matmul, or a sub-pool chunk reaching back to the coordinator's
//!   pool) executes sequentially: that pool's job is blocked on this
//!   chunk, so injecting would deadlock. Dispatch into an *unrelated*
//!   pool (the data-parallel coordinator's per-worker sub-pools, see
//!   [`crate::backend::split`]) injects normally and runs in parallel
//!   there. Caveat: cross-pool injection must stay **tree-shaped** —
//!   two pools whose concurrent jobs inject into *each other* (an
//!   ABBA cycle between unrelated pools) would block on each other's
//!   inject locks forever. The chain rule only detects ancestors; it
//!   cannot see a cycle formed by two independent injectors. The
//!   `current()` resolution in [`crate::backend`] never builds such a
//!   shape (implicit nested dispatch inlines; scoped handles are
//!   per-worker trees), so this only concerns direct `WorkerPool`
//!   users.
//! * **Panic-tolerant accounting.** Chunk completion is decremented by
//!   a drop guard, so a panicking chunk body cannot strand the
//!   injector; workers catch the unwind and keep serving.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Monotonic pool-id source; id 0 is never used, so a zeroed slot can
/// never alias a live pool.
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Ids of the pools whose jobs the code on this thread is
    /// (transitively) running under, innermost last. Pushed around
    /// every chunk execution from the job's serving context — which
    /// includes the pools the *injector* was serving when it published
    /// the job — so a chunk can tell that a pool is an ancestor even
    /// when the ancestor's chunk lives on a different thread.
    static SERVING: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// True while the current thread is executing inside any pool job
/// (worker thread, or injector during its participation phase).
pub fn in_pool() -> bool {
    SERVING.with(|s| !s.borrow().is_empty())
}

/// True while the current thread is executing a chunk of *this* pool's
/// job — the condition under which [`WorkerPool::run`] must inline.
fn serving(id: u64) -> bool {
    SERVING.with(|s| s.borrow().contains(&id))
}

/// Lock helper that shrugs off poisoning (a panicking chunk body must
/// not wedge every later dispatch).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The published job: lifetime-erased chunk body + serving context +
/// chunk count.
#[derive(Clone, Copy)]
struct Job {
    body: &'static (dyn Fn(usize) + Sync),
    /// Pool ids this job is (transitively) running under, ending with
    /// the publishing pool's own id. Pushed onto each executing
    /// thread's `SERVING` stack for the duration of a chunk, so
    /// dispatch back into *any* ancestor pool inlines — the ancestor's
    /// job is blocked on this chunk, and injecting into it would
    /// deadlock. Same lifetime-erasure argument as `body`.
    ctx: &'static [u64],
    chunks: usize,
}

/// Shared dispatch state.
struct Slot {
    job: Option<Job>,
    /// Next unclaimed chunk index of the current job.
    next: usize,
    /// Chunks not yet finished (claimed-and-running included).
    remaining: usize,
    /// Bumped once per injected job; stale workers compare-and-skip.
    epoch: u64,
    /// Set when a chunk body panicked on a worker; the injector
    /// re-raises after the job drains so a partial result can never
    /// be mistaken for a complete one.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Signals workers: new job or shutdown.
    work_cv: Condvar,
    /// Signals the injector: all chunks finished.
    done_cv: Condvar,
}

/// Decrements `remaining` even if the chunk body panics.
struct FinishGuard<'a> {
    shared: &'a Shared,
}

impl Drop for FinishGuard<'_> {
    fn drop(&mut self) {
        let mut g = lock(&self.shared.slot);
        // Flag panics under the same lock acquisition as the final
        // decrement, so the injector can never observe `remaining ==
        // 0` without also observing the flag.
        if std::thread::panicking() {
            g.panicked = true;
        }
        g.remaining -= 1;
        if g.remaining == 0 {
            self.shared.done_cv.notify_all();
        }
    }
}

/// Blocks until the current job fully drains, then retires it. Runs on
/// drop so an unwinding injector cannot leave workers holding the
/// lifetime-erased closure past its borrow.
struct JobGuard<'a> {
    shared: &'a Shared,
}

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        let mut g = lock(&self.shared.slot);
        while g.remaining > 0 {
            g = self.shared.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        g.job = None;
        // If we are unwinding from the injector's own chunk, a stale
        // worker-panic flag must not leak into the next job.
        g.panicked = false;
    }
}

/// Marks the current thread as serving a job's pool chain for a scope;
/// pops the marks on exit (panic included).
struct ServeGuard {
    count: usize,
}

impl ServeGuard {
    fn enter(ids: &[u64]) -> Self {
        SERVING.with(|s| s.borrow_mut().extend_from_slice(ids));
        ServeGuard { count: ids.len() }
    }
}

impl Drop for ServeGuard {
    fn drop(&mut self) {
        SERVING.with(|s| {
            let mut s = s.borrow_mut();
            let keep = s.len() - self.count;
            s.truncate(keep);
        });
    }
}

/// Claim and execute chunks of job `epoch` until none are left.
///
/// The body reference is re-read from the slot *under the lock* at
/// every claim: a successful claim proves an unclaimed chunk existed,
/// hence `remaining > 0`, hence the injector is still blocked in
/// [`WorkerPool::run`] and the erased borrow is live for the whole
/// `body(idx)` call (our own chunk keeps `remaining > 0` until the
/// guard drops).
fn run_chunks(shared: &Shared, epoch: u64) {
    loop {
        let (idx, job) = {
            let mut g = lock(&shared.slot);
            match g.job {
                Some(j) if g.epoch == epoch && g.next < j.chunks => {
                    let i = g.next;
                    g.next += 1;
                    (i, j)
                }
                _ => break,
            }
        };
        // Serve the job's whole pool chain while the body runs (the
        // guard drops after FinishGuard, which never touches the
        // erased `ctx` borrow).
        let _serve = ServeGuard::enter(job.ctx);
        let _finish = FinishGuard { shared };
        (job.body)(idx);
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let epoch = {
            let mut g = lock(&shared.slot);
            loop {
                if g.shutdown {
                    return;
                }
                match g.job {
                    Some(j) if g.next < j.chunks => break g.epoch,
                    _ => g = shared.work_cv.wait(g).unwrap_or_else(|e| e.into_inner()),
                }
            }
        };
        // Contain panics from chunk bodies so the pool keeps its
        // workers. FinishGuard has already balanced the books *and*
        // set the panic flag (under the decrement's lock), which the
        // injector re-raises on its own thread; the worker's default
        // panic hook has already printed the message + location.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_chunks(shared, epoch);
        }));
    }
}

/// A persistent pool of worker threads executing chunked jobs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serializes injectors; held for the whole duration of a job.
    inject: Mutex<()>,
    threads: usize,
    /// Unique pool identity — what lets nested dispatch distinguish
    /// "inject into my own pool" (inline) from "inject into a sibling
    /// pool" (fan out).
    id: u64,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Pool with `threads` total execution lanes (the injecting thread
    /// counts as one, so `threads - 1` OS threads are spawned).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let id = NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                job: None,
                next: 0,
                remaining: 0,
                epoch: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads - 1)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("eva-backend-{id}-{i}"))
                    // Serving marks are pushed per chunk from the
                    // job's context (run_chunks), not per thread.
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn backend worker")
            })
            .collect();
        WorkerPool { shared, inject: Mutex::new(()), threads, id, handles }
    }

    /// Total execution lanes (workers + injector).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// This pool's unique identity (diagnostics; also what same-pool
    /// nesting detection keys on).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Run `body(i)` for every `i in 0..chunks`, cooperatively across
    /// the pool. Returns only after every chunk finished. Nested calls
    /// into this pool from code already running under one of its jobs
    /// (directly or through a chain of sub-pool jobs) run inline on
    /// the calling thread; dispatch into an unrelated pool injects
    /// normally — see the module notes on nesting.
    pub fn run(&self, chunks: usize, body: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        if chunks == 1 || self.threads == 1 || serving(self.id) {
            for i in 0..chunks {
                body(i);
            }
            return;
        }
        // Serving context published with the job: every pool this
        // thread is already running under, plus this pool. Chunk
        // executors (workers *and* this injector) push it for each
        // chunk, so nested dispatch into any pool along the chain —
        // whose job is necessarily blocked on this one — inlines
        // instead of deadlocking.
        let ctx: Vec<u64> = SERVING.with(|s| {
            let mut v = s.borrow().clone();
            v.push(self.id);
            v
        });
        // SAFETY: erase the borrow lifetimes — sound because this frame
        // blocks until `remaining == 0`, i.e. until no thread can still
        // hold or claim a reference to `body` or `ctx`.
        let body_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(body) };
        // SAFETY: same lifetime-erasure argument as `body_static`; the
        // vec outlives the job because this frame owns it.
        let ctx_static: &'static [u64] = unsafe { std::mem::transmute(ctx.as_slice()) };
        let _inject = lock(&self.inject);
        let epoch = {
            let mut g = lock(&self.shared.slot);
            g.epoch += 1;
            g.job = Some(Job { body: body_static, ctx: ctx_static, chunks });
            g.next = 0;
            g.remaining = chunks;
            g.epoch
        };
        // Dropped last (declared first): waits for `remaining == 0`
        // and retires the job even if a chunk body panics below.
        let _drain = JobGuard { shared: &self.shared };
        self.shared.work_cv.notify_all();
        // The injector works too; its chunks get the same serving
        // context as the workers'.
        run_chunks(&self.shared, epoch);
        // Drain on the happy path (JobGuard's drop then finds the job
        // already retired) and surface any worker panic here rather
        // than returning a partially-written result.
        let panicked = {
            let mut g = lock(&self.shared.slot);
            while g.remaining > 0 {
                g = self.shared.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            g.job = None;
            std::mem::take(&mut g.panicked)
        };
        if panicked {
            panic!("eva-backend: a parallel chunk panicked on a worker thread (see stderr above)");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut g = lock(&self.shared.slot);
            g.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = WorkerPool::new(4);
        for chunks in [1usize, 2, 7, 64, 300] {
            let hits: Vec<AtomicUsize> = (0..chunks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(chunks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "chunks={chunks}");
        }
    }

    #[test]
    fn sequential_pool_still_completes() {
        let pool = WorkerPool::new(1);
        let total = AtomicUsize::new(0);
        pool.run(10, &|i| {
            total.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn nested_dispatch_runs_inline_without_deadlock() {
        let pool = WorkerPool::new(4);
        let total = AtomicUsize::new(0);
        pool.run(8, &|_| {
            // Nested same-pool job: must run inline on this thread.
            pool.run(8, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn cross_pool_nested_dispatch_fans_out() {
        // A chunk body of one pool may inject into a *different* pool
        // — the per-worker sub-pool pattern the data-parallel
        // coordinator relies on. Each outer chunk owns its own inner
        // pool, so injections never contend.
        let outer = WorkerPool::new(3);
        let inners: Vec<WorkerPool> = (0..4).map(|_| WorkerPool::new(2)).collect();
        let total = AtomicUsize::new(0);
        outer.run(4, &|w| {
            inners[w].run(16, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
        // All serve marks popped: a fresh same-pool run still works.
        outer.run(2, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 66);
    }

    #[test]
    fn dispatch_into_busy_ancestor_pool_inlines() {
        // A sub-pool chunk that dispatches back into the ancestor pool
        // whose job is blocked on it must inline, not inject — the
        // serving context travels with the job across threads, so this
        // completes even though the ancestor's chunk lives on another
        // thread. (Injection would deadlock: the ancestor cannot serve
        // a new job until this chunk finishes.)
        let outer = WorkerPool::new(2);
        let inner = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        outer.run(2, &|_| {
            inner.run(4, &|_| {
                outer.run(4, &|_| {
                    total.fetch_add(1, Ordering::Relaxed);
                });
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 2 * 4 * 4);
    }

    #[test]
    fn reusable_across_many_jobs() {
        let pool = WorkerPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(16, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 800);
    }

    #[test]
    fn concurrent_injectors_serialize() {
        let pool = Arc::new(WorkerPool::new(4));
        let total = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for k in 0..4 {
            let (p, t) = (Arc::clone(&pool), Arc::clone(&total));
            // Named like every other spawn site; joined below so the
            // assertion sees all 4 injectors' work.
            let b = std::thread::Builder::new().name(format!("test-inject-{k}"));
            joins.push(b.spawn(move || {
                for _ in 0..20 {
                    p.run(8, &|_| {
                        t.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }).expect("spawn test injector"));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * 8);
    }

    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(64, &|i| {
                if i == 13 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "panic must reach the injector");
        // The pool stays serviceable afterwards.
        let total = AtomicUsize::new(0);
        pool.run(8, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn borrowed_state_is_visible_and_writable() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0usize; 123];
        let base = 7usize;
        {
            let ptr = out.as_mut_ptr() as usize;
            let n = out.len();
            pool.run(n, &move |i| {
                // SAFETY: disjoint element writes via the raw pointer
                // (i < n = out.len(), one chunk per element).
                unsafe { *(ptr as *mut usize).add(i) = base + i };
            });
        }
        assert!(out.iter().enumerate().all(|(i, &v)| v == 7 + i));
    }
}
