//! Persistent worker pool (substrate; no rayon offline).
//!
//! One pool = N−1 parked worker threads plus the injecting thread,
//! cooperating on a single chunked job at a time. Jobs are borrowed
//! closures (`&dyn Fn(usize) + Sync`): the injector publishes the
//! closure with its lifetime erased, workers pull chunk indices from a
//! shared counter, and the injector does not return until every chunk
//! has finished — which is exactly what makes the lifetime erasure
//! sound (the borrow strictly outlives all uses).
//!
//! Design notes:
//! * **One job at a time.** A second injector blocks on `inject` until
//!   the current job drains. Dispatch epochs guard against stale
//!   workers claiming chunks of a newer job.
//! * **Nesting runs inline.** A chunk body that itself calls
//!   [`WorkerPool::run`] (e.g. a parallel layer loop whose per-layer
//!   work calls a parallel matmul) executes sequentially via the
//!   [`in_pool`] thread-local — no deadlock, no oversubscription.
//! * **Panic-tolerant accounting.** Chunk completion is decremented by
//!   a drop guard, so a panicking chunk body cannot strand the
//!   injector; workers catch the unwind and keep serving.

use std::cell::Cell;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

thread_local! {
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// True while the current thread is executing inside a pool job
/// (worker thread, or injector during its participation phase).
pub fn in_pool() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Lock helper that shrugs off poisoning (a panicking chunk body must
/// not wedge every later dispatch).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The published job: lifetime-erased chunk body + chunk count.
#[derive(Clone, Copy)]
struct Job {
    body: &'static (dyn Fn(usize) + Sync),
    chunks: usize,
}

/// Shared dispatch state.
struct Slot {
    job: Option<Job>,
    /// Next unclaimed chunk index of the current job.
    next: usize,
    /// Chunks not yet finished (claimed-and-running included).
    remaining: usize,
    /// Bumped once per injected job; stale workers compare-and-skip.
    epoch: u64,
    /// Set when a chunk body panicked on a worker; the injector
    /// re-raises after the job drains so a partial result can never
    /// be mistaken for a complete one.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Signals workers: new job or shutdown.
    work_cv: Condvar,
    /// Signals the injector: all chunks finished.
    done_cv: Condvar,
}

/// Decrements `remaining` even if the chunk body panics.
struct FinishGuard<'a> {
    shared: &'a Shared,
}

impl Drop for FinishGuard<'_> {
    fn drop(&mut self) {
        let mut g = lock(&self.shared.slot);
        // Flag panics under the same lock acquisition as the final
        // decrement, so the injector can never observe `remaining ==
        // 0` without also observing the flag.
        if std::thread::panicking() {
            g.panicked = true;
        }
        g.remaining -= 1;
        if g.remaining == 0 {
            self.shared.done_cv.notify_all();
        }
    }
}

/// Blocks until the current job fully drains, then retires it. Runs on
/// drop so an unwinding injector cannot leave workers holding the
/// lifetime-erased closure past its borrow.
struct JobGuard<'a> {
    shared: &'a Shared,
}

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        let mut g = lock(&self.shared.slot);
        while g.remaining > 0 {
            g = self.shared.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        g.job = None;
        // If we are unwinding from the injector's own chunk, a stale
        // worker-panic flag must not leak into the next job.
        g.panicked = false;
    }
}

/// Restores the thread's `IN_POOL` flag on scope exit (panic included).
struct PoolFlagGuard {
    was: bool,
}

impl PoolFlagGuard {
    fn enter() -> Self {
        PoolFlagGuard { was: IN_POOL.with(|c| c.replace(true)) }
    }
}

impl Drop for PoolFlagGuard {
    fn drop(&mut self) {
        let was = self.was;
        IN_POOL.with(|c| c.set(was));
    }
}

/// Claim and execute chunks of job `epoch` until none are left.
///
/// The body reference is re-read from the slot *under the lock* at
/// every claim: a successful claim proves an unclaimed chunk existed,
/// hence `remaining > 0`, hence the injector is still blocked in
/// [`WorkerPool::run`] and the erased borrow is live for the whole
/// `body(idx)` call (our own chunk keeps `remaining > 0` until the
/// guard drops).
fn run_chunks(shared: &Shared, epoch: u64) {
    loop {
        let (idx, body) = {
            let mut g = lock(&shared.slot);
            match g.job {
                Some(j) if g.epoch == epoch && g.next < j.chunks => {
                    let i = g.next;
                    g.next += 1;
                    (i, j.body)
                }
                _ => break,
            }
        };
        let _finish = FinishGuard { shared };
        body(idx);
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let epoch = {
            let mut g = lock(&shared.slot);
            loop {
                if g.shutdown {
                    return;
                }
                match g.job {
                    Some(j) if g.next < j.chunks => break g.epoch,
                    _ => g = shared.work_cv.wait(g).unwrap_or_else(|e| e.into_inner()),
                }
            }
        };
        // Contain panics from chunk bodies so the pool keeps its
        // workers. FinishGuard has already balanced the books *and*
        // set the panic flag (under the decrement's lock), which the
        // injector re-raises on its own thread; the worker's default
        // panic hook has already printed the message + location.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_chunks(shared, epoch);
        }));
    }
}

/// A persistent pool of worker threads executing chunked jobs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serializes injectors; held for the whole duration of a job.
    inject: Mutex<()>,
    threads: usize,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Pool with `threads` total execution lanes (the injecting thread
    /// counts as one, so `threads - 1` OS threads are spawned).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                job: None,
                next: 0,
                remaining: 0,
                epoch: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads - 1)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("eva-backend-{i}"))
                    .spawn(move || {
                        IN_POOL.with(|c| c.set(true));
                        worker_loop(&sh);
                    })
                    .expect("spawn backend worker")
            })
            .collect();
        WorkerPool { shared, inject: Mutex::new(()), threads, handles }
    }

    /// Total execution lanes (workers + injector).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `body(i)` for every `i in 0..chunks`, cooperatively across
    /// the pool. Returns only after every chunk finished. Nested calls
    /// (from inside a chunk body) run inline on the calling thread.
    pub fn run(&self, chunks: usize, body: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        if chunks == 1 || self.threads == 1 || in_pool() {
            for i in 0..chunks {
                body(i);
            }
            return;
        }
        // Erase the borrow lifetime: sound because this frame blocks
        // until `remaining == 0`, i.e. until no thread can still hold
        // or claim a reference to `body`.
        let body_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(body) };
        let _inject = lock(&self.inject);
        let epoch = {
            let mut g = lock(&self.shared.slot);
            g.epoch += 1;
            g.job = Some(Job { body: body_static, chunks });
            g.next = 0;
            g.remaining = chunks;
            g.epoch
        };
        // Dropped last (declared first): waits for `remaining == 0`
        // and retires the job even if a chunk body panics below.
        let _drain = JobGuard { shared: &self.shared };
        self.shared.work_cv.notify_all();
        // The injector works too (and is flagged so nested dispatch
        // from its own chunks runs inline).
        {
            let _flag = PoolFlagGuard::enter();
            run_chunks(&self.shared, epoch);
        }
        // Drain on the happy path (JobGuard's drop then finds the job
        // already retired) and surface any worker panic here rather
        // than returning a partially-written result.
        let panicked = {
            let mut g = lock(&self.shared.slot);
            while g.remaining > 0 {
                g = self.shared.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            g.job = None;
            std::mem::take(&mut g.panicked)
        };
        if panicked {
            panic!("eva-backend: a parallel chunk panicked on a worker thread (see stderr above)");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut g = lock(&self.shared.slot);
            g.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = WorkerPool::new(4);
        for chunks in [1usize, 2, 7, 64, 300] {
            let hits: Vec<AtomicUsize> = (0..chunks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(chunks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "chunks={chunks}");
        }
    }

    #[test]
    fn sequential_pool_still_completes() {
        let pool = WorkerPool::new(1);
        let total = AtomicUsize::new(0);
        pool.run(10, &|i| {
            total.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn nested_dispatch_runs_inline_without_deadlock() {
        let pool = WorkerPool::new(4);
        let total = AtomicUsize::new(0);
        pool.run(8, &|_| {
            // Nested job: must run inline on this thread.
            pool.run(8, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn reusable_across_many_jobs() {
        let pool = WorkerPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(16, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 800);
    }

    #[test]
    fn concurrent_injectors_serialize() {
        let pool = Arc::new(WorkerPool::new(4));
        let total = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..4 {
            let (p, t) = (Arc::clone(&pool), Arc::clone(&total));
            joins.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    p.run(8, &|_| {
                        t.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * 8);
    }

    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(64, &|i| {
                if i == 13 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "panic must reach the injector");
        // The pool stays serviceable afterwards.
        let total = AtomicUsize::new(0);
        pool.run(8, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn borrowed_state_is_visible_and_writable() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0usize; 123];
        let base = 7usize;
        {
            let ptr = out.as_mut_ptr() as usize;
            let n = out.len();
            pool.run(n, &move |i| {
                // Disjoint element writes via the raw pointer.
                unsafe { *(ptr as *mut usize).add(i) = base + i };
            });
        }
        assert!(out.iter().enumerate().all(|(i, &v)| v == 7 + i));
    }
}
