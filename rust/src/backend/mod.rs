//! Pluggable parallel compute backend for the tensor/linalg hot paths.
//!
//! Every expensive kernel in the stack — the three matmul variants,
//! big elementwise ops, `spd_inverse` column solves, and the per-layer
//! factorization loops in K-FAC/FOOF/Shampoo — dispatches through a
//! [`Backend`]: either [`Sequential`] (the original single-threaded
//! code path) or [`Threaded`] (a persistent worker pool, see
//! [`WorkerPool`]). Selection is per-process via the global
//! dispatcher ([`install`]/[`global`]), driven by `TrainConfig.backend`
//! or the CLI flag `--backend seq|threads[:N]`.
//!
//! **Determinism contract:** kernels split work so that per-element
//! arithmetic order is independent of the backend and of the thread
//! count, and reductions use *size-derived* fixed chunking
//! ([`par_reduce_sum`]). `Sequential` and `Threaded(N)` therefore
//! produce bit-identical results for every routed operation — parity
//! is structural, not approximate (see `tests/backend_parity.rs`).
//!
//! **One dispatch layer for kernel- and data-parallelism.** Kernels
//! resolve their backend with [`current`]: a scoped per-thread handle
//! installed by [`with_backend`] if one is active, otherwise the
//! process-wide [`global`]. The data-parallel coordinator uses the
//! same layer twice — its worker loop is one `par_for` over the global
//! backend, and each simulated worker's compute runs under
//! `with_backend` on a *sub-pool handle* carved from the global lane
//! budget by [`split`]. A handle whose budget is exhausted (one lane)
//! degrades to [`Sequential`], i.e. nested dispatch inlines; threads
//! already inside a pool job default to inline dispatch too, so the
//! layers compose without oversubscription or cross-pool deadlock —
//! the dispatch tree this module builds stays tree-shaped, which is
//! what [`WorkerPool`]'s nesting rules require (see its notes for the
//! cyclic-injection caveat that applies to direct pool users).
//!
//! Std-only by design: the offline build has no rayon/crossbeam, and a
//! ~300-line pool is enough for row-partitioned kernels.

#![warn(missing_docs)]

mod pool;

pub use pool::{in_pool, WorkerPool};

use std::cell::RefCell;
use std::ops::Range;
use std::sync::{Arc, OnceLock, RwLock};

/// A parallel-for execution strategy.
///
/// `par_for` runs `body(i)` for `i in 0..chunks`; implementations may
/// run chunks concurrently but must complete all of them before
/// returning. Bodies must therefore only write to chunk-disjoint data.
pub trait Backend: Send + Sync {
    /// Human-readable name, e.g. `seq` or `threads:8`.
    fn label(&self) -> String;

    /// Number of execution lanes this backend can use.
    fn threads(&self) -> usize;

    /// Identity of the underlying worker pool; 0 for backends without
    /// one ([`Sequential`], the default). Labels are not identities —
    /// two `threads:N` backends with the same `N` are different pools
    /// — so consumers that cache handles carved from a backend (the
    /// serve scheduler) must key on this, not on [`Backend::label`].
    fn pool_id(&self) -> u64 {
        0
    }

    /// Execute all chunk indices, returning after the last finishes.
    fn par_for(&self, chunks: usize, body: &(dyn Fn(usize) + Sync));
}

/// The original single-threaded execution path.
pub struct Sequential;

impl Backend for Sequential {
    fn label(&self) -> String {
        "seq".into()
    }

    fn threads(&self) -> usize {
        1
    }

    fn par_for(&self, chunks: usize, body: &(dyn Fn(usize) + Sync)) {
        for i in 0..chunks {
            body(i);
        }
    }
}

/// Worker-pool backend with `N` total execution lanes.
pub struct Threaded {
    pool: WorkerPool,
}

impl Threaded {
    /// Backend backed by a fresh persistent pool with `threads` lanes.
    pub fn new(threads: usize) -> Self {
        Threaded { pool: WorkerPool::new(threads.max(1)) }
    }
}

impl Backend for Threaded {
    fn label(&self) -> String {
        format!("threads:{}", self.pool.threads())
    }

    fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn pool_id(&self) -> u64 {
        self.pool.id()
    }

    fn par_for(&self, chunks: usize, body: &(dyn Fn(usize) + Sync)) {
        self.pool.run(chunks, body);
    }
}

/// Parsed backend selection (config/CLI layer).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// The single-threaded [`Sequential`] path.
    Sequential,
    /// Total lanes (≥ 1); `threads` / `auto` resolve to the hardware
    /// parallelism at parse time.
    Threaded(usize),
}

impl BackendChoice {
    /// Parse `seq | sequential | threads | threads:N | auto`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "seq" | "sequential" => Ok(BackendChoice::Sequential),
            "threads" | "threaded" | "auto" => Ok(BackendChoice::Threaded(default_threads())),
            _ => match s.strip_prefix("threads:") {
                Some(n) => match n.parse::<usize>() {
                    Ok(n) if n >= 1 => Ok(BackendChoice::Threaded(n)),
                    _ => Err(format!("--backend threads:N needs an integer ≥ 1, got '{n}'")),
                },
                None => Err(format!(
                    "unknown backend '{s}' (use seq | threads | threads:N)"
                )),
            },
        }
    }

    /// Instantiate the backend.
    pub fn build(&self) -> Arc<dyn Backend> {
        match *self {
            BackendChoice::Sequential => Arc::new(Sequential),
            BackendChoice::Threaded(n) => Arc::new(Threaded::new(n)),
        }
    }
}

/// Hardware parallelism (1 if undetectable).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn registry() -> &'static RwLock<Arc<dyn Backend>> {
    static REGISTRY: OnceLock<RwLock<Arc<dyn Backend>>> = OnceLock::new();
    REGISTRY.get_or_init(|| RwLock::new(Arc::new(Sequential) as Arc<dyn Backend>))
}

/// Flipped (permanently) by the first [`set_global`]/[`install`].
static GLOBAL_EXPLICIT: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// True while the process is still on the boot-time [`Sequential`]
/// default — i.e. no CLI flag, config key, or [`install`] call has
/// chosen a backend yet. Consumers that used OS threads before the
/// dispatch layer existed (the data-parallel coordinator) use this to
/// keep their real parallelism under the untouched default while
/// still honoring an *explicit* `seq` choice.
pub fn global_is_default() -> bool {
    !GLOBAL_EXPLICIT.load(std::sync::atomic::Ordering::Relaxed)
}

/// The process-wide backend used by kernels without an explicit handle.
/// Defaults to [`Sequential`] until [`install`]/[`set_global`] runs.
pub fn global() -> Arc<dyn Backend> {
    registry().read().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Replace the global backend (marks the choice as explicit — see
/// [`global_is_default`]).
pub fn set_global(backend: Arc<dyn Backend>) {
    GLOBAL_EXPLICIT.store(true, std::sync::atomic::Ordering::Relaxed);
    *registry().write().unwrap_or_else(|e| e.into_inner()) = backend;
}

/// Build `choice` and make it the global backend; returns the handle.
pub fn install(choice: &BackendChoice) -> Arc<dyn Backend> {
    let b = choice.build();
    set_global(Arc::clone(&b));
    b
}

// ---------------------------------------------------------------------------
// Scoped handles and sub-pool carving
// ---------------------------------------------------------------------------

thread_local! {
    /// Per-thread stack of scoped backend overrides ([`with_backend`]),
    /// innermost last.
    static SCOPED: RefCell<Vec<Arc<dyn Backend>>> = const { RefCell::new(Vec::new()) };
}

/// True while a [`with_backend`] scope is active on this thread — the
/// caller chose a backend explicitly, so defaults must not override it.
pub(crate) fn scoped_override_active() -> bool {
    SCOPED.with(|s| !s.borrow().is_empty())
}

/// Shared [`Sequential`] handle (inline execution).
fn sequential_handle() -> Arc<dyn Backend> {
    static SEQ: OnceLock<Arc<dyn Backend>> = OnceLock::new();
    Arc::clone(SEQ.get_or_init(|| Arc::new(Sequential)))
}

/// The backend kernels on this thread should dispatch through.
///
/// Resolution order:
/// 1. the innermost [`with_backend`] scope, if any (how the
///    data-parallel coordinator hands each simulated worker its own
///    sub-pool handle);
/// 2. [`Sequential`] when the thread is already executing inside a
///    pool job ([`in_pool`]) — implicit nested dispatch inlines rather
///    than injecting into some *other* busy pool, which could deadlock
///    and would oversubscribe;
/// 3. the process-wide [`global`] backend.
pub fn current() -> Arc<dyn Backend> {
    if let Some(b) = SCOPED.with(|s| s.borrow().last().cloned()) {
        return b;
    }
    if in_pool() {
        return sequential_handle();
    }
    global()
}

/// Run `f` with `backend` as this thread's [`current`] backend.
///
/// The override is scoped and panic-safe; it applies to the calling
/// thread only (worker threads of a pool that `f` dispatches into
/// resolve their own defaults). Scopes nest: the innermost wins.
pub fn with_backend<T>(backend: Arc<dyn Backend>, f: impl FnOnce() -> T) -> T {
    SCOPED.with(|s| s.borrow_mut().push(backend));
    struct PopGuard;
    impl Drop for PopGuard {
        fn drop(&mut self) {
            SCOPED.with(|s| {
                s.borrow_mut().pop();
            });
        }
    }
    let _pop = PopGuard;
    f()
}

/// A backend handle with exactly `lanes` execution lanes: a dedicated
/// [`Threaded`] sub-pool for `lanes >= 2`, the shared [`Sequential`]
/// handle otherwise (an exhausted budget means nested dispatch
/// inlines).
pub fn handle_with_lanes(lanes: usize) -> Arc<dyn Backend> {
    if lanes >= 2 {
        Arc::new(Threaded::new(lanes))
    } else {
        sequential_handle()
    }
}

/// Carve `parts` per-worker handles out of `backend`'s lane budget.
///
/// The parent's `threads()` are partitioned as evenly as possible
/// (earlier handles get the remainder); each share with ≥ 2 lanes
/// becomes its own persistent [`Threaded`] sub-pool, and a share of
/// 1 lane — the budget-exhausted case, e.g. more workers than hardware
/// threads or a [`Sequential`] parent — becomes the inline
/// [`Sequential`] handle. Sub-pools are independent pools (injecting
/// into one never contends with its siblings or the parent), so a
/// coordinator can fan out over the parent via [`par_map`] while every
/// chunk body computes through its own handle under [`with_backend`] —
/// one dispatch layer for data- *and* kernel-parallelism.
pub fn split(backend: &dyn Backend, parts: usize) -> Vec<Arc<dyn Backend>> {
    if parts == 0 {
        return Vec::new();
    }
    split_weighted(backend, &vec![1; parts])
}

/// [`split`] with per-part weights: carve `backend`'s lane budget into
/// one handle per weight, apportioning lanes proportionally to the
/// weights (largest-remainder method, ties broken toward earlier
/// parts — equal weights reproduce [`split`]'s even partition
/// exactly). A part whose share rounds to ≤ 1 lane gets the inline
/// [`Sequential`] handle, so over-subscription degrades the same way
/// `split` does. Zero-weight parts always get [`Sequential`]. This is
/// how the `serve` scheduler turns session priorities into fair lane
/// budgets, re-carving on every join/leave.
pub fn split_weighted(backend: &dyn Backend, weights: &[usize]) -> Vec<Arc<dyn Backend>> {
    let total = backend.threads().max(1);
    let wsum: usize = weights.iter().sum();
    if wsum == 0 {
        return weights.iter().map(|_| sequential_handle()).collect();
    }
    // Integer largest-remainder apportionment of `total` lanes, done in
    // u128 so weight*total cannot overflow: floor shares first, then
    // the leftover lanes go to the largest fractional remainders
    // (earlier index wins ties).
    let mut lanes: Vec<usize> = Vec::with_capacity(weights.len());
    let mut rems: Vec<(usize, u128)> = Vec::with_capacity(weights.len());
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let num = w as u128 * total as u128;
        let share = (num / wsum as u128) as usize;
        lanes.push(share);
        assigned += share;
        rems.push((i, num % wsum as u128));
    }
    let mut leftover = total.saturating_sub(assigned);
    rems.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    for &(i, _) in rems.iter() {
        if leftover == 0 {
            break;
        }
        if weights[i] > 0 {
            lanes[i] += 1;
            leftover -= 1;
        }
    }
    lanes.into_iter().map(handle_with_lanes).collect()
}

// ---------------------------------------------------------------------------
// Dispatch helpers shared by tensor / linalg / optim
// ---------------------------------------------------------------------------

/// Oversubscription factor for range partitioning: more chunks than
/// lanes smooths imbalanced rows without meaningful dispatch overhead.
const CHUNKS_PER_THREAD: usize = 4;

/// Raw pointer wrapper for provably chunk-disjoint parallel writes.
///
/// Safety contract for users: distinct chunk indices must touch
/// distinct elements. The wrapper only exists to move the pointer
/// across threads; all dereferences remain `unsafe` at the call site.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub *mut T);

// SAFETY: SendPtr is a bare pointer moved across threads; the struct
// docs above are the contract — users index disjoint chunks only.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: same contract as Send — shared references only hand out the
// pointer, every dereference is a separate unsafe site.
unsafe impl<T> Sync for SendPtr<T> {}

/// Run `body` over balanced sub-ranges of `0..n`, at most one range
/// per chunk. `min_grain` bounds how small a range may get (amortizes
/// dispatch); with one lane (or tiny `n`) the whole range runs inline.
pub fn par_ranges(
    backend: &dyn Backend,
    n: usize,
    min_grain: usize,
    body: &(dyn Fn(Range<usize>) + Sync),
) {
    if n == 0 {
        return;
    }
    let max_parts = backend.threads().max(1) * CHUNKS_PER_THREAD;
    let parts = (n / min_grain.max(1)).clamp(1, max_parts).min(n);
    if parts <= 1 {
        body(0..n);
        return;
    }
    let base = n / parts;
    let rem = n % parts;
    backend.par_for(parts, &|p| {
        let lo = p * base + p.min(rem);
        let hi = lo + base + usize::from(p < rem);
        body(lo..hi);
    });
}

/// Parallel map `0..n → Vec<T>` preserving index order. Independent
/// items (layer factorizations, tile roots) are embarrassingly
/// parallel; results land in pre-allocated slots.
pub fn par_map<T, F>(backend: &dyn Backend, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let slots = SendPtr(out.as_mut_ptr());
    backend.par_for(n, &|i| {
        let v = f(i);
        // SAFETY: disjoint slot per chunk index (i < n = capacity);
        // overwrites the pre-filled None.
        unsafe { *slots.0.add(i) = Some(v) };
    });
    out.into_iter()
        .map(|s| s.expect("par_map: a parallel chunk failed to produce its result"))
        .collect()
}

/// Deterministic chunked sum: `Σ_p partial(lo..hi)` where the chunk
/// grid depends only on `n` and `chunk` — never on the backend or its
/// thread count — and partials are combined in fixed index order. This
/// is what keeps `Sequential` and `Threaded` bit-identical on
/// reductions (dot products, norms).
pub fn par_reduce_sum(
    backend: &dyn Backend,
    n: usize,
    chunk: usize,
    partial: &(dyn Fn(Range<usize>) -> f32 + Sync),
) -> f32 {
    if n == 0 {
        return 0.0;
    }
    let chunk = chunk.max(1);
    let parts = n.div_ceil(chunk);
    if parts == 1 {
        return partial(0..n);
    }
    let mut partials = vec![0.0f32; parts];
    let slots = SendPtr(partials.as_mut_ptr());
    backend.par_for(parts, &|p| {
        let lo = p * chunk;
        let hi = (lo + chunk).min(n);
        // SAFETY: one disjoint slot per part index (p < parts = len).
        unsafe { *slots.0.add(p) = partial(lo..hi) };
    });
    partials.iter().sum()
}

/// Serializes unit tests (across modules of this crate) that swap the
/// process-global backend, so install/restore windows never
/// interleave. Integration tests keep their own lock.
#[cfg(test)]
pub(crate) static TEST_GLOBAL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn choice_parses_and_labels() {
        assert_eq!(BackendChoice::parse("seq").unwrap(), BackendChoice::Sequential);
        assert_eq!(
            BackendChoice::parse("threads:3").unwrap(),
            BackendChoice::Threaded(3)
        );
        assert!(matches!(
            BackendChoice::parse("threads").unwrap(),
            BackendChoice::Threaded(n) if n >= 1
        ));
        assert!(BackendChoice::parse("gpu").is_err());
        assert!(BackendChoice::parse("threads:0").is_err());
        assert!(BackendChoice::parse("threads:x").is_err());
        assert_eq!(BackendChoice::Sequential.build().label(), "seq");
        assert_eq!(BackendChoice::Threaded(2).build().label(), "threads:2");
        // Pool identity: unique per pool (labels can collide), 0 when
        // there is no pool.
        let (t1, t2) = (Threaded::new(2), Threaded::new(2));
        assert_eq!(t1.label(), t2.label());
        assert_ne!(t1.pool_id(), t2.pool_id());
        assert_ne!(t1.pool_id(), 0);
        assert_eq!(Sequential.pool_id(), 0);
    }

    #[test]
    fn par_ranges_covers_exactly_once() {
        for backend in [&Sequential as &dyn Backend, &Threaded::new(4)] {
            for (n, grain) in [(0usize, 8usize), (5, 8), (64, 1), (257, 16), (1000, 7)] {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                par_ranges(backend, n, grain, &|r| {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                    "n={n} grain={grain}"
                );
            }
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let thr = Threaded::new(4);
        let v = par_map(&thr, 100, |i| i * i);
        assert_eq!(v.len(), 100);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i * i));
        let empty: Vec<usize> = par_map(&thr, 0, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn reduce_sum_is_backend_invariant() {
        let xs: Vec<f32> = (0..10_000).map(|i| ((i * 37) % 101) as f32 * 0.123).collect();
        let body = |r: Range<usize>| xs[r].iter().sum::<f32>();
        let seq = par_reduce_sum(&Sequential, xs.len(), 256, &body);
        for n in [2usize, 3, 8] {
            let thr = Threaded::new(n);
            let got = par_reduce_sum(&thr, xs.len(), 256, &body);
            // Identical chunk grid + fixed combine order ⇒ bit-equal.
            assert_eq!(seq.to_bits(), got.to_bits(), "threads={n}");
        }
    }

    #[test]
    fn split_partitions_the_lane_budget() {
        // 8 lanes over 3 workers → 3 + 3 + 2.
        let parent = Threaded::new(8);
        let handles = split(&parent, 3);
        let lanes: Vec<usize> = handles.iter().map(|h| h.threads()).collect();
        assert_eq!(lanes, vec![3, 3, 2]);
        assert_eq!(lanes.iter().sum::<usize>(), 8);
        // Exhausted budget (more parts than lanes) degrades to seq.
        for h in split(&parent, 16) {
            assert_eq!(h.label(), "seq");
        }
        for h in split(&Sequential, 4) {
            assert_eq!(h.label(), "seq");
        }
        assert!(split(&parent, 0).is_empty());
    }

    #[test]
    fn split_weighted_apportions_by_priority() {
        let parent = Threaded::new(8);
        // 2:1:1 over 8 lanes → 4 + 2 + 2.
        let lanes: Vec<usize> =
            split_weighted(&parent, &[2, 1, 1]).iter().map(|h| h.threads()).collect();
        assert_eq!(lanes, vec![4, 2, 2]);
        // Remainders favour the heavier (then earlier) parts and the
        // total budget is never exceeded.
        let lanes: Vec<usize> =
            split_weighted(&parent, &[3, 2, 2]).iter().map(|h| h.threads()).collect();
        assert_eq!(lanes.iter().sum::<usize>(), 8);
        assert_eq!(lanes, vec![4, 2, 2]);
        // Equal weights reproduce split() exactly.
        let even: Vec<usize> = split(&parent, 3).iter().map(|h| h.threads()).collect();
        let weighted: Vec<usize> =
            split_weighted(&parent, &[1, 1, 1]).iter().map(|h| h.threads()).collect();
        assert_eq!(even, weighted);
        // Zero-weight parts and exhausted budgets degrade to seq.
        let handles = split_weighted(&parent, &[0, 1]);
        assert_eq!(handles[0].label(), "seq");
        assert_eq!(handles[1].label(), "threads:8");
        for h in split_weighted(&Sequential, &[5, 1]) {
            assert_eq!(h.label(), "seq");
        }
        assert!(split_weighted(&parent, &[]).is_empty());
        for h in split_weighted(&parent, &[0, 0]) {
            assert_eq!(h.label(), "seq");
        }
    }

    #[test]
    fn scoped_backend_overrides_and_restores() {
        let _serial = TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = global();
        set_global(Arc::new(Sequential));
        let handle: Arc<dyn Backend> = Arc::new(Threaded::new(2));
        let (inside, nested) = with_backend(Arc::clone(&handle), || {
            let inside = current().label();
            let nested = with_backend(sequential_handle(), || current().label());
            (inside, nested)
        });
        assert_eq!(inside, "threads:2");
        assert_eq!(nested, "seq");
        // Scope exited: back to the global default.
        assert_eq!(current().label(), "seq");
        set_global(prev);
    }

    #[test]
    fn current_defaults_to_inline_inside_pool_jobs() {
        use std::sync::atomic::AtomicBool;
        let pool = Threaded::new(4);
        let all_inline = AtomicBool::new(true);
        pool.par_for(8, &|_| {
            if current().label() != "seq" {
                all_inline.store(false, Ordering::Relaxed);
            }
        });
        assert!(all_inline.load(Ordering::Relaxed));
    }

    #[test]
    fn scoped_handle_fans_out_from_inside_another_pool() {
        // The dp shape: a chunk body of pool A computes under a scoped
        // sub-pool handle B — current() must resolve to B there.
        let outer = Threaded::new(2);
        let inner: Arc<dyn Backend> = Arc::new(Threaded::new(2));
        let hits = AtomicUsize::new(0);
        outer.par_for(2, &|_| {
            with_backend(Arc::clone(&inner), || {
                current().par_for(4, &|_| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn global_registry_swaps() {
        let _serial = TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = global();
        let b = install(&BackendChoice::Threaded(2));
        assert_eq!(b.label(), "threads:2");
        assert_eq!(global().label(), "threads:2");
        // Once any explicit choice is made the boot-default flag stays
        // cleared (one-way latch; order-independent assertion).
        assert!(!global_is_default());
        set_global(prev);
    }
}
