//! `validate` — cross-layer consistency: PJRT artifacts vs the native
//! Rust implementations.
//!
//! Three triangulations:
//! 1. the `kernel.eva*` Pallas probe artifacts against
//!    `optim::Eva/EvaF/EvaS` preconditioners (L1 vs L3 numerics);
//! 2. `quickstart.fwdbwd_kv` against `nn::Mlp::forward_backward`
//!    given identical parameters (L2 vs L3 fwd/bwd + KV capture);
//! 3. the fused `quickstart.eva_step` driver actually trains (loss
//!    decreases) on the same synthetic task the native engine uses.

use anyhow::{anyhow, Result};

use crate::nn::{Activation, Loss, Mlp, MlpSpec, StatsMode};
use crate::rng::Pcg64;
use crate::runtime::{HostArray, Runtime, StepDriver, StepHp, StepKind};
use crate::tensor::Tensor;

pub fn run() -> Result<()> {
    let mut rt = Runtime::open_default()
        .map_err(|e| anyhow!("{e}\n(hint: run `make artifacts` first)"))?;
    kernel_probes(&mut rt)?;
    fwdbwd_cross_check(&mut rt)?;
    fused_step_trains(&mut rt)?;
    println!("validate: all PJRT vs native cross-checks passed");
    Ok(())
}

/// 1. Pallas kernel probes vs native preconditioners.
pub fn kernel_probes(rt: &mut Runtime) -> Result<()> {
    let (d_out, d_in) = (48usize, 40usize);
    let mut rng = Pcg64::seeded(77);
    let mut g = Tensor::zeros(d_out, d_in);
    rng.fill_normal(g.data_mut(), 1.0);
    let a: Vec<f32> = (0..d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let b: Vec<f32> = (0..d_out).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let gamma = 0.07f32;

    // eva: PJRT vs the same closed form natively.
    let exe = rt.load("kernel.eva_precond")?;
    let out = exe.run(&[
        HostArray::from_tensor(&g),
        HostArray::from_vec1(a.clone()),
        HostArray::from_vec1(b.clone()),
        HostArray::from_vec1(vec![gamma]),
    ])?;
    let pjrt = out[0].to_tensor();
    let native = native_eva(&g, &a, &b, gamma);
    let d = pjrt.max_abs_diff(&native);
    anyhow::ensure!(d < 1e-4, "eva kernel probe diff {d}");
    println!("  kernel.eva_precond    vs native: max|Δ| = {d:.2e}");

    // eva-f.
    let exe = rt.load("kernel.eva_f_precond")?;
    let out = exe.run(&[
        HostArray::from_tensor(&g),
        HostArray::from_vec1(a.clone()),
        HostArray::from_vec1(vec![gamma]),
    ])?;
    let native = native_eva_f(&g, &a, gamma);
    let d = out[0].to_tensor().max_abs_diff(&native);
    anyhow::ensure!(d < 1e-4, "eva-f kernel probe diff {d}");
    println!("  kernel.eva_f_precond  vs native: max|Δ| = {d:.2e}");

    // eva-s.
    let exe = rt.load("kernel.eva_s_precond")?;
    let out = exe.run(&[HostArray::from_tensor(&g), HostArray::from_vec1(vec![gamma])])?;
    let native = native_eva_s(&g, gamma);
    let d = out[0].to_tensor().max_abs_diff(&native);
    anyhow::ensure!(d < 1e-4, "eva-s kernel probe diff {d}");
    println!("  kernel.eva_s_precond  vs native: max|Δ| = {d:.2e}");
    Ok(())
}

fn native_eva(g: &Tensor, a: &[f32], b: &[f32], gamma: f32) -> Tensor {
    let ga = g.matvec(a);
    let num = crate::tensor::dot(&ga, b);
    let denom = gamma + crate::tensor::dot(a, a) * crate::tensor::dot(b, b);
    let mut p = g.clone();
    p.add_outer(-num / denom, b, a);
    p.scale(1.0 / gamma);
    p
}

fn native_eva_f(g: &Tensor, a: &[f32], gamma: f32) -> Tensor {
    let ga = g.matvec(a);
    let denom = gamma + crate::tensor::dot(a, a);
    let mut p = g.clone();
    p.add_outer(-1.0 / denom, &ga, a);
    p.scale(1.0 / gamma);
    p
}

fn native_eva_s(g: &Tensor, gamma: f32) -> Tensor {
    let v1 = g.mean_cols();
    let v2 = g.mean_rows();
    let gv2 = g.matvec(&v2);
    let num = crate::tensor::dot(&gv2, &v1);
    let denom = gamma + crate::tensor::dot(&v1, &v1) * crate::tensor::dot(&v2, &v2);
    let mut p = g.clone();
    p.add_outer(-num / denom, &v1, &v2);
    p.scale(1.0 / gamma);
    p
}

/// 2. PJRT fwdbwd_kv vs native Mlp with identical parameters.
pub fn fwdbwd_cross_check(rt: &mut Runtime) -> Result<()> {
    let meta = rt.manifest().models["quickstart"].clone();
    let exe = rt.load("quickstart.fwdbwd_kv")?;
    // Build a native model and copy its weights into the artifact input.
    let spec = MlpSpec {
        dims: meta.dims.clone(),
        hidden_act: Activation::Relu,
        output_act: Activation::Identity,
        loss: Loss::SoftmaxCrossEntropy,
    };
    let model = Mlp::init(spec, 5);
    let ll = model.num_layers();
    let batch = meta.batch;
    let d0 = meta.dims[0];
    let classes = *meta.dims.last().unwrap();
    let mut rng = Pcg64::seeded(6);
    let mut x = Tensor::zeros(batch, d0);
    rng.fill_normal(x.data_mut(), 1.0);
    let labels: Vec<usize> = (0..batch).map(|i| i % classes).collect();
    // PJRT inputs.
    let mut inputs: Vec<HostArray> = Vec::new();
    for w in &model.weights {
        inputs.push(HostArray::from_tensor(w));
    }
    for b in &model.biases {
        inputs.push(HostArray::from_vec1(b.clone()));
    }
    inputs.push(HostArray::from_tensor(&x).reshaped(vec![batch, d0]));
    let mut y = vec![0.0f32; batch * classes];
    for (i, &l) in labels.iter().enumerate() {
        y[i * classes + l] = 1.0;
    }
    inputs.push(HostArray::new(vec![batch, classes], y));
    let out = exe.run(&inputs)?;
    // Native result.
    let native = model.forward_backward(&x, &labels, StatsMode::KvOnly);
    // Compare loss + per-layer grads + KVs.
    let loss_diff = (out[0].scalar_value() - native.loss).abs();
    anyhow::ensure!(loss_diff < 1e-3, "loss diff {loss_diff}");
    for l in 0..ll {
        let gw = out[1 + l].to_tensor();
        let d = gw.max_abs_diff(&native.grads[l]);
        anyhow::ensure!(d < 1e-3, "layer {l} grad diff {d}");
        let am = &out[1 + 2 * ll + l].data;
        for (p, n) in am.iter().zip(&native.stats[l].a_mean) {
            anyhow::ensure!((p - n).abs() < 1e-3, "a_mean mismatch layer {l}");
        }
        let bm = &out[1 + 3 * ll + l].data;
        for (p, n) in bm.iter().zip(&native.stats[l].b_mean) {
            anyhow::ensure!((p - n).abs() < 1e-3, "b_mean mismatch layer {l}");
        }
    }
    println!("  quickstart.fwdbwd_kv  vs native: loss |Δ| = {loss_diff:.2e}, grads+KVs match");
    Ok(())
}

/// 3. The fused Eva step artifact trains on the quickstart task.
pub fn fused_step_trains(rt: &mut Runtime) -> Result<()> {
    let mut driver = StepDriver::new(rt, "quickstart", StepKind::Eva, StepHp::default(), 3)?;
    let batch = driver.meta.batch;
    let d0 = driver.meta.dims[0];
    let classes = *driver.meta.dims.last().unwrap();
    let ds = crate::data::by_name("c10-small", 4).map_err(anyhow::Error::msg)?;
    let mut batcher = crate::data::Batcher::new(ds.train.len(), batch, 1);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..25 {
        let idx = batcher.next_indices().to_vec();
        let (x, labels) = ds.train.gather(&idx);
        let mut xb = vec![0.0f32; batch * d0];
        let mut yb = vec![0.0f32; batch * classes];
        for r in 0..batch {
            let src = r % x.rows();
            xb[r * d0..(r + 1) * d0].copy_from_slice(x.row(src));
            yb[r * classes + labels[src]] = 1.0;
        }
        let loss = driver.step(
            &HostArray::new(vec![batch, d0], xb),
            &HostArray::new(vec![batch, classes], yb),
        )?;
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    let first = first.unwrap();
    anyhow::ensure!(
        last < first * 0.8,
        "fused eva step did not reduce loss: {first} -> {last}"
    );
    println!("  quickstart.eva_step   trains: loss {first:.3} -> {last:.3} in 25 fused steps");
    Ok(())
}

