//! Efficiency experiments: Table 5 (iteration time & memory), Table 10
//! (Eva-f/Eva-s), Fig. 5 (wall-clock to accuracy), Fig. 6 (K-FAC
//! update-interval sweep).

use anyhow::Result;

use super::{cfg, default_lr, model_zoo, TablePrinter};
use crate::config::ModelArch;
use crate::train::{Metrics, Trainer};

/// Measure per-iteration time + optimizer memory of `optimizer`
/// relative to SGD on a model/dataset, over `steps` steps (warmup
/// excluded). Returns (relative time, relative memory-overhead proxy).
fn relative_cost(
    dataset: &str,
    arch: &ModelArch,
    optimizer: &str,
    interval: usize,
    steps: u64,
) -> Result<(f64, f64)> {
    let measure = |opt: &str, interval: usize| -> Result<(f64, usize, usize)> {
        let mut c = cfg("t5", dataset, arch.clone(), opt, 1, default_lr(opt), 3);
        c.optim.hp.update_interval = interval;
        c.max_steps = Some(steps);
        let mut t = Trainer::from_config(&c)?;
        let r = t.run()?;
        // Model params as the memory baseline (weights + grads are
        // common to all optimizers).
        let params = t.model().map(|m| m.num_params()).unwrap_or(1);
        Ok((
            r.history.iter().map(|h| h.mean_step_ms).sum::<f64>()
                / r.history.len().max(1) as f64,
            r.optimizer_state_bytes,
            params * 4,
        ))
    };
    let (t_sgd, m_sgd, base) = measure("sgd", 1)?;
    let (t_opt, m_opt, _) = measure(optimizer, interval)?;
    // Memory ratio proxy: (params + grads + state) / (params + grads + sgd state).
    let denom = (2 * base + m_sgd) as f64;
    let ratio = (2 * base + m_opt) as f64 / denom;
    Ok((t_opt / t_sgd, ratio))
}

/// Table 5 — relative iteration time and memory over SGD.
pub fn table5() -> Result<()> {
    println!("Table 5 — relative iteration time & memory over SGD");
    println!("(parenthesis = interval-10 regime, as in the paper)\n");
    let tp = TablePrinter::new(
        &["model", "shampoo t", "kfac t", "eva t", "shampoo m", "kfac m", "eva m"],
        &[12, 15, 15, 8, 10, 9, 7],
    );
    let mut csv = Metrics::new(
        "results/table5.csv",
        "model,optimizer,interval,rel_time,rel_mem",
    );
    for (mname, arch) in model_zoo() {
        let steps = 12;
        let mut row = vec![mname.to_string()];
        let mut table: Vec<(String, f64, f64)> = Vec::new();
        for opt in ["shampoo", "kfac", "eva"] {
            let (t1, m1) = relative_cost("c10-small", &arch, opt, 1, steps)?;
            csv.row(&[mname.into(), opt.into(), "1".into(), format!("{t1:.3}"), format!("{m1:.3}")]);
            if opt == "eva" {
                table.push((format!("{t1:.2}x"), t1, m1));
            } else {
                let (t10, _) = relative_cost("c10-small", &arch, opt, 10, steps)?;
                csv.row(&[
                    mname.into(),
                    opt.into(),
                    "10".into(),
                    format!("{t10:.3}"),
                    format!("{m1:.3}"),
                ]);
                table.push((format!("{t1:.2}x ({t10:.2}x)"), t1, m1));
            }
        }
        row.push(table[0].0.clone()); // shampoo time
        row.push(table[1].0.clone()); // kfac time
        row.push(table[2].0.clone()); // eva time
        row.push(format!("{:.2}x", table[0].2));
        row.push(format!("{:.2}x", table[1].2));
        row.push(format!("{:.2}x", table[2].2));
        tp.row(&row);
    }
    csv.flush()?;
    println!("\n(expect: shampoo ≫ kfac ≫ eva ≈ 1.0–1.2×; eva memory ≈ 1.0×)  csv: results/table5.csv");
    Ok(())
}

/// Table 10 — Eva-f / Eva-s relative cost over SGD.
pub fn table10() -> Result<()> {
    println!("Table 10 — Eva-f / Eva-s relative iteration time & memory over SGD");
    let tp = TablePrinter::new(
        &["model", "eva-f t", "eva-f m", "eva-s t", "eva-s m"],
        &[12, 9, 9, 9, 9],
    );
    let mut csv = Metrics::new("results/table10.csv", "model,optimizer,rel_time,rel_mem");
    for (mname, arch) in model_zoo() {
        let mut row = vec![mname.to_string()];
        for opt in ["eva-f", "eva-s"] {
            let (t, m) = relative_cost("c10-small", &arch, opt, 1, 12)?;
            csv.row(&[mname.into(), opt.into(), format!("{t:.3}"), format!("{m:.3}")]);
            row.push(format!("{t:.2}x"));
            row.push(format!("{m:.2}x"));
        }
        tp.row(&row);
    }
    csv.flush()?;
    println!("(expect: both ≈ 1.0–1.4× time, ≈ 1.0× memory)  csv: results/table10.csv");
    Ok(())
}

/// Fig. 5 — wall-clock time to reach a target accuracy.
pub fn fig5() -> Result<()> {
    println!("Fig. 5 — wall-clock time-to-accuracy (native engine, CPU)");
    let mut csv = Metrics::new(
        "results/fig5.csv",
        "model,optimizer,epoch,cum_time_s,val_acc",
    );
    let tp = TablePrinter::new(
        &["model", "optimizer", "best acc", "t→target(s)", "rel. to eva"],
        &[12, 10, 9, 12, 12],
    );
    for (mname, arch) in model_zoo() {
        let target = 0.60f32; // scaled stand-in for the paper's 93.5% etc.
        let mut eva_time = None;
        let mut rows = Vec::new();
        for opt in ["sgd", "kfac", "shampoo", "eva"] {
            let c = cfg("fig5", "c10-small", arch.clone(), opt, 4, default_lr(opt), 9);
            let mut t = Trainer::from_config(&c)?;
            let r = t.run()?;
            let mut cum = 0.0;
            for e in &r.history {
                cum += e.wall_time_s;
                csv.row(&[
                    mname.into(),
                    opt.into(),
                    e.epoch.to_string(),
                    format!("{cum:.3}"),
                    format!("{:.4}", e.val_metric),
                ]);
            }
            let tta = r.time_to_accuracy(target);
            if opt == "eva" {
                eva_time = tta.map(|x| x.1);
            }
            rows.push((opt, r.best_val_acc, tta));
        }
        for (opt, acc, tta) in rows {
            let (t_s, rel) = match (tta, eva_time) {
                (Some((_, t)), Some(te)) => (format!("{t:.2}"), format!("{:.2}x", t / te)),
                (Some((_, t)), None) => (format!("{t:.2}"), "-".into()),
                _ => ("n/r".into(), "-".into()),
            };
            tp.row(&[
                mname.into(),
                opt.into(),
                format!("{:.2}", 100.0 * acc),
                t_s,
                rel,
            ]);
        }
    }
    csv.flush()?;
    println!("(expect: eva fastest to target; sgd needs more epochs; shampoo pays per-step cost)  csv: results/fig5.csv");
    Ok(())
}

/// Fig. 6 — K-FAC update-interval sweep vs Eva.
pub fn fig6() -> Result<()> {
    println!("Fig. 6 — K-FAC@interval wall-clock vs Eva (c10-small)");
    let mut csv = Metrics::new("results/fig6.csv", "model,optimizer,interval,total_time_s,best_acc");
    let tp = TablePrinter::new(
        &["model", "run", "best acc", "total time(s)", "rel. to eva"],
        &[12, 10, 9, 13, 12],
    );
    for (mname, arch) in [&model_zoo()[0], &model_zoo()[1]] {
        let mut eva_t = 0.0f64;
        let mut rows = Vec::new();
        for (label, opt, interval) in [
            ("eva", "eva", 1usize),
            ("kfac@1", "kfac", 1),
            ("kfac@10", "kfac", 10),
            ("kfac@50", "kfac", 50),
        ] {
            let mut c = cfg("fig6", "c10-small", arch.clone(), opt, 3, default_lr(opt), 13);
            c.optim.hp.update_interval = interval;
            let mut t = Trainer::from_config(&c)?;
            let r = t.run()?;
            if label == "eva" {
                eva_t = r.total_time_s;
            }
            csv.row(&[
                mname.to_string(),
                opt.into(),
                interval.to_string(),
                format!("{:.3}", r.total_time_s),
                format!("{:.4}", r.best_val_acc),
            ]);
            rows.push((label, r.best_val_acc, r.total_time_s));
        }
        for (label, acc, time) in rows {
            tp.row(&[
                mname.to_string(),
                label.into(),
                format!("{:.2}", 100.0 * acc),
                format!("{time:.2}"),
                format!("{:.2}x", time / eva_t),
            ]);
        }
    }
    csv.flush()?;
    println!("(expect: kfac@1 ≫ eva; interval 10–50 closes the gap at equal accuracy)  csv: results/fig6.csv");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 5's headline at miniature scale: Eva's per-step overhead
    /// over SGD is small, K-FAC@1's is large.
    #[test]
    fn eva_step_overhead_small() {
        let arch = ModelArch::Classifier { hidden: vec![96, 64] };
        let (t_eva, m_eva) = relative_cost("c10-small", &arch, "eva", 1, 8).unwrap();
        assert!(t_eva < 2.0, "eva rel time {t_eva}");
        assert!(m_eva < 1.3, "eva rel mem {m_eva}");
        let (t_kfac, m_kfac) = relative_cost("c10-small", &arch, "kfac", 1, 8).unwrap();
        assert!(t_kfac > t_eva, "kfac {t_kfac} vs eva {t_eva}");
        assert!(m_kfac > m_eva, "kfac mem {m_kfac} vs eva {m_eva}");
    }
}
