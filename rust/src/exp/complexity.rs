//! Table 1 — time & memory complexity of each second-order update,
//! measured empirically as a function of the layer dimension `d`.
//!
//! The paper's claim: per second-order update, Eva is O(d²) time /
//! O(2d) memory, K-FAC and Shampoo O(2d³)/O(2d²), FOOF O(d³)/O(d²).
//! We time one preconditioning step (stats consumption + inverse +
//! gradient transform) for a single (d, d) layer at increasing d and
//! fit the log–log slope; state bytes come from `Optimizer::state_bytes`.

use anyhow::Result;

use super::TablePrinter;
use crate::nn::LayerStats;
use crate::optim::{by_name, HyperParams, StepCtx};
use crate::rng::Pcg64;
use crate::tensor::Tensor;
use crate::train::Metrics;

/// Time one optimizer update at layer dim `d`; returns (seconds, state bytes).
pub fn measure(optimizer: &str, d: usize, reps: usize) -> Result<(f64, usize)> {
    let mut hp = HyperParams::default();
    hp.update_interval = 1; // every step is a full second-order update
    hp.mfac_history = 8;
    let mut opt = by_name(optimizer, &hp).map_err(anyhow::Error::msg)?;
    let mut rng = Pcg64::seeded(d as u64);
    let mut g = Tensor::zeros(d, d);
    rng.fill_normal(g.data_mut(), 1.0);
    let params = vec![Tensor::zeros(d, d)];
    let grads = vec![g];
    let bias = vec![vec![0.0f32; d]];
    // Stats as the backward pass would deliver them.
    let mut a = Tensor::zeros(d, 2 * d);
    rng.fill_normal(a.data_mut(), 1.0);
    let mut aat = crate::tensor::matmul_a_bt(&a, &a);
    aat.scale(1.0 / (2 * d) as f32);
    let mut b = Tensor::zeros(d, 2 * d);
    rng.fill_normal(b.data_mut(), 1.0);
    let mut bbt = crate::tensor::matmul_a_bt(&b, &b);
    bbt.scale(1.0 / (2 * d) as f32);
    let stats = vec![LayerStats {
        a_mean: a.mean_cols(),
        b_mean: b.mean_cols(),
        aat: Some(aat),
        bbt: Some(bbt),
    }];
    // Warmup (allocations, first inverse).
    let ctx0 = StepCtx { params: &params, grads: &grads, bias_grads: &bias, stats: &stats, lr: 0.1, step: 0 };
    let _ = opt.step(&ctx0);
    let t0 = std::time::Instant::now();
    for rep in 0..reps {
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &stats,
            lr: 0.1,
            step: rep as u64,
        };
        let _ = opt.step(&ctx);
    }
    Ok((t0.elapsed().as_secs_f64() / reps as f64, opt.state_bytes()))
}

/// Fit slope of log(y) vs log(x) by least squares.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.max(1e-12).ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var
}

pub fn table1() -> Result<()> {
    println!("Table 1 — measured per-update cost vs layer dim d (one (d,d) layer)");
    println!("paper: time Eva O(d²) < FOOF O(d³) < K-FAC/Shampoo O(2d³); mem Eva O(2d) sublinear\n");
    let dims = [32usize, 64, 128, 256];
    let opts = ["eva", "eva-f", "eva-s", "foof", "kfac", "shampoo"];
    let tp = TablePrinter::new(
        &["optimizer", "d=32", "d=64", "d=128", "d=256", "time slope", "mem slope", "mem@256"],
        &[9, 10, 10, 10, 10, 10, 9, 10],
    );
    let mut csv = Metrics::new("results/table1.csv", "optimizer,d,update_s,state_bytes");
    for opt in opts {
        let mut times = Vec::new();
        let mut mems = Vec::new();
        for &d in &dims {
            let reps = if matches!(opt, "kfac" | "shampoo" | "foof") && d >= 128 { 2 } else { 5 };
            let (t, m) = measure(opt, d, reps)?;
            csv.row(&[opt.into(), d.to_string(), format!("{t:.6}"), m.to_string()]);
            times.push(t);
            mems.push(m as f64);
        }
        let ds: Vec<f64> = dims.iter().map(|&d| d as f64).collect();
        let ts = loglog_slope(&ds, &times);
        let ms = loglog_slope(&ds, &mems);
        tp.row(&[
            opt.to_string(),
            format!("{:.2}ms", times[0] * 1e3),
            format!("{:.2}ms", times[1] * 1e3),
            format!("{:.2}ms", times[2] * 1e3),
            format!("{:.2}ms", times[3] * 1e3),
            format!("{ts:.2}"),
            format!("{ms:.2}"),
            format!("{}KiB", mems[3] as usize / 1024),
        ]);
    }
    csv.flush()?;
    println!("\n(expect: eva* time slope ≈ 2, kfac/shampoo/foof ≈ 3; eva mem slope ≈ 1+momentum, kf mem slope ≈ 2)");
    println!("csv: results/table1.csv");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_positive() {
        let (t, m) = measure("eva", 16, 2).unwrap();
        assert!(t > 0.0);
        assert!(m > 0);
    }

    #[test]
    fn slope_fit_recovers_powers() {
        let xs = [32.0, 64.0, 128.0, 256.0];
        let quad: Vec<f64> = xs.iter().map(|x| x * x * 3.0).collect();
        assert!((loglog_slope(&xs, &quad) - 2.0).abs() < 1e-6);
        let cubic: Vec<f64> = xs.iter().map(|x| x.powi(3) * 0.1).collect();
        assert!((loglog_slope(&xs, &cubic) - 3.0).abs() < 1e-6);
    }

    /// The headline Table 1 contrast at a fixed d: Eva's update is far
    /// cheaper than K-FAC's and Shampoo's, and holds far less state.
    #[test]
    fn eva_cheaper_than_kfac_and_shampoo() {
        let d = 96;
        let (te, me) = measure("eva", d, 3).unwrap();
        let (tk, mk) = measure("kfac", d, 3).unwrap();
        let (ts, ms) = measure("shampoo", d, 3).unwrap();
        assert!(te * 3.0 < tk, "eva {te} vs kfac {tk}");
        assert!(te * 3.0 < ts, "eva {te} vs shampoo {ts}");
        // Eva state (KVs+momentum) ≪ factor state.
        assert!(me * 2 < mk, "eva mem {me} vs kfac {mk}");
        assert!(me * 2 < ms, "eva mem {me} vs shampoo {ms}");
    }
}
