//! Table 8 — data-parallel throughput under the simulated interconnect
//! (the paper's 32×RTX2080Ti ImageNet setup, substituted per DESIGN.md
//! §3: worker threads + the α–β network model).
//!
//! The paper's setting: SGD/Eva run per-GPU batch 96; K-FAC@50 and
//! Shampoo@50 must drop to 64 to fit factor state in memory. Here the
//! batch asymmetry is reproduced directly and throughput is global
//! samples per simulated second.

use anyhow::Result;

use super::TablePrinter;
use crate::config::ModelArch;
use crate::coordinator::{DataParallelCfg, DataParallelTrainer, SimNetwork};
use crate::train::Metrics;

fn dp_cfg(opt: &str, workers: usize, batch: usize, interval: usize) -> DataParallelCfg {
    let mut c = DataParallelCfg::new(workers, opt);
    c.per_worker_batch = batch;
    c.steps = 8;
    c.arch = ModelArch::Classifier { hidden: vec![256, 128] };
    c.dataset = "c10-small".into();
    c.hp.update_interval = interval;
    c.network = SimNetwork::datacenter(workers);
    c
}

pub fn table8() -> Result<()> {
    println!("Table 8 — simulated data-parallel throughput (8 workers; paper uses 32 GPUs)");
    println!(
        "(dispatch backend: {}{} — simulated-time accounting is backend-independent)",
        crate::backend::current().label(),
        if crate::backend::global_is_default() {
            " [boot default: dp worker compute auto-uses all hardware threads]"
        } else {
            ""
        }
    );
    let tp = TablePrinter::new(
        &["algorithm", "batch", "throughput", "comm KB/step", "msgs", "step breakdown (comp/comm/prec ms)"],
        &[11, 6, 11, 13, 5, 36],
    );
    let mut csv = Metrics::new(
        "results/table8.csv",
        "algorithm,batch,throughput,comm_bytes,messages,compute_ms,comm_ms,precond_ms",
    );
    let runs = [
        ("sgd", 96usize, 1usize),
        ("eva", 96, 1),
        ("shampoo", 64, 50),
        ("kfac", 64, 50),
    ];
    let workers = 8;
    let mut tput = std::collections::BTreeMap::new();
    for (opt, batch, interval) in runs {
        let mut t = DataParallelTrainer::new(dp_cfg(opt, workers, batch, interval))
            .map_err(anyhow::Error::msg)?;
        let r = t.run().map_err(anyhow::Error::msg)?;
        tput.insert(opt, r.throughput);
        csv.row(&[
            opt.into(),
            batch.to_string(),
            format!("{:.1}", r.throughput),
            r.comm_bytes_per_step.to_string(),
            r.messages_per_step.to_string(),
            format!("{:.2}", 1e3 * r.sim_compute_s),
            format!("{:.2}", 1e3 * r.sim_comm_s),
            format!("{:.2}", 1e3 * r.sim_precond_s),
        ]);
        tp.row(&[
            format!("{opt}@{interval}"),
            batch.to_string(),
            format!("{:.0}/s", r.throughput),
            format!("{:.1}", r.comm_bytes_per_step as f64 / 1024.0),
            r.messages_per_step.to_string(),
            format!(
                "{:.1} / {:.2} / {:.1}",
                1e3 * r.sim_compute_s,
                1e3 * r.sim_comm_s,
                1e3 * r.sim_precond_s
            ),
        ]);
    }
    csv.flush()?;
    println!(
        "\n(expect ordering: sgd ≥ eva ≫ kfac@50 ≥ shampoo@50 — paper: 7420/6857/5520/4367)"
    );
    println!("csv: results/table8.csv");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Throughput ordering is the Table 8 claim.
    #[test]
    fn throughput_ordering_holds() {
        let run = |opt: &str, batch: usize, interval: usize| {
            let mut c = dp_cfg(opt, 4, batch, interval);
            c.steps = 4;
            c.arch = ModelArch::Classifier { hidden: vec![96, 64] };
            DataParallelTrainer::new(c).unwrap().run().unwrap().throughput
        };
        let sgd = run("sgd", 96, 1);
        let eva = run("eva", 96, 1);
        let kfac = run("kfac", 64, 2); // refresh every other step
        // Wall-clock-based ordering — generous margins to stay robust
        // against scheduler noise on a loaded single-core test box.
        assert!(eva <= sgd * 1.8, "eva {eva} vs sgd {sgd}");
        assert!(eva > kfac * 0.9, "eva {eva} vs kfac {kfac}");
    }
}
