//! Convergence experiments: Fig. 3, Fig. 4, Fig. 8, Tables 4, 6, 7, 9.
//!
//! All runs are scaled to the CPU testbed (DESIGN.md §3): synthetic
//! classification stand-ins for Cifar, procedural image families for
//! the autoencoder suite, epoch budgets shrunk proportionally
//! (50/100/200 → 2/4/8). What must reproduce is the *shape*: Eva ≈
//! K-FAC ≥ SGD at equal epochs; Eva-f ≈ FOOF; Eva-s ≈ Shampoo;
//! ablations degrade Eva.

use anyhow::Result;

use super::{cfg, default_lr, model_zoo, run_seeds, TablePrinter};
use crate::config::{ModelArch, TrainConfig};
use crate::optim::{Eva, HyperParams};
use crate::train::{Metrics, Trainer};

const SEEDS: &[u64] = &[11, 23];

/// Fig. 3 — FOOF vs rank-1 FOOF: the observation motivating Eva-f.
pub fn fig3() -> Result<()> {
    println!("Fig. 3 — FOOF vs FOOF(rank-1), deep classifier on c100-small");
    let mut csv = Metrics::new("results/fig3.csv", "optimizer,epoch,train_loss,val_acc");
    let tp = TablePrinter::new(&["optimizer", "final loss", "best acc"], &[12, 11, 9]);
    for opt in ["foof", "foof-rank1"] {
        let arch = ModelArch::Classifier { hidden: vec![128; 4] };
        let c = cfg("fig3", "c100-small", arch, opt, 3, default_lr(opt), 11);
        let mut t = Trainer::from_config(&c)?;
        let r = t.run()?;
        for e in &r.history {
            csv.row(&[
                opt.into(),
                e.epoch.to_string(),
                format!("{:.4}", e.train_loss),
                format!("{:.4}", e.val_metric),
            ]);
        }
        tp.row(&[opt.into(), format!("{:.4}", r.final_loss), format!("{:.2}%", 100.0 * r.best_val_acc)]);
    }
    csv.flush()?;
    println!("(expect: the two curves nearly coincide — R is near-rank-1)  csv: results/fig3.csv");
    Ok(())
}

/// Fig. 4 — the §5.1 autoencoder suite on 4 procedural datasets.
pub fn fig4() -> Result<()> {
    println!("Fig. 4 — 8-layer autoencoder optimization, 4 datasets × 5 optimizers");
    let mut csv = Metrics::new("results/fig4.csv", "dataset,optimizer,epoch,train_loss,val_loss");
    let datasets = ["mnist-like", "fmnist-like", "faces-like", "curves"];
    let opts = ["sgd", "adagrad", "shampoo", "kfac", "eva"];
    let tp = TablePrinter::new(
        &["dataset", "sgd", "adagrad", "shampoo", "kfac", "eva"],
        &[12, 9, 9, 9, 9, 9],
    );
    for ds in datasets {
        let mut cells = vec![ds.to_string()];
        for opt in opts {
            let mut c = cfg("fig4", ds, ModelArch::AutoencoderSmall, opt, 2, default_lr(opt), 5);
            c.lr_schedule = crate::config::LrSchedule::Linear; // paper §5.1
            c.optim.hp.weight_decay = 0.0;
            let mut t = Trainer::from_config(&c)?;
            let r = t.run()?;
            for e in &r.history {
                csv.row(&[
                    ds.into(),
                    opt.into(),
                    e.epoch.to_string(),
                    format!("{:.5}", e.train_loss),
                    format!("{:.5}", e.val_metric),
                ]);
            }
            cells.push(format!("{:.4}", r.final_loss));
        }
        tp.row(&cells);
    }
    csv.flush()?;
    println!("(expect: eva ≈ kfac < shampoo/adagrad < sgd final loss)  csv: results/fig4.csv");
    Ok(())
}

/// Table 4 — validation accuracy across models × epoch budgets, SGD vs
/// K-FAC vs Eva, on both classification stand-ins.
pub fn table4() -> Result<()> {
    println!("Table 4 — val acc (%) over epoch buckets (paper 50/100/200 → 1/2/4 scaled)");
    let mut csv = Metrics::new("results/table4.csv", "dataset,model,epochs,optimizer,acc_mean,acc_std");
    let tp = TablePrinter::new(
        &["dataset", "model", "ep", "sgd", "kfac", "eva"],
        &[11, 12, 3, 14, 14, 14],
    );
    for ds in ["c10-small", "c100-small"] {
        for (mname, arch) in model_zoo() {
            for epochs in [1usize, 2, 4] {
                let mut cells =
                    vec![ds.to_string(), mname.to_string(), epochs.to_string()];
                for opt in ["sgd", "kfac", "eva"] {
                    let c = cfg("table4", ds, arch.clone(), opt, epochs, default_lr(opt), 0);
                    let (mean, std, _) = run_seeds(&c, SEEDS)?;
                    csv.row(&[
                        ds.into(),
                        mname.into(),
                        epochs.to_string(),
                        opt.into(),
                        format!("{:.4}", mean),
                        format!("{:.4}", std),
                    ]);
                    cells.push(format!("{:.2}±{:.1}", 100.0 * mean, 100.0 * std));
                }
                tp.row(&cells);
            }
        }
    }
    csv.flush()?;
    println!("(expect: eva ≈ kfac ≥ sgd, gap largest at the small epoch budget)  csv: results/table4.csv");
    Ok(())
}

/// Table 6 — finetuning a pretrained model (pretrain on one synthetic
/// task with SGD, finetune on a shifted task with each optimizer).
pub fn table6() -> Result<()> {
    println!("Table 6 — finetune val acc (%) after SGD pretraining (shifted task)");
    let tp = TablePrinter::new(&["dataset", "sgd", "kfac", "eva"], &[11, 10, 10, 10]);
    let mut csv = Metrics::new("results/table6.csv", "dataset,optimizer,acc");
    for ds in ["c10-small", "c100-small"] {
        // Pretrain.
        let arch = ModelArch::Classifier { hidden: vec![128, 64] };
        let pre = cfg("pretrain", ds, arch.clone(), "sgd", 4, 0.1, 99);
        let mut trainer = Trainer::from_config(&pre)?;
        let _ = trainer.run()?;
        let pretrained = trainer.model().unwrap().clone();
        let mut cells = vec![ds.to_string()];
        for opt in ["sgd", "kfac", "eva"] {
            // Finetune on a different draw of the task (new seed ⇒
            // shifted decoder/noise — the "new dataset" analogue).
            let mut fine = cfg("finetune", ds, arch.clone(), opt, 2, default_lr(opt) * 0.2, 7);
            fine.seed = 123; // dataset shift
            let mut ft = Trainer::from_config(&fine)?;
            // Warm-start from the pretrained weights.
            ft.set_optimizer(crate::optim::by_name(opt, &fine.optim.hp).map_err(anyhow::Error::msg)?);
            if let Some(_) = ft.model() {
                // Replace params in-place.
            }
            let r = ft_run_with_init(&mut ft, &pretrained)?;
            csv.row(&[ds.into(), opt.into(), format!("{:.4}", r)]);
            cells.push(format!("{:.2}", 100.0 * r));
        }
        tp.row(&cells);
    }
    csv.flush()?;
    println!("(expect: all three close — second-order finetunes as well as SGD)  csv: results/table6.csv");
    Ok(())
}

fn ft_run_with_init(t: &mut Trainer, init: &crate::nn::Mlp) -> Result<f32> {
    t.set_model(init.clone());
    let r = t.run()?;
    Ok(r.best_val_acc)
}

/// Table 7 — Adagrad / AdamW / Shampoo / M-FAC on the three models.
pub fn table7() -> Result<()> {
    println!("Table 7 — val acc (%) with 4 more optimizers (epochs = 4)");
    let tp = TablePrinter::new(
        &["model", "adagrad", "adamw", "shampoo", "mfac", "eva"],
        &[12, 10, 10, 10, 10, 10],
    );
    let mut csv = Metrics::new("results/table7.csv", "model,optimizer,acc_mean,acc_std");
    for (mname, arch) in model_zoo() {
        let mut cells = vec![mname.to_string()];
        for opt in ["adagrad", "adamw", "shampoo", "mfac", "eva"] {
            let mut c = cfg("table7", "c10-small", arch.clone(), opt, 4, default_lr(opt), 0);
            if opt == "mfac" {
                c.optim.hp.mfac_history = 16; // paper's 1024 scaled; see DESIGN.md
            }
            let (mean, std, _) = run_seeds(&c, SEEDS)?;
            csv.row(&[mname.into(), opt.into(), format!("{mean:.4}"), format!("{std:.4}")]);
            cells.push(format!("{:.2}", 100.0 * mean));
        }
        tp.row(&cells);
    }
    csv.flush()?;
    println!("(expect: eva ≈ shampoo ≈ mfac ≥ adamw ≥ adagrad)  csv: results/table7.csv");
    Ok(())
}

/// Table 9 — Eva ablations: w/o momentum, w/o KL clip, w/o KVs.
pub fn table9() -> Result<()> {
    println!("Table 9 — Eva ablation, val acc (%) (epochs = 4)");
    let tp = TablePrinter::new(
        &["model", "eva", "w/o momentum", "w/o KL clip", "w/o KVs"],
        &[12, 10, 13, 12, 10],
    );
    let mut csv = Metrics::new("results/table9.csv", "model,variant,acc");
    let variants: &[(&str, fn(&mut Eva))] = &[
        ("eva", |_e| {}),
        ("w/o m.", |e| e.use_momentum = false),
        ("w/o klclip", |e| e.use_kl_clip = false),
        ("w/o kvs", |e| e.use_kvs = false),
    ];
    for (mname, arch) in [&model_zoo()[0], &model_zoo()[1]] {
        let mut cells = vec![mname.to_string()];
        for (vname, mutate) in variants {
            let c = cfg("table9", "c10-small", arch.clone(), "eva", 4, default_lr("eva"), 3);
            let mut t = Trainer::from_config(&c)?;
            let mut e = Eva::new(c.optim.hp.clone());
            mutate(&mut e);
            t.set_optimizer(Box::new(e));
            let r = t.run()?;
            csv.row(&[mname.to_string(), vname.to_string(), format!("{:.4}", r.best_val_acc)]);
            cells.push(format!("{:.2}", 100.0 * r.best_val_acc));
        }
        tp.row(&cells);
    }
    csv.flush()?;
    println!("(expect: full eva best; each ablation degrades)  csv: results/table9.csv");
    Ok(())
}

/// Fig. 8 — Eva-f vs FOOF and Eva-s vs Shampoo convergence pairing.
pub fn fig8() -> Result<()> {
    println!("Fig. 8 — vectorized vs original: eva-f/foof and eva-s/shampoo");
    let mut csv = Metrics::new("results/fig8.csv", "pair,dataset,optimizer,epoch,train_loss,val_acc");
    let tp = TablePrinter::new(&["pair", "dataset", "orig acc", "vec acc", "gap"], &[14, 11, 9, 9, 7]);
    let pairs = [("foof", "eva-f"), ("shampoo", "eva-s")];
    for (orig, vecd) in pairs {
        for ds in ["c10-small", "c100-small"] {
            let mut accs = Vec::new();
            for opt in [orig, vecd] {
                let arch = ModelArch::Classifier { hidden: vec![128, 64] };
                let mut c = cfg("fig8", ds, arch, opt, 3, default_lr(opt), 21);
                c.lr_schedule = crate::config::LrSchedule::Cosine;
                let mut t = Trainer::from_config(&c)?;
                let r = t.run()?;
                for e in &r.history {
                    csv.row(&[
                        format!("{orig}/{vecd}"),
                        ds.into(),
                        opt.into(),
                        e.epoch.to_string(),
                        format!("{:.4}", e.train_loss),
                        format!("{:.4}", e.val_metric),
                    ]);
                }
                accs.push(r.best_val_acc);
            }
            tp.row(&[
                format!("{orig}/{vecd}"),
                ds.into(),
                format!("{:.2}", 100.0 * accs[0]),
                format!("{:.2}", 100.0 * accs[1]),
                format!("{:+.2}", 100.0 * (accs[1] - accs[0])),
            ]);
        }
    }
    csv.flush()?;
    println!("(expect: |gap| small — vectorization preserves convergence)  csv: results/fig8.csv");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The central generalization claim at miniature scale: Eva matches
    /// K-FAC and beats SGD under a compressed epoch budget.
    #[test]
    fn eva_matches_kfac_beats_sgd_small_budget() {
        let arch = ModelArch::Classifier { hidden: vec![64, 32] };
        let mut accs = std::collections::BTreeMap::new();
        for opt in ["sgd", "kfac", "eva"] {
            let mut c = cfg("t4-mini", "c10-small", arch.clone(), opt, 2, default_lr(opt), 1);
            c.max_steps = Some(45);
            let (mean, _, _) = run_seeds(&c, &[1]).unwrap();
            accs.insert(opt, mean);
        }
        assert!(
            accs["eva"] >= accs["sgd"] - 0.03,
            "eva {} should be ≥ sgd {} (tol 3%)",
            accs["eva"],
            accs["sgd"]
        );
        assert!(
            (accs["eva"] - accs["kfac"]).abs() < 0.15,
            "eva {} ≈ kfac {}",
            accs["eva"],
            accs["kfac"]
        );
    }

    #[test]
    fn hp_defaults_match_paper() {
        let hp = HyperParams::default();
        assert_eq!(hp.momentum, 0.9);
        assert_eq!(hp.running_avg, 0.95);
    }
}
