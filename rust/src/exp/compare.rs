//! Cross-optimizer convergence/cost harness (`optim-compare`).
//!
//! Trains every second-order algorithm in the registry — the Eva
//! family, the dense baselines it approximates, and the
//! vectorized-approximation cousins (MKOR, KrADagrad) — on one shared
//! task, and reports convergence vs wall-clock vs memory side by
//! side: best validation accuracy, final loss, total time, mean
//! ms/step, and optimizer state bytes. The same rows feed three
//! surfaces: the `eva experiment optim-compare` table + CSV, the
//! `optimizer_bench` example, and the `optim_compare` section of
//! `BENCH_telemetry.json` (via `cargo bench --bench bench_snapshot`).

use anyhow::Result;

use super::{cfg, default_lr, TablePrinter};
use crate::config::ModelArch;
use crate::jsonx::Json;
use crate::train::{Metrics, Trainer};

/// Every second-order method the registry knows, paper order: Eva
/// variants first, then the dense/approximate baselines they are
/// measured against. SGD rides along as the first-order anchor.
pub const COMPARED: &[&str] = &[
    "sgd", "eva", "eva-f", "eva-s", "kfac", "foof", "foof-rank1", "shampoo", "mfac",
    "mkor", "kradagrad",
];

/// One optimizer's line in the comparison table.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub optimizer: String,
    pub best_val_acc: f32,
    pub final_loss: f32,
    pub total_time_s: f64,
    pub mean_step_ms: f64,
    pub state_bytes: usize,
    pub steps: u64,
}

/// Train each optimizer in [`COMPARED`] for `max_steps` steps on the
/// shared task and collect one [`CompareRow`] per optimizer.
///
/// All runs share the dataset, architecture, seed, batch size and LR
/// schedule; only the algorithm and its family-default LR differ
/// (the paper's "same hyper-parameters for fairness" setup).
pub fn collect(
    dataset: &str,
    arch: &ModelArch,
    max_steps: u64,
    seed: u64,
) -> Result<Vec<CompareRow>> {
    let mut rows = Vec::with_capacity(COMPARED.len());
    for opt in COMPARED {
        let mut c = cfg("optim-compare", dataset, arch.clone(), opt, 1, default_lr(opt), seed);
        c.max_steps = Some(max_steps);
        let mut t = Trainer::from_config(&c)?;
        let r = t.run()?;
        rows.push(CompareRow {
            optimizer: (*opt).into(),
            best_val_acc: r.best_val_acc,
            final_loss: r.final_loss,
            total_time_s: r.total_time_s,
            mean_step_ms: r.mean_step_ms,
            state_bytes: r.optimizer_state_bytes,
            steps: r.steps,
        });
    }
    Ok(rows)
}

/// Print the comparison as a fixed-width table (times relative to the
/// SGD anchor when present).
pub fn print_table(rows: &[CompareRow]) {
    let sgd_ms = rows
        .iter()
        .find(|r| r.optimizer == "sgd")
        .map(|r| r.mean_step_ms)
        .filter(|&m| m > 0.0);
    let tp = TablePrinter::new(
        &["optimizer", "best acc", "final loss", "time(s)", "ms/step", "rel t", "state KiB"],
        &[10, 8, 10, 8, 8, 6, 9],
    );
    for r in rows {
        let rel = match sgd_ms {
            Some(base) => format!("{:.2}x", r.mean_step_ms / base),
            None => "-".into(),
        };
        tp.row(&[
            r.optimizer.clone(),
            format!("{:.2}", 100.0 * r.best_val_acc),
            format!("{:.4}", r.final_loss),
            format!("{:.2}", r.total_time_s),
            format!("{:.3}", r.mean_step_ms),
            rel,
            format!("{:.1}", r.state_bytes as f64 / 1024.0),
        ]);
    }
}

/// The `optim_compare` JSON section persisted into
/// `BENCH_telemetry.json`: one object per optimizer, keyed by name.
pub fn rows_to_json(rows: &[CompareRow]) -> Json {
    Json::obj(
        rows.iter()
            .map(|r| {
                (
                    r.optimizer.as_str(),
                    Json::obj(vec![
                        ("best_val_acc", Json::Num(r.best_val_acc as f64)),
                        ("final_loss", Json::Num(r.final_loss as f64)),
                        ("total_time_s", Json::Num(r.total_time_s)),
                        ("mean_step_ms", Json::Num(r.mean_step_ms)),
                        ("state_bytes", Json::Num(r.state_bytes as f64)),
                        ("steps", Json::Num(r.steps as f64)),
                    ]),
                )
            })
            .collect::<Vec<_>>(),
    )
}

/// `eva experiment optim-compare` — the runnable comparison: short
/// shared run over every second-order optimizer, table to stdout, CSV
/// under `results/`.
pub fn optim_compare() -> Result<()> {
    println!("optim-compare — convergence vs wall-clock vs memory, all second-order methods");
    println!("(c10-small, one hidden layer, shared seed/schedule; interval-10 regime for dense baselines)\n");
    let arch = ModelArch::Classifier { hidden: vec![32] };
    let rows = collect("c10-small", &arch, 40, 11)?;
    print_table(&rows);
    let mut csv = Metrics::new(
        "results/optim_compare.csv",
        "optimizer,best_val_acc,final_loss,total_time_s,mean_step_ms,state_bytes,steps",
    );
    for r in &rows {
        csv.row(&[
            r.optimizer.clone(),
            format!("{:.4}", r.best_val_acc),
            format!("{:.4}", r.final_loss),
            format!("{:.3}", r.total_time_s),
            format!("{:.3}", r.mean_step_ms),
            r.state_bytes.to_string(),
            r.steps.to_string(),
        ]);
    }
    csv.flush()?;
    println!("\n(expect: eva family ≈ SGD cost at second-order accuracy; mkor/kradagrad between eva and the dense baselines)  csv: results/optim_compare.csv");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim;

    /// Every compared optimizer must exist in the registry — the
    /// harness cannot silently drift from `OPTIMIZER_NAMES`.
    #[test]
    fn compared_optimizers_are_registered() {
        for opt in COMPARED {
            assert!(
                optim::OPTIMIZER_NAMES.contains(opt),
                "{opt} not in optimizer registry"
            );
            optim::by_name(opt, &optim::HyperParams::default())
                .unwrap_or_else(|e| panic!("{opt}: {e}"));
        }
        // The harness covers the whole registry except the first-order
        // diagonal methods (adagrad/adam/adamw keep no curvature
        // factors to compare).
        for name in optim::OPTIMIZER_NAMES {
            let diag = matches!(*name, "adagrad" | "adam" | "adamw");
            assert_eq!(
                !diag,
                COMPARED.contains(name),
                "{name} coverage drifted between registry and harness"
            );
        }
    }

    /// The harness runs end to end on a miniature task and produces
    /// one well-formed row per optimizer, including the new
    /// vectorized-approximation cousins.
    #[test]
    fn collect_produces_complete_rows() {
        let arch = ModelArch::Classifier { hidden: vec![8] };
        let rows = collect("c10-small", &arch, 3, 5).unwrap();
        assert_eq!(rows.len(), COMPARED.len());
        for r in &rows {
            assert_eq!(r.steps, 3, "{}", r.optimizer);
            assert!(r.final_loss.is_finite(), "{} loss", r.optimizer);
            assert!(r.mean_step_ms >= 0.0, "{} step time", r.optimizer);
        }
        // Curvature-carrying methods must report more state than SGD's
        // bare momentum.
        let sgd = rows.iter().find(|r| r.optimizer == "sgd").unwrap().state_bytes;
        for name in ["mkor", "kradagrad", "kfac", "shampoo"] {
            let r = rows.iter().find(|r| r.optimizer == name).unwrap();
            assert!(
                r.state_bytes > sgd,
                "{name} state {} <= sgd {sgd}",
                r.state_bytes
            );
        }
        let j = rows_to_json(&rows);
        assert!(j.get("mkor").and_then(|o| o.get_f64("state_bytes")).unwrap() > 0.0);
        assert!(j.get("kradagrad").and_then(|o| o.get_f64("steps")).unwrap() > 0.0);
    }
}
