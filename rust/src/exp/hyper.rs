//! Fig. 7 — hyper-parameter study: learning rate, batch size, damping,
//! running average.

use anyhow::Result;

use super::{cfg, default_lr, TablePrinter};
use crate::config::ModelArch;
use crate::train::{Metrics, Trainer};

fn arch() -> ModelArch {
    ModelArch::Classifier { hidden: vec![64; 4] } // resnet-like (deep thin)
}

fn run_one(opt: &str, lr: f32, batch: usize, damping: f32, ra: f32) -> Result<f32> {
    let mut c = cfg("fig7", "c10-small", arch(), opt, 2, lr, 31);
    c.batch_size = batch;
    c.optim.hp.damping = damping;
    c.optim.hp.running_avg = ra;
    let mut t = Trainer::from_config(&c)?;
    Ok(t.run()?.best_val_acc)
}

pub fn fig7() -> Result<()> {
    println!("Fig. 7 — hyper-parameter sensitivity (val acc %, resnet-like on c10-small)");
    let mut csv = Metrics::new("results/fig7.csv", "sweep,setting,optimizer,acc");

    // (a) learning rate.
    println!("\n(a) learning rate");
    let lrs = [0.01f32, 0.05, 0.1, 0.3];
    let tp = TablePrinter::new(&["optimizer", "0.01", "0.05", "0.1", "0.3"], &[9, 7, 7, 7, 7]);
    for opt in ["sgd", "kfac", "eva"] {
        let mut cells = vec![opt.to_string()];
        for &lr in &lrs {
            let acc = run_one(opt, lr, 64, 0.03, 0.95)?;
            csv.row(&["lr".into(), format!("{lr}"), opt.into(), format!("{acc:.4}")]);
            cells.push(format!("{:.1}", 100.0 * acc));
        }
        tp.row(&cells);
    }

    // (b) batch size.
    println!("\n(b) batch size");
    let batches = [32usize, 64, 128, 256];
    let tp = TablePrinter::new(&["optimizer", "32", "64", "128", "256"], &[9, 7, 7, 7, 7]);
    for opt in ["sgd", "kfac", "eva"] {
        let mut cells = vec![opt.to_string()];
        for &b in &batches {
            let acc = run_one(opt, default_lr(opt), b, 0.03, 0.95)?;
            csv.row(&["batch".into(), b.to_string(), opt.into(), format!("{acc:.4}")]);
            cells.push(format!("{:.1}", 100.0 * acc));
        }
        tp.row(&cells);
    }

    // (c) damping (second-order only).
    println!("\n(c) damping γ");
    let gammas = [0.003f32, 0.03, 0.3];
    let tp = TablePrinter::new(&["optimizer", "0.003", "0.03", "0.3"], &[9, 7, 7, 7]);
    for opt in ["kfac", "eva"] {
        let mut cells = vec![opt.to_string()];
        for &g in &gammas {
            let acc = run_one(opt, default_lr(opt), 64, g, 0.95)?;
            csv.row(&["damping".into(), format!("{g}"), opt.into(), format!("{acc:.4}")]);
            cells.push(format!("{:.1}", 100.0 * acc));
        }
        tp.row(&cells);
    }

    // (d) running average ξ.
    println!("\n(d) running average ξ");
    let ras = [0.5f32, 0.95, 0.99];
    let tp = TablePrinter::new(&["optimizer", "0.5", "0.95", "0.99"], &[9, 7, 7, 7]);
    for opt in ["kfac", "eva"] {
        let mut cells = vec![opt.to_string()];
        for &ra in &ras {
            let acc = run_one(opt, default_lr(opt), 64, 0.03, ra)?;
            csv.row(&["running_avg".into(), format!("{ra}"), opt.into(), format!("{acc:.4}")]);
            cells.push(format!("{:.1}", 100.0 * acc));
        }
        tp.row(&cells);
    }

    csv.flush()?;
    println!("\n(expect: eva ≈ kfac across settings, robust to γ and ξ; sgd degrades at large lr/batch)");
    println!("csv: results/fig7.csv");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn damping_robustness_of_eva() {
        // Fig. 7(c) at miniature scale: two orders of magnitude of γ
        // both beat the 10% chance level by a wide margin (the KL clip
        // is what keeps the tiny-γ end trainable at all).
        let lo = run_one("eva", 0.05, 64, 0.003, 0.95).unwrap();
        let hi = run_one("eva", 0.05, 64, 0.3, 0.95).unwrap();
        assert!(lo > 0.14 && hi > 0.14, "lo {lo} hi {hi}");
    }
}
