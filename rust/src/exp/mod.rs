//! Experiment harness: regenerates every table and figure of the paper.
//!
//! `eva experiment <id>` runs one entry of the index below (scaled to
//! this single-core CPU testbed — see DESIGN.md §3 and §5 for the
//! substitutions and the expected *shape* of each result); `eva
//! experiment all` runs the full sweep. Each experiment prints a
//! paper-style table and writes CSV series under `results/`.
//!
//! | id | paper | module |
//! |---|---|---|
//! | `table1` | complexity vs layer dim | [`complexity`] |
//! | `fig3` | FOOF vs rank-1 FOOF | [`convergence`] |
//! | `fig4` | autoencoder suite | [`convergence`] |
//! | `table4` | SGD/K-FAC/Eva accuracy grid | [`convergence`] |
//! | `table5` | iteration time & memory | [`efficiency`] |
//! | `table6` | finetuning | [`convergence`] |
//! | `table7` | more optimizers | [`convergence`] |
//! | `table8` | DP throughput | [`distributed`] |
//! | `fig5` | wall-clock to accuracy | [`efficiency`] |
//! | `fig6` | K-FAC interval sweep | [`efficiency`] |
//! | `fig7` | hyper-parameter study | [`hyper`] |
//! | `table9` | Eva ablations | [`convergence`] |
//! | `fig8` | Eva-f/FOOF, Eva-s/Shampoo | [`convergence`] |
//! | `optim-compare` | all second-order methods, cost vs convergence | [`compare`] |
//! | `validate` | PJRT vs native cross-check | [`validate`] |

pub mod compare;
pub mod complexity;
pub mod convergence;
pub mod distributed;
pub mod efficiency;
pub mod hyper;
pub mod validate;

use anyhow::{anyhow, Result};

use crate::config::{LrSchedule, ModelArch, OptimConfig, TrainConfig};
use crate::optim::HyperParams;
use crate::train::{Report, Trainer};

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "table1", "fig3", "fig4", "table4", "table5", "table6", "table7", "table8", "fig5",
    "fig6", "fig7", "table9", "fig8", "table10", "optim-compare", "validate",
];

/// Run one experiment by id (or `all`).
pub fn run(id: &str) -> Result<()> {
    match id {
        "table1" => complexity::table1(),
        "fig3" => convergence::fig3(),
        "fig4" => convergence::fig4(),
        "table4" => convergence::table4(),
        "table5" => efficiency::table5(),
        "table6" => convergence::table6(),
        "table7" => convergence::table7(),
        "table8" => distributed::table8(),
        "fig5" => efficiency::fig5(),
        "fig6" => efficiency::fig6(),
        "fig7" => hyper::fig7(),
        "table9" => convergence::table9(),
        "fig8" => convergence::fig8(),
        "table10" => efficiency::table10(),
        "optim-compare" => compare::optim_compare(),
        "validate" => validate::run(),
        "all" => {
            for id in ALL {
                println!("\n================ experiment {id} ================");
                run(id)?;
            }
            Ok(())
        }
        other => Err(anyhow!("unknown experiment '{other}' (try: {})", ALL.join(", "))),
    }
}

// ---------------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------------

/// The three model families standing in for VGG-19 / ResNet-110 /
/// WRN-28-10 (DESIGN.md §3): wide-shallow, deep-thin, wide.
pub fn model_zoo() -> Vec<(&'static str, ModelArch)> {
    vec![
        ("vgg-like", ModelArch::Classifier { hidden: vec![256, 128] }),
        ("resnet-like", ModelArch::Classifier { hidden: vec![64; 6] }),
        ("wrn-like", ModelArch::Classifier { hidden: vec![320, 320] }),
    ]
}

/// Build a training config for experiments.
pub fn cfg(
    name: &str,
    dataset: &str,
    arch: ModelArch,
    optimizer: &str,
    epochs: usize,
    lr: f32,
    seed: u64,
) -> TrainConfig {
    let mut hp = HyperParams::default();
    // Expensive-inverse baselines run on the paper's increased update
    // interval by default (Table 5's parenthetical regime); Eva runs at
    // interval 1 — its headline property.
    if optimizer == "shampoo" || optimizer == "kfac" || optimizer == "foof" {
        hp.update_interval = 10;
    }
    TrainConfig {
        name: name.into(),
        dataset: dataset.into(),
        seed,
        arch,
        optim: OptimConfig { algorithm: optimizer.into(), hp },
        engine: crate::config::Engine::Native,
        epochs,
        batch_size: 64,
        base_lr: lr,
        lr_schedule: LrSchedule::Cosine,
        warmup_steps: 0,
        max_steps: None,
        eval_every: 1,
        backend: None,
        worker_threads: None,
        simd: None,
        telemetry: None,
    }
}

/// Run a config across seeds; returns (mean, std) of best val accuracy
/// plus the last report.
pub fn run_seeds(base: &TrainConfig, seeds: &[u64]) -> Result<(f32, f32, Report)> {
    let mut accs = Vec::new();
    let mut last = None;
    for &s in seeds {
        let mut c = base.clone();
        c.seed = s;
        let mut t = Trainer::from_config(&c)?;
        let r = t.run()?;
        accs.push(r.best_val_acc);
        last = Some(r);
    }
    let mean = accs.iter().sum::<f32>() / accs.len() as f32;
    let var =
        accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / accs.len() as f32;
    Ok((mean, var.sqrt(), last.unwrap()))
}

/// Default LR per optimizer family (tuned once on the quickstart task;
/// mirrors the paper's "same hyper-parameters for fairness" setup).
pub fn default_lr(optimizer: &str) -> f32 {
    match optimizer {
        "sgd" => 0.1,
        "adagrad" => 0.02,
        "adam" | "adamw" => 0.002,
        "mfac" => 0.03,
        // Eva family: the KL clip (Eq. 16) absorbs the 1/γ scale, so it
        // runs at SGD-like rates; the fig7 lr sweep confirms accuracy
        // still rising at 0.3 (paper uses the SGD grid for Eva too).
        "eva" | "eva-f" | "eva-s" => 0.3,
        // remaining second-order methods share one LR (paper §5.2).
        _ => 0.05,
    }
}

/// Fixed-width table printer.
pub struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(header: &[&str], widths: &[usize]) -> Self {
        let t = TablePrinter { widths: widths.to_vec() };
        t.row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        t
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{:<width$}  ", c, width = w));
        }
        println!("{}", line.trim_end());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_rejects_unknown() {
        assert!(run("table99").is_err());
    }

    #[test]
    fn model_zoo_has_three_families() {
        assert_eq!(model_zoo().len(), 3);
    }

    #[test]
    fn run_seeds_aggregates() {
        let mut c = cfg(
            "t",
            "c10-small",
            ModelArch::Classifier { hidden: vec![16] },
            "sgd",
            1,
            0.1,
            0,
        );
        c.max_steps = Some(10);
        let (mean, std, r) = run_seeds(&c, &[1, 2]).unwrap();
        assert!(mean >= 0.0 && mean <= 1.0);
        assert!(std >= 0.0);
        assert_eq!(r.steps, 10);
    }
}
