//! The native MLP: forward, backward, and curvature-stat capture.

use super::{Activation, BackwardResult, LayerStats, Loss, StatsMode};
use crate::rng::Pcg64;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Tensor};

/// Architecture description: `dims = [d0, d1, …, dL]` with an activation
/// per hidden layer and at the output.
#[derive(Clone, Debug)]
pub struct MlpSpec {
    pub dims: Vec<usize>,
    pub hidden_act: Activation,
    pub output_act: Activation,
    pub loss: Loss,
}

impl MlpSpec {
    /// A classifier: ReLU hidden layers, linear logits, softmax-CE.
    pub fn classifier(dims: Vec<usize>) -> Self {
        MlpSpec {
            dims,
            hidden_act: Activation::Relu,
            output_act: Activation::Identity,
            loss: Loss::SoftmaxCrossEntropy,
        }
    }

    /// The paper's §5.1 autoencoder: hidden dims
    /// `[1000, 500, 250, 30, 250, 500, 1000]` around the input dim, tanh
    /// units, sigmoid output, MSE loss (8 learnable layers).
    pub fn autoencoder(input_dim: usize) -> Self {
        let mut dims = vec![input_dim];
        dims.extend_from_slice(&[1000, 500, 250, 30, 250, 500, 1000]);
        dims.push(input_dim);
        MlpSpec {
            dims,
            hidden_act: Activation::Tanh,
            output_act: Activation::Sigmoid,
            loss: Loss::Mse,
        }
    }

    /// A reduced autoencoder for fast experiments/tests (same depth,
    /// smaller widths).
    pub fn autoencoder_small(input_dim: usize) -> Self {
        let mut dims = vec![input_dim];
        dims.extend_from_slice(&[200, 100, 50, 16, 50, 100, 200]);
        dims.push(input_dim);
        MlpSpec {
            dims,
            hidden_act: Activation::Tanh,
            output_act: Activation::Sigmoid,
            loss: Loss::Mse,
        }
    }

    pub fn num_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// Total learnable parameters (weights + biases).
    pub fn num_params(&self) -> usize {
        self.dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    fn act_at(&self, layer: usize) -> Activation {
        if layer + 1 == self.num_layers() {
            self.output_act
        } else {
            self.hidden_act
        }
    }
}

/// A multilayer perceptron with per-layer weight matrices `(d_out, d_in)`
/// and bias vectors.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub spec: MlpSpec,
    pub weights: Vec<Tensor>,
    pub biases: Vec<Vec<f32>>,
}

impl Mlp {
    /// He/Xavier initialization keyed by the hidden activation.
    pub fn init(spec: MlpSpec, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0x3317);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for l in 0..spec.num_layers() {
            let (d_in, d_out) = (spec.dims[l], spec.dims[l + 1]);
            let std = match spec.hidden_act {
                Activation::Relu => (2.0 / d_in as f32).sqrt(),
                _ => (1.0 / d_in as f32).sqrt(),
            };
            let mut w = Tensor::zeros(d_out, d_in);
            rng.fill_normal(w.data_mut(), std);
            weights.push(w);
            biases.push(vec![0.0; d_out]);
        }
        Mlp { spec, weights, biases }
    }

    pub fn num_layers(&self) -> usize {
        self.weights.len()
    }

    pub fn num_params(&self) -> usize {
        self.spec.num_params()
    }

    /// Forward pass only: returns the output `(n, dL)`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        for l in 0..self.num_layers() {
            h = self.layer_forward(l, &h);
        }
        h
    }

    /// One layer: `act(X Wᵀ + b)`.
    fn layer_forward(&self, l: usize, x: &Tensor) -> Tensor {
        let mut s = matmul_a_bt(x, &self.weights[l]);
        let act = self.spec.act_at(l);
        let b = &self.biases[l];
        for i in 0..s.rows() {
            let row = s.row_mut(i);
            for (v, &bj) in row.iter_mut().zip(b) {
                *v = act.apply(*v + bj);
            }
        }
        s
    }

    /// Forward + backward over a batch.
    ///
    /// `x` is `(n, d0)`. For classification pass `labels`; for
    /// autoencoding the reconstruction target is `x` itself and `labels`
    /// is ignored. `stats` selects which curvature statistics to
    /// capture (see [`StatsMode`]).
    pub fn forward_backward(
        &self,
        x: &Tensor,
        labels: &[usize],
        stats: StatsMode,
    ) -> BackwardResult {
        let n = x.rows();
        let ll = self.num_layers();
        // ---- forward, keeping every layer's output -----------------------
        let mut acts: Vec<Tensor> = Vec::with_capacity(ll + 1);
        acts.push(x.clone());
        for l in 0..ll {
            let next = self.layer_forward(l, &acts[l]);
            acts.push(next);
        }
        // ---- output loss + initial per-sample pre-activation grads -------
        let out = &acts[ll];
        let (loss, mut bhat, correct) = match self.spec.loss {
            Loss::SoftmaxCrossEntropy => {
                // output activation must be identity for CE.
                let (l, g, c) = super::loss::cross_entropy_grad(out, labels);
                (l, g, c)
            }
            Loss::Mse => {
                let (l, mut g) = super::loss::mse_grad(out, x);
                // chain through the output activation
                let act = self.spec.act_at(ll - 1);
                if act != Activation::Identity {
                    for i in 0..g.rows() {
                        for (gv, &ov) in g.row_mut(i).iter_mut().zip(out.row(i)) {
                            *gv *= act.grad_from_output(ov);
                        }
                    }
                }
                (l, g, 0)
            }
        };
        // ---- backward through layers --------------------------------------
        let mut grads = vec![Tensor::zeros(0, 0); ll];
        let mut bias_grads = vec![Vec::new(); ll];
        let mut layer_stats = Vec::with_capacity(ll);
        let inv_n = 1.0 / n as f32;
        for l in (0..ll).rev() {
            let a_in = &acts[l];
            // Mean weight gradient G = B̂ᵀ X / n  → (d_out, d_in)
            let mut g = matmul_at_b(&bhat, a_in);
            g.scale(inv_n);
            // Mean bias gradient: per-sample grads averaged over the
            // batch (mean_rows divides by n), matching G's scale.
            grads[l] = g;
            bias_grads[l] = bhat.mean_rows();
            // ---- curvature statistics ------------------------------------
            let st = match stats {
                StatsMode::None => LayerStats::empty(0, 0),
                StatsMode::KvOnly => LayerStats {
                    a_mean: a_in.mean_rows(),
                    b_mean: bhat.mean_rows(),
                    aat: None,
                    bbt: None,
                },
                StatsMode::Full => {
                    let mut aat = matmul_at_b(a_in, a_in);
                    aat.scale(inv_n);
                    let mut bbt = matmul_at_b(&bhat, &bhat);
                    bbt.scale(inv_n);
                    LayerStats {
                        a_mean: a_in.mean_rows(),
                        b_mean: bhat.mean_rows(),
                        aat: Some(aat),
                        bbt: Some(bbt),
                    }
                }
            };
            layer_stats.push(st);
            // ---- propagate to previous layer ------------------------------
            if l > 0 {
                // dL/dX = B̂ W  → (n, d_in); then chain prev activation.
                let mut dx = matmul(&bhat, &self.weights[l]);
                let act = self.spec.act_at(l - 1);
                if act != Activation::Identity {
                    // acts[l] is the *output* of layer l-1; chain rule
                    // through its activation using grad_from_output.
                    for i in 0..dx.rows() {
                        let arow = acts[l].row(i).to_vec();
                        for (dv, av) in dx.row_mut(i).iter_mut().zip(arow) {
                            *dv *= act.grad_from_output(av);
                        }
                    }
                }
                bhat = dx;
            }
        }
        layer_stats.reverse();
        BackwardResult { loss, grads, bias_grads, stats: layer_stats, correct }
    }

    /// Apply a parameter update: `W_l += deltas[l]`, `b_l += bias_deltas[l]`.
    pub fn apply_update(&mut self, deltas: &[Tensor], bias_deltas: &[Vec<f32>]) {
        for l in 0..self.num_layers() {
            self.weights[l].axpy(1.0, &deltas[l]);
            for (b, &d) in self.biases[l].iter_mut().zip(&bias_deltas[l]) {
                *b += d;
            }
        }
    }

    /// Classification accuracy over a split, batched.
    pub fn accuracy(&self, inputs: &Tensor, labels: &[usize], batch: usize) -> f32 {
        let n = inputs.rows();
        let mut correct = 0usize;
        let mut i = 0;
        while i < n {
            let end = (i + batch).min(n);
            let mut xb = Tensor::zeros(end - i, inputs.cols());
            for r in i..end {
                xb.row_mut(r - i).copy_from_slice(inputs.row(r));
            }
            let out = self.forward(&xb);
            for r in 0..out.rows() {
                let row = out.row(r);
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap();
                if argmax == labels[i + r] {
                    correct += 1;
                }
            }
            i = end;
        }
        correct as f32 / n as f32
    }

    /// Mean reconstruction loss over a split (autoencoding).
    pub fn reconstruction_loss(&self, inputs: &Tensor, batch: usize) -> f32 {
        let n = inputs.rows();
        let mut total = 0.0f64;
        let mut i = 0;
        while i < n {
            let end = (i + batch).min(n);
            let mut xb = Tensor::zeros(end - i, inputs.cols());
            for r in i..end {
                xb.row_mut(r - i).copy_from_slice(inputs.row(r));
            }
            let out = self.forward(&xb);
            let (l, _) = super::loss::mse_grad(&out, &xb);
            total += l as f64 * (end - i) as f64;
            i = end;
        }
        (total / n as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, close};

    fn tiny_classifier(seed: u64) -> Mlp {
        Mlp::init(MlpSpec::classifier(vec![6, 8, 4]), seed)
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_classifier(0);
        let x = Tensor::full(5, 6, 0.1);
        let out = m.forward(&x);
        assert_eq!(out.shape(), (5, 4));
    }

    #[test]
    fn weight_gradients_match_finite_difference() {
        let mut m = tiny_classifier(1);
        let mut rng = Pcg64::seeded(2);
        let mut x = Tensor::zeros(3, 6);
        rng.fill_normal(x.data_mut(), 1.0);
        let labels = [0usize, 2, 3];
        let res = m.forward_backward(&x, &labels, StatsMode::None);
        let eps = 1e-2f32;
        for l in 0..m.num_layers() {
            for &(i, j) in &[(0usize, 0usize), (1, 3), (2, 5.min(m.weights[l].cols() - 1))] {
                let orig = m.weights[l].at(i, j);
                *m.weights[l].at_mut(i, j) = orig + eps;
                let lp = m.forward_backward(&x, &labels, StatsMode::None).loss;
                *m.weights[l].at_mut(i, j) = orig - eps;
                let lm = m.forward_backward(&x, &labels, StatsMode::None).loss;
                *m.weights[l].at_mut(i, j) = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = res.grads[l].at(i, j);
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "layer {l} ({i},{j}): fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn bias_gradients_match_finite_difference() {
        let mut m = tiny_classifier(3);
        let mut rng = Pcg64::seeded(4);
        let mut x = Tensor::zeros(4, 6);
        rng.fill_normal(x.data_mut(), 1.0);
        let labels = [1usize, 0, 3, 2];
        let res = m.forward_backward(&x, &labels, StatsMode::None);
        let eps = 1e-2f32;
        for l in 0..m.num_layers() {
            for j in 0..m.biases[l].len().min(3) {
                let orig = m.biases[l][j];
                m.biases[l][j] = orig + eps;
                let lp = m.forward_backward(&x, &labels, StatsMode::None).loss;
                m.biases[l][j] = orig - eps;
                let lm = m.forward_backward(&x, &labels, StatsMode::None).loss;
                m.biases[l][j] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = res.bias_grads[l][j];
                assert!((fd - an).abs() < 2e-2, "layer {l} bias {j}: {fd} vs {an}");
            }
        }
    }

    #[test]
    fn autoencoder_gradients_match_finite_difference() {
        let spec = MlpSpec {
            dims: vec![5, 7, 3, 7, 5],
            hidden_act: Activation::Tanh,
            output_act: Activation::Sigmoid,
            loss: Loss::Mse,
        };
        let mut m = Mlp::init(spec, 5);
        let mut rng = Pcg64::seeded(6);
        let mut x = Tensor::zeros(3, 5);
        for v in x.data_mut() {
            *v = rng.uniform() as f32;
        }
        let res = m.forward_backward(&x, &[], StatsMode::None);
        let eps = 1e-2f32;
        for l in [0usize, 2] {
            let orig = m.weights[l].at(1, 1);
            *m.weights[l].at_mut(1, 1) = orig + eps;
            let lp = m.forward_backward(&x, &[], StatsMode::None).loss;
            *m.weights[l].at_mut(1, 1) = orig - eps;
            let lm = m.forward_backward(&x, &[], StatsMode::None).loss;
            *m.weights[l].at_mut(1, 1) = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let an = res.grads[l].at(1, 1);
            assert!((fd - an).abs() < 2e-2 * (1.0 + an.abs()), "layer {l}: {fd} vs {an}");
        }
    }

    /// Property: G == b̄ āᵀ exactly when the batch has one sample
    /// (rank-one identity underpinning Eva's approximation).
    #[test]
    fn prop_single_sample_gradient_is_outer_product() {
        check("G == b̄āᵀ for n=1", 20, |g| {
            let d_in = g.usize_in(2, 10);
            let d_hidden = g.usize_in(2, 10);
            let classes = g.usize_in(2, 5);
            let m = Mlp::init(
                MlpSpec::classifier(vec![d_in, d_hidden, classes]),
                g.rng().next_u64(),
            );
            let x = g.normal_tensor(1, d_in);
            let label = vec![g.usize_in(0, classes - 1)];
            let res = m.forward_backward(&x, &label, StatsMode::KvOnly);
            for l in 0..m.num_layers() {
                let st = &res.stats[l];
                let mut outer = Tensor::zeros(st.b_mean.len(), st.a_mean.len());
                outer.add_outer(1.0, &st.b_mean, &st.a_mean);
                crate::testing::tensors_close(&outer, &res.grads[l], 1e-4, "G vs b̄āᵀ")?;
            }
            Ok(())
        });
    }

    /// Property: KFs dominate KVs in the PSD order — `R ⪰ āāᵀ`
    /// (Eq. 19; this is the trust-region containment argument).
    #[test]
    fn prop_kf_dominates_kv_psd() {
        check("AAᵀ/n ⪰ āāᵀ", 15, |g| {
            let d = g.usize_in(2, 8);
            let n = g.usize_in(2, 12);
            let a = g.normal_tensor(n, d); // batch-major activations
            let mut r = matmul_at_b(&a, &a);
            r.scale(1.0 / n as f32);
            let abar = a.mean_rows();
            // M = R − āāᵀ must be PSD: check Cholesky of M + tiny ridge.
            let mut m = r.clone();
            m.add_outer(-1.0, &abar, &abar);
            m.add_diag(1e-4);
            crate::linalg::cholesky(&m).map(|_| ()).map_err(|e| format!("not PSD: {e}"))
        });
    }

    #[test]
    fn stats_shapes_match_layers() {
        let m = tiny_classifier(7);
        let x = Tensor::full(4, 6, 0.3);
        let res = m.forward_backward(&x, &[0, 1, 2, 3], StatsMode::Full);
        assert_eq!(res.stats.len(), 2);
        assert_eq!(res.stats[0].a_mean.len(), 6);
        assert_eq!(res.stats[0].b_mean.len(), 8);
        assert_eq!(res.stats[0].aat.as_ref().unwrap().shape(), (6, 6));
        assert_eq!(res.stats[1].bbt.as_ref().unwrap().shape(), (4, 4));
    }

    #[test]
    fn sgd_steps_reduce_loss() {
        let mut m = tiny_classifier(8);
        let mut rng = Pcg64::seeded(9);
        let mut x = Tensor::zeros(16, 6);
        rng.fill_normal(x.data_mut(), 1.0);
        let labels: Vec<usize> = (0..16).map(|i| i % 4).collect();
        let first = m.forward_backward(&x, &labels, StatsMode::None).loss;
        for _ in 0..60 {
            let res = m.forward_backward(&x, &labels, StatsMode::None);
            let deltas: Vec<Tensor> = res
                .grads
                .iter()
                .map(|g| {
                    let mut d = g.clone();
                    d.scale(-0.5);
                    d
                })
                .collect();
            let bias_deltas: Vec<Vec<f32>> = res
                .bias_grads
                .iter()
                .map(|g| g.iter().map(|v| -0.5 * v).collect())
                .collect();
            m.apply_update(&deltas, &bias_deltas);
        }
        let last = m.forward_backward(&x, &labels, StatsMode::None).loss;
        assert!(last < first * 0.5, "loss {first} -> {last}");
        close(m.accuracy(&x, &labels, 8), 1.0, 0.3, "train acc").unwrap();
    }

    use crate::rng::Pcg64;
}
