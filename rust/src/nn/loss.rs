//! Loss functions and their per-sample gradients.

use crate::tensor::Tensor;

/// Row-wise softmax of a logits matrix `(n, C)`, numerically stabilized.
pub fn softmax_rows(logits: &Tensor) -> Tensor {
    let mut p = logits.clone();
    for i in 0..p.rows() {
        let row = p.row_mut(i);
        let m = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        let inv = 1.0 / z;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    p
}

/// Softmax cross-entropy. Returns `(mean_loss, B̂, correct)` where `B̂`
/// is the `(n, C)` matrix of per-sample gradients w.r.t. the logits of
/// the *per-sample* loss: `p_i − onehot(y_i)`.
pub fn cross_entropy_grad(logits: &Tensor, labels: &[usize]) -> (f32, Tensor, usize) {
    let n = logits.rows();
    assert_eq!(labels.len(), n);
    let mut b = softmax_rows(logits);
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for i in 0..n {
        let y = labels[i];
        let row = b.row_mut(i);
        // top-1 before mutation
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j)
            .unwrap();
        if argmax == y {
            correct += 1;
        }
        loss += -(row[y].max(1e-30) as f64).ln();
        row[y] -= 1.0;
    }
    ((loss / n as f64) as f32, b, correct)
}

/// Mean squared error `0.5·Σ_dims (o−t)²` averaged over the batch.
/// Returns `(mean_loss, B̂)` with per-sample gradient `o_i − t_i`.
pub fn mse_grad(out: &Tensor, target: &Tensor) -> (f32, Tensor) {
    assert_eq!(out.shape(), target.shape());
    let n = out.rows();
    let mut b = out.clone();
    b.axpy(-1.0, target);
    let loss = 0.5 * b.norm_sq() / n as f32;
    (loss, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one() {
        let l = Tensor::from_rows(&[&[1.0, 2.0, 3.0], &[-5.0, 0.0, 5.0]]);
        let p = softmax_rows(&l);
        for i in 0..2 {
            let s: f32 = p.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(p.row(i).iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let a = Tensor::from_rows(&[&[100.0, 101.0]]);
        let b = Tensor::from_rows(&[&[0.0, 1.0]]);
        assert!(softmax_rows(&a).max_abs_diff(&softmax_rows(&b)) < 1e-5);
    }

    #[test]
    fn ce_loss_and_grad_finite_difference() {
        let logits = Tensor::from_rows(&[&[0.5, -0.2, 0.1], &[-1.0, 2.0, 0.3]]);
        let labels = [2usize, 0];
        let (l0, g, _c) = cross_entropy_grad(&logits, &labels);
        let eps = 1e-3f32;
        for i in 0..2 {
            for j in 0..3 {
                let mut lp = logits.clone();
                *lp.at_mut(i, j) += eps;
                let (l1, _, _) = cross_entropy_grad(&lp, &labels);
                let fd = (l1 - l0) / eps;
                // g holds per-sample grads; mean-loss grad is g/n.
                let analytic = g.at(i, j) / 2.0;
                assert!((fd - analytic).abs() < 1e-2, "({i},{j}): {fd} vs {analytic}");
            }
        }
    }

    #[test]
    fn ce_counts_correct_predictions() {
        let logits = Tensor::from_rows(&[&[3.0, 0.0], &[0.0, 3.0], &[3.0, 0.0]]);
        let (_, _, correct) = cross_entropy_grad(&logits, &[0, 1, 1]);
        assert_eq!(correct, 2);
    }

    #[test]
    fn mse_matches_manual() {
        let o = Tensor::from_rows(&[&[1.0, 2.0], &[0.0, 0.0]]);
        let t = Tensor::from_rows(&[&[0.0, 2.0], &[0.0, -2.0]]);
        let (loss, g) = mse_grad(&o, &t);
        // 0.5*((1)^2 + 0 + 0 + (2)^2)/2 = 0.5*5/2
        assert!((loss - 1.25).abs() < 1e-6);
        assert_eq!(g.at(0, 0), 1.0);
        assert_eq!(g.at(1, 1), 2.0);
    }
}
