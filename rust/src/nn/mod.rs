//! Neural-network layer + model definitions (native Rust path).
//!
//! The native MLP implements exactly the computation the L2 JAX model
//! performs, including capture of the per-layer statistics every
//! optimizer in the paper consumes:
//!
//! * `ā = mean-col(A)`, `b̄ = mean-col(B)` — Eva's Kronecker **vectors**
//!   (Eq. 10),
//! * `R = AAᵀ/n`, `Q = BBᵀ/n` — K-FAC/FOOF Kronecker **factors** (Eq. 4).
//!
//! Convention (see DESIGN.md): activations `A` are stored batch-major
//! `(n, d)`; `B̂` holds per-sample pre-activation gradients of the
//! *per-sample* loss, so the mean weight gradient is `G = B̂ᵀX / n` and
//! the empirical-Fisher factors are `Q = B̂ᵀB̂ / n`, `R = XᵀX / n`.
//!
//! The native path exists so that (a) the optimizer zoo and coordinator
//! are testable without artifacts, (b) finite-difference and PJRT
//! cross-checks triangulate correctness, and (c) experiments can run
//! at CPU-friendly sizes. The fused-Eva PJRT artifact is the optimized
//! hot path (see `runtime`).

mod loss;
mod mlp;

pub use loss::{cross_entropy_grad, mse_grad, softmax_rows};
pub use mlp::{Mlp, MlpSpec};

use crate::tensor::Tensor;

/// Elementwise nonlinearity of a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Tanh,
    Sigmoid,
    Identity,
}

impl Activation {
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::Relu => x.max(0.0),
            Activation::Tanh => x.tanh(),
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Activation::Identity => x,
        }
    }

    /// Derivative expressed through the *output* value `y = f(x)` (all
    /// four activations admit this form, which avoids storing `x`).
    pub fn grad_from_output(&self, y: f32) -> f32 {
        match self {
            Activation::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Tanh => 1.0 - y * y,
            Activation::Sigmoid => y * (1.0 - y),
            Activation::Identity => 1.0,
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "relu" => Ok(Activation::Relu),
            "tanh" => Ok(Activation::Tanh),
            "sigmoid" => Ok(Activation::Sigmoid),
            "identity" | "linear" => Ok(Activation::Identity),
            other => Err(format!("unknown activation '{other}'")),
        }
    }
}

/// The training objective at the output layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// Softmax cross-entropy over class logits.
    SoftmaxCrossEntropy,
    /// 0.5·Σ_dims (o−t)², averaged over the batch (autoencoding).
    Mse,
}

/// Which curvature statistics the backward pass should compute.
///
/// `KvOnly` is Eva's O(d) capture; `Full` additionally builds the d×d
/// Kronecker factors K-FAC/FOOF need (the expensive path Table 1/5
/// measures).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatsMode {
    None,
    KvOnly,
    Full,
}

/// Per-layer curvature statistics captured during backward.
#[derive(Clone, Debug)]
pub struct LayerStats {
    /// Mean input activation `ā` (length d_in).
    pub a_mean: Vec<f32>,
    /// Mean pre-activation gradient `b̄` (length d_out).
    pub b_mean: Vec<f32>,
    /// `R = XᵀX/n` (d_in × d_in) when `StatsMode::Full`.
    pub aat: Option<Tensor>,
    /// `Q = B̂ᵀB̂/n` (d_out × d_out) when `StatsMode::Full`.
    pub bbt: Option<Tensor>,
}

impl LayerStats {
    pub fn empty(d_in: usize, d_out: usize) -> Self {
        LayerStats {
            a_mean: vec![0.0; d_in],
            b_mean: vec![0.0; d_out],
            aat: None,
            bbt: None,
        }
    }
}

/// Output of one forward+backward pass over a mini-batch.
#[derive(Clone, Debug)]
pub struct BackwardResult {
    /// Mean loss over the batch.
    pub loss: f32,
    /// Per-layer mean weight gradients `(d_out, d_in)`.
    pub grads: Vec<Tensor>,
    /// Per-layer mean bias gradients.
    pub bias_grads: Vec<Vec<f32>>,
    /// Per-layer curvature statistics (empty vec when `StatsMode::None`).
    pub stats: Vec<LayerStats>,
    /// Number of correct top-1 predictions (classification only).
    pub correct: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activation_grads_match_finite_difference() {
        let eps = 1e-3f32;
        for act in [Activation::Relu, Activation::Tanh, Activation::Sigmoid, Activation::Identity]
        {
            for &x in &[-1.7f32, -0.3, 0.4, 2.1] {
                let y = act.apply(x);
                let g = act.grad_from_output(y);
                let fd = (act.apply(x + eps) - act.apply(x - eps)) / (2.0 * eps);
                assert!((g - fd).abs() < 5e-3, "{act:?} at {x}: {g} vs {fd}");
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Activation::parse("relu").unwrap(), Activation::Relu);
        assert!(Activation::parse("gelu").is_err());
    }
}
