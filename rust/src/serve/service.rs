//! The multi-tenant service: session registry + scheduler lifecycle.
//!
//! **Admission control** (durable since ISSUE 5): `max_sessions` caps
//! *admitted* sessions — the ones holding a live compute slot — not
//! submissions. A submit beyond the cap parks the session in the
//! admission queue (`Queued` with a 1-based `queue_position`); the
//! scheduler promotes waiting sessions FIFO-within-priority as slots
//! free up. `max_sessions_per_tenant` bounds how many *live* (queued
//! + running + paused) sessions one tenant may hold, so a single
//! client cannot monopolize the queue. Terminal sessions are retained
//! for status queries up to `retain_terminal`, then evicted (a later
//! `status` gets a distinct "evicted" error).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::TrainConfig;
use crate::serve::checkpoint::Checkpoint;
use crate::serve::scheduler;
use crate::serve::session::{Session, SessionState, SessionStatus, StepEvent};
use crate::serve::ServeConfig;
use crate::train::StepTimer;

/// One registry entry: the session plus the scheduling metadata that
/// must be readable without the session mutex, so admission
/// bookkeeping and status queries never block behind a mid-quantum
/// compute lock.
pub(crate) struct Slot {
    pub(crate) sess: Arc<Mutex<Session>>,
    pub(crate) tenant: String,
    pub(crate) priority: usize,
    /// True once the session holds one of the `max_sessions` live
    /// slots; false while parked in the admission queue. One-way;
    /// flipped only by [`promote_waiting`] under the registry lock.
    /// Key invariant: the scheduler only ever steps admitted
    /// sessions, so an *unadmitted* session's mutex is never held
    /// longer than a brief control-plane read.
    pub(crate) admitted: AtomicBool,
    /// Serializes checkpoint *writes* of this session. The session
    /// mutex is deliberately dropped before disk I/O (a slow disk
    /// must not stall the scheduler), so without this a stale LIVE
    /// snapshot could rename over a freshly written terminal
    /// tombstone at the same `<stem>-step<K>.ckpt` path and
    /// un-tombstone the lineage.
    pub(crate) ckpt_io: Arc<Mutex<()>>,
}

/// The admission-queue order shared by promotion, `queue_position`
/// reporting and `stats`: higher priority first, then submission (id)
/// order within a priority — FIFO within priority.
pub(crate) fn admission_cmp(a: &(usize, u64), b: &(usize, u64)) -> std::cmp::Ordering {
    b.0.cmp(&a.0).then(a.1.cmp(&b.1))
}

/// Order `(priority, id)` waiting entries into promotion order and
/// return just the ids — the one shape behind [`promote_waiting`],
/// [`Service::status`]'s `queue_position` and `stats`, so the three
/// can never disagree about who is next.
pub(crate) fn order_waiting(mut waiting: Vec<(usize, u64)>) -> Vec<u64> {
    waiting.sort_by(admission_cmp);
    waiting.into_iter().map(|(_, id)| id).collect()
}

/// Shared state between the service facade, the scheduler thread and
/// the TCP server.
pub(crate) struct Inner {
    pub(crate) cfg: ServeConfig,
    pub(crate) sessions: Mutex<BTreeMap<u64, Slot>>,
    /// Ids of terminal sessions dropped by the `retain_terminal` cap —
    /// kept (bounded; see `scheduler::EVICTED_IDS_REMEMBERED`) so
    /// `status` can distinguish "evicted" from "never existed".
    pub(crate) evicted: Mutex<BTreeSet<u64>>,
    /// Monotonic count of evictions (the stats counter — unlike the
    /// id memory above, this never plateaus).
    pub(crate) evicted_total: AtomicU64,
    pub(crate) next_id: AtomicU64,
    pub(crate) stop: AtomicBool,
    pub(crate) rounds: AtomicU64,
    pub(crate) sched_steps: AtomicU64,
    /// Checkpoints written by the scheduler clock + shutdown snapshot
    /// (explicit client `checkpoint` commands are not counted here).
    pub(crate) auto_checkpoints: AtomicU64,
    /// Waiting sessions promoted into live slots.
    pub(crate) promotions: AtomicU64,
    sched_handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Prometheus scrape listener (`cfg.metrics_addr`); `None` when
    /// the endpoint is off or failed to bind. Stopped at shutdown.
    metrics_srv: Mutex<Option<crate::telemetry::export::MetricsServer>>,
}

/// Handle to a running training-session service. Cheap to clone (all
/// clones share one registry + scheduler); stop it with
/// [`Service::shutdown`].
#[derive(Clone)]
pub struct Service {
    inner: Arc<Inner>,
}

/// Aggregate service statistics (the `stats` protocol command).
#[derive(Clone, Debug)]
pub struct ServiceStats {
    /// Admission-queue length: live sessions waiting for a slot.
    pub queue_depth: usize,
    /// Sessions currently being stepped.
    pub running: usize,
    /// Sessions held by `pause`.
    pub paused: usize,
    /// Live sessions (queued + running + paused), admitted or waiting.
    pub live: usize,
    /// Live sessions holding one of the `max_sessions` slots.
    pub admitted: usize,
    /// Admission cap on concurrently *admitted* sessions.
    pub max_sessions: usize,
    /// Lanes of the shared compute pool the scheduler carves.
    pub total_lanes: usize,
    /// Label of the shared backend (e.g. `threads:8`).
    pub backend: String,
    /// Scheduler rounds executed.
    pub rounds: u64,
    /// Optimizer steps executed by the scheduler, all sessions.
    pub scheduler_steps: u64,
    /// Scheduler-driven checkpoints written (periodic + shutdown).
    pub auto_checkpoints: u64,
    /// Waiting sessions promoted into live slots so far.
    pub promotions: u64,
    /// Terminal sessions evicted by the `retain_terminal` cap.
    pub evicted: u64,
    /// Median step latency (ms) across every session's lifetime.
    pub p50_step_ms: f64,
    /// 95th-percentile step latency (ms) across every session.
    pub p95_step_ms: f64,
    /// Per-session states (evicted sessions excluded).
    pub sessions: Vec<SessionState>,
}

/// Snapshot one session to its checkpoint lineage file under
/// `cfg.checkpoint_dir`; returns `(path, step)`.
///
/// Lock discipline (the torn-checkpoint fix): the session mutex is
/// held only for the *in-memory* capture — it is dropped before any
/// filesystem work, so a slow disk never stalls a scheduler round on
/// this session's lock — and [`Checkpoint::save`] writes tmp + rename,
/// so a crash mid-write never leaves a truncated `.ckpt` at the
/// canonical name. The periodic clock (`last_checkpoint_step`) is only
/// advanced after the rename succeeds. `io` (the slot's
/// [`Slot::ckpt_io`]) is held across capture → write → bookkeeping so
/// same-session writers cannot reorder a stale LIVE snapshot over a
/// terminal tombstone.
pub(crate) fn checkpoint_session(
    cfg: &ServeConfig,
    sess: &Arc<Mutex<Session>>,
    io: &Mutex<()>,
) -> Result<(String, u64), String> {
    let _write_order = io.lock().unwrap_or_else(|e| e.into_inner());
    let (ck, stem) = {
        let s = sess.lock().unwrap_or_else(|e| e.into_inner());
        (s.checkpoint()?, s.ckpt_stem().to_string())
    };
    let step = ck.loop_snap.step;
    let tag = ck.status_tag;
    let path = std::path::Path::new(&cfg.checkpoint_dir)
        .join(format!("{stem}-step{step}.ckpt"))
        .to_string_lossy()
        .into_owned();
    // Direct record (not `time_phase`): checkpoint I/O runs on the
    // scheduler/control-plane threads, which never drain the
    // per-step thread-local phase list.
    let io_t0 = crate::telemetry::enabled().then(std::time::Instant::now);
    ck.save(&path)?;
    if let Some(t0) = io_t0 {
        crate::telemetry::SERVE_SCHED_CHECKPOINT_IO_US.record_us(t0.elapsed().as_micros() as u64);
        crate::telemetry::SERVE_CHECKPOINTS.add(1);
    }
    sess.lock().unwrap_or_else(|e| e.into_inner()).note_checkpointed_at(step, tag);
    if cfg.retain_snapshots > 0 {
        prune_lineage(&cfg.checkpoint_dir, &stem, cfg.retain_snapshots);
    }
    Ok((path, step))
}

/// Delete this lineage's snapshots beyond the newest `keep` *loadable*
/// ones. Terminal tombstones are never deleted (they are what keeps a
/// finished session finished across a `--resume-dir` restart), torn
/// files are (they can never be loaded, so nothing is lost). Runs
/// under the caller's [`Slot::ckpt_io`] lock, so a concurrent
/// same-session write can never race the scan. Best-effort: failures
/// are logged, never fatal — pruning must not fail a checkpoint that
/// already landed. Each deletion bumps the `serve.ckpt.pruned`
/// counter.
pub(crate) fn prune_lineage(dir: &str, stem: &str, keep: usize) {
    let lineages = match crate::serve::checkpoint::scan_lineages(dir) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("serve: snapshot prune scan of '{dir}' failed: {e}");
            return;
        }
    };
    let Some(files) = lineages.get(stem) else { return };
    let mut kept = 0usize;
    // Newest step first (scan_lineages order), so retention keeps the
    // most recent snapshots.
    for (_step, path) in files {
        let loadable_live = match Checkpoint::load(path) {
            // Tombstones are exempt from retention counting *and*
            // deletion.
            Ok(ck) if crate::serve::checkpoint::status_tag::is_terminal(ck.status_tag) => continue,
            Ok(_) => true,
            Err(_) => false,
        };
        if loadable_live && kept < keep {
            kept += 1;
            continue;
        }
        match std::fs::remove_file(path) {
            Ok(()) => crate::telemetry::SERVE_CKPT_PRUNED.add(1),
            Err(e) => eprintln!("serve: prune of '{path}' failed: {e}"),
        }
    }
}

/// Promote waiting sessions into free live slots in
/// [`admission_cmp`] order. Returns the number promoted.
///
/// The registry lock is held across the scan *and* the flips, so
/// concurrent submits cannot both count the same free slot. The scan
/// never blocks behind compute: waiting sessions are unadmitted (the
/// scheduler never steps them, so their locks are only briefly held),
/// and for admitted sessions a busy mutex *means* mid-quantum, hence
/// live — `try_lock`-else-live is exact there. (A control-plane read
/// holding a terminal session's lock can transiently over-count by
/// one, which only delays a promotion to the next scheduler round.)
pub(crate) fn promote_waiting(inner: &Inner) -> usize {
    let map = inner.sessions.lock().unwrap_or_else(|e| e.into_inner());
    let mut admitted_live = 0usize;
    let mut waiting: Vec<(usize, u64)> = Vec::new();
    for (id, slot) in map.iter() {
        if slot.admitted.load(Ordering::Relaxed) {
            let live = match slot.sess.try_lock() {
                Ok(s) => s.status().is_live(),
                Err(_) => true, // busy ⇒ mid-quantum ⇒ live
            };
            if live {
                admitted_live += 1;
            }
        } else {
            let s = slot.sess.lock().unwrap_or_else(|e| e.into_inner());
            if *s.status() == SessionStatus::Queued {
                waiting.push((slot.priority, *id));
            }
            // A paused-but-never-admitted session is live (it counts
            // against quotas) but not promotable until resumed.
        }
    }
    let free = inner.cfg.max_sessions.saturating_sub(admitted_live);
    if free == 0 || waiting.is_empty() {
        return 0;
    }
    let mut promoted = 0usize;
    for id in order_waiting(waiting).into_iter().take(free) {
        if let Some(slot) = map.get(&id) {
            slot.admitted.store(true, Ordering::Relaxed);
            promoted += 1;
        }
    }
    inner.promotions.fetch_add(promoted as u64, Ordering::Relaxed);
    promoted
}

impl Service {
    /// Start a service: the scheduler thread begins immediately;
    /// sessions arrive via [`Service::submit`] (or the TCP server /
    /// clients layered on top). When `cfg.resume_dir` is set, the
    /// previous incarnation's sessions are re-admitted before this
    /// returns ([`Service::resume_from_dir`]; per-lineage failures
    /// are logged, never fatal).
    pub fn start(cfg: ServeConfig) -> Service {
        let resume_dir = cfg.resume_dir.clone();
        crate::telemetry::health::set_every(cfg.health_every_steps);
        let metrics_srv = cfg.metrics_addr.as_deref().and_then(|addr| {
            match crate::telemetry::export::MetricsServer::start(addr) {
                Ok(srv) => Some(srv),
                Err(e) => {
                    eprintln!("serve: metrics endpoint on '{addr}' failed to bind: {e}");
                    None
                }
            }
        });
        let inner = Arc::new(Inner {
            cfg,
            sessions: Mutex::new(BTreeMap::new()),
            evicted: Mutex::new(BTreeSet::new()),
            evicted_total: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            rounds: AtomicU64::new(0),
            sched_steps: AtomicU64::new(0),
            auto_checkpoints: AtomicU64::new(0),
            promotions: AtomicU64::new(0),
            sched_handle: Mutex::new(None),
            metrics_srv: Mutex::new(metrics_srv),
        });
        let for_thread = Arc::clone(&inner);
        let handle = std::thread::Builder::new()
            .name("eva-serve-sched".into())
            .spawn(move || scheduler::run(for_thread))
            // eva-lint: allow(L5) -- boot-time spawn: the scheduler is mandatory and no connection exists yet
            .expect("spawn scheduler thread");
        *inner.sched_handle.lock().unwrap_or_else(|e| e.into_inner()) = Some(handle);
        let svc = Service { inner };
        if let Some(dir) = resume_dir {
            if let Err(e) = svc.resume_from_dir(&dir) {
                eprintln!("serve: resume from '{dir}' failed: {e}");
            }
        }
        svc
    }

    /// The service configuration.
    pub fn config(&self) -> &ServeConfig {
        &self.inner.cfg
    }

    /// True once [`Service::shutdown`] ran (the TCP accept loop polls
    /// this).
    pub fn is_stopped(&self) -> bool {
        self.inner.stop.load(Ordering::Relaxed)
    }

    /// Stop the scheduler and wake nothing further. Idempotent; joins
    /// the scheduler thread so in-flight quanta finish first, then —
    /// unless `checkpoint_on_shutdown` is off — snapshots every live
    /// session to `checkpoint_dir`, and writes a terminal tombstone
    /// for any terminal session whose lineage doesn't have one yet,
    /// so a restart with [`Service::resume_from_dir`] reproduces the
    /// pre-shutdown population exactly (terminal sessions come back
    /// terminal, not resurrected).
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        let handle = self.inner.sched_handle.lock().unwrap_or_else(|e| e.into_inner()).take();
        let Some(h) = handle else { return };
        let _ = h.join();
        // Export surfaces close with the scheduler: the trace now
        // holds every step that will ever run, and the scrape
        // endpoint dies with the service instead of serving a stale
        // registry.
        if let Some(path) = self.inner.cfg.trace_out.as_deref() {
            let spans = self.trace_spans();
            let out = std::path::Path::new(path);
            if let Err(e) = crate::telemetry::export::write_chrome_trace(out, &spans) {
                eprintln!("serve: trace export to '{path}' failed: {e}");
            }
        }
        if let Some(srv) =
            self.inner.metrics_srv.lock().unwrap_or_else(|e| e.into_inner()).as_mut()
        {
            srv.stop();
        }
        if !self.inner.cfg.checkpoint_on_shutdown {
            return;
        }
        // The scheduler is gone: session locks are only briefly held
        // by control-plane commands now, so a blocking sweep is safe.
        let sessions: Vec<(u64, Arc<Mutex<Session>>, Arc<Mutex<()>>)> = self
            .inner
            .sessions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(id, slot)| (*id, Arc::clone(&slot.sess), Arc::clone(&slot.ckpt_io)))
            .collect();
        for (id, sess, io) in sessions {
            let wants_snapshot = {
                let s = sess.lock().unwrap_or_else(|e| e.into_inner());
                s.status().is_live() || !s.last_checkpoint_was_terminal()
            };
            if !wants_snapshot {
                continue;
            }
            match checkpoint_session(&self.inner.cfg, &sess, &io) {
                Ok(_) => {
                    self.inner.auto_checkpoints.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => eprintln!("serve: shutdown checkpoint of session {id} failed: {e}"),
            }
        }
    }

    fn admit(&self, session: Session) -> Result<u64, String> {
        self.admit_with_quota(session, true)
    }

    /// Register a session. `enforce_quota` is false on the
    /// `resume_from_dir` path: quotas bound *new* submissions, while a
    /// restart re-admits the pre-restart population verbatim — a
    /// lineage must never be silently dropped because the quota
    /// config shrank or a tombstone hadn't landed before the kill.
    fn admit_with_quota(&self, session: Session, enforce_quota: bool) -> Result<u64, String> {
        let quota = self.inner.cfg.max_sessions_per_tenant;
        let mut map = self.inner.sessions.lock().unwrap_or_else(|e| e.into_inner());
        if enforce_quota && quota > 0 {
            // Same lock discipline as promote_waiting: unadmitted
            // sessions are read exactly (their locks are never
            // compute-held), admitted ones count as live when busy
            // (mid-quantum ⇒ live), so the check never stalls the
            // control plane behind a quantum.
            let used = map
                .values()
                .filter(|slot| slot.tenant == session.tenant)
                .filter(|slot| {
                    if slot.admitted.load(Ordering::Relaxed) {
                        match slot.sess.try_lock() {
                            Ok(s) => s.status().is_live(),
                            Err(_) => true,
                        }
                    } else {
                        slot.sess.lock().unwrap_or_else(|e| e.into_inner()).status().is_live()
                    }
                })
                .count();
            if used >= quota {
                return Err(format!(
                    "tenant '{}' is at its quota ({used}/{quota} live sessions)",
                    session.tenant
                ));
            }
        }
        let id = session.id;
        map.insert(
            id,
            Slot {
                tenant: session.tenant.clone(),
                priority: session.priority,
                sess: Arc::new(Mutex::new(session)),
                admitted: AtomicBool::new(false),
                ckpt_io: Arc::new(Mutex::new(())),
            },
        );
        drop(map);
        // Grab a free slot immediately if one exists (the scheduler
        // round would otherwise do this within ~idle_sleep_ms).
        promote_waiting(&self.inner);
        Ok(id)
    }

    /// Admit a new session for `cfg`; returns its id. Never rejects
    /// for capacity — a submit past `max_sessions` parks in the
    /// admission queue (check `queue_position` via [`Service::status`]).
    /// Fails on a per-tenant quota violation or after shutdown.
    pub fn submit(&self, cfg: &TrainConfig, name: &str, priority: usize) -> Result<u64, String> {
        self.submit_as(cfg, name, priority, None)
    }

    /// [`Service::submit`] with an explicit tenant (defaults to the
    /// name prefix before the first `/`).
    pub fn submit_as(
        &self,
        cfg: &TrainConfig,
        name: &str,
        priority: usize,
        tenant: Option<&str>,
    ) -> Result<u64, String> {
        if self.is_stopped() {
            return Err("service is shut down".into());
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let mut session = Session::new(id, name, priority, cfg)?;
        if let Some(t) = tenant {
            session.tenant = t.to_string();
        }
        self.admit(session)
    }

    /// Admit a session restored from a checkpoint file (fork
    /// semantics: fresh checkpoint lineage under the new id).
    pub fn submit_checkpoint(
        &self,
        path: &str,
        name: &str,
        priority: usize,
    ) -> Result<u64, String> {
        self.submit_checkpoint_as(path, name, priority, None)
    }

    /// [`Service::submit_checkpoint`] with an explicit tenant.
    pub fn submit_checkpoint_as(
        &self,
        path: &str,
        name: &str,
        priority: usize,
        tenant: Option<&str>,
    ) -> Result<u64, String> {
        if self.is_stopped() {
            return Err("service is shut down".into());
        }
        let ck = Checkpoint::load(path)?;
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let mut session = Session::from_checkpoint(id, name, priority, &ck)?;
        if let Some(t) = tenant {
            session.tenant = t.to_string();
        }
        self.admit(session)
    }

    /// Admit a checkpoint file *continuing its lineage* — the cluster
    /// migration entry point (the protocol reaches it via `submit`
    /// with `"lineage": true`). Unlike [`Service::submit_checkpoint`]
    /// (fork semantics: fresh stem under the new id), the restored
    /// session keeps the snapshot's own name, priority, tenant,
    /// pause/terminal state and checkpoint stem, so one logical
    /// session keeps one identity as it moves between hosts — its
    /// future snapshots extend the same lineage, and the stem-embedded
    /// original id is reserved so fresh submits can never mint a
    /// colliding stem. Per-tenant quotas are bypassed, as on the
    /// `--resume-dir` path: a migration must never drop a session the
    /// cluster already admitted. Returns the new local session id.
    pub fn submit_checkpoint_lineage(&self, path: &str) -> Result<u64, String> {
        if self.is_stopped() {
            return Err("service is shut down".into());
        }
        let ck = Checkpoint::load(path)?;
        // v1 snapshots carry no stem; fall back to the on-disk file
        // prefix so even those keep a stable identity.
        let fallback = std::path::Path::new(path)
            .file_name()
            .and_then(|s| s.to_str())
            .and_then(|f| f.strip_suffix(".ckpt"))
            .and_then(|b| b.rsplit_once("-step"))
            .map(|(stem, _)| stem.to_string())
            .unwrap_or_default();
        let stem = if ck.stem.is_empty() { fallback } else { ck.stem.clone() };
        self.admit_lineage(&ck, &stem)
    }

    /// Re-admit the newest checkpoint of every lineage found in `dir`
    /// (files named `<stem>-step<N>.ckpt`), making a restarted serve
    /// process transparent to clients: names, priorities, tenants and
    /// checkpoint lineages all survive. Corrupt or torn files are
    /// skipped with a warning, falling back to the next-newest step of
    /// the same lineage; stray `*.tmp` files from interrupted atomic
    /// writes are ignored entirely. Per-tenant quotas are *not*
    /// enforced here — they bound new submissions, and dropping a
    /// pre-restart lineage because the quota shrank would lose a job.
    /// A missing directory resumes nothing. Returns the re-admitted
    /// session ids.
    pub fn resume_from_dir(&self, dir: &str) -> Result<Vec<u64>, String> {
        if self.is_stopped() {
            return Err("service is shut down".into());
        }
        // A dir that was never created is a fresh boot (empty scan);
        // any other failure (permissions, I/O) surfaces — silently
        // booting empty would strand every pre-restart session.
        let lineages = crate::serve::checkpoint::scan_lineages(dir)?;
        let mut ids = Vec::new();
        for (stem, files) in lineages {
            for (step, path) in &files {
                match self.resume_one(&stem, path) {
                    Ok(id) => {
                        ids.push(id);
                        break;
                    }
                    Err(e) => eprintln!(
                        "serve: resume of lineage '{stem}' at step {step} failed ({e}); \
                         trying an older snapshot"
                    ),
                }
            }
        }
        Ok(ids)
    }

    fn resume_one(&self, stem: &str, path: &str) -> Result<u64, String> {
        let ck = Checkpoint::load(path)?;
        self.admit_lineage(&ck, stem)
    }

    /// Shared lineage-admission tail of `--resume-dir` boot and
    /// [`Service::submit_checkpoint_lineage`]: reserve the
    /// stem-embedded original id, mint a fresh local id, and admit
    /// quota-free.
    fn admit_lineage(&self, ck: &Checkpoint, stem: &str) -> Result<u64, String> {
        // Stems embed the session's *original* id; fresh ids must
        // never reuse one, or a new submit with the same name would
        // mint an identical stem and the two sessions would overwrite
        // each other's checkpoint lineage.
        if let Some((_, tail)) = stem.rsplit_once('-') {
            if let Ok(old_id) = tail.parse::<u64>() {
                self.inner.next_id.fetch_max(old_id.saturating_add(1), Ordering::Relaxed);
            }
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.admit_with_quota(Session::from_checkpoint_lineage(id, ck, stem)?, false)
    }

    fn session(&self, id: u64) -> Result<Arc<Mutex<Session>>, String> {
        self.session_entry(id).map(|(sess, _)| sess)
    }

    fn session_entry(&self, id: u64) -> Result<(Arc<Mutex<Session>>, Arc<Mutex<()>>), String> {
        let found = self
            .inner
            .sessions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&id)
            .map(|slot| (Arc::clone(&slot.sess), Arc::clone(&slot.ckpt_io)));
        match found {
            Some(s) => Ok(s),
            None
                if self
                    .inner
                    .evicted
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .contains(&id) =>
            {
                Err(format!(
                    "session {id} was evicted (terminal history is capped at {})",
                    self.inner.cfg.retain_terminal
                ))
            }
            None => Err(format!("no session {id}")),
        }
    }

    /// Ids of sessions waiting in the admission queue, in
    /// [`admission_cmp`] order. Only *unadmitted* sessions are locked
    /// (briefly — the scheduler never steps them), so this never
    /// blocks behind a running quantum; positions are exact, which
    /// the submit response relies on.
    fn waiting_order(&self) -> Vec<u64> {
        let candidates: Vec<(u64, usize, Arc<Mutex<Session>>)> = self
            .inner
            .sessions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .filter(|(_, slot)| !slot.admitted.load(Ordering::Relaxed))
            .map(|(id, slot)| (*id, slot.priority, Arc::clone(&slot.sess)))
            .collect();
        let mut waiting: Vec<(usize, u64)> = Vec::new();
        for (id, priority, sess) in candidates {
            let s = sess.lock().unwrap_or_else(|e| e.into_inner());
            if *s.status() == SessionStatus::Queued {
                waiting.push((priority, id));
            }
        }
        order_waiting(waiting)
    }

    /// Point-in-time state of one session, including its admission
    /// queue position (0 once admitted).
    pub fn status(&self, id: u64) -> Result<SessionState, String> {
        let sess = self.session(id)?;
        let mut st = {
            let s = sess.lock().unwrap_or_else(|e| e.into_inner());
            s.state()
        };
        if st.status == SessionStatus::Queued {
            if let Some(pos) = self.waiting_order().iter().position(|&x| x == id) {
                st.queue_position = pos + 1;
            }
        }
        Ok(st)
    }

    /// Hold a session after its current quantum. A waiting session
    /// leaves the admission queue until resumed. No-op on terminal
    /// sessions.
    pub fn pause(&self, id: u64) -> Result<SessionState, String> {
        let s = self.session(id)?;
        let mut s = s.lock().unwrap_or_else(|e| e.into_inner());
        s.set_status(SessionStatus::Paused);
        Ok(s.state())
    }

    /// Re-queue a paused session (it keeps its slot if it was already
    /// admitted; otherwise it re-enters the admission queue — the
    /// returned state carries its `queue_position`).
    pub fn resume(&self, id: u64) -> Result<SessionState, String> {
        let sess = self.session(id)?;
        let mut st = {
            let mut s = sess.lock().unwrap_or_else(|e| e.into_inner());
            if *s.status() == SessionStatus::Paused {
                s.set_status(SessionStatus::Queued);
            }
            s.state()
        };
        if st.status == SessionStatus::Queued {
            if let Some(pos) = self.waiting_order().iter().position(|&x| x == id) {
                st.queue_position = pos + 1;
            }
        }
        Ok(st)
    }

    /// Cancel a session (terminal; frees its slot or queue spot).
    /// No-op if already terminal.
    pub fn cancel(&self, id: u64) -> Result<SessionState, String> {
        let s = self.session(id)?;
        let mut s = s.lock().unwrap_or_else(|e| e.into_inner());
        s.set_status(SessionStatus::Cancelled);
        Ok(s.state())
    }

    /// Snapshot a session to `checkpoint_dir`; returns the file path.
    /// The in-memory capture waits for the session's current quantum
    /// (step-atomic); the disk write happens outside the session lock
    /// and is atomic (tmp + rename).
    pub fn checkpoint(&self, id: u64) -> Result<(String, u64), String> {
        let (sess, io) = self.session_entry(id)?;
        checkpoint_session(&self.inner.cfg, &sess, &io)
    }

    /// Step events of one session with sequence number `since` or
    /// later, plus a `terminal` flag: once true no further events can
    /// arrive (the session left the live set), so a watcher should
    /// drain what it got and stop. Backed by the session's bounded
    /// event ring ([`Session::events_since`]) — a slow watcher sees a
    /// sequence-number gap rather than stalling the stepper. The TCP
    /// `watch` stream and the in-process client both poll this.
    pub fn watch_events(&self, id: u64, since: u64) -> Result<(Vec<StepEvent>, bool), String> {
        let sess = self.session(id)?;
        let s = sess.lock().unwrap_or_else(|e| e.into_inner());
        Ok((s.events_since(since), !s.status().is_live()))
    }

    /// FNV digest of a session's exact model bits (see
    /// [`crate::serve::model_digest`]) — the equality witness the
    /// lane-independence and checkpoint tests compare.
    pub fn model_digest(&self, id: u64) -> Result<u64, String> {
        let s = self.session(id)?;
        let s = s.lock().unwrap_or_else(|e| e.into_inner());
        Ok(s.digest())
    }

    /// Aggregate statistics + per-session states.
    pub fn stats(&self) -> ServiceStats {
        let slots: Vec<(bool, Arc<Mutex<Session>>)> = self
            .inner
            .sessions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .map(|slot| (slot.admitted.load(Ordering::Relaxed), Arc::clone(&slot.sess)))
            .collect();
        let mut states = Vec::with_capacity(slots.len());
        let mut admitted_flags = Vec::with_capacity(slots.len());
        let mut agg = StepTimer::new();
        for (admitted, sess) in &slots {
            let s = sess.lock().unwrap_or_else(|e| e.into_inner());
            agg.merge(s.timer());
            admitted_flags.push(*admitted);
            states.push(s.state());
        }
        // Admission-queue order over the snapshot just taken.
        let waiting = order_waiting(
            states
                .iter()
                .zip(&admitted_flags)
                .filter(|(st, admitted)| st.status == SessionStatus::Queued && !**admitted)
                .map(|(st, _)| (st.priority, st.id))
                .collect(),
        );
        for (pos, id) in waiting.iter().enumerate() {
            if let Some(st) = states.iter_mut().find(|st| st.id == *id) {
                st.queue_position = pos + 1;
            }
        }
        let count = |st: &SessionStatus| states.iter().filter(|x| &x.status == st).count();
        let admitted = states
            .iter()
            .zip(&admitted_flags)
            .filter(|(st, admitted)| st.status.is_live() && **admitted)
            .count();
        let backend = crate::backend::global();
        ServiceStats {
            queue_depth: waiting.len(),
            running: count(&SessionStatus::Running),
            paused: count(&SessionStatus::Paused),
            live: states.iter().filter(|x| x.status.is_live()).count(),
            admitted,
            max_sessions: self.inner.cfg.max_sessions,
            total_lanes: backend.threads(),
            backend: backend.label(),
            rounds: self.inner.rounds.load(Ordering::Relaxed),
            scheduler_steps: self.inner.sched_steps.load(Ordering::Relaxed),
            auto_checkpoints: self.inner.auto_checkpoints.load(Ordering::Relaxed),
            promotions: self.inner.promotions.load(Ordering::Relaxed),
            evicted: self.inner.evicted_total.load(Ordering::Relaxed),
            p50_step_ms: agg.percentile_ms(50.0),
            p95_step_ms: agg.percentile_ms(95.0),
            sessions: states,
        }
    }

    /// Optimizer-health summary (the `health` protocol command):
    /// per-session rings when `id` is given, otherwise the
    /// process-global aggregate every stepped session feeds. Shape:
    /// `{every, series, anomalies}` (see
    /// [`crate::telemetry::health::summarize`]).
    pub fn health(&self, id: Option<u64>) -> Result<crate::jsonx::Json, String> {
        use crate::telemetry::health;
        match id {
            Some(id) => {
                let sess = self.session(id)?;
                let s = sess.lock().unwrap_or_else(|e| e.into_inner());
                Ok(health::summarize(s.health()))
            }
            None => Ok(health::with_global(health::summarize)),
        }
    }

    /// Chrome trace-event spans reconstructed from every session's
    /// step-event ring: one complete (`ph:"X"`) span per telemetry
    /// phase per retained step, pid = session id, timestamps laid out
    /// cumulatively per session. Empty when telemetry is off (events
    /// then carry no phase breakdown).
    pub fn trace_spans(&self) -> Vec<crate::telemetry::export::TraceSpan> {
        let sessions: Vec<(u64, Arc<Mutex<Session>>)> = self
            .inner
            .sessions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(id, slot)| (*id, Arc::clone(&slot.sess)))
            .collect();
        let mut spans = Vec::new();
        for (id, sess) in sessions {
            let events = sess.lock().unwrap_or_else(|e| e.into_inner()).events_since(0);
            let mut ts_us = 0u64;
            for ev in events {
                for (label, dur_us) in ev.phases {
                    spans.push(crate::telemetry::export::TraceSpan {
                        pid: id,
                        tid: 0,
                        name: label.to_string(),
                        ts_us,
                        dur_us,
                    });
                    ts_us += dur_us.max(1);
                }
            }
        }
        spans
    }

    /// Actual bound address of the Prometheus scrape endpoint (`None`
    /// when `metrics_addr` is unset or the bind failed). With
    /// `"host:0"` in the config this reports the kernel-chosen port.
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.inner
            .metrics_srv
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .map(|srv| srv.addr())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelArch;

    fn tiny(steps: u64) -> TrainConfig {
        TrainConfig {
            name: "svc".into(),
            dataset: "c10-small".into(),
            arch: ModelArch::Classifier { hidden: vec![12] },
            max_steps: Some(steps),
            // Enough epochs that max_steps is always the binding
            // budget, so "long-running" test sessions really are.
            epochs: 10_000,
            batch_size: 64,
            ..TrainConfig::default()
        }
    }

    fn test_cfg() -> ServeConfig {
        ServeConfig {
            max_sessions: 2,
            checkpoint_dir: std::env::temp_dir()
                .join("eva-serve-svc-test")
                .to_string_lossy()
                .into_owned(),
            quantum_steps: 4,
            checkpoint_on_shutdown: false,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn service_queues_over_cap_and_promotes_as_slots_free() {
        let svc = Service::start(test_cfg());
        // Two long-running tenants pin both capacity slots
        // deterministically (they cannot finish during the test).
        let a = svc.submit(&tiny(1_000_000), "a", 1).unwrap();
        let b = svc.submit(&tiny(1_000_000), "b", 2).unwrap();
        // Over-cap submit queues instead of erroring.
        let c = svc.submit(&tiny(10), "c", 1).unwrap();
        let sc = svc.status(c).unwrap();
        assert_eq!(sc.status, SessionStatus::Queued, "over-cap submit must queue");
        assert_eq!(sc.queue_position, 1, "sole waiter is first in line");
        assert_eq!(sc.step, 0, "waiting sessions must not be stepped");
        // Cancelling the slot holders lets the waiter in.
        svc.cancel(a).unwrap();
        svc.cancel(b).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
        loop {
            let sc = svc.status(c).unwrap();
            if sc.status == SessionStatus::Done {
                assert_eq!(sc.step, 10);
                assert_eq!(sc.queue_position, 0);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "session c did not finish");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let st = svc.stats();
        assert_eq!(st.sessions.len(), 3);
        assert_eq!(st.max_sessions, 2);
        assert!(st.scheduler_steps >= 10);
        assert!(st.promotions >= 1, "the waiter was promoted");
        assert!(svc.status(999).is_err());
        svc.shutdown();
        assert!(svc.submit(&tiny(1), "late", 1).is_err());
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("eva-serve-svc-test"));
    }

    #[test]
    fn per_tenant_quota_bounds_live_sessions() {
        let svc = Service::start(ServeConfig {
            max_sessions: 1, // one slot: quota must bite on *queued* sessions too
            max_sessions_per_tenant: 2,
            ..test_cfg()
        });
        let j1 = svc.submit(&tiny(1_000_000), "acme/j1", 1).unwrap();
        let _j2 = svc.submit(&tiny(1_000_000), "acme/j2", 1).unwrap();
        let err = svc.submit(&tiny(5), "acme/j3", 1).unwrap_err();
        assert!(err.contains("quota"), "{err}");
        // Another tenant is unaffected; an explicit tenant field wins
        // over the name prefix.
        svc.submit(&tiny(1_000_000), "zeta/j1", 1).unwrap();
        let err = svc.submit_as(&tiny(5), "other-name", 1, Some("acme")).unwrap_err();
        assert!(err.contains("acme"), "{err}");
        // Freeing one of the tenant's sessions frees the quota.
        svc.cancel(j1).unwrap();
        svc.submit(&tiny(1_000_000), "acme/j4", 1).unwrap();
        svc.shutdown();
    }

    #[test]
    fn prune_lineage_keeps_newest_and_tombstones() {
        use crate::serve::checkpoint::status_tag;
        let dir = std::env::temp_dir().join("eva-serve-prune-test");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_string_lossy().into_owned();
        let sess = Session::new(1, "p", 1, &tiny(10)).unwrap();
        let ck = sess.checkpoint().unwrap();
        for step in 1..=4u64 {
            ck.save(&format!("{dirs}/p-1-step{step}.ckpt")).unwrap();
        }
        // A terminal tombstone older than every live snapshot.
        let mut tomb = ck.clone();
        tomb.status_tag = status_tag::DONE;
        tomb.save(&format!("{dirs}/p-1-step0.ckpt")).unwrap();
        // A torn file newer than everything: never loadable, so it
        // neither counts toward retention nor survives the prune.
        std::fs::write(dir.join("p-1-step9.ckpt"), b"garbage").unwrap();
        // An unrelated lineage must be untouched.
        ck.save(&format!("{dirs}/other-2-step1.ckpt")).unwrap();
        prune_lineage(&dirs, "p-1", 2);
        let mut left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        left.sort();
        assert_eq!(
            left,
            ["other-2-step1.ckpt", "p-1-step0.ckpt", "p-1-step3.ckpt", "p-1-step4.ckpt"],
            "keep the 2 newest loadable + the tombstone; drop older + torn"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pause_resume_cancel_lifecycle() {
        let svc = Service::start(ServeConfig {
            quantum_steps: 1,
            ..test_cfg()
        });
        let id = svc.submit(&tiny(100_000), "p", 1).unwrap();
        let st = svc.pause(id).unwrap();
        assert!(matches!(st.status, SessionStatus::Paused | SessionStatus::Running));
        // Wait until the pause takes effect at a quantum boundary.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        while svc.status(id).unwrap().status != SessionStatus::Paused {
            let _ = svc.pause(id);
            assert!(std::time::Instant::now() < deadline);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let frozen = svc.status(id).unwrap().step;
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(svc.status(id).unwrap().step, frozen, "paused session advanced");
        let st = svc.resume(id).unwrap();
        assert!(st.status.is_live());
        let st = svc.cancel(id).unwrap();
        assert_eq!(st.status, SessionStatus::Cancelled);
        // Cancel sticks even through resume attempts.
        assert_eq!(svc.resume(id).unwrap().status, SessionStatus::Cancelled);
        svc.shutdown();
    }
}
