//! The multi-tenant service: session registry + scheduler lifecycle.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::TrainConfig;
use crate::serve::checkpoint::Checkpoint;
use crate::serve::scheduler;
use crate::serve::session::{Session, SessionState, SessionStatus};
use crate::serve::ServeConfig;
use crate::train::StepTimer;

/// Shared state between the service facade, the scheduler thread and
/// the TCP server.
pub(crate) struct Inner {
    pub(crate) cfg: ServeConfig,
    pub(crate) sessions: Mutex<BTreeMap<u64, Arc<Mutex<Session>>>>,
    pub(crate) next_id: AtomicU64,
    pub(crate) stop: AtomicBool,
    pub(crate) rounds: AtomicU64,
    pub(crate) sched_steps: AtomicU64,
    sched_handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// Handle to a running training-session service. Cheap to clone (all
/// clones share one registry + scheduler); stop it with
/// [`Service::shutdown`].
#[derive(Clone)]
pub struct Service {
    inner: Arc<Inner>,
}

/// Aggregate service statistics (the `stats` protocol command).
#[derive(Clone, Debug)]
pub struct ServiceStats {
    /// Sessions admitted but not yet picked up by the scheduler.
    pub queue_depth: usize,
    /// Sessions currently being stepped.
    pub running: usize,
    /// Sessions held by `pause`.
    pub paused: usize,
    /// Live sessions (queued + running + paused) against
    /// `max_sessions`.
    pub live: usize,
    /// Admission cap.
    pub max_sessions: usize,
    /// Lanes of the shared compute pool the scheduler carves.
    pub total_lanes: usize,
    /// Label of the shared backend (e.g. `threads:8`).
    pub backend: String,
    /// Scheduler rounds executed.
    pub rounds: u64,
    /// Optimizer steps executed by the scheduler, all sessions.
    pub scheduler_steps: u64,
    /// Median step latency (ms) across every session's lifetime.
    pub p50_step_ms: f64,
    /// 95th-percentile step latency (ms) across every session.
    pub p95_step_ms: f64,
    /// Per-session states.
    pub sessions: Vec<SessionState>,
}

impl Service {
    /// Start a service: the scheduler thread begins immediately;
    /// sessions arrive via [`Service::submit`] (or the TCP server /
    /// clients layered on top).
    pub fn start(cfg: ServeConfig) -> Service {
        let inner = Arc::new(Inner {
            cfg,
            sessions: Mutex::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            rounds: AtomicU64::new(0),
            sched_steps: AtomicU64::new(0),
            sched_handle: Mutex::new(None),
        });
        let for_thread = Arc::clone(&inner);
        let handle = std::thread::Builder::new()
            .name("eva-serve-sched".into())
            .spawn(move || scheduler::run(for_thread))
            .expect("spawn scheduler thread");
        *inner.sched_handle.lock().unwrap_or_else(|e| e.into_inner()) = Some(handle);
        Service { inner }
    }

    /// The service configuration.
    pub fn config(&self) -> &ServeConfig {
        &self.inner.cfg
    }

    /// True once [`Service::shutdown`] ran (the TCP accept loop polls
    /// this).
    pub fn is_stopped(&self) -> bool {
        self.inner.stop.load(Ordering::Relaxed)
    }

    /// Stop the scheduler and wake nothing further. Idempotent; joins
    /// the scheduler thread so in-flight quanta finish first.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        let handle = self.inner.sched_handle.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    fn admit(&self, session: Session) -> Result<u64, String> {
        let mut map = self.inner.sessions.lock().unwrap_or_else(|e| e.into_inner());
        let live = map
            .values()
            .filter(|s| s.lock().unwrap_or_else(|e| e.into_inner()).status().is_live())
            .count();
        if live >= self.inner.cfg.max_sessions {
            return Err(format!(
                "at capacity ({live}/{} live sessions)",
                self.inner.cfg.max_sessions
            ));
        }
        let id = session.id;
        map.insert(id, Arc::new(Mutex::new(session)));
        Ok(id)
    }

    /// Admit a new session for `cfg`; returns its id. Fails when the
    /// service is at `max_sessions` live sessions.
    pub fn submit(&self, cfg: &TrainConfig, name: &str, priority: usize) -> Result<u64, String> {
        if self.is_stopped() {
            return Err("service is shut down".into());
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.admit(Session::new(id, name, priority, cfg)?)
    }

    /// Admit a session restored from a checkpoint file.
    pub fn submit_checkpoint(
        &self,
        path: &str,
        name: &str,
        priority: usize,
    ) -> Result<u64, String> {
        if self.is_stopped() {
            return Err("service is shut down".into());
        }
        let ck = Checkpoint::load(path)?;
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.admit(Session::from_checkpoint(id, name, priority, &ck)?)
    }

    fn session(&self, id: u64) -> Result<Arc<Mutex<Session>>, String> {
        self.inner
            .sessions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&id)
            .cloned()
            .ok_or_else(|| format!("no session {id}"))
    }

    /// Point-in-time state of one session.
    pub fn status(&self, id: u64) -> Result<SessionState, String> {
        let s = self.session(id)?;
        let s = s.lock().unwrap_or_else(|e| e.into_inner());
        Ok(s.state())
    }

    /// Hold a session after its current quantum. No-op on terminal
    /// sessions.
    pub fn pause(&self, id: u64) -> Result<SessionState, String> {
        let s = self.session(id)?;
        let mut s = s.lock().unwrap_or_else(|e| e.into_inner());
        s.set_status(SessionStatus::Paused);
        Ok(s.state())
    }

    /// Re-queue a paused session.
    pub fn resume(&self, id: u64) -> Result<SessionState, String> {
        let s = self.session(id)?;
        let mut s = s.lock().unwrap_or_else(|e| e.into_inner());
        if *s.status() == SessionStatus::Paused {
            s.set_status(SessionStatus::Queued);
        }
        Ok(s.state())
    }

    /// Cancel a session (terminal). No-op if already terminal.
    pub fn cancel(&self, id: u64) -> Result<SessionState, String> {
        let s = self.session(id)?;
        let mut s = s.lock().unwrap_or_else(|e| e.into_inner());
        s.set_status(SessionStatus::Cancelled);
        Ok(s.state())
    }

    /// Snapshot a session to `checkpoint_dir`; returns the file path.
    /// Waits for the session's current quantum (it takes the session
    /// lock), so the snapshot is step-atomic.
    pub fn checkpoint(&self, id: u64) -> Result<(String, u64), String> {
        let s = self.session(id)?;
        let s = s.lock().unwrap_or_else(|e| e.into_inner());
        let ck = s.checkpoint()?;
        let step = ck.loop_snap.step;
        let safe_name: String = s
            .name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        let path = std::path::Path::new(&self.inner.cfg.checkpoint_dir)
            .join(format!("{safe_name}-{id}-step{step}.ckpt"))
            .to_string_lossy()
            .into_owned();
        ck.save(&path)?;
        Ok((path, step))
    }

    /// FNV digest of a session's exact model bits (see
    /// [`crate::serve::model_digest`]) — the equality witness the
    /// lane-independence and checkpoint tests compare.
    pub fn model_digest(&self, id: u64) -> Result<u64, String> {
        let s = self.session(id)?;
        let s = s.lock().unwrap_or_else(|e| e.into_inner());
        Ok(s.digest())
    }

    /// Aggregate statistics + per-session states.
    pub fn stats(&self) -> ServiceStats {
        let sessions: Vec<Arc<Mutex<Session>>> = self
            .inner
            .sessions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .cloned()
            .collect();
        let mut states = Vec::with_capacity(sessions.len());
        let mut agg = StepTimer::new();
        for s in &sessions {
            let s = s.lock().unwrap_or_else(|e| e.into_inner());
            agg.merge(s.timer());
            states.push(s.state());
        }
        let count = |st: &SessionStatus| states.iter().filter(|x| &x.status == st).count();
        let backend = crate::backend::global();
        ServiceStats {
            queue_depth: count(&SessionStatus::Queued),
            running: count(&SessionStatus::Running),
            paused: count(&SessionStatus::Paused),
            live: states.iter().filter(|x| x.status.is_live()).count(),
            max_sessions: self.inner.cfg.max_sessions,
            total_lanes: backend.threads(),
            backend: backend.label(),
            rounds: self.inner.rounds.load(Ordering::Relaxed),
            scheduler_steps: self.inner.sched_steps.load(Ordering::Relaxed),
            p50_step_ms: agg.percentile_ms(50.0),
            p95_step_ms: agg.percentile_ms(95.0),
            sessions: states,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelArch;

    fn tiny(steps: u64) -> TrainConfig {
        TrainConfig {
            name: "svc".into(),
            dataset: "c10-small".into(),
            arch: ModelArch::Classifier { hidden: vec![12] },
            max_steps: Some(steps),
            // Enough epochs that max_steps is always the binding
            // budget, so "long-running" test sessions really are.
            epochs: 10_000,
            batch_size: 64,
            ..TrainConfig::default()
        }
    }

    fn test_cfg() -> ServeConfig {
        ServeConfig {
            max_sessions: 2,
            checkpoint_dir: std::env::temp_dir()
                .join("eva-serve-svc-test")
                .to_string_lossy()
                .into_owned(),
            quantum_steps: 4,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn service_runs_sessions_to_completion_and_enforces_capacity() {
        let svc = Service::start(test_cfg());
        // Two long-running tenants pin both capacity slots
        // deterministically (they cannot finish during the test).
        let a = svc.submit(&tiny(1_000_000), "a", 1).unwrap();
        let b = svc.submit(&tiny(1_000_000), "b", 2).unwrap();
        assert!(svc.submit(&tiny(10), "c", 1).is_err(), "capacity must be enforced");
        // Cancelling frees the slots.
        svc.cancel(a).unwrap();
        svc.cancel(b).unwrap();
        let c = svc.submit(&tiny(10), "c", 1).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
        loop {
            let sc = svc.status(c).unwrap();
            if sc.status == SessionStatus::Done {
                assert_eq!(sc.step, 10);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "session c did not finish");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let st = svc.stats();
        assert_eq!(st.sessions.len(), 3);
        assert_eq!(st.max_sessions, 2);
        assert!(st.scheduler_steps >= 10);
        assert!(svc.status(999).is_err());
        svc.shutdown();
        assert!(svc.submit(&tiny(1), "late", 1).is_err());
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("eva-serve-svc-test"));
    }

    #[test]
    fn pause_resume_cancel_lifecycle() {
        let svc = Service::start(ServeConfig {
            quantum_steps: 1,
            ..test_cfg()
        });
        let id = svc.submit(&tiny(100_000), "p", 1).unwrap();
        let st = svc.pause(id).unwrap();
        assert!(matches!(st.status, SessionStatus::Paused | SessionStatus::Running));
        // Wait until the pause takes effect at a quantum boundary.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        while svc.status(id).unwrap().status != SessionStatus::Paused {
            let _ = svc.pause(id);
            assert!(std::time::Instant::now() < deadline);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let frozen = svc.status(id).unwrap().step;
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(svc.status(id).unwrap().step, frozen, "paused session advanced");
        let st = svc.resume(id).unwrap();
        assert!(st.status.is_live());
        let st = svc.cancel(id).unwrap();
        assert_eq!(st.status, SessionStatus::Cancelled);
        // Cancel sticks even through resume attempts.
        assert_eq!(svc.resume(id).unwrap().status, SessionStatus::Cancelled);
        svc.shutdown();
    }
}
