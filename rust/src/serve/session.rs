//! One tenant's resumable training session.
//!
//! A [`Session`] pairs a [`Trainer`] with the steppable
//! [`LoopState`] and a lifecycle [`SessionStatus`]. The scheduler
//! advances it one quantum ([`Session::run_quantum`]) at a time;
//! control-plane commands flip the status between quanta, so pause /
//! checkpoint / cancel take effect at quantum granularity without ever
//! tearing a step in half.

use anyhow::Result;

use crate::config::{Engine, TrainConfig};
use crate::nn::Mlp;
use crate::serve::checkpoint::Checkpoint;
use crate::train::{LoopState, StepOutcome, StepTimer, Trainer};

/// Lifecycle of a session. Terminal states (`Done`, `Cancelled`,
/// `Failed`) are never left.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionStatus {
    /// Admitted, waiting for the scheduler to pick it up.
    Queued,
    /// Being stepped by the scheduler.
    Running,
    /// Held by a `pause` command; `resume` re-queues it.
    Paused,
    /// Reached its configured step target.
    Done,
    /// Stopped by a `cancel` command.
    Cancelled,
    /// A step raised an error or panicked; the message is kept.
    Failed(String),
}

impl SessionStatus {
    /// Protocol string for this status.
    pub fn as_str(&self) -> &str {
        match self {
            SessionStatus::Queued => "queued",
            SessionStatus::Running => "running",
            SessionStatus::Paused => "paused",
            SessionStatus::Done => "done",
            SessionStatus::Cancelled => "cancelled",
            SessionStatus::Failed(_) => "failed",
        }
    }

    /// True for states that still hold a capacity slot.
    pub fn is_live(&self) -> bool {
        matches!(
            self,
            SessionStatus::Queued | SessionStatus::Running | SessionStatus::Paused
        )
    }
}

/// Point-in-time view of a session, as reported by `status` / `stats`.
#[derive(Clone, Debug)]
pub struct SessionState {
    /// Session id.
    pub id: u64,
    /// Client-supplied display name.
    pub name: String,
    /// Scheduling weight (≥ 1).
    pub priority: usize,
    /// Lifecycle state.
    pub status: SessionStatus,
    /// Failure message, when `status` is `Failed`.
    pub error: Option<String>,
    /// Steps taken so far.
    pub step: u64,
    /// Configured step target.
    pub total_steps: u64,
    /// Current epoch index.
    pub epoch: usize,
    /// Most recent training loss.
    pub last_loss: f32,
    /// Most recent completed-epoch validation metric.
    pub last_val_metric: Option<f32>,
    /// Median step latency (ms) over the session's lifetime.
    pub p50_step_ms: f64,
    /// 95th-percentile step latency (ms).
    pub p95_step_ms: f64,
    /// Lanes the last scheduler carve granted this session.
    pub lane_share: usize,
}

/// A resumable, time-sliceable training job.
pub struct Session {
    /// Service-assigned id.
    pub id: u64,
    /// Client-supplied display name.
    pub name: String,
    /// Scheduling weight (≥ 1); the scheduler carves lanes
    /// proportionally to it.
    pub priority: usize,
    trainer: Trainer,
    lp: LoopState,
    status: SessionStatus,
    timer: StepTimer,
    last_loss: f32,
    last_val: Option<f32>,
    /// Lanes granted by the most recent scheduler carve.
    pub lane_share: usize,
}

// SAFETY: sessions cross threads (scheduler fan-out, service
// registry), but `Trainer` is not `Send` solely because its PJRT
// engine variant holds `Rc<Executable>` handles. A `Session` is only
// ever constructed over the native engine (`Session::new` rejects
// `Engine::Pjrt`, and `Session::from_checkpoint` funnels through it),
// nothing can swap the engine afterwards (`set_model` replaces only
// the `Mlp`), and every native-engine field is `Send` (`Mlp`,
// `Dataset`, `Box<dyn Optimizer>` where `Optimizer: Send`). So the
// non-`Send` state is unreachable from any live `Session`.
unsafe impl Send for Session {}

impl Session {
    /// Admit a new session for `cfg`. The config's process-global knobs
    /// (`backend`, `worker_threads`, `simd`) are stripped — one tenant
    /// must not reconfigure the shared pool or the process ISA path
    /// (and because numerics are bit-identical across ISA paths, a
    /// tenant's checkpoint restores identically regardless of the
    /// server's `--simd`) — and only the native engine is accepted
    /// (PJRT state lives in device buffers and cannot be checkpointed).
    pub fn new(id: u64, name: &str, priority: usize, cfg: &TrainConfig) -> Result<Self, String> {
        if !matches!(cfg.engine, Engine::Native) {
            return Err("serve sessions require the native engine".into());
        }
        let mut cfg = cfg.clone();
        cfg.backend = None;
        cfg.worker_threads = None;
        cfg.simd = None;
        let trainer = Trainer::from_config(&cfg).map_err(|e| e.to_string())?;
        let lp = LoopState::new(&trainer);
        Ok(Session {
            id,
            name: name.to_string(),
            priority: priority.clamp(1, 100),
            status: if lp.is_done() { SessionStatus::Done } else { SessionStatus::Queued },
            lp,
            trainer,
            timer: StepTimer::new(),
            last_loss: f32::NAN,
            last_val: None,
            lane_share: 0,
        })
    }

    /// Rebuild a session from a checkpoint (the restore half of
    /// `serve::checkpoint`). Continuing the restored session is
    /// bit-identical to never having snapshotted.
    pub fn from_checkpoint(
        id: u64,
        name: &str,
        priority: usize,
        ck: &Checkpoint,
    ) -> Result<Self, String> {
        let mut s = Session::new(id, name, priority, &ck.config)?;
        ck.apply(&mut s.trainer)?;
        s.lp = LoopState::restore(&s.trainer, &ck.loop_snap)?;
        s.last_loss = ck.loop_snap.final_loss;
        if s.lp.is_done() {
            s.status = SessionStatus::Done;
        }
        Ok(s)
    }

    /// Take exactly one optimizer step (latency recorded for the
    /// p50/p95 stats).
    pub fn step(&mut self) -> Result<StepOutcome> {
        let t0 = std::time::Instant::now();
        let out = self.lp.step_once(&mut self.trainer)?;
        self.timer.record(t0.elapsed());
        self.last_loss = out.loss;
        if let Some(v) = out.val_metric {
            self.last_val = Some(v);
        }
        Ok(out)
    }

    /// Run the validation metric on demand (does not advance the loop).
    pub fn eval(&mut self) -> Result<f32> {
        self.trainer.evaluate()
    }

    /// Advance up to `max_steps` steps, stopping early at completion.
    /// Returns the number of steps taken; flips the status to `Done`
    /// or `Failed` as appropriate. Called by the scheduler with the
    /// configured quantum.
    pub fn run_quantum(&mut self, max_steps: usize) -> usize {
        let mut taken = 0;
        for _ in 0..max_steps {
            if self.lp.is_done() {
                break;
            }
            match self.step() {
                Ok(out) => {
                    taken += 1;
                    if out.done {
                        self.status = SessionStatus::Done;
                        break;
                    }
                }
                Err(e) => {
                    self.status = SessionStatus::Failed(format!("{e:#}"));
                    break;
                }
            }
        }
        if self.lp.is_done() && self.status == SessionStatus::Running {
            self.status = SessionStatus::Done;
        }
        taken
    }

    /// Current lifecycle state.
    pub fn status(&self) -> &SessionStatus {
        &self.status
    }

    /// Set the lifecycle state (scheduler/service use; sessions never
    /// leave terminal states).
    pub(crate) fn set_status(&mut self, s: SessionStatus) {
        if !matches!(
            self.status,
            SessionStatus::Done | SessionStatus::Cancelled | SessionStatus::Failed(_)
        ) {
            self.status = s;
        }
    }

    /// True once every configured step has run.
    pub fn is_done(&self) -> bool {
        self.lp.is_done()
    }

    /// Point-in-time state snapshot for status/stats reporting.
    pub fn state(&self) -> SessionState {
        SessionState {
            id: self.id,
            name: self.name.clone(),
            priority: self.priority,
            status: self.status.clone(),
            error: match &self.status {
                SessionStatus::Failed(e) => Some(e.clone()),
                _ => None,
            },
            step: self.lp.step(),
            total_steps: self.lp.total_steps(),
            epoch: self.lp.epoch(),
            last_loss: self.last_loss,
            last_val_metric: self.last_val,
            p50_step_ms: self.timer.percentile_ms(50.0),
            p95_step_ms: self.timer.percentile_ms(95.0),
            lane_share: self.lane_share,
        }
    }

    /// Snapshot everything needed to resume this session elsewhere.
    pub fn checkpoint(&self) -> Result<Checkpoint, String> {
        Checkpoint::capture(&self.trainer, &self.lp)
    }

    /// Lifetime step-latency samples (for stats aggregation).
    pub fn timer(&self) -> &StepTimer {
        &self.timer
    }

    /// The underlying trainer (read access for tests/examples).
    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    /// FNV-1a digest over the model's exact weight + bias bits — the
    /// equality witness used by the checkpoint and lane-independence
    /// tests.
    pub fn digest(&self) -> u64 {
        model_digest(self.trainer.model().expect("native session has a model"))
    }
}

/// FNV-1a 64-bit digest over a model's parameter bits. Two models
/// digest equal iff every weight and bias is bit-identical.
pub fn model_digest(m: &Mlp) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut upd = |bits: u32| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for w in &m.weights {
        for v in w.data() {
            upd(v.to_bits());
        }
    }
    for bias in &m.biases {
        for v in bias {
            upd(v.to_bits());
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LrSchedule, ModelArch};

    fn tiny_cfg(optimizer: &str, steps: u64) -> TrainConfig {
        TrainConfig {
            name: format!("serve-{optimizer}"),
            dataset: "c10-small".into(),
            seed: 11,
            arch: ModelArch::Classifier { hidden: vec![16] },
            optim: crate::config::OptimConfig {
                algorithm: optimizer.into(),
                hp: Default::default(),
            },
            engine: Engine::Native,
            epochs: 2,
            batch_size: 64,
            base_lr: 0.05,
            lr_schedule: LrSchedule::Cosine,
            warmup_steps: 0,
            max_steps: Some(steps),
            eval_every: 1,
            backend: None,
            worker_threads: None,
            simd: None,
        }
    }

    #[test]
    fn session_steps_to_completion() {
        let mut s = Session::new(1, "t", 1, &tiny_cfg("eva", 12)).unwrap();
        assert_eq!(s.status(), &SessionStatus::Queued);
        s.set_status(SessionStatus::Running);
        let mut total = 0;
        while !s.is_done() {
            total += s.run_quantum(5);
        }
        assert_eq!(total, 12);
        assert_eq!(s.status(), &SessionStatus::Done);
        assert_eq!(s.state().step, 12);
        assert!(s.state().p50_step_ms >= 0.0);
        // Terminal states stick.
        s.set_status(SessionStatus::Running);
        assert_eq!(s.status(), &SessionStatus::Done);
        // eval works on demand.
        assert!(s.eval().unwrap().is_finite());
    }

    #[test]
    fn session_rejects_pjrt_and_strips_global_knobs() {
        let mut cfg = tiny_cfg("eva", 4);
        cfg.engine = Engine::Pjrt { model: "quickstart".into() };
        assert!(Session::new(1, "x", 1, &cfg).is_err());
        // A config carrying a backend choice must not reconfigure the
        // process-global pool when admitted.
        let _serial = crate::backend::TEST_GLOBAL_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut cfg = tiny_cfg("sgd", 4);
        cfg.backend = Some("threads:2".into());
        cfg.simd = Some("scalar".into());
        let before = crate::backend::global().label();
        let simd_before = crate::simd::active();
        let _s = Session::new(2, "y", 1, &cfg).unwrap();
        assert_eq!(crate::backend::global().label(), before);
        assert_eq!(crate::simd::active(), simd_before);
    }
}
