//! One tenant's resumable training session.
//!
//! A [`Session`] pairs a [`Trainer`] with the steppable
//! [`LoopState`] and a lifecycle [`SessionStatus`]. The scheduler
//! advances it one quantum ([`Session::run_quantum`]) at a time;
//! control-plane commands flip the status between quanta, so pause /
//! checkpoint / cancel take effect at quantum granularity without ever
//! tearing a step in half.

use std::collections::VecDeque;

use anyhow::Result;

use crate::config::{Engine, TrainConfig};
use crate::nn::Mlp;
use crate::serve::checkpoint::Checkpoint;
use crate::train::{LoopState, StepOutcome, StepTimer, Trainer};

/// Per-session step-event ring capacity. A slow (or absent) watcher
/// costs a session at most this many buffered events; older ones are
/// dropped oldest-first, so stepping never blocks on a consumer.
const EVENT_RING_CAP: usize = 256;

/// One per-step record streamed to `watch` clients: loss, latency and
/// the step's telemetry phase breakdown (label → µs; empty when
/// telemetry is off).
#[derive(Clone, Debug)]
pub struct StepEvent {
    /// Monotonic per-session sequence number (starts at 0); watchers
    /// resume from the last seq they saw.
    pub seq: u64,
    /// Global step count after this step.
    pub step: u64,
    /// Training loss of this step's batch.
    pub loss: f32,
    /// Wall time of this step in milliseconds.
    pub step_ms: f64,
    /// Phase breakdown from the telemetry spans, in first-seen order.
    pub phases: Vec<(&'static str, u64)>,
}

/// Lifecycle of a session. Terminal states (`Done`, `Cancelled`,
/// `Failed`) are never left.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionStatus {
    /// Waiting: either parked in the admission queue (over
    /// `max_sessions`, reported with a `queue_position`) or admitted
    /// and about to be picked up by the next scheduler round.
    Queued,
    /// Being stepped by the scheduler.
    Running,
    /// Held by a `pause` command; `resume` re-queues it.
    Paused,
    /// Reached its configured step target.
    Done,
    /// Stopped by a `cancel` command.
    Cancelled,
    /// A step raised an error or panicked; the message is kept.
    Failed(String),
}

impl SessionStatus {
    /// Protocol string for this status.
    pub fn as_str(&self) -> &str {
        match self {
            SessionStatus::Queued => "queued",
            SessionStatus::Running => "running",
            SessionStatus::Paused => "paused",
            SessionStatus::Done => "done",
            SessionStatus::Cancelled => "cancelled",
            SessionStatus::Failed(_) => "failed",
        }
    }

    /// True for states that still hold a capacity slot.
    pub fn is_live(&self) -> bool {
        matches!(
            self,
            SessionStatus::Queued | SessionStatus::Running | SessionStatus::Paused
        )
    }
}

/// Point-in-time view of a session, as reported by `status` / `stats`.
#[derive(Clone, Debug)]
pub struct SessionState {
    /// Session id.
    pub id: u64,
    /// Client-supplied display name.
    pub name: String,
    /// Tenant this session is accounted to (explicit `tenant` submit
    /// field, else the name prefix before the first `/`).
    pub tenant: String,
    /// Scheduling weight (≥ 1).
    pub priority: usize,
    /// Lifecycle state.
    pub status: SessionStatus,
    /// 1-based position in the admission queue while parked over
    /// `max_sessions`; 0 once admitted (or terminal).
    pub queue_position: usize,
    /// Failure message, when `status` is `Failed`.
    pub error: Option<String>,
    /// Steps taken so far.
    pub step: u64,
    /// Configured step target.
    pub total_steps: u64,
    /// Current epoch index.
    pub epoch: usize,
    /// Most recent training loss.
    pub last_loss: f32,
    /// Most recent completed-epoch validation metric.
    pub last_val_metric: Option<f32>,
    /// Median step latency (ms) over the session's lifetime.
    pub p50_step_ms: f64,
    /// 95th-percentile step latency (ms).
    pub p95_step_ms: f64,
    /// Lanes the last scheduler carve granted this session.
    pub lane_share: usize,
    /// Checkpoint lineage stem (`<safe-name>-<original-id>`) — the
    /// stable identity of this logical session across restarts and
    /// cluster migrations; routers key on it.
    pub lineage: String,
}

/// A resumable, time-sliceable training job.
pub struct Session {
    /// Service-assigned id.
    pub id: u64,
    /// Client-supplied display name.
    pub name: String,
    /// Scheduling weight (≥ 1); the scheduler carves lanes
    /// proportionally to it.
    pub priority: usize,
    /// Tenant key for per-tenant quotas (see [`default_tenant`]).
    /// (The admitted/waiting flag lives in the service registry, not
    /// here, so admission bookkeeping never touches this mutex.)
    pub(crate) tenant: String,
    /// Step the most recent checkpoint captured (explicit or auto) —
    /// the periodic auto-checkpoint clock.
    last_ckpt_step: u64,
    /// Lifecycle tag the most recent snapshot carried (see
    /// [`crate::serve::checkpoint::status_tag`]): what the on-disk
    /// lineage currently claims about this session. Terminal tags are
    /// tombstones, written exactly once; a LIVE/PAUSED mismatch with
    /// the actual status means the lineage needs re-stamping.
    last_ckpt_tag: u8,
    /// Whether any snapshot of this lineage has ever been written —
    /// eviction must tombstone such a lineage before forgetting the
    /// session, or the stale LIVE snapshot would resurrect it on the
    /// next `--resume-dir`.
    ever_checkpointed: bool,
    /// Checkpoint lineage stem (`<safe-name>-<original-id>`), stable
    /// across `--resume-dir` restarts.
    ckpt_stem: String,
    trainer: Trainer,
    lp: LoopState,
    status: SessionStatus,
    timer: StepTimer,
    last_loss: f32,
    last_val: Option<f32>,
    /// Lanes granted by the most recent scheduler carve.
    pub lane_share: usize,
    /// Bounded ring of recent step events for `watch` streaming.
    events: VecDeque<StepEvent>,
    /// Next event sequence number.
    next_seq: u64,
    /// Per-session optimizer-health rings (sampled; NOT checkpointed —
    /// diagnostics restart empty after a restore, like the event ring).
    health: crate::telemetry::series::SeriesStore,
}

// SAFETY: sessions cross threads (scheduler fan-out, service
// registry), but `Trainer` is not `Send` solely because its PJRT
// engine variant holds `Rc<Executable>` handles. A `Session` is only
// ever constructed over the native engine (`Session::new` rejects
// `Engine::Pjrt`, and `Session::from_checkpoint` funnels through it),
// nothing can swap the engine afterwards (`set_model` replaces only
// the `Mlp`), and every native-engine field is `Send` (`Mlp`,
// `Dataset`, `Box<dyn Optimizer>` where `Optimizer: Send`). So the
// non-`Send` state is unreachable from any live `Session`.
unsafe impl Send for Session {}

impl Session {
    /// Admit a new session for `cfg`. The config's process-global knobs
    /// (`backend`, `worker_threads`, `simd`) are stripped — one tenant
    /// must not reconfigure the shared pool or the process ISA path
    /// (and because numerics are bit-identical across ISA paths, a
    /// tenant's checkpoint restores identically regardless of the
    /// server's `--simd`) — and only the native engine is accepted
    /// (PJRT state lives in device buffers and cannot be checkpointed).
    pub fn new(id: u64, name: &str, priority: usize, cfg: &TrainConfig) -> Result<Self, String> {
        if !matches!(cfg.engine, Engine::Native) {
            return Err("serve sessions require the native engine".into());
        }
        let mut cfg = cfg.clone();
        cfg.backend = None;
        cfg.worker_threads = None;
        cfg.simd = None;
        cfg.telemetry = None;
        let trainer = Trainer::from_config(&cfg).map_err(|e| e.to_string())?;
        let lp = LoopState::new(&trainer);
        Ok(Session {
            id,
            name: name.to_string(),
            priority: priority.clamp(1, 100),
            tenant: default_tenant(name).to_string(),
            last_ckpt_step: 0,
            last_ckpt_tag: crate::serve::checkpoint::status_tag::LIVE,
            ever_checkpointed: false,
            ckpt_stem: safe_stem(name, id),
            status: if lp.is_done() { SessionStatus::Done } else { SessionStatus::Queued },
            lp,
            trainer,
            timer: StepTimer::new(),
            last_loss: f32::NAN,
            last_val: None,
            lane_share: 0,
            events: VecDeque::new(),
            next_seq: 0,
            health: crate::telemetry::series::SeriesStore::new(),
        })
    }

    /// Rebuild a session from a checkpoint (the restore half of
    /// `serve::checkpoint`). Continuing the restored session is
    /// bit-identical to never having snapshotted. This is the *fork*
    /// path (explicit client `submit` of a checkpoint file): the new
    /// session gets a fresh checkpoint lineage stem so its future
    /// snapshots never collide with the original's. Boot-time
    /// re-admission uses [`Session::from_checkpoint_lineage`] instead.
    pub fn from_checkpoint(
        id: u64,
        name: &str,
        priority: usize,
        ck: &Checkpoint,
    ) -> Result<Self, String> {
        let mut s = Session::new(id, name, priority, &ck.config)?;
        ck.apply(&mut s.trainer)?;
        s.lp = LoopState::restore(&s.trainer, &ck.loop_snap)?;
        s.last_loss = ck.loop_snap.final_loss;
        s.last_ckpt_step = ck.loop_snap.step;
        if s.lp.is_done() {
            s.status = SessionStatus::Done;
        }
        Ok(s)
    }

    /// Rebuild a session from a checkpoint *continuing its lineage*:
    /// name, priority, tenant, lifecycle state and the checkpoint
    /// stem come from the snapshot's own metadata, so a
    /// `--resume-dir` boot reproduces the pre-restart session
    /// population — a lineage whose newest snapshot is a terminal
    /// tombstone comes back *terminal* (status queryable, never
    /// re-run) — and later snapshots keep overwriting the same
    /// lineage, so the newest step always wins on the next resume.
    /// `fallback_stem` (the on-disk file prefix) covers v1 files,
    /// whose metadata carries no stem: without it every restart would
    /// fork such a lineage into a fresh one and duplicate the job.
    pub fn from_checkpoint_lineage(
        id: u64,
        ck: &Checkpoint,
        fallback_stem: &str,
    ) -> Result<Self, String> {
        use crate::serve::checkpoint::status_tag;
        let name = if ck.name.is_empty() { "restored" } else { ck.name.as_str() };
        let mut s = Session::from_checkpoint(id, name, ck.priority.max(1), ck)?;
        if !ck.tenant.is_empty() {
            s.tenant = ck.tenant.clone();
        }
        if !ck.stem.is_empty() {
            s.ckpt_stem = ck.stem.clone();
        } else if !fallback_stem.is_empty() {
            s.ckpt_stem = fallback_stem.to_string();
        }
        match ck.status_tag {
            status_tag::DONE => s.status = SessionStatus::Done,
            status_tag::CANCELLED => s.status = SessionStatus::Cancelled,
            status_tag::FAILED => {
                s.status = SessionStatus::Failed("failed before the restart".into())
            }
            status_tag::PAUSED => {
                // Don't un-finish a session that is Done by its loop
                // state; otherwise the operator's pause survives.
                if s.status == SessionStatus::Queued {
                    s.status = SessionStatus::Paused;
                }
            }
            _ => {}
        }
        s.last_ckpt_tag = ck.status_tag;
        // The lineage provably has at least one on-disk snapshot (we
        // just loaded it), so eviction knows a tombstone is required
        // before this session may be forgotten.
        s.ever_checkpointed = true;
        Ok(s)
    }

    /// Take exactly one optimizer step (latency recorded for the
    /// p50/p95 stats; a [`StepEvent`] is appended to the bounded
    /// `watch` ring — never blocking on consumers).
    pub fn step(&mut self) -> Result<StepOutcome> {
        let t0 = std::time::Instant::now();
        let out = self.lp.step_once(&mut self.trainer)?;
        let wall = t0.elapsed();
        self.timer.record(wall);
        self.last_loss = out.loss;
        if let Some(v) = out.val_metric {
            self.last_val = Some(v);
        }
        // Drain the step's telemetry spans on the stepping thread (the
        // phase list is thread-local). Empty when telemetry is off.
        let phases = crate::telemetry::take_step_phases();
        // Likewise the sampled optimizer-health probes: into this
        // session's rings and the process-global aggregate.
        let samples = crate::telemetry::health::take_samples();
        if !samples.is_empty() {
            for (name, value) in &samples {
                self.health.record(name, out.step, *value);
            }
            crate::telemetry::health::record_global(out.step, &samples);
        }
        if self.events.len() >= EVENT_RING_CAP {
            self.events.pop_front();
        }
        self.events.push_back(StepEvent {
            seq: self.next_seq,
            step: out.step,
            loss: out.loss,
            step_ms: wall.as_secs_f64() * 1e3,
            phases,
        });
        self.next_seq += 1;
        Ok(out)
    }

    /// Step events with `seq >= since`, oldest first. Events older than
    /// the ring capacity are gone (watchers that fall behind skip
    /// ahead; `seq` gaps make the loss visible).
    pub fn events_since(&self, since: u64) -> Vec<StepEvent> {
        self.events.iter().filter(|e| e.seq >= since).cloned().collect()
    }

    /// Sequence number the next step event will carry.
    pub fn next_event_seq(&self) -> u64 {
        self.next_seq
    }

    /// This session's optimizer-health rings (empty when health
    /// sampling is off or no probed step has run yet).
    pub fn health(&self) -> &crate::telemetry::series::SeriesStore {
        &self.health
    }

    /// Run the validation metric on demand (does not advance the loop).
    pub fn eval(&mut self) -> Result<f32> {
        self.trainer.evaluate()
    }

    /// Advance up to `max_steps` steps, stopping early at completion.
    /// Returns the number of steps taken; flips the status to `Done`
    /// or `Failed` as appropriate. Called by the scheduler with the
    /// configured quantum.
    pub fn run_quantum(&mut self, max_steps: usize) -> usize {
        let mut taken = 0;
        for _ in 0..max_steps {
            if self.lp.is_done() {
                break;
            }
            match self.step() {
                Ok(out) => {
                    taken += 1;
                    if out.done {
                        self.status = SessionStatus::Done;
                        break;
                    }
                }
                Err(e) => {
                    self.status = SessionStatus::Failed(format!("{e:#}"));
                    break;
                }
            }
        }
        if self.lp.is_done() && self.status == SessionStatus::Running {
            self.status = SessionStatus::Done;
        }
        taken
    }

    /// Current lifecycle state.
    pub fn status(&self) -> &SessionStatus {
        &self.status
    }

    /// Set the lifecycle state (scheduler/service use; sessions never
    /// leave terminal states).
    pub(crate) fn set_status(&mut self, s: SessionStatus) {
        if !matches!(
            self.status,
            SessionStatus::Done | SessionStatus::Cancelled | SessionStatus::Failed(_)
        ) {
            self.status = s;
        }
    }

    /// True once every configured step has run.
    pub fn is_done(&self) -> bool {
        self.lp.is_done()
    }

    /// Steps taken so far.
    pub fn step_count(&self) -> u64 {
        self.lp.step()
    }

    /// Tenant this session is accounted to.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// File-name stem this session's checkpoints are written under.
    pub fn ckpt_stem(&self) -> &str {
        &self.ckpt_stem
    }

    /// Step captured by the most recent checkpoint (0 if none) — what
    /// the scheduler's `checkpoint_every_steps` clock compares against.
    pub fn last_checkpoint_step(&self) -> u64 {
        self.last_ckpt_step
    }

    /// Lifecycle tag of this lineage's newest snapshot.
    pub fn last_checkpoint_tag(&self) -> u8 {
        self.last_ckpt_tag
    }

    /// True once this lineage's newest snapshot is a terminal
    /// tombstone — the scheduler then never rewrites it.
    pub fn last_checkpoint_was_terminal(&self) -> bool {
        crate::serve::checkpoint::status_tag::is_terminal(self.last_ckpt_tag)
    }

    /// True once any snapshot of this lineage exists on disk.
    pub fn ever_checkpointed(&self) -> bool {
        self.ever_checkpointed
    }

    /// Record that a checkpoint capturing `step` with lifecycle `tag`
    /// was durably written (resets the periodic auto-checkpoint
    /// clock).
    pub(crate) fn note_checkpointed_at(&mut self, step: u64, tag: u8) {
        self.last_ckpt_step = self.last_ckpt_step.max(step);
        self.last_ckpt_tag = tag;
        self.ever_checkpointed = true;
    }

    /// Point-in-time state snapshot for status/stats reporting. The
    /// `queue_position` field is filled by the service (it needs the
    /// registry-wide waiting order); it is 0 here.
    pub fn state(&self) -> SessionState {
        SessionState {
            id: self.id,
            name: self.name.clone(),
            tenant: self.tenant.clone(),
            priority: self.priority,
            status: self.status.clone(),
            queue_position: 0,
            error: match &self.status {
                SessionStatus::Failed(e) => Some(e.clone()),
                _ => None,
            },
            step: self.lp.step(),
            total_steps: self.lp.total_steps(),
            epoch: self.lp.epoch(),
            last_loss: self.last_loss,
            last_val_metric: self.last_val,
            p50_step_ms: self.timer.percentile_ms(50.0),
            p95_step_ms: self.timer.percentile_ms(95.0),
            lane_share: self.lane_share,
            lineage: self.ckpt_stem.clone(),
        }
    }

    /// Snapshot everything needed to resume this session elsewhere,
    /// including its identity metadata (name, priority, tenant,
    /// checkpoint lineage stem, lifecycle tag — so terminal states
    /// survive a restart).
    pub fn checkpoint(&self) -> Result<Checkpoint, String> {
        use crate::serve::checkpoint::status_tag;
        let mut ck = Checkpoint::capture(&self.trainer, &self.lp)?;
        ck.name = self.name.clone();
        ck.priority = self.priority;
        ck.tenant = self.tenant.clone();
        ck.stem = self.ckpt_stem.clone();
        ck.status_tag = match &self.status {
            SessionStatus::Done => status_tag::DONE,
            SessionStatus::Cancelled => status_tag::CANCELLED,
            SessionStatus::Failed(_) => status_tag::FAILED,
            SessionStatus::Paused => status_tag::PAUSED,
            _ => status_tag::LIVE,
        };
        Ok(ck)
    }

    /// Lifetime step-latency samples (for stats aggregation).
    pub fn timer(&self) -> &StepTimer {
        &self.timer
    }

    /// The underlying trainer (read access for tests/examples).
    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    /// FNV-1a digest over the model's exact weight + bias bits — the
    /// equality witness used by the checkpoint and lane-independence
    /// tests.
    pub fn digest(&self) -> u64 {
        model_digest(self.trainer.model().expect("native session has a model"))
    }
}

/// Tenant a session belongs to when the submit carried no explicit
/// `tenant` field: the name prefix before the first `/` (the whole
/// name when there is none). `"acme/retrain-7"` → `"acme"`.
pub fn default_tenant(name: &str) -> &str {
    name.split('/').next().unwrap_or(name)
}

/// File-name-safe checkpoint stem for a session: the sanitized name
/// plus the service-assigned id (`<safe-name>-<id>`), the prefix every
/// snapshot of this session is written under.
pub(crate) fn safe_stem(name: &str, id: u64) -> String {
    let safe: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect();
    format!("{safe}-{id}")
}

/// FNV-1a 64-bit digest over a model's parameter bits. Two models
/// digest equal iff every weight and bias is bit-identical.
pub fn model_digest(m: &Mlp) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut upd = |bits: u32| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for w in &m.weights {
        for v in w.data() {
            upd(v.to_bits());
        }
    }
    for bias in &m.biases {
        for v in bias {
            upd(v.to_bits());
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LrSchedule, ModelArch};

    fn tiny_cfg(optimizer: &str, steps: u64) -> TrainConfig {
        TrainConfig {
            name: format!("serve-{optimizer}"),
            dataset: "c10-small".into(),
            seed: 11,
            arch: ModelArch::Classifier { hidden: vec![16] },
            optim: crate::config::OptimConfig {
                algorithm: optimizer.into(),
                hp: Default::default(),
            },
            engine: Engine::Native,
            epochs: 2,
            batch_size: 64,
            base_lr: 0.05,
            lr_schedule: LrSchedule::Cosine,
            warmup_steps: 0,
            max_steps: Some(steps),
            eval_every: 1,
            backend: None,
            worker_threads: None,
            simd: None,
            telemetry: None,
        }
    }

    #[test]
    fn session_steps_to_completion() {
        let mut s = Session::new(1, "t", 1, &tiny_cfg("eva", 12)).unwrap();
        assert_eq!(s.status(), &SessionStatus::Queued);
        s.set_status(SessionStatus::Running);
        let mut total = 0;
        while !s.is_done() {
            total += s.run_quantum(5);
        }
        assert_eq!(total, 12);
        assert_eq!(s.status(), &SessionStatus::Done);
        assert_eq!(s.state().step, 12);
        assert!(s.state().p50_step_ms >= 0.0);
        // Terminal states stick.
        s.set_status(SessionStatus::Running);
        assert_eq!(s.status(), &SessionStatus::Done);
        // eval works on demand.
        assert!(s.eval().unwrap().is_finite());
    }

    #[test]
    fn tenant_defaults_and_lineage_restore_preserve_identity() {
        assert_eq!(default_tenant("acme/retrain-7"), "acme");
        assert_eq!(default_tenant("solo-job"), "solo-job");
        assert_eq!(default_tenant(""), "");
        let mut s = Session::new(7, "acme/j1", 3, &tiny_cfg("sgd", 8)).unwrap();
        assert_eq!(s.tenant(), "acme");
        assert_eq!(s.ckpt_stem(), "acme_j1-7");
        s.set_status(SessionStatus::Running);
        s.run_quantum(3);
        let ck = s.checkpoint().unwrap();
        assert_eq!((ck.name.as_str(), ck.priority, ck.tenant.as_str()), ("acme/j1", 3, "acme"));
        // Lineage restore keeps name/priority/tenant/stem; fork restore
        // gets a fresh stem under the new id.
        let lineage = Session::from_checkpoint_lineage(42, &ck, "").unwrap();
        assert_eq!(lineage.name, "acme/j1");
        assert_eq!(lineage.priority, 3);
        assert_eq!(lineage.tenant(), "acme");
        assert_eq!(lineage.ckpt_stem(), "acme_j1-7");
        assert_eq!(lineage.last_checkpoint_step(), 3);
        let fork = Session::from_checkpoint(43, "fork", 1, &ck).unwrap();
        assert_eq!(fork.ckpt_stem(), "fork-43");
        // A pause survives a lineage restore — restarts must not
        // silently resume a job the operator froze.
        s.set_status(SessionStatus::Paused);
        let pck = s.checkpoint().unwrap();
        assert_eq!(pck.status_tag, crate::serve::checkpoint::status_tag::PAUSED);
        let paused = Session::from_checkpoint_lineage(45, &pck, "").unwrap();
        assert_eq!(paused.status(), &SessionStatus::Paused);
        assert!(!paused.last_checkpoint_was_terminal());
        // Terminal states survive a lineage restore: a cancelled
        // tombstone comes back cancelled, never re-run.
        s.set_status(SessionStatus::Cancelled);
        let tomb = s.checkpoint().unwrap();
        assert_eq!(tomb.status_tag, crate::serve::checkpoint::status_tag::CANCELLED);
        let back = Session::from_checkpoint_lineage(44, &tomb, "").unwrap();
        assert_eq!(back.status(), &SessionStatus::Cancelled);
        assert!(back.last_checkpoint_was_terminal());
    }

    #[test]
    fn session_rejects_pjrt_and_strips_global_knobs() {
        let mut cfg = tiny_cfg("eva", 4);
        cfg.engine = Engine::Pjrt { model: "quickstart".into() };
        assert!(Session::new(1, "x", 1, &cfg).is_err());
        // A config carrying a backend choice must not reconfigure the
        // process-global pool when admitted.
        let _serial = crate::backend::TEST_GLOBAL_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut cfg = tiny_cfg("sgd", 4);
        cfg.backend = Some("threads:2".into());
        cfg.simd = Some("scalar".into());
        cfg.telemetry = Some("off".into());
        let before = crate::backend::global().label();
        let simd_before = crate::simd::active();
        let tel_before = crate::telemetry::enabled();
        let _s = Session::new(2, "y", 1, &cfg).unwrap();
        assert_eq!(crate::backend::global().label(), before);
        assert_eq!(crate::simd::active(), simd_before);
        assert_eq!(crate::telemetry::enabled(), tel_before);
    }

    #[test]
    fn step_events_accumulate_and_resume_by_seq() {
        let mut s = Session::new(3, "w", 1, &tiny_cfg("sgd", 12)).unwrap();
        s.set_status(SessionStatus::Running);
        s.run_quantum(5);
        let ev = s.events_since(0);
        assert_eq!(ev.len(), 5);
        assert_eq!(ev[0].seq, 0);
        assert_eq!(ev[0].step, 1);
        assert_eq!(ev[4].step, 5);
        assert!(ev.iter().all(|e| e.loss.is_finite() && e.step_ms >= 0.0));
        // Watchers resume from the last seq they saw.
        assert_eq!(s.events_since(3).len(), 2);
        assert_eq!(s.next_event_seq(), 5);
        // Losses in events match the step stream (last one == state).
        assert_eq!(ev[4].loss.to_bits(), s.state().last_loss.to_bits());
    }
}
