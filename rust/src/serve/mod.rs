//! Multi-tenant training-session service.
//!
//! Eva's core economics — second-order preconditioning collapsed to
//! per-layer vectors, so optimizer state per job is O(d) instead of
//! O(d²) — make it feasible to host *many concurrent training jobs*
//! in one process. This module is that host:
//!
//! * [`session`] — a resumable [`Session`]: one tenant's
//!   [`crate::train::Trainer`] plus the steppable
//!   [`crate::train::LoopState`], advanced one quantum at a time so
//!   jobs can be time-sliced, paused and resumed mid-epoch.
//! * [`checkpoint`] — versioned binary snapshots (weights, optimizer
//!   state via [`crate::optim::Optimizer::export_state`], batcher
//!   cursor + RNG, step counters, session identity). Save → restore →
//!   continue is **bit-identical** to an uninterrupted run, and writes
//!   are atomic (tmp + rename — no torn files).
//! * [`scheduler`] — promotes waiting sessions into free live slots
//!   (FIFO within priority: submits past `max_sessions` queue instead
//!   of erroring), runs every admitted runnable session concurrently
//!   over the shared compute pool — carving fair per-session lane
//!   budgets from the global backend with
//!   [`crate::backend::split_weighted`] (weighted by priority,
//!   re-carved on join/leave/pool swap, degrading to sequential at
//!   one lane) — then handles durability: periodic auto-checkpoints
//!   (`checkpoint_every_steps`) and terminal-session eviction
//!   (`retain_terminal`).
//! * [`signal`] — std-only SIGTERM/SIGINT shim; `eva serve` reacts by
//!   checkpointing every live session and exiting, and a restart with
//!   `--resume-dir` re-admits the newest snapshot per session lineage
//!   ([`Service::resume_from_dir`]) — restart-transparent serving.
//! * [`protocol`] / [`server`] / [`client`] — a newline-delimited-JSON
//!   control plane (`submit` / `status` / `pause` / `resume` /
//!   `checkpoint` / `cancel` / `stats` / `metrics` / `shutdown`, plus
//!   the streaming `watch` command that pushes one line per completed
//!   optimizer step) over `std::net::TcpListener`, plus an in-process
//!   client that speaks the same wire format for tests and embedding.
//!   `metrics` dumps the process-wide [`crate::telemetry`] registry;
//!   `watch` is backed by each session's bounded [`StepEvent`] ring,
//!   so a slow or stalled watcher can never block the scheduler.
//!
//! Run it with `eva serve [--addr A] [--max-sessions N]
//! [--checkpoint-dir D]`, or embed it:
//!
//! ```no_run
//! use eva::config::TrainConfig;
//! use eva::serve::client::{LocalClient, ServeClient};
//! use eva::serve::{ServeConfig, Service};
//!
//! let svc = Service::start(ServeConfig::default());
//! let mut client = LocalClient::new(&svc);
//! let mut cfg = TrainConfig::preset("quickstart");
//! cfg.max_steps = Some(50);
//! let id = client.submit(&cfg, "demo", 1).unwrap();
//! client.wait_done(id, std::time::Duration::from_secs(300)).unwrap();
//! svc.shutdown();
//! ```

#![warn(missing_docs)]

pub mod checkpoint;
pub mod client;
pub mod protocol;
pub mod scheduler;
pub mod server;
pub mod session;
pub mod signal;
mod service;

pub use checkpoint::Checkpoint;
pub use client::{LocalClient, ServeClient, TcpClient};
pub use server::Server;
pub use service::{Service, ServiceStats};
pub use session::{default_tenant, model_digest, Session, SessionState, SessionStatus, StepEvent};

use crate::jsonx::Json;

/// Service-level configuration, loadable from a JSON object with the
/// keys `serve_addr`, `max_sessions`, `max_sessions_per_tenant`,
/// `checkpoint_dir`, `quantum_steps`, `checkpoint_every_steps`,
/// `checkpoint_on_shutdown`, `retain_terminal`, `retain_snapshots`,
/// `resume_dir`, `metrics_addr`, `trace_out`, `health_every_steps`
/// (all optional; unknown keys are rejected to catch typos, mirroring
/// [`crate::config::TrainConfig::from_json`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// TCP listen address for the control plane (`serve_addr`).
    /// Port 0 binds an ephemeral port (tests/CI).
    pub addr: String,
    /// Maximum concurrently *admitted* sessions (`max_sessions`).
    /// Submits beyond this are parked in the admission queue
    /// (`Queued`, with a reported `queue_position`) and promoted FIFO
    /// within priority as slots free — never rejected.
    pub max_sessions: usize,
    /// Per-tenant cap on *live* (queued + running + paused) sessions
    /// (`max_sessions_per_tenant`); 0 = unlimited. Tenants are the
    /// explicit `tenant` submit field, defaulting to the session-name
    /// prefix before the first `/`. Keeps one client from
    /// monopolizing the admission queue.
    pub max_sessions_per_tenant: usize,
    /// Directory checkpoint snapshots are written to
    /// (`checkpoint_dir`).
    pub checkpoint_dir: String,
    /// Steps a session runs per scheduler round — the time-slice
    /// granularity for pause/checkpoint/cancel (`quantum_steps`).
    pub quantum_steps: usize,
    /// Auto-checkpoint every session each time its step count
    /// advances this far past its last snapshot
    /// (`checkpoint_every_steps`); 0 = disabled. Scheduler-driven,
    /// same path scheme and atomic write as the `checkpoint` command.
    pub checkpoint_every_steps: u64,
    /// Snapshot every live session during [`Service::shutdown`]
    /// (`checkpoint_on_shutdown`, default true) so a restart with
    /// `--resume-dir` loses nothing.
    pub checkpoint_on_shutdown: bool,
    /// How many terminal (done/cancelled/failed) sessions to keep in
    /// the registry for `status` queries (`retain_terminal`); the
    /// scheduler evicts the oldest beyond this, and `status` on an
    /// evicted id reports "evicted".
    pub retain_terminal: usize,
    /// Directory to re-admit the newest checkpoint per session
    /// lineage from at boot (`resume_dir`; the CLI flag
    /// `--resume-dir` overrides it). `None` = fresh boot.
    pub resume_dir: Option<String>,
    /// Scheduler idle sleep between rounds with no runnable session.
    pub idle_sleep_ms: u64,
    /// Keep only the newest N *loadable* snapshots per checkpoint
    /// lineage, pruning older ones after each successful write
    /// (`retain_snapshots`, CLI `--retain-snapshots`); 0 = unlimited.
    /// Terminal tombstones are never pruned. Deletions bump the
    /// `serve.ckpt.pruned` counter.
    pub retain_snapshots: usize,
    /// Optional listen address for the Prometheus scrape endpoint
    /// (`metrics_addr`, CLI `--metrics-addr`); a separate std-only
    /// HTTP GET listener serving text exposition v0.0.4. `None` = off.
    pub metrics_addr: Option<String>,
    /// Optional path a Chrome trace-event JSON file is written to at
    /// shutdown (`trace_out`, CLI `--trace-out`) — the per-step phase
    /// spans of every session, loadable in Perfetto. `None` = off.
    pub trace_out: Option<String>,
    /// Optimizer-health probe cadence in steps (`health_every_steps`,
    /// CLI `--health-every`): sample per-layer second-order
    /// diagnostics every Nth step; 0 disables probing. Observational
    /// only — numerics are bit-identical at any cadence.
    pub health_every_steps: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7931".into(),
            max_sessions: 8,
            max_sessions_per_tenant: 0,
            checkpoint_dir: "checkpoints".into(),
            quantum_steps: 8,
            checkpoint_every_steps: 0,
            checkpoint_on_shutdown: true,
            retain_terminal: 64,
            resume_dir: None,
            idle_sleep_ms: 5,
            retain_snapshots: 0,
            metrics_addr: None,
            trace_out: None,
            health_every_steps: crate::telemetry::health::DEFAULT_EVERY,
        }
    }
}

impl ServeConfig {
    /// Parse from a JSON object (see type docs for the keys).
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = Json::parse(text)?;
        let obj = v.as_obj().ok_or("serve config must be an object")?;
        let mut c = ServeConfig::default();
        for (k, val) in obj {
            match k.as_str() {
                "serve_addr" => c.addr = val.as_str().ok_or("serve_addr: string")?.to_string(),
                "max_sessions" => {
                    let n = val.as_usize().ok_or("max_sessions: number")?;
                    if n == 0 {
                        return Err("max_sessions must be ≥ 1".into());
                    }
                    c.max_sessions = n;
                }
                "checkpoint_dir" => {
                    c.checkpoint_dir = val.as_str().ok_or("checkpoint_dir: string")?.to_string()
                }
                "quantum_steps" => {
                    let n = val.as_usize().ok_or("quantum_steps: number")?;
                    if n == 0 {
                        return Err("quantum_steps must be ≥ 1".into());
                    }
                    c.quantum_steps = n;
                }
                "max_sessions_per_tenant" => {
                    c.max_sessions_per_tenant =
                        val.as_usize().ok_or("max_sessions_per_tenant: number")?;
                }
                "checkpoint_every_steps" => {
                    c.checkpoint_every_steps =
                        val.as_usize().ok_or("checkpoint_every_steps: number")? as u64;
                }
                "checkpoint_on_shutdown" => {
                    c.checkpoint_on_shutdown =
                        val.as_bool().ok_or("checkpoint_on_shutdown: bool")?;
                }
                "retain_terminal" => {
                    c.retain_terminal = val.as_usize().ok_or("retain_terminal: number")?;
                }
                "resume_dir" => {
                    c.resume_dir = Some(val.as_str().ok_or("resume_dir: string")?.to_string());
                }
                "retain_snapshots" => {
                    c.retain_snapshots = val.as_usize().ok_or("retain_snapshots: number")?;
                }
                "metrics_addr" => {
                    c.metrics_addr =
                        Some(val.as_str().ok_or("metrics_addr: string")?.to_string());
                }
                "trace_out" => {
                    c.trace_out = Some(val.as_str().ok_or("trace_out: string")?.to_string());
                }
                "health_every_steps" => {
                    c.health_every_steps =
                        val.as_usize().ok_or("health_every_steps: number")? as u64;
                }
                other => return Err(format!("unknown serve config key '{other}'")),
            }
        }
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_parses_and_validates() {
        let c = ServeConfig::from_json(
            r#"{"serve_addr": "0.0.0.0:9000", "max_sessions": 3,
                "checkpoint_dir": "/tmp/ck", "quantum_steps": 4,
                "max_sessions_per_tenant": 2, "checkpoint_every_steps": 50,
                "checkpoint_on_shutdown": false, "retain_terminal": 16,
                "resume_dir": "/tmp/ck", "retain_snapshots": 5,
                "metrics_addr": "127.0.0.1:0", "trace_out": "/tmp/trace.json",
                "health_every_steps": 25}"#,
        )
        .unwrap();
        assert_eq!(c.addr, "0.0.0.0:9000");
        assert_eq!(c.max_sessions, 3);
        assert_eq!(c.checkpoint_dir, "/tmp/ck");
        assert_eq!(c.quantum_steps, 4);
        assert_eq!(c.max_sessions_per_tenant, 2);
        assert_eq!(c.checkpoint_every_steps, 50);
        assert!(!c.checkpoint_on_shutdown);
        assert_eq!(c.retain_terminal, 16);
        assert_eq!(c.resume_dir.as_deref(), Some("/tmp/ck"));
        assert_eq!(c.retain_snapshots, 5);
        assert_eq!(c.metrics_addr.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(c.trace_out.as_deref(), Some("/tmp/trace.json"));
        assert_eq!(c.health_every_steps, 25);
        // Defaults: quotas off, periodic checkpoints off, shutdown
        // snapshot on.
        let d = ServeConfig::from_json("{}").unwrap();
        assert_eq!(d.max_sessions_per_tenant, 0);
        assert_eq!(d.checkpoint_every_steps, 0);
        assert!(d.checkpoint_on_shutdown);
        assert_eq!(d.retain_terminal, 64);
        assert!(d.resume_dir.is_none());
        assert_eq!(d.retain_snapshots, 0);
        assert!(d.metrics_addr.is_none());
        assert!(d.trace_out.is_none());
        assert_eq!(d.health_every_steps, crate::telemetry::health::DEFAULT_EVERY);
        assert!(ServeConfig::from_json(r#"{"max_sessions": 0}"#).is_err());
        assert!(ServeConfig::from_json(r#"{"port": 1}"#).is_err());
        assert!(ServeConfig::from_json(r#"{"checkpoint_on_shutdown": 1}"#).is_err());
    }
}
