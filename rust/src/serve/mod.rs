//! Multi-tenant training-session service.
//!
//! Eva's core economics — second-order preconditioning collapsed to
//! per-layer vectors, so optimizer state per job is O(d) instead of
//! O(d²) — make it feasible to host *many concurrent training jobs*
//! in one process. This module is that host:
//!
//! * [`session`] — a resumable [`Session`]: one tenant's
//!   [`crate::train::Trainer`] plus the steppable
//!   [`crate::train::LoopState`], advanced one quantum at a time so
//!   jobs can be time-sliced, paused and resumed mid-epoch.
//! * [`checkpoint`] — versioned binary snapshots (weights, optimizer
//!   state via [`crate::optim::Optimizer::export_state`], batcher
//!   cursor + RNG, step counters). Save → restore → continue is
//!   **bit-identical** to an uninterrupted run.
//! * [`scheduler`] — runs every runnable session concurrently over the
//!   shared compute pool, carving fair per-session lane budgets from
//!   the global backend with [`crate::backend::split_weighted`]
//!   (weighted by priority, re-carved on join/leave, degrading to
//!   sequential at one lane).
//! * [`protocol`] / [`server`] / [`client`] — a newline-delimited-JSON
//!   control plane (`submit` / `status` / `pause` / `resume` /
//!   `checkpoint` / `cancel` / `stats` / `shutdown`) over
//!   `std::net::TcpListener`, plus an in-process client that speaks
//!   the same wire format for tests and embedding.
//!
//! Run it with `eva serve [--addr A] [--max-sessions N]
//! [--checkpoint-dir D]`, or embed it:
//!
//! ```no_run
//! use eva::config::TrainConfig;
//! use eva::serve::client::{LocalClient, ServeClient};
//! use eva::serve::{ServeConfig, Service};
//!
//! let svc = Service::start(ServeConfig::default());
//! let mut client = LocalClient::new(&svc);
//! let mut cfg = TrainConfig::preset("quickstart");
//! cfg.max_steps = Some(50);
//! let id = client.submit(&cfg, "demo", 1).unwrap();
//! client.wait_done(id, std::time::Duration::from_secs(300)).unwrap();
//! svc.shutdown();
//! ```

#![warn(missing_docs)]

pub mod checkpoint;
pub mod client;
pub mod protocol;
pub mod scheduler;
pub mod server;
pub mod session;
mod service;

pub use checkpoint::Checkpoint;
pub use client::{LocalClient, ServeClient, TcpClient};
pub use server::Server;
pub use service::{Service, ServiceStats};
pub use session::{model_digest, Session, SessionState, SessionStatus};

use crate::jsonx::Json;

/// Service-level configuration, loadable from a JSON object with the
/// keys `serve_addr`, `max_sessions`, `checkpoint_dir`,
/// `quantum_steps` (all optional; unknown keys are rejected to catch
/// typos, mirroring [`crate::config::TrainConfig::from_json`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// TCP listen address for the control plane (`serve_addr`).
    /// Port 0 binds an ephemeral port (tests/CI).
    pub addr: String,
    /// Maximum live (queued + running + paused) sessions; submits
    /// beyond this are rejected (`max_sessions`).
    pub max_sessions: usize,
    /// Directory checkpoint snapshots are written to
    /// (`checkpoint_dir`).
    pub checkpoint_dir: String,
    /// Steps a session runs per scheduler round — the time-slice
    /// granularity for pause/checkpoint/cancel (`quantum_steps`).
    pub quantum_steps: usize,
    /// Scheduler idle sleep between rounds with no runnable session.
    pub idle_sleep_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7931".into(),
            max_sessions: 8,
            checkpoint_dir: "checkpoints".into(),
            quantum_steps: 8,
            idle_sleep_ms: 5,
        }
    }
}

impl ServeConfig {
    /// Parse from a JSON object (see type docs for the keys).
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = Json::parse(text)?;
        let obj = v.as_obj().ok_or("serve config must be an object")?;
        let mut c = ServeConfig::default();
        for (k, val) in obj {
            match k.as_str() {
                "serve_addr" => c.addr = val.as_str().ok_or("serve_addr: string")?.to_string(),
                "max_sessions" => {
                    let n = val.as_usize().ok_or("max_sessions: number")?;
                    if n == 0 {
                        return Err("max_sessions must be ≥ 1".into());
                    }
                    c.max_sessions = n;
                }
                "checkpoint_dir" => {
                    c.checkpoint_dir = val.as_str().ok_or("checkpoint_dir: string")?.to_string()
                }
                "quantum_steps" => {
                    let n = val.as_usize().ok_or("quantum_steps: number")?;
                    if n == 0 {
                        return Err("quantum_steps must be ≥ 1".into());
                    }
                    c.quantum_steps = n;
                }
                other => return Err(format!("unknown serve config key '{other}'")),
            }
        }
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_parses_and_validates() {
        let c = ServeConfig::from_json(
            r#"{"serve_addr": "0.0.0.0:9000", "max_sessions": 3,
                "checkpoint_dir": "/tmp/ck", "quantum_steps": 4}"#,
        )
        .unwrap();
        assert_eq!(c.addr, "0.0.0.0:9000");
        assert_eq!(c.max_sessions, 3);
        assert_eq!(c.checkpoint_dir, "/tmp/ck");
        assert_eq!(c.quantum_steps, 4);
        assert!(ServeConfig::from_json(r#"{"max_sessions": 0}"#).is_err());
        assert!(ServeConfig::from_json(r#"{"port": 1}"#).is_err());
    }
}
