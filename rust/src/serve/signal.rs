//! Std-only termination-signal shim for the `serve` subcommand.
//!
//! Pure `std` has no signal API and the offline build has no `libc`
//! crate, but the platform C library Rust already links against
//! exports `signal(2)`/`raise(3)` — a two-line `extern "C"` block is
//! all the shim needs. The handler is the minimal async-signal-safe
//! form: one relaxed store into a process-global [`AtomicBool`] that
//! the serve loop polls (the "atomic-flag" variant of the classic
//! self-pipe trick — polling is fine here because the serve loop
//! already wakes every few milliseconds).
//!
//! On SIGTERM/SIGINT the `eva serve` loop sees [`term_requested`],
//! runs [`crate::serve::Service::shutdown`] — which snapshots every
//! live session (`checkpoint_on_shutdown`) — and exits; a restart
//! with `--resume-dir` then re-admits everything. Non-Unix targets
//! compile to no-ops (install nothing, the flag can still be raised
//! in-process for tests).

use std::sync::atomic::{AtomicBool, Ordering};

/// Set by the signal handler (or [`raise_term`]); read by
/// [`term_requested`]. One-way for the life of the process.
static TERM: AtomicBool = AtomicBool::new(false);

/// True once a termination signal (SIGTERM/SIGINT) was received —
/// the serve loop's cue to checkpoint and exit.
pub fn term_requested() -> bool {
    TERM.load(Ordering::Relaxed)
}

#[cfg(unix)]
mod sys {
    use super::TERM;
    use std::sync::atomic::Ordering;

    pub(super) const SIGINT: i32 = 2;
    pub(super) const SIGTERM: i32 = 15;

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
        fn raise(signum: i32) -> i32;
    }

    /// Async-signal-safe: a single atomic store, nothing else.
    extern "C" fn on_term(_signum: i32) {
        TERM.store(true, Ordering::Relaxed);
    }

    pub(super) fn install() {
        let handler = on_term as extern "C" fn(i32) as usize;
        // SAFETY: signal(2) with a valid extern "C" handler address;
        // the handler is async-signal-safe (one relaxed atomic store).
        unsafe {
            signal(SIGTERM, handler);
            signal(SIGINT, handler);
        }
    }

    pub(super) fn raise_term() {
        // SAFETY: raise(3) with a constant, valid signal number.
        unsafe {
            raise(SIGTERM);
        }
    }
}

/// Install the SIGTERM/SIGINT handler (no-op on non-Unix targets).
/// Idempotent; call once before serving.
pub fn install_term_handler() {
    #[cfg(unix)]
    sys::install();
}

/// Deliver a real SIGTERM to this process (Unix; elsewhere the flag is
/// set directly). For tests and the serve-smoke example, which
/// exercise the full signal → flag → checkpoint-shutdown path without
/// an external `kill`.
pub fn raise_term() {
    #[cfg(unix)]
    sys::raise_term();
    #[cfg(not(unix))]
    TERM.store(true, Ordering::Relaxed);
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    #[test]
    fn sigterm_flips_the_flag_without_killing_the_process() {
        install_term_handler();
        assert!(!term_requested(), "flag must start clear");
        raise_term();
        // Signal delivery is synchronous for raise() on the calling
        // thread, but don't rely on it — poll briefly.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while !term_requested() {
            assert!(std::time::Instant::now() < deadline, "handler never ran");
            std::thread::yield_now();
        }
    }
}
