//! Fair time-slicing of sessions over the shared compute pool.
//!
//! Each **round**, the scheduler collects every runnable session
//! (promoting `Queued` → `Running`), carves the global backend's lane
//! budget into per-session handles with
//! [`crate::backend::split_weighted`] — lanes proportional to session
//! priority, re-carved only when the runnable set or weights change
//! (join/leave/pause), since each carve builds real worker pools —
//! and fans the quanta out with one [`crate::backend::par_map`] over
//! the shared backend. Every session's compute then runs under
//! [`crate::backend::with_backend`] on its own sub-pool handle: the
//! same one-dispatch-layer shape the data-parallel coordinator uses,
//! so numerics are bit-identical whatever the carve (a 1-lane share
//! degrades to inline sequential execution).
//!
//! A panic inside one session's step is contained: the session is
//! marked `Failed` and the neighbouring tenants keep running.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::backend::{self, Backend};
use crate::serve::service::Inner;
use crate::serve::session::{Session, SessionStatus};

/// Cached lane carve, invalidated when the runnable (id, priority) set
/// or the shared backend changes.
#[derive(Default)]
pub(crate) struct CarveCache {
    key: Vec<(u64, usize)>,
    parent: String,
    handles: Vec<Arc<dyn Backend>>,
}

/// Scheduler thread body: rounds until the service stops.
pub(crate) fn run(inner: Arc<Inner>) {
    let mut carve = CarveCache::default();
    while !inner.stop.load(Ordering::Relaxed) {
        let stepped = round(&inner, &mut carve);
        inner.rounds.fetch_add(1, Ordering::Relaxed);
        if stepped == 0 {
            std::thread::sleep(std::time::Duration::from_millis(inner.cfg.idle_sleep_ms));
        }
    }
}

/// One scheduler round; returns the total steps executed.
pub(crate) fn round(inner: &Inner, carve: &mut CarveCache) -> usize {
    // Collect runnable sessions, promoting freshly queued ones. Status
    // transitions only ever happen under the session mutex.
    let runnable: Vec<(u64, Arc<Mutex<Session>>, usize)> = {
        let map = inner.sessions.lock().unwrap_or_else(|e| e.into_inner());
        map.iter()
            .filter_map(|(id, s)| {
                let mut sl = s.lock().unwrap_or_else(|e| e.into_inner());
                let status = sl.status().clone();
                match status {
                    SessionStatus::Queued => sl.set_status(SessionStatus::Running),
                    SessionStatus::Running => {}
                    _ => return None,
                }
                let p = sl.priority;
                Some((*id, Arc::clone(s), p))
            })
            .collect()
    };
    if runnable.is_empty() {
        return 0;
    }
    // (Re-)carve per-session lane budgets on join/leave or a backend
    // swap.
    let parent = backend::global();
    let key: Vec<(u64, usize)> = runnable.iter().map(|(id, _, p)| (*id, *p)).collect();
    if carve.key != key || carve.parent != parent.label() {
        let weights: Vec<usize> = key.iter().map(|(_, p)| *p).collect();
        carve.handles = backend::split_weighted(&*parent, &weights);
        carve.key = key;
        carve.parent = parent.label();
    }
    let handles = &carve.handles;
    let quantum = inner.cfg.quantum_steps;
    // Fan the quanta out over the shared pool; each session computes
    // under its own carved handle.
    let steps = backend::par_map(&*parent, runnable.len(), |i| {
        let (_, ref sess, _) = runnable[i];
        let mut s = sess.lock().unwrap_or_else(|e| e.into_inner());
        if *s.status() != SessionStatus::Running {
            return 0; // paused/cancelled between collect and dispatch
        }
        s.lane_share = handles[i].threads();
        let handle = Arc::clone(&handles[i]);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            backend::with_backend(handle, || s.run_quantum(quantum))
        }));
        match result {
            Ok(n) => n,
            Err(payload) => {
                s.set_status(SessionStatus::Failed(format!(
                    "panic during step: {}",
                    panic_message(payload.as_ref())
                )));
                0
            }
        }
    });
    let total: usize = steps.iter().sum();
    inner.sched_steps.fetch_add(total as u64, Ordering::Relaxed);
    total
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".into()
    }
}
