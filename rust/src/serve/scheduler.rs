//! Fair time-slicing of sessions over the shared compute pool.
//!
//! Each **round**, the scheduler first promotes waiting sessions into
//! free live slots (FIFO within priority — the admission queue), then
//! collects every *admitted* runnable session (flipping `Queued` →
//! `Running`), carves the global backend's lane budget into
//! per-session handles with [`crate::backend::split_weighted`] —
//! lanes proportional to session priority, re-carved only when the
//! runnable set, weights, or the *identity* of the shared pool
//! changes, since each carve builds real worker pools — and fans the
//! quanta out with one [`crate::backend::par_map`] over the shared
//! backend. Every session's compute then runs under
//! [`crate::backend::with_backend`] on its own sub-pool handle: the
//! same one-dispatch-layer shape the data-parallel coordinator uses,
//! so numerics are bit-identical whatever the carve (a 1-lane share
//! degrades to inline sequential execution).
//!
//! After the quanta, the round runs the durability housekeeping:
//! sessions whose step advanced `checkpoint_every_steps` past their
//! last snapshot are checkpointed (atomic tmp + rename, session lock
//! dropped before disk I/O), and terminal sessions beyond the
//! `retain_terminal` cap are evicted from the registry.
//!
//! A panic inside one session's step is contained: the session is
//! marked `Failed` and the neighbouring tenants keep running.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::backend::{self, Backend};
use crate::serve::checkpoint::status_tag;
use crate::serve::service::{self, Inner};
use crate::serve::session::{Session, SessionStatus};

/// Cached lane carve, invalidated when the runnable (id, priority) set
/// or the shared backend changes. The backend is keyed on **pool
/// identity + label**, not label alone: two `threads:N` pools with the
/// same `N` are different pools, and sub-pool handles carved from a
/// replaced pool must not be reused (they would keep dispatching into
/// the dead pool's workers).
#[derive(Default)]
pub(crate) struct CarveCache {
    key: Vec<(u64, usize)>,
    parent: (u64, String),
    handles: Vec<Arc<dyn Backend>>,
}

impl CarveCache {
    /// Make sure the cache matches `parent` + the runnable `key`,
    /// re-carving if anything changed. Returns true when it re-carved.
    pub(crate) fn ensure(&mut self, parent: &Arc<dyn Backend>, key: Vec<(u64, usize)>) -> bool {
        let pkey = (parent.pool_id(), parent.label());
        if self.key == key && self.parent == pkey {
            return false;
        }
        let weights: Vec<usize> = key.iter().map(|(_, p)| *p).collect();
        self.handles = backend::split_weighted(&**parent, &weights);
        self.key = key;
        self.parent = pkey;
        true
    }
}

/// Scheduler thread body: rounds until the service stops.
pub(crate) fn run(inner: Arc<Inner>) {
    let mut carve = CarveCache::default();
    while !inner.stop.load(Ordering::Relaxed) {
        let stepped = round(&inner, &mut carve);
        inner.rounds.fetch_add(1, Ordering::Relaxed);
        if stepped == 0 {
            std::thread::sleep(std::time::Duration::from_millis(inner.cfg.idle_sleep_ms));
        }
    }
}

/// One scheduler round; returns the total steps executed.
pub(crate) fn round(inner: &Inner, carve: &mut CarveCache) -> usize {
    // Fill freed slots from the admission queue.
    service::promote_waiting(inner);
    // Collect runnable sessions among the admitted. Status transitions
    // only ever happen under the session mutex.
    let (mut admitted, mut waiting) = (0u64, 0u64);
    let runnable: Vec<(u64, Arc<Mutex<Session>>, usize)> = {
        let map = inner.sessions.lock().unwrap_or_else(|e| e.into_inner());
        map.iter()
            .filter_map(|(id, slot)| {
                if !slot.admitted.load(Ordering::Relaxed) {
                    waiting += 1;
                    return None; // parked in the admission queue
                }
                admitted += 1;
                let mut sl = slot.sess.lock().unwrap_or_else(|e| e.into_inner());
                match sl.status().clone() {
                    SessionStatus::Queued => sl.set_status(SessionStatus::Running),
                    SessionStatus::Running => {}
                    _ => return None,
                }
                Some((*id, Arc::clone(&slot.sess), slot.priority))
            })
            .collect()
    };
    if crate::telemetry::enabled() {
        crate::telemetry::SERVE_SESSIONS_ADMITTED.set(admitted);
        crate::telemetry::SERVE_QUEUE_DEPTH.set(waiting);
    }
    if runnable.is_empty() {
        // Housekeeping still runs on idle rounds: a cancelled/failed
        // session must get its terminal tombstone (and a paused one
        // its pending snapshot) even when nothing is stepping — a
        // hard kill during an idle stretch must not resurrect it.
        auto_checkpoint(inner);
        evict_terminal(inner);
        return 0;
    }
    // (Re-)carve per-session lane budgets on join/leave or a backend
    // swap (pool identity, not just label — see CarveCache).
    let parent = backend::global();
    let key: Vec<(u64, usize)> = runnable.iter().map(|(id, _, p)| (*id, *p)).collect();
    // Scheduler spans record straight into the registry histograms —
    // NOT via `time_phase`: the thread-local phase list is only
    // drained on stepping threads, and the scheduler thread isn't one.
    let telemetry_on = crate::telemetry::enabled();
    let carve_t0 = telemetry_on.then(std::time::Instant::now);
    carve.ensure(&parent, key);
    if let Some(t0) = carve_t0 {
        crate::telemetry::SERVE_SCHED_CARVE_US.record_us(t0.elapsed().as_micros() as u64);
    }
    let handles = &carve.handles;
    let quantum = inner.cfg.quantum_steps;
    let quantum_t0 = telemetry_on.then(std::time::Instant::now);
    // Fan the quanta out over the shared pool; each session computes
    // under its own carved handle.
    let steps = backend::par_map(&*parent, runnable.len(), |i| {
        let (_, ref sess, _) = runnable[i];
        let mut s = sess.lock().unwrap_or_else(|e| e.into_inner());
        if *s.status() != SessionStatus::Running {
            return 0; // paused/cancelled between collect and dispatch
        }
        s.lane_share = handles[i].threads();
        let handle = Arc::clone(&handles[i]);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            backend::with_backend(handle, || s.run_quantum(quantum))
        }));
        match result {
            Ok(n) => n,
            Err(payload) => {
                s.set_status(SessionStatus::Failed(format!(
                    "panic during step: {}",
                    panic_message(payload.as_ref())
                )));
                0
            }
        }
    });
    if let Some(t0) = quantum_t0 {
        crate::telemetry::SERVE_SCHED_QUANTUM_US.record_us(t0.elapsed().as_micros() as u64);
    }
    let total: usize = steps.iter().sum();
    inner.sched_steps.fetch_add(total as u64, Ordering::Relaxed);
    auto_checkpoint(inner);
    evict_terminal(inner);
    total
}

/// Periodic durability: checkpoint every live session whose step
/// advanced `checkpoint_every_steps` past its last snapshot, and
/// write a one-time terminal *tombstone* for sessions that reached a
/// terminal state — so a restart never resurrects a job the operator
/// saw finish, fail or get cancelled. Runs between rounds (locks
/// free); the disk write itself happens outside the session lock via
/// [`service::checkpoint_session`].
fn auto_checkpoint(inner: &Inner) {
    let every = inner.cfg.checkpoint_every_steps;
    let sessions: Vec<(u64, Arc<Mutex<Session>>, Arc<Mutex<()>>)> = inner
        .sessions
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .map(|(id, slot)| (*id, Arc::clone(&slot.sess), Arc::clone(&slot.ckpt_io)))
        .collect();
    for (id, sess, io) in sessions {
        let due = {
            let s = sess.lock().unwrap_or_else(|e| e.into_inner());
            if s.status().is_live() {
                // Periodic snapshots only when the operator asked —
                // but a pause/resume flip must be re-stamped onto an
                // existing lineage even with no step progress, or a
                // hard kill silently un-pauses (or re-pauses) the
                // session on the next restart.
                let want_tag = if *s.status() == SessionStatus::Paused {
                    status_tag::PAUSED
                } else {
                    status_tag::LIVE
                };
                (every > 0 && s.step_count() >= s.last_checkpoint_step() + every)
                    || (s.ever_checkpointed() && s.last_checkpoint_tag() != want_tag)
            } else {
                // Tombstones are NOT gated on `every`: any lineage
                // with on-disk snapshots must not be left LIVE-tagged
                // once its session is terminal, or a hard kill
                // resurrects it. A lineage with no files has nothing
                // to contradict and gets no file.
                s.ever_checkpointed() && !s.last_checkpoint_was_terminal()
            }
        };
        if !due {
            continue;
        }
        match service::checkpoint_session(&inner.cfg, &sess, &io) {
            Ok(_) => {
                inner.auto_checkpoints.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => eprintln!("serve: auto-checkpoint of session {id} failed: {e}"),
        }
    }
}

/// How many evicted ids to remember for the "evicted" status error.
/// Bounds the memory of the eviction bookkeeping itself: a service
/// churning through millions of short sessions must not re-grow the
/// very leak `retain_terminal` fixes. Ids pruned from this memory
/// fall back to the plain "no session" error.
const EVICTED_IDS_REMEMBERED: usize = 1024;

/// Drop the oldest terminal sessions beyond `retain_terminal` so a
/// long-lived service doesn't grow its registry (and `stats` cost)
/// without bound. A session whose lineage has on-disk snapshots but
/// no terminal tombstone yet gets the tombstone written *before* it
/// is forgotten — otherwise the stale LIVE snapshot would resurrect
/// the job on the next `--resume-dir` with nobody left to contradict
/// it. Evicted ids are remembered (up to [`EVICTED_IDS_REMEMBERED`])
/// so `status` can report "evicted" instead of "no such session".
fn evict_terminal(inner: &Inner) {
    let cap = inner.cfg.retain_terminal;
    // Phase 1 — find terminal sessions (oldest first: BTreeMap
    // iteration is id-ascending) without any disk I/O under the map
    // lock. try_lock: a busy session is mid-quantum, hence live.
    type Candidate = (u64, Arc<Mutex<Session>>, Arc<Mutex<()>>, bool);
    let terminal: Vec<Candidate> = {
        let map = inner.sessions.lock().unwrap_or_else(|e| e.into_inner());
        if map.len() <= cap {
            return; // cheap out: terminal count ≤ registry size
        }
        map.iter()
            .filter_map(|(id, slot)| match slot.sess.try_lock() {
                Ok(s) if !s.status().is_live() => {
                    let needs_tombstone =
                        s.ever_checkpointed() && !s.last_checkpoint_was_terminal();
                    Some((
                        *id,
                        Arc::clone(&slot.sess),
                        Arc::clone(&slot.ckpt_io),
                        needs_tombstone,
                    ))
                }
                _ => None,
            })
            .collect()
    };
    if terminal.len() <= cap {
        return;
    }
    // Phase 2 — tombstone where required (outside the map lock). A
    // failed write keeps the session registered for a later retry.
    let n_evict = terminal.len() - cap;
    let mut evict_ids: Vec<u64> = Vec::with_capacity(n_evict);
    for (id, sess, io, needs_tombstone) in terminal.into_iter().take(n_evict) {
        if needs_tombstone {
            match service::checkpoint_session(&inner.cfg, &sess, &io) {
                Ok(_) => {
                    inner.auto_checkpoints.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    eprintln!("serve: tombstone before evicting session {id} failed: {e}");
                    continue;
                }
            }
        }
        evict_ids.push(id);
    }
    // Phase 3 — forget them. Terminal states are never left, so the
    // collected sessions are still terminal here.
    let mut map = inner.sessions.lock().unwrap_or_else(|e| e.into_inner());
    let mut evicted = inner.evicted.lock().unwrap_or_else(|e| e.into_inner());
    for id in evict_ids {
        if map.remove(&id).is_some() {
            evicted.insert(id);
            inner.evicted_total.fetch_add(1, Ordering::Relaxed);
        }
    }
    while evicted.len() > EVICTED_IDS_REMEMBERED {
        evicted.pop_first();
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Sequential, Threaded};

    #[test]
    fn carve_cache_rekeys_on_pool_identity_not_just_label() {
        let mut cache = CarveCache::default();
        let key = vec![(1u64, 2usize), (2, 1)];
        let pool_a: Arc<dyn Backend> = Arc::new(Threaded::new(2));
        let pool_b: Arc<dyn Backend> = Arc::new(Threaded::new(2));
        assert_eq!(pool_a.label(), pool_b.label(), "setup: labels must collide");
        assert_ne!(pool_a.pool_id(), pool_b.pool_id(), "pools have distinct identities");
        assert!(cache.ensure(&pool_a, key.clone()), "first use carves");
        assert!(!cache.ensure(&pool_a, key.clone()), "same pool + key reuses");
        // The regression: swapping in a different pool with the same
        // label used to silently reuse handles carved from the old one.
        assert!(cache.ensure(&pool_b, key.clone()), "same-label pool swap must re-carve");
        // And the other invalidation axes still work.
        assert!(cache.ensure(&pool_b, vec![(1, 2)]), "runnable-set change re-carves");
        let seq: Arc<dyn Backend> = Arc::new(Sequential);
        assert!(cache.ensure(&seq, vec![(1, 2)]), "backend kind change re-carves");
        assert_eq!(seq.pool_id(), 0, "Sequential has no pool identity");
    }
}
