//! The control-plane wire protocol: newline-delimited JSON.
//!
//! One request object per line, one response object per line. Both the
//! TCP server and the in-process client funnel through [`dispatch`],
//! so the two paths cannot drift.
//!
//! | `cmd` | request fields | response fields |
//! |---|---|---|
//! | `submit` | `config` *(object)* **or** `checkpoint` *(path)* \[+ `lineage: true`\], `name`?, `priority`?, `tenant`? | `session`, `status`, `queue_position` |
//! | `status` | `session` | session state |
//! | `pause` | `session` | session state |
//! | `resume` | `session` | session state |
//! | `checkpoint` | `session` | `path`, `step` |
//! | `cancel` | `session` | session state |
//! | `stats` | — | service stats + per-session states |
//! | `metrics` | — | [`crate::telemetry`] registry dump (`telemetry`, `counters`, `gauges`, `histograms`) |
//! | `health` | `session`? | `health` object `{every, series, anomalies}` — per-session rings with `session`, else the service aggregate ([`crate::telemetry::health`]) |
//! | `trace` | — | `trace`: Chrome trace-event JSON of per-step phase spans (open in Perfetto) |
//! | `hosts` | — | `hosts` array (one self entry; a cluster router returns its whole registry) |
//! | `watch` | `session` | *streaming* — see below |
//! | `shutdown` | — | `stopping: true` |
//!
//! A checkpoint `submit` is *fork* semantics by default (fresh
//! lineage under the new id); with `"lineage": true` it instead
//! **continues** the snapshot's lineage — name, priority, tenant,
//! pause/terminal state and the checkpoint stem all come from the
//! file's own metadata, which is how the cluster router migrates a
//! session between hosts without forking its identity
//! ([`crate::serve::Service::submit_checkpoint_lineage`]).
//!
//! The same wire protocol is spoken by single-process `eva serve`
//! hosts and by the `eva router` cluster front door
//! ([`crate::cluster`]); [`forwardable`] classifies which commands a
//! router proxies to the backend host owning the addressed session.
//!
//! Every response carries `ok` (bool) and, on failure, `error`
//! (string). A request's `id` field, if present, is echoed back so
//! clients can pipeline.
//!
//! `watch` is the one command that does **not** fit the
//! one-line-in/one-line-out shape, so the TCP server handles it
//! before [`dispatch`] (see [`crate::serve::server`]): the response
//! is an acknowledgement line (`"event": "watching"`), then one line
//! per completed optimizer step (`"event": "step"` with `seq`,
//! `step`, `loss`, `step_ms` and a `phases` object of per-phase
//! microseconds), then a final `"event": "end"` line carrying the
//! session's terminal status. Dropped events from a slow reader show
//! up as gaps in `seq`. Calling `watch` through [`dispatch`] (the
//! in-process path) returns an error pointing at the streaming API.

use crate::config::TrainConfig;
use crate::jsonx::Json;
use crate::serve::service::{Service, ServiceStats};
use crate::serve::session::SessionState;

/// Handle one parsed request against the service, producing the
/// response object (never panics; all failures become `ok: false`).
pub fn dispatch(svc: &Service, req: &Json) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    match handle(svc, req) {
        Ok(fields) => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.extend(fields);
        }
        Err(e) => {
            pairs.push(("ok", Json::Bool(false)));
            pairs.push(("error", Json::Str(e)));
        }
    }
    if let Some(id) = req.get("id") {
        pairs.push(("id", id.clone()));
    }
    Json::obj(pairs)
}

fn session_arg(req: &Json) -> Result<u64, String> {
    req.get_f64("session")
        .map(|v| v as u64)
        .ok_or_else(|| "missing 'session' id".into())
}

fn handle(svc: &Service, req: &Json) -> Result<Vec<(&'static str, Json)>, String> {
    let cmd = req.get_str("cmd").ok_or("missing 'cmd'")?;
    match cmd {
        "submit" => {
            let name = req.get_str("name").unwrap_or("job").to_string();
            let priority = req.get_usize("priority").unwrap_or(1);
            let tenant = req.get_str("tenant");
            let id = if let Some(path) = req.get_str("checkpoint") {
                if req.get("lineage").and_then(|v| v.as_bool()) == Some(true) {
                    svc.submit_checkpoint_lineage(path)?
                } else {
                    svc.submit_checkpoint_as(path, &name, priority, tenant)?
                }
            } else {
                let cfg_json = req
                    .get("config")
                    .ok_or("submit needs 'config' (object) or 'checkpoint' (path)")?;
                let cfg = TrainConfig::from_json(&cfg_json.dump())?;
                svc.submit_as(&cfg, &name, priority, tenant)?
            };
            // An over-cap submit is *queued*, not rejected — tell the
            // client where it stands. Best-effort: the submit already
            // succeeded, so a failed status lookup (the session can
            // finish and be evicted in this very window) must not be
            // reported as a submit error.
            let mut fields = vec![("session", Json::Num(id as f64))];
            if let Ok(st) = svc.status(id) {
                fields.push(("status", Json::Str(st.status.as_str().to_string())));
                fields.push(("queue_position", Json::Num(st.queue_position as f64)));
            }
            Ok(fields)
        }
        "status" => Ok(state_fields(&svc.status(session_arg(req)?)?)),
        "pause" => Ok(state_fields(&svc.pause(session_arg(req)?)?)),
        "resume" => Ok(state_fields(&svc.resume(session_arg(req)?)?)),
        "cancel" => Ok(state_fields(&svc.cancel(session_arg(req)?)?)),
        "checkpoint" => {
            let (path, step) = svc.checkpoint(session_arg(req)?)?;
            Ok(vec![("path", Json::Str(path)), ("step", Json::Num(step as f64))])
        }
        "stats" => Ok(stats_fields(&svc.stats())),
        "metrics" => Ok(metrics_fields()),
        // Optional `session`: per-session health rings when present,
        // the process-global aggregate otherwise.
        "health" => {
            let id = req.get_f64("session").map(|v| v as u64);
            Ok(vec![("health", svc.health(id)?)])
        }
        "trace" => Ok(vec![(
            "trace",
            crate::telemetry::export::chrome_trace_json(&svc.trace_spans()),
        )]),
        // A plain serve process is a cluster of one: report itself so
        // router-aware clients can speak to either endpoint uniformly.
        "hosts" => {
            let st = svc.stats();
            let me = Json::obj(vec![
                ("addr", Json::Str(svc.config().addr.clone())),
                ("health", Json::Str("up".into())),
                ("draining", Json::Bool(false)),
                ("live", Json::Num(st.live as f64)),
                ("checkpoint_dir", Json::Str(svc.config().checkpoint_dir.clone())),
            ]);
            Ok(vec![("hosts", Json::Arr(vec![me]))])
        }
        // `watch` streams many lines; dispatch is strictly one
        // request / one response, so the TCP server intercepts it
        // before this point. Reaching here means an in-process caller
        // (LocalClient has a dedicated `watch`) or a transport bug.
        "watch" => Err(
            "'watch' streams newline-delimited step events and is only \
             available over the TCP transport (or Service::watch_events \
             / ServeClient::watch in-process)"
            .into(),
        ),
        "shutdown" => {
            svc.shutdown();
            Ok(vec![("stopping", Json::Bool(true))])
        }
        other => Err(format!("unknown command '{other}'")),
    }
}

/// Commands a cluster router forwards verbatim to the backend host
/// that owns the addressed session (everything keyed by a `session`
/// id, plus the streaming `watch`). The rest — `submit`, `stats`,
/// `metrics`, `hosts`, `shutdown` and router-only verbs like `drain`
/// — need placement or aggregation logic and are handled by the
/// router itself.
pub const FORWARDABLE_SESSION_CMDS: &[&str] =
    &["status", "pause", "resume", "cancel", "checkpoint", "watch", "health"];

/// Whether a command is proxied as-is to the owning backend host by
/// the cluster router (see [`FORWARDABLE_SESSION_CMDS`]).
pub fn forwardable(cmd: &str) -> bool {
    FORWARDABLE_SESSION_CMDS.contains(&cmd)
}

/// A session state as protocol response fields.
fn state_fields(st: &SessionState) -> Vec<(&'static str, Json)> {
    vec![("session", session_state_json(st))]
}

/// A session state as one JSON object (shared by `status` and
/// `stats`).
pub fn session_state_json(st: &SessionState) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("id", Json::Num(st.id as f64)),
        ("name", Json::Str(st.name.clone())),
        ("tenant", Json::Str(st.tenant.clone())),
        ("priority", Json::Num(st.priority as f64)),
        ("status", Json::Str(st.status.as_str().to_string())),
        ("queue_position", Json::Num(st.queue_position as f64)),
        ("step", Json::Num(st.step as f64)),
        ("total_steps", Json::Num(st.total_steps as f64)),
        ("epoch", Json::Num(st.epoch as f64)),
        ("last_loss", Json::Num(st.last_loss as f64)),
        ("p50_step_ms", Json::Num(st.p50_step_ms)),
        ("p95_step_ms", Json::Num(st.p95_step_ms)),
        ("lane_share", Json::Num(st.lane_share as f64)),
        ("lineage", Json::Str(st.lineage.clone())),
    ];
    if let Some(v) = st.last_val_metric {
        pairs.push(("last_val_metric", Json::Num(v as f64)));
    }
    if let Some(e) = &st.error {
        pairs.push(("error", Json::Str(e.clone())));
    }
    Json::obj(pairs)
}

/// Service stats as one JSON object.
pub fn stats_fields(st: &ServiceStats) -> Vec<(&'static str, Json)> {
    vec![
        ("queue_depth", Json::Num(st.queue_depth as f64)),
        ("running", Json::Num(st.running as f64)),
        ("paused", Json::Num(st.paused as f64)),
        ("live", Json::Num(st.live as f64)),
        ("admitted", Json::Num(st.admitted as f64)),
        ("max_sessions", Json::Num(st.max_sessions as f64)),
        ("total_lanes", Json::Num(st.total_lanes as f64)),
        ("backend", Json::Str(st.backend.clone())),
        ("rounds", Json::Num(st.rounds as f64)),
        ("scheduler_steps", Json::Num(st.scheduler_steps as f64)),
        ("auto_checkpoints", Json::Num(st.auto_checkpoints as f64)),
        ("promotions", Json::Num(st.promotions as f64)),
        ("evicted", Json::Num(st.evicted as f64)),
        ("p50_step_ms", Json::Num(st.p50_step_ms)),
        ("p95_step_ms", Json::Num(st.p95_step_ms)),
        (
            "sessions",
            Json::Arr(st.sessions.iter().map(session_state_json).collect()),
        ),
    ]
}

/// The process-wide telemetry registry as protocol response fields
/// (the `metrics` command). Counters and gauges are `name → value`
/// objects; histograms map `name → {count, mean_ms, p50_ms, p95_ms,
/// p99_ms, max_ms}` (the last two are additive extensions — old
/// consumers that only read the original four keep parsing). With
/// telemetry off everything reads zero and `telemetry` is `"off"`,
/// so clients can tell "disabled" from "idle".
pub fn metrics_fields() -> Vec<(&'static str, Json)> {
    let counters = crate::telemetry::counters()
        .iter()
        .map(|c| (c.name(), Json::Num(c.get() as f64)))
        .collect::<Vec<_>>();
    let gauges = crate::telemetry::gauges()
        .iter()
        .map(|g| (g.name(), Json::Num(g.get() as f64)))
        .collect::<Vec<_>>();
    let histograms = crate::telemetry::histograms()
        .iter()
        .map(|h| {
            (
                h.name(),
                Json::obj(vec![
                    ("count", Json::Num(h.count() as f64)),
                    ("mean_ms", Json::Num(h.mean_ms())),
                    ("p50_ms", Json::Num(h.percentile_ms(50.0))),
                    ("p95_ms", Json::Num(h.percentile_ms(95.0))),
                    ("p99_ms", Json::Num(h.percentile_ms(99.0))),
                    ("max_ms", Json::Num(h.max_ms())),
                ]),
            )
        })
        .collect::<Vec<_>>();
    vec![
        (
            "telemetry",
            Json::Str(if crate::telemetry::enabled() { "on" } else { "off" }.into()),
        ),
        ("counters", Json::obj(counters)),
        ("gauges", Json::obj(gauges)),
        ("histograms", Json::obj(histograms)),
    ]
}

/// One [`crate::serve::StepEvent`] as a `watch` stream line body
/// (shared by the TCP streaming loop and the in-process client so
/// the two transports emit identical objects). `phases` is an object
/// of per-phase microseconds in recorded order; it is empty when
/// telemetry is off (the stream itself still flows — step, loss and
/// wall time come from the session, not the registry).
pub fn step_event_fields(ev: &crate::serve::StepEvent) -> Vec<(&'static str, Json)> {
    let phases = ev
        .phases
        .iter()
        .map(|(label, us)| (*label, Json::Num(*us as f64)))
        .collect::<Vec<_>>();
    vec![
        ("event", Json::Str("step".into())),
        ("seq", Json::Num(ev.seq as f64)),
        ("step", Json::Num(ev.step as f64)),
        ("loss", Json::Num(ev.loss as f64)),
        ("step_ms", Json::Num(ev.step_ms)),
        ("phases", Json::obj(phases)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelArch;
    use crate::serve::ServeConfig;

    fn svc() -> Service {
        Service::start(ServeConfig {
            checkpoint_dir: std::env::temp_dir()
                .join("eva-serve-proto-test")
                .to_string_lossy()
                .into_owned(),
            checkpoint_on_shutdown: false,
            ..ServeConfig::default()
        })
    }

    fn tiny_cfg_json() -> Json {
        let cfg = TrainConfig {
            name: "proto".into(),
            dataset: "c10-small".into(),
            arch: ModelArch::Classifier { hidden: vec![8] },
            max_steps: Some(6),
            epochs: 1,
            ..TrainConfig::default()
        };
        cfg.to_json()
    }

    #[test]
    fn submit_status_cancel_over_protocol() {
        let svc = svc();
        let req = Json::obj(vec![
            ("cmd", Json::Str("submit".into())),
            ("config", tiny_cfg_json()),
            ("name", Json::Str("p1".into())),
            ("priority", Json::Num(2.0)),
            ("id", Json::Num(42.0)),
        ]);
        let resp = dispatch(&svc, &req);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("id"), Some(&Json::Num(42.0)), "request id echoed");
        // Under the cap: admitted straight away, no queue position.
        assert_eq!(resp.get_f64("queue_position"), Some(0.0), "{resp:?}");
        let sid = resp.get_f64("session").unwrap();
        let resp = dispatch(
            &svc,
            &Json::obj(vec![
                ("cmd", Json::Str("status".into())),
                ("session", Json::Num(sid)),
            ]),
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let state = resp.get("session").unwrap();
        assert_eq!(state.get_str("name"), Some("p1"));
        assert_eq!(state.get_str("tenant"), Some("p1"), "tenant defaults to the name prefix");
        assert_eq!(state.get_f64("priority"), Some(2.0));
        let resp = dispatch(
            &svc,
            &Json::obj(vec![
                ("cmd", Json::Str("cancel".into())),
                ("session", Json::Num(sid)),
            ]),
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        // Errors come back as ok:false.
        let resp = dispatch(
            &svc,
            &Json::obj(vec![
                ("cmd", Json::Str("status".into())),
                ("session", Json::Num(9999.0)),
            ]),
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp.get_str("error").unwrap().contains("9999"));
        let resp = dispatch(&svc, &Json::obj(vec![("cmd", Json::Str("nope".into()))]));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let resp = dispatch(&svc, &Json::obj(vec![]));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Stats round-trips as parseable JSON.
        let resp = dispatch(&svc, &Json::obj(vec![("cmd", Json::Str("stats".into()))]));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert!(Json::parse(&resp.dump()).is_ok());
        svc.shutdown();
    }

    #[test]
    fn metrics_dumps_registry_and_watch_needs_streaming() {
        let svc = svc();
        let resp = dispatch(&svc, &Json::obj(vec![("cmd", Json::Str("metrics".into()))]));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert!(matches!(resp.get_str("telemetry"), Some("on") | Some("off")));
        let counters = resp.get("counters").and_then(|c| c.as_obj()).unwrap();
        assert!(counters.contains_key("train.steps"), "{counters:?}");
        let hists = resp.get("histograms").and_then(|h| h.as_obj()).unwrap();
        let step = hists.get("train.step_us").unwrap();
        assert!(step.get_f64("count").is_some());
        assert!(step.get_f64("p95_ms").is_some());
        assert!(step.get_f64("p99_ms").is_some(), "additive p99 field");
        assert!(step.get_f64("max_ms").is_some(), "additive max field");
        assert!(Json::parse(&resp.dump()).is_ok(), "metrics must round-trip");
        // watch cannot fit the one-line dispatch shape.
        let resp = dispatch(
            &svc,
            &Json::obj(vec![
                ("cmd", Json::Str("watch".into())),
                ("session", Json::Num(1.0)),
            ]),
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp.get_str("error").unwrap().contains("stream"), "{resp:?}");
        svc.shutdown();
    }

    #[test]
    fn health_and_trace_over_protocol() {
        let svc = svc();
        // Aggregate health: always answers, with or without samples.
        let resp = dispatch(&svc, &Json::obj(vec![("cmd", Json::Str("health".into()))]));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let health = resp.get("health").unwrap();
        assert!(health.get_f64("every").is_some(), "{health:?}");
        assert!(health.get("series").is_some() && health.get("anomalies").is_some());
        // Per-session health needs a real session.
        let resp = dispatch(
            &svc,
            &Json::obj(vec![
                ("cmd", Json::Str("health".into())),
                ("session", Json::Num(777.0)),
            ]),
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        // Trace always yields a well-formed Chrome trace envelope.
        let resp = dispatch(&svc, &Json::obj(vec![("cmd", Json::Str("trace".into()))]));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let trace = resp.get("trace").unwrap();
        assert!(trace.get("traceEvents").and_then(|t| t.as_arr()).is_some(), "{trace:?}");
        assert!(forwardable("health"), "router forwards per-session health");
        svc.shutdown();
    }

    #[test]
    fn step_event_fields_serialize_phases_in_order() {
        let ev = crate::serve::StepEvent {
            seq: 3,
            step: 4,
            loss: 0.5,
            step_ms: 1.25,
            phases: vec![("data", 10), ("forward_backward", 200)],
        };
        let obj = Json::obj(step_event_fields(&ev));
        assert_eq!(obj.get_str("event"), Some("step"));
        assert_eq!(obj.get_f64("seq"), Some(3.0));
        assert_eq!(obj.get_f64("step"), Some(4.0));
        assert_eq!(obj.get_f64("step_ms"), Some(1.25));
        let phases = obj.get("phases").and_then(|p| p.as_obj()).unwrap();
        assert_eq!(phases.get("data").and_then(|v| v.as_f64()), Some(10.0));
        assert_eq!(
            phases.get("forward_backward").and_then(|v| v.as_f64()),
            Some(200.0)
        );
    }
}
