//! Versioned binary training-session snapshots.
//!
//! A [`Checkpoint`] captures *everything* that determines a run's
//! future: the full [`TrainConfig`] (as its JSON form, so snapshots
//! are self-describing), the model's exact weight/bias bits, the
//! optimizer's exported state ([`crate::optim::OptState`]), and the
//! loop state ([`LoopSnapshot`] — step counters, epoch bookkeeping,
//! batcher cursor and shuffle-RNG state). Floats are stored as raw
//! little-endian bits, so **save → restore → continue is bit-identical
//! to an uninterrupted run** — the property `tests/serve_checkpoint.rs`
//! enforces for every optimizer in the zoo.
//!
//! Format: magic `EVACKPT` + a `u32` version, then a fixed field
//! order per version (see [`Checkpoint::to_bytes`]). Unknown versions
//! and truncated/oversized payloads are rejected on load. Version 2
//! appends session metadata (name, priority, tenant, checkpoint stem,
//! lifecycle [`status_tag`]) so a serve process restarted with
//! `--resume-dir` can re-admit sessions with their full identity —
//! including terminal states, which resume as terminal instead of
//! re-running; version-1 files still load with default metadata.
//!
//! Writes are **atomic**: [`Checkpoint::save`] writes to a unique
//! `*.tmp` sibling, fsyncs, then `rename`s onto the final path — a
//! crash mid-write can only ever leave a stray `.tmp`, never a
//! truncated `.ckpt` at the canonical name (the torn-checkpoint test
//! in `tests/serve_admission.rs`).

use crate::config::TrainConfig;
use crate::data::BatcherSnapshot;
use crate::optim::{OptState, StateBuf};
use crate::rng::PcgSnapshot;
use crate::tensor::Tensor;
use crate::train::{EpochMetrics, LoopSnapshot, Trainer};

/// Magic prefix of every checkpoint file.
pub const MAGIC: &[u8; 7] = b"EVACKPT";
/// Current checkpoint format version (v2 = v1 + session metadata).
pub const VERSION: u32 = 2;

/// Session-status tags stored in v2 checkpoints, so terminal states
/// survive a restart: a lineage whose newest snapshot is a `DONE` /
/// `CANCELLED` / `FAILED` tombstone is re-admitted *as terminal* by
/// `--resume-dir` instead of rising from the dead and training again.
pub mod status_tag {
    /// The session was live (queued or running) at capture.
    pub const LIVE: u8 = 0;
    /// The session had reached its step target.
    pub const DONE: u8 = 1;
    /// The session had been cancelled.
    pub const CANCELLED: u8 = 2;
    /// The session had failed.
    pub const FAILED: u8 = 3;
    /// The session was live but held by `pause` — restored paused,
    /// so a restart doesn't silently resume a job the operator froze.
    pub const PAUSED: u8 = 4;
    /// Largest valid tag value.
    pub const MAX: u8 = PAUSED;

    /// True for the terminal tags (tombstones).
    pub fn is_terminal(tag: u8) -> bool {
        matches!(tag, DONE | CANCELLED | FAILED)
    }
}

/// A complete, self-describing session snapshot.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// The run's full configuration (restored sessions rebuild their
    /// dataset and trainer from this).
    pub config: TrainConfig,
    /// Loop counters, batcher cursor and RNG.
    pub loop_snap: LoopSnapshot,
    /// Per-layer weight matrices (exact bits).
    pub weights: Vec<Tensor>,
    /// Per-layer bias vectors (exact bits).
    pub biases: Vec<Vec<f32>>,
    /// Exported optimizer state.
    pub opt_state: OptState,
    /// Session display name at capture time (v2; empty for v1 files).
    pub name: String,
    /// Session scheduling priority at capture time (v2; 1 for v1
    /// files).
    pub priority: usize,
    /// Session tenant at capture time (v2; empty for v1 files —
    /// restore derives it from the name).
    pub tenant: String,
    /// Checkpoint lineage stem (`<safe-name>-<original-id>`): the file
    /// prefix this session's snapshots are written under. Inherited
    /// across `--resume-dir` restarts so one logical session keeps one
    /// lineage, and the newest step of that lineage always wins (v2;
    /// empty for v1 files).
    pub stem: String,
    /// Session lifecycle at capture time (see [`status_tag`]); v1
    /// files read as [`status_tag::LIVE`].
    pub status_tag: u8,
}

impl Checkpoint {
    /// Capture a trainer + loop state (native engine only). Session
    /// metadata defaults to empty; [`crate::serve::Session::checkpoint`]
    /// fills it in.
    pub fn capture(trainer: &Trainer, lp: &crate::train::LoopState) -> Result<Self, String> {
        let model = trainer.model().ok_or("checkpoint requires the native engine")?;
        let opt = trainer.optimizer().ok_or("checkpoint requires the native engine")?;
        Ok(Checkpoint {
            config: trainer.cfg.clone(),
            loop_snap: lp.snapshot(),
            weights: model.weights.clone(),
            biases: model.biases.clone(),
            opt_state: opt.export_state(),
            name: String::new(),
            priority: 1,
            tenant: String::new(),
            stem: String::new(),
            status_tag: status_tag::LIVE,
        })
    }

    /// Overwrite `trainer`'s model parameters and optimizer state with
    /// this snapshot's (the trainer must have been built from
    /// [`Checkpoint::config`], so shapes line up).
    pub fn apply(&self, trainer: &mut Trainer) -> Result<(), String> {
        {
            let model = trainer.model().ok_or("checkpoint requires the native engine")?;
            if model.weights.len() != self.weights.len() {
                return Err(format!(
                    "checkpoint has {} layers, model has {}",
                    self.weights.len(),
                    model.weights.len()
                ));
            }
            for (l, (w, cw)) in model.weights.iter().zip(&self.weights).enumerate() {
                if w.shape() != cw.shape() {
                    return Err(format!(
                        "layer {l}: checkpoint shape {:?} ≠ model shape {:?}",
                        cw.shape(),
                        w.shape()
                    ));
                }
            }
            let mut restored = model.clone();
            restored.weights = self.weights.clone();
            restored.biases = self.biases.clone();
            trainer.set_model(restored);
        }
        trainer
            .optimizer_mut()
            .ok_or("checkpoint requires the native engine")?
            .import_state(&self.opt_state)
    }

    /// Serialize (see module docs for the format).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.bytes(MAGIC);
        w.u32(VERSION);
        w.str(&self.config.to_json().dump());
        // Loop state.
        let s = &self.loop_snap;
        w.u64(s.step);
        w.u64(s.epoch);
        w.u64(s.nsteps_in_epoch);
        w.f64(s.loss_sum);
        w.f32(s.final_loss);
        w.f32(s.best_acc);
        w.f32(s.best_loss);
        w.f64(s.epoch_wall_s);
        w.f64(s.total_wall_s);
        w.u64(s.history.len() as u64);
        for h in &s.history {
            w.u64(h.epoch as u64);
            w.f32(h.train_loss);
            w.f32(h.val_metric);
            w.f64(h.wall_time_s);
            w.f64(h.mean_step_ms);
        }
        // Batcher.
        let b = &s.batcher;
        w.u64(b.order.len() as u64);
        for &i in &b.order {
            w.u64(i as u64);
        }
        w.u64(b.pos as u64);
        w.u64(b.batch as u64);
        w.u128(b.rng.state);
        w.u128(b.rng.inc);
        match b.rng.spare_normal {
            Some(bits) => {
                w.u8(1);
                w.u64(bits);
            }
            None => w.u8(0),
        }
        // Model.
        w.u64(self.weights.len() as u64);
        for (t, bias) in self.weights.iter().zip(&self.biases) {
            w.u64(t.rows() as u64);
            w.u64(t.cols() as u64);
            w.f32s(t.data());
            w.u64(bias.len() as u64);
            w.f32s(bias);
        }
        // Optimizer state.
        w.str(&self.opt_state.algo);
        w.u32(self.opt_state.version);
        w.u64(self.opt_state.scalars.len() as u64);
        for &v in &self.opt_state.scalars {
            w.u64(v);
        }
        w.u64(self.opt_state.bufs.len() as u64);
        for b in &self.opt_state.bufs {
            w.str(&b.name);
            w.u64(b.rows as u64);
            w.u64(b.cols as u64);
            w.f32s(&b.data);
        }
        // Session metadata (v2).
        w.str(&self.name);
        w.u64(self.priority as u64);
        w.str(&self.tenant);
        w.str(&self.stem);
        w.u8(self.status_tag);
        w.buf
    }

    /// Parse bytes produced by [`Checkpoint::to_bytes`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, String> {
        let mut r = Reader::new(bytes);
        let magic = r.bytes(MAGIC.len())?;
        if magic != MAGIC {
            return Err("not an eva checkpoint (bad magic)".into());
        }
        let version = r.u32()?;
        if version != 1 && version != VERSION {
            return Err(format!(
                "checkpoint version {version} unsupported (expected 1..={VERSION})"
            ));
        }
        let config = TrainConfig::from_json(&r.str()?)?;
        let step = r.u64()?;
        let epoch = r.u64()?;
        let nsteps_in_epoch = r.u64()?;
        let loss_sum = r.f64()?;
        let final_loss = r.f32()?;
        let best_acc = r.f32()?;
        let best_loss = r.f32()?;
        let epoch_wall_s = r.f64()?;
        let total_wall_s = r.f64()?;
        let nhist = r.len()?;
        let mut history = Vec::with_capacity(nhist);
        for _ in 0..nhist {
            history.push(EpochMetrics {
                epoch: r.u64()? as usize,
                train_loss: r.f32()?,
                val_metric: r.f32()?,
                wall_time_s: r.f64()?,
                mean_step_ms: r.f64()?,
            });
        }
        let norder = r.len()?;
        let mut order = Vec::with_capacity(norder);
        for _ in 0..norder {
            order.push(r.u64()? as usize);
        }
        let pos = r.u64()? as usize;
        let batch = r.u64()? as usize;
        let state = r.u128()?;
        let inc = r.u128()?;
        let spare_normal = match r.u8()? {
            0 => None,
            1 => Some(r.u64()?),
            v => return Err(format!("bad spare-normal flag {v}")),
        };
        let batcher = BatcherSnapshot {
            order,
            pos,
            batch,
            rng: PcgSnapshot { state, inc, spare_normal },
        };
        let loop_snap = LoopSnapshot {
            batcher,
            step,
            epoch,
            nsteps_in_epoch,
            loss_sum,
            final_loss,
            best_acc,
            best_loss,
            epoch_wall_s,
            total_wall_s,
            history,
        };
        let nlayers = r.len()?;
        let mut weights = Vec::with_capacity(nlayers);
        let mut biases = Vec::with_capacity(nlayers);
        for _ in 0..nlayers {
            let rows = r.u64()? as usize;
            let cols = r.u64()? as usize;
            let data = r.f32s(rows.checked_mul(cols).ok_or("layer shape overflow")?)?;
            weights.push(Tensor::from_vec(rows, cols, data));
            let blen = r.len()?;
            biases.push(r.f32s(blen)?);
        }
        let algo = r.str()?;
        let opt_version = r.u32()?;
        let nscalars = r.len()?;
        let mut scalars = Vec::with_capacity(nscalars);
        for _ in 0..nscalars {
            scalars.push(r.u64()?);
        }
        let nbufs = r.len()?;
        let mut bufs = Vec::with_capacity(nbufs);
        for _ in 0..nbufs {
            let name = r.str()?;
            let rows = r.u64()? as usize;
            let cols = r.u64()? as usize;
            let data = r.f32s(rows.checked_mul(cols).ok_or("state buf overflow")?)?;
            bufs.push(StateBuf { name, rows, cols, data });
        }
        let (sname, priority, tenant, stem, tag) = if version >= 2 {
            let n = r.str()?;
            let p = r.u64()? as usize;
            let t = r.str()?;
            let st = r.str()?;
            let tag = r.u8()?;
            if tag > status_tag::MAX {
                return Err(format!("bad session status tag {tag}"));
            }
            (n, p.max(1), t, st, tag)
        } else {
            (String::new(), 1, String::new(), String::new(), status_tag::LIVE)
        };
        r.finish()?;
        Ok(Checkpoint {
            config,
            loop_snap,
            weights,
            biases,
            opt_state: OptState { algo, version: opt_version, scalars, bufs },
            name: sname,
            priority,
            tenant,
            stem,
            status_tag: tag,
        })
    }

    /// Write to a file (parent directories are created). The write is
    /// atomic: bytes go to a unique `*.tmp` sibling first (fsynced),
    /// then `rename` moves it onto `path` — a crash mid-write never
    /// leaves a truncated file at the canonical name.
    pub fn save(&self, path: &str) -> Result<(), String> {
        use std::io::Write as _;
        let p = std::path::Path::new(path);
        if let Some(parent) = p.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| format!("{path}: {e}"))?;
            }
        }
        // Unique tmp name: concurrent writers targeting the same final
        // path (explicit + auto checkpoint racing at the same step,
        // or an old serve process's shutdown sweep overlapping its
        // replacement on one checkpoint_dir — hence the pid) must
        // never interleave bytes in one tmp file.
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tmp = format!("{path}.{pid}.{seq}.tmp", pid = std::process::id());
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
            std::fs::rename(&tmp, p)?;
            // fsync the directory entry too (Unix): without it the
            // rename itself may not survive power loss, yet the
            // auto-checkpoint clock has already been advanced by the
            // caller on our Ok.
            #[cfg(unix)]
            {
                let dir = match p.parent() {
                    Some(d) if !d.as_os_str().is_empty() => d,
                    _ => std::path::Path::new("."),
                };
                std::fs::File::open(dir)?.sync_all()?;
            }
            Ok(())
        };
        write().map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            format!("{path}: {e}")
        })
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Self, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_bytes(&bytes)
    }
}

/// Scan a checkpoint directory for lineage files
/// (`<stem>-step<N>.ckpt`), grouped by stem with each lineage's
/// snapshots sorted newest step first. Stray `*.tmp` files from
/// interrupted atomic writes and unrelated names are skipped; files
/// are *not* opened — callers validate with [`Checkpoint::load`] and
/// fall back to the next-newest step on a torn file. A missing
/// directory is an empty scan (fresh boot), any other I/O failure is
/// an error. Shared by `Service::resume_from_dir` and the cluster
/// router's dead-host migration, so the two can never disagree about
/// which snapshot is "newest".
pub fn scan_lineages(
    dir: &str,
) -> Result<std::collections::BTreeMap<String, Vec<(u64, String)>>, String> {
    let mut lineages: std::collections::BTreeMap<String, Vec<(u64, String)>> =
        std::collections::BTreeMap::new();
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(lineages),
        Err(e) => return Err(format!("{dir}: {e}")),
    };
    for entry in rd.flatten() {
        let path = entry.path();
        let Some(fname) = path.file_name().and_then(|s| s.to_str()) else { continue };
        let Some(base) = fname.strip_suffix(".ckpt") else { continue };
        let Some((stem, step)) = base.rsplit_once("-step") else { continue };
        let Ok(step) = step.parse::<u64>() else { continue };
        lineages
            .entry(stem.to_string())
            .or_default()
            .push((step, path.to_string_lossy().into_owned()));
    }
    for files in lineages.values_mut() {
        files.sort_by(|a, b| b.0.cmp(&a.0));
    }
    Ok(lineages)
}

/// The newest *loadable* snapshot of one lineage in `dir` — the
/// migration entry point for resuming a session off a host that can
/// no longer answer a `checkpoint` command. Torn or corrupt files are
/// skipped in favor of the next-newest step (same fallback as
/// `--resume-dir`). Returns `(step, path, checkpoint)`; `None` when
/// the lineage has no loadable snapshot at all.
pub fn newest_loadable(dir: &str, stem: &str) -> Option<(u64, String, Checkpoint)> {
    let lineages = scan_lineages(dir).ok()?;
    for (step, path) in lineages.get(stem)? {
        if let Ok(ck) = Checkpoint::load(path) {
            return Some((*step, path.clone(), ck));
        }
    }
    None
}

// ---------------------------------------------------------------------------
// Little-endian byte codec
// ---------------------------------------------------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::with_capacity(4096) }
    }
    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.buf.reserve(vs.len() * 4);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Reader { b, i: 0 }
    }
    fn bytes(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self.i.checked_add(n).ok_or("checkpoint truncated")?;
        let s = self.b.get(self.i..end).ok_or("checkpoint truncated")?;
        self.i = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.bytes(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
    fn u128(&mut self) -> Result<u128, String> {
        Ok(u128::from_le_bytes(self.bytes(16)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        let raw = self.bytes(n.checked_mul(4).ok_or("length overflow")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
    /// A u64 length, sanity-capped against the remaining payload so a
    /// corrupt header cannot trigger an absurd pre-allocation.
    fn len(&mut self) -> Result<usize, String> {
        let n = self.u64()? as usize;
        if n > self.b.len() {
            return Err(format!("checkpoint length field {n} exceeds payload"));
        }
        Ok(n)
    }
    fn str(&mut self) -> Result<String, String> {
        let n = self.len()?;
        String::from_utf8(self.bytes(n)?.to_vec()).map_err(|_| "bad utf-8 string".into())
    }
    fn finish(self) -> Result<(), String> {
        if self.i != self.b.len() {
            return Err(format!("{} trailing bytes after checkpoint", self.b.len() - self.i));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelArch;
    use crate::serve::session::Session;
    use crate::serve::SessionStatus;

    fn cfg() -> TrainConfig {
        TrainConfig {
            name: "ck".into(),
            dataset: "c10-small".into(),
            seed: 3,
            arch: ModelArch::Classifier { hidden: vec![12] },
            max_steps: Some(9),
            epochs: 2,
            batch_size: 32,
            base_lr: 0.05,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn bytes_roundtrip_exactly() {
        let mut s = Session::new(1, "a", 1, &cfg()).unwrap();
        s.set_status(SessionStatus::Running);
        s.run_quantum(5);
        let ck = s.checkpoint().unwrap();
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back.to_bytes(), bytes, "byte-level re-serialization diverged");
        assert_eq!(back.loop_snap.step, 5);
        assert_eq!(back.weights.len(), ck.weights.len());
        for (a, b) in ck.weights.iter().zip(&back.weights) {
            assert_eq!(a.data(), b.data());
        }
        assert_eq!(back.opt_state, ck.opt_state);
    }

    #[test]
    fn corrupt_payloads_are_rejected() {
        let mut s = Session::new(1, "a", 1, &cfg()).unwrap();
        s.set_status(SessionStatus::Running);
        s.run_quantum(2);
        let bytes = s.checkpoint().unwrap().to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 3]).is_err(), "truncated");
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(Checkpoint::from_bytes(&extra).is_err(), "trailing bytes");
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Checkpoint::from_bytes(&bad).is_err(), "bad magic");
        let mut badver = bytes;
        badver[7] = 0xff;
        assert!(Checkpoint::from_bytes(&badver).is_err(), "bad version");
    }

    #[test]
    fn save_load_via_file() {
        let dir = std::env::temp_dir().join("eva-serve-ck-test");
        let path = dir.join("s.ckpt").to_string_lossy().into_owned();
        let mut s = Session::new(1, "a", 2, &cfg()).unwrap();
        s.set_status(SessionStatus::Running);
        s.run_quantum(3);
        let ck = s.checkpoint().unwrap();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.to_bytes(), ck.to_bytes());
        // Session metadata round-trips (v2).
        assert_eq!(back.name, "a");
        assert_eq!(back.priority, 2);
        assert_eq!(back.stem, "a-1");
        // The atomic write leaves no tmp debris behind.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
            .collect();
        assert!(leftovers.is_empty(), "stray tmp files: {leftovers:?}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn version1_files_load_with_default_metadata() {
        // Reconstruct a v1 payload from a v2 one: with empty metadata
        // strings and priority 1 the v2 tail is exactly four u64-sized
        // fields plus the status tag byte (33 bytes); strip it and
        // patch the version field.
        let mut s = Session::new(1, "a", 2, &cfg()).unwrap();
        s.set_status(SessionStatus::Running);
        s.run_quantum(2);
        let mut ck = s.checkpoint().unwrap();
        ck.name.clear();
        ck.tenant.clear();
        ck.stem.clear();
        ck.priority = 1;
        let mut bytes = ck.to_bytes();
        bytes.truncate(bytes.len() - 33);
        bytes[MAGIC.len()..MAGIC.len() + 4].copy_from_slice(&1u32.to_le_bytes());
        let back = Checkpoint::from_bytes(&bytes).expect("v1 payload must still load");
        assert_eq!(back.loop_snap.step, 2);
        assert_eq!(back.name, "");
        assert_eq!(back.priority, 1);
        assert_eq!(back.stem, "");
        assert_eq!(back.status_tag, status_tag::LIVE);
    }
}
