//! TCP front door: newline-delimited JSON over `std::net`.
//!
//! One thread accepts connections (non-blocking poll so shutdown is
//! prompt); each connection gets its own handler thread reading one
//! request per line and writing one response per line (see
//! [`crate::serve::protocol`]). A `shutdown` command — or
//! [`crate::serve::Service::shutdown`] from the embedding process —
//! stops the accept loop and drains the handlers.
//!
//! The streaming `watch` command is the one exception to the
//! one-line-in/one-line-out shape: it is intercepted here, before
//! [`dispatch`], and turns the connection into a step-event stream
//! (ack line, one line per step, a final `end` line) until the
//! watched session goes terminal, the client disconnects or the
//! service stops. Watchers only ever *poll* the session's bounded
//! event ring — a slow or absent reader costs dropped events (visible
//! as `seq` gaps), never scheduler stalls.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use crate::jsonx::Json;
use crate::serve::protocol::{dispatch, step_event_fields};
use crate::serve::service::Service;

/// Hard cap on one request line. Submit configs are a few KiB; a
/// client streaming bytes without a newline must not be able to grow
/// server memory without bound.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// A running control-plane listener.
pub struct Server {
    addr: SocketAddr,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7931`; port 0 for ephemeral) and
    /// start accepting. The server serves until the service stops.
    pub fn start(svc: Service, addr: &str) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let handle = std::thread::Builder::new()
            .name("eva-serve-accept".into())
            .spawn(move || accept_loop(listener, svc))?;
        Ok(Server { addr: local, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (i.e. until the service is
    /// shut down) and drain connection handlers.
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, svc: Service) {
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !svc.is_stopped() {
        match listener.accept() {
            Ok((stream, _)) => {
                let svc = svc.clone();
                if let Ok(h) = std::thread::Builder::new()
                    .name("eva-serve-conn".into())
                    .spawn(move || handle_conn(stream, svc))
                {
                    handlers.push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => break,
        }
        handlers.retain(|h| !h.is_finished());
    }
    for h in handlers {
        let _ = h.join();
    }
}

fn handle_conn(stream: TcpStream, svc: Service) {
    // Short read timeouts keep the handler responsive to shutdown
    // without dropping bytes: a timed-out read_line keeps its partial
    // line in `line` and the next call appends to it.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let write = stream.try_clone();
    let mut reader = BufReader::new(stream);
    let Ok(mut write) = write else { return };
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {
                if line.trim().is_empty() {
                    line.clear();
                    continue;
                }
                let resp = if line.len() > MAX_LINE_BYTES {
                    Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        (
                            "error",
                            Json::Str(format!(
                                "request exceeds {MAX_LINE_BYTES} bytes"
                            )),
                        ),
                    ])
                } else {
                    match Json::parse(line.trim()) {
                        // `watch` streams many lines; it cannot go
                        // through the one-response dispatch.
                        Ok(req) if req.get_str("cmd") == Some("watch") => {
                            line.clear();
                            if stream_watch(&mut write, &svc, &req) {
                                continue; // end line delivered; conn reusable
                            }
                            break; // client gone mid-stream
                        }
                        Ok(req) => dispatch(&svc, &req),
                        Err(e) => Json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", Json::Str(format!("bad request: {e}"))),
                        ]),
                    }
                };
                let oversized = line.len() > MAX_LINE_BYTES;
                line.clear();
                let mut out = resp.dump();
                out.push('\n');
                if write.write_all(out.as_bytes()).is_err() || write.flush().is_err() {
                    break;
                }
                if oversized {
                    break; // framing is untrustworthy past the cap
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Partial lines survive timeouts (see above), so the
                // cap must be enforced here too or a newline-free
                // stream grows `line` forever.
                if svc.is_stopped() || line.len() > MAX_LINE_BYTES {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// How often the watch loop polls the session's event ring. Far below
/// realistic step latency, so events stream with negligible lag while
/// an idle watcher costs two mutex grabs per tick.
const WATCH_POLL: Duration = Duration::from_millis(10);

/// Serve one `watch` request as a step-event stream: an
/// acknowledgement line, one line per completed step, and a final
/// `end` line once the session goes terminal (or the service stops).
/// Returns `true` when the connection is still usable for further
/// requests (the stream concluded with a delivered line) and `false`
/// when the peer vanished mid-stream. Never blocks the scheduler —
/// this thread only polls [`Service::watch_events`].
fn stream_watch(write: &mut TcpStream, svc: &Service, req: &Json) -> bool {
    let echo_id = req.get("id").cloned();
    let send = |write: &mut TcpStream, mut pairs: Vec<(&'static str, Json)>| -> bool {
        if let Some(id) = &echo_id {
            pairs.push(("id", id.clone()));
        }
        let mut out = Json::obj(pairs).dump();
        out.push('\n');
        write.write_all(out.as_bytes()).is_ok() && write.flush().is_ok()
    };
    let fail = |write: &mut TcpStream, e: String| -> bool {
        send(write, vec![("ok", Json::Bool(false)), ("error", Json::Str(e))])
    };
    let Some(id) = req.get_f64("session").map(|v| v as u64) else {
        return fail(write, "missing 'session' id".into());
    };
    // Validate the id before acking, so watching a bogus session is an
    // ordinary single-line error, not an ack followed by a failure.
    let mut seq = 0u64;
    if let Err(e) = svc.watch_events(id, seq) {
        return fail(write, e);
    }
    if !send(
        write,
        vec![
            ("ok", Json::Bool(true)),
            ("event", Json::Str("watching".into())),
            ("session", Json::Num(id as f64)),
        ],
    ) {
        return false;
    }
    loop {
        let (events, terminal) = match svc.watch_events(id, seq) {
            Ok(v) => v,
            // Evicted mid-watch: surface it and end the stream.
            Err(e) => return fail(write, e),
        };
        for ev in &events {
            seq = ev.seq + 1;
            let mut pairs = vec![("ok", Json::Bool(true))];
            pairs.extend(step_event_fields(ev));
            if !send(write, pairs) {
                return false; // client gone; the session steps on
            }
        }
        if terminal {
            let status = svc
                .status(id)
                .map(|st| st.status.as_str().to_string())
                .unwrap_or_else(|_| "evicted".into());
            return send(
                write,
                vec![
                    ("ok", Json::Bool(true)),
                    ("event", Json::Str("end".into())),
                    ("status", Json::Str(status)),
                ],
            );
        }
        if svc.is_stopped() {
            return send(
                write,
                vec![
                    ("ok", Json::Bool(true)),
                    ("event", Json::Str("end".into())),
                    ("status", Json::Str("stopped".into())),
                ],
            );
        }
        std::thread::sleep(WATCH_POLL);
    }
}
