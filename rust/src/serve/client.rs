//! Clients for the serve control plane.
//!
//! [`TcpClient`] speaks the wire protocol over a socket;
//! [`LocalClient`] drives an in-process [`Service`] through the *same*
//! request/response JSON (it literally serializes and re-parses each
//! request), so tests exercising the protocol don't need a socket.
//! Both implement [`ServeClient`], which carries typed helpers for
//! every command.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::config::TrainConfig;
use crate::jsonx::Json;
use crate::serve::protocol::dispatch;
use crate::serve::service::Service;

/// Typed helpers over the raw request/response protocol. Implemented
/// by [`TcpClient`] and [`LocalClient`].
pub trait ServeClient {
    /// Send one request object, returning the response object.
    fn request(&mut self, req: Json) -> Result<Json, String>;

    /// Send, then surface protocol-level failures (`ok: false`) as
    /// `Err`.
    fn request_ok(&mut self, req: Json) -> Result<Json, String> {
        let resp = self.request(req)?;
        match resp.get("ok") {
            Some(Json::Bool(true)) => Ok(resp),
            _ => Err(resp.get_str("error").unwrap_or("request failed").to_string()),
        }
    }

    /// Submit a config; returns the session id. Never rejected for
    /// capacity — past `max_sessions` the session queues (see
    /// [`ServeClient::submit_as`] for the queue position).
    fn submit(&mut self, cfg: &TrainConfig, name: &str, priority: usize) -> Result<u64, String> {
        self.submit_as(cfg, name, priority, None).map(|(id, _)| id)
    }

    /// [`ServeClient::submit`] with an explicit tenant; returns
    /// `(session id, queue_position)` — position 0 means the session
    /// was admitted immediately, n ≥ 1 that it is n-th in the
    /// admission queue.
    fn submit_as(
        &mut self,
        cfg: &TrainConfig,
        name: &str,
        priority: usize,
        tenant: Option<&str>,
    ) -> Result<(u64, usize), String> {
        let mut pairs = vec![
            ("cmd", Json::Str("submit".into())),
            ("config", cfg.to_json()),
            ("name", Json::Str(name.into())),
            ("priority", Json::Num(priority as f64)),
        ];
        if let Some(t) = tenant {
            pairs.push(("tenant", Json::Str(t.into())));
        }
        let resp = self.request_ok(Json::obj(pairs))?;
        let id = resp
            .get_f64("session")
            .map(|v| v as u64)
            .ok_or("no session id in response")?;
        let pos = resp.get_f64("queue_position").unwrap_or(0.0) as usize;
        Ok((id, pos))
    }

    /// Submit a checkpoint file for restoration; returns the new
    /// session id.
    fn submit_checkpoint(
        &mut self,
        path: &str,
        name: &str,
        priority: usize,
    ) -> Result<u64, String> {
        let resp = self.request_ok(Json::obj(vec![
            ("cmd", Json::Str("submit".into())),
            ("checkpoint", Json::Str(path.into())),
            ("name", Json::Str(name.into())),
            ("priority", Json::Num(priority as f64)),
        ]))?;
        resp.get_f64("session").map(|v| v as u64).ok_or("no session id in response".into())
    }

    /// Submit a checkpoint file *continuing* its recorded lineage —
    /// name, priority, tenant, pause state and the checkpoint stem
    /// all come from the file's metadata (migration semantics, not
    /// fork semantics). Returns the new session id.
    fn submit_checkpoint_lineage(&mut self, path: &str) -> Result<u64, String> {
        let resp = self.request_ok(Json::obj(vec![
            ("cmd", Json::Str("submit".into())),
            ("checkpoint", Json::Str(path.into())),
            ("lineage", Json::Bool(true)),
        ]))?;
        resp.get_f64("session").map(|v| v as u64).ok_or("no session id in response".into())
    }

    /// The host registry (`hosts` command): one entry per backend
    /// host with `addr`, `health`, `draining`, `live`. A plain serve
    /// process reports itself as a cluster of one; the router returns
    /// its whole registry.
    fn hosts(&mut self) -> Result<Vec<Json>, String> {
        let resp = self.request_ok(Json::obj(vec![("cmd", Json::Str("hosts".into()))]))?;
        resp.get("hosts")
            .and_then(|h| h.as_arr())
            .map(|h| h.to_vec())
            .ok_or("no hosts in response".into())
    }

    /// Router-only: stop admitting to `host` and migrate its sessions
    /// away (checkpoint there, resume elsewhere). Returns the
    /// response object (`migrated`, `failed` counts).
    fn drain(&mut self, host: &str) -> Result<Json, String> {
        self.request_ok(Json::obj(vec![
            ("cmd", Json::Str("drain".into())),
            ("host", Json::Str(host.into())),
        ]))
    }

    /// Router-only: re-admit a drained host.
    fn undrain(&mut self, host: &str) -> Result<Json, String> {
        self.request_ok(Json::obj(vec![
            ("cmd", Json::Str("undrain".into())),
            ("host", Json::Str(host.into())),
        ]))
    }

    /// One session's state object.
    fn status(&mut self, id: u64) -> Result<Json, String> {
        let resp = self.request_ok(Json::obj(vec![
            ("cmd", Json::Str("status".into())),
            ("session", Json::Num(id as f64)),
        ]))?;
        resp.get("session").cloned().ok_or("no session state in response".into())
    }

    /// Pause a session (takes effect at the next quantum boundary).
    fn pause(&mut self, id: u64) -> Result<Json, String> {
        self.request_ok(Json::obj(vec![
            ("cmd", Json::Str("pause".into())),
            ("session", Json::Num(id as f64)),
        ]))
    }

    /// Re-queue a paused session.
    fn resume(&mut self, id: u64) -> Result<Json, String> {
        self.request_ok(Json::obj(vec![
            ("cmd", Json::Str("resume".into())),
            ("session", Json::Num(id as f64)),
        ]))
    }

    /// Cancel a session.
    fn cancel(&mut self, id: u64) -> Result<Json, String> {
        self.request_ok(Json::obj(vec![
            ("cmd", Json::Str("cancel".into())),
            ("session", Json::Num(id as f64)),
        ]))
    }

    /// Snapshot a session; returns the checkpoint file path.
    fn checkpoint(&mut self, id: u64) -> Result<String, String> {
        let resp = self.request_ok(Json::obj(vec![
            ("cmd", Json::Str("checkpoint".into())),
            ("session", Json::Num(id as f64)),
        ]))?;
        resp.get_str("path").map(String::from).ok_or("no path in response".into())
    }

    /// Service-wide stats object.
    fn stats(&mut self) -> Result<Json, String> {
        self.request_ok(Json::obj(vec![("cmd", Json::Str("stats".into()))]))
    }

    /// The process-wide telemetry registry (`metrics` command):
    /// `telemetry` on/off, `counters`/`gauges` as name → value,
    /// `histograms` as name → `{count, mean_ms, p50_ms, p95_ms}`.
    fn metrics(&mut self) -> Result<Json, String> {
        self.request_ok(Json::obj(vec![("cmd", Json::Str("metrics".into()))]))
    }

    /// Optimizer-health summary (`health` command): per-session rings
    /// and anomaly flags when `session` is given, the service-wide
    /// aggregate otherwise. Returns the `health` object
    /// (`{every, series, anomalies}`).
    fn health(&mut self, session: Option<u64>) -> Result<Json, String> {
        let mut pairs = vec![("cmd", Json::Str("health".into()))];
        if let Some(id) = session {
            pairs.push(("session", Json::Num(id as f64)));
        }
        let resp = self.request_ok(Json::obj(pairs))?;
        resp.get("health").cloned().ok_or("no health in response".into())
    }

    /// Chrome trace-event JSON of per-step phase spans (`trace`
    /// command) — write it to a file and open in Perfetto.
    fn trace(&mut self) -> Result<Json, String> {
        let resp = self.request_ok(Json::obj(vec![("cmd", Json::Str("trace".into()))]))?;
        resp.get("trace").cloned().ok_or("no trace in response".into())
    }

    /// Stream a session's per-step events until it goes terminal.
    /// `on_event` is called once per `"event": "step"` object (`seq`,
    /// `step`, `loss`, `step_ms`, `phases`; see
    /// [`crate::serve::protocol`]); the returned object is the final
    /// `"event": "end"` line carrying the session's terminal status.
    /// Events dropped by the session's bounded ring (slow consumer)
    /// appear as gaps in `seq`. Over TCP this reads the server's
    /// stream; in-process it polls
    /// [`crate::serve::Service::watch_events`] — both deliver
    /// identical objects.
    fn watch(&mut self, id: u64, on_event: &mut dyn FnMut(&Json)) -> Result<Json, String>;

    /// Ask the service to stop.
    fn shutdown(&mut self) -> Result<(), String> {
        self.request_ok(Json::obj(vec![("cmd", Json::Str("shutdown".into()))]))?;
        Ok(())
    }

    /// Poll `status` until the session completes; errors if it fails,
    /// is cancelled, or `timeout` elapses. Returns the final state.
    fn wait_done(&mut self, id: u64, timeout: Duration) -> Result<Json, String> {
        let deadline = Instant::now() + timeout;
        loop {
            let st = self.status(id)?;
            match st.get_str("status") {
                Some("done") => return Ok(st),
                Some("failed") => {
                    return Err(format!(
                        "session {id} failed: {}",
                        st.get_str("error").unwrap_or("unknown")
                    ))
                }
                Some("cancelled") => return Err(format!("session {id} was cancelled")),
                _ => {}
            }
            if Instant::now() >= deadline {
                return Err(format!(
                    "session {id} did not finish in {timeout:?} (at step {})",
                    st.get_f64("step").unwrap_or(-1.0)
                ));
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

/// Wire client over a `TcpStream`.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    /// Connect to a serve control plane.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(TcpClient { reader: BufReader::new(stream), writer })
    }

    /// Read one newline-terminated response object.
    fn recv_line(&mut self) -> Result<Json, String> {
        let mut resp = String::new();
        loop {
            match self.reader.read_line(&mut resp) {
                Ok(0) => return Err("server closed the connection".into()),
                Ok(_) if resp.ends_with('\n') => break,
                Ok(_) => {} // partial line, keep reading
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) => {}
                Err(e) => return Err(format!("recv: {e}")),
            }
        }
        Json::parse(resp.trim()).map_err(|e| format!("bad response: {e}"))
    }

    fn send_line(&mut self, req: &Json) -> Result<(), String> {
        let mut line = req.dump();
        line.push('\n');
        self.writer.write_all(line.as_bytes()).map_err(|e| format!("send: {e}"))?;
        self.writer.flush().map_err(|e| format!("send: {e}"))
    }
}

impl ServeClient for TcpClient {
    fn request(&mut self, req: Json) -> Result<Json, String> {
        self.send_line(&req)?;
        self.recv_line()
    }

    fn watch(&mut self, id: u64, on_event: &mut dyn FnMut(&Json)) -> Result<Json, String> {
        self.send_line(&Json::obj(vec![
            ("cmd", Json::Str("watch".into())),
            ("session", Json::Num(id as f64)),
        ]))?;
        // Ack line first; an unknown session is an ordinary error.
        let ack = self.recv_line()?;
        if ack.get("ok") != Some(&Json::Bool(true)) {
            return Err(ack.get_str("error").unwrap_or("watch failed").to_string());
        }
        loop {
            let line = self.recv_line()?;
            if line.get("ok") != Some(&Json::Bool(true)) {
                return Err(line.get_str("error").unwrap_or("watch failed").to_string());
            }
            match line.get_str("event") {
                Some("step") => on_event(&line),
                Some("end") => return Ok(line),
                _ => {} // future event kinds: skip, don't break old clients
            }
        }
    }
}

/// In-process client: same request/response JSON, no socket. Holds a
/// [`Service`] clone.
pub struct LocalClient {
    svc: Service,
}

impl LocalClient {
    /// Client over an in-process service.
    pub fn new(svc: &Service) -> Self {
        LocalClient { svc: svc.clone() }
    }
}

impl ServeClient for LocalClient {
    fn request(&mut self, req: Json) -> Result<Json, String> {
        // Round-trip through the wire text so the in-process path
        // exercises exactly what the socket path does.
        let req = Json::parse(&req.dump())?;
        Ok(dispatch(&self.svc, &req))
    }

    fn watch(&mut self, id: u64, on_event: &mut dyn FnMut(&Json)) -> Result<Json, String> {
        use crate::serve::protocol::step_event_fields;
        let mut seq = 0u64;
        self.svc.watch_events(id, seq)?; // validate the id up front
        loop {
            let (events, terminal) = self.svc.watch_events(id, seq)?;
            for ev in &events {
                seq = ev.seq + 1;
                // Same object shape as the TCP stream lines.
                let mut pairs = vec![("ok", Json::Bool(true))];
                pairs.extend(step_event_fields(ev));
                on_event(&Json::obj(pairs));
            }
            if terminal {
                let status = self
                    .svc
                    .status(id)
                    .map(|st| st.status.as_str().to_string())
                    .unwrap_or_else(|_| "evicted".into());
                return Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("event", Json::Str("end".into())),
                    ("status", Json::Str(status)),
                ]));
            }
            if self.svc.is_stopped() {
                return Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("event", Json::Str("end".into())),
                    ("status", Json::Str("stopped".into())),
                ]));
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}
