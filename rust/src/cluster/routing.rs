//! Rendezvous (highest-random-weight) placement.
//!
//! Every (key, host) pair gets a pseudo-random score; the key lives
//! on the highest-scoring host. Two properties make this the right
//! shape for session placement:
//!
//! * **Deterministic** — every router instance, restarted or not,
//!   computes the same placement from the same host list. No
//!   placement table has to survive a router crash.
//! * **Minimal disruption** — removing a host only remaps the keys
//!   whose top choice it was (they fall to their second choice);
//!   every other key's ranking is untouched. Consistent-hash rings
//!   share the property but need virtual nodes to balance; HRW is
//!   balanced by construction at our fleet sizes (N ≤ dozens, and
//!   scoring is O(N) per placement — negligible next to a training
//!   step).
//!
//! The key is the session's checkpoint lineage stem
//! (`<safe-name>-<original-id>`), the one identity that survives
//! checkpoint/restore and cluster migration — so a lineage resumed
//! after a full cluster restart lands back on the host it would have
//! been on all along.

/// 64-bit FNV-1a over `bytes` — the same hash family the serve layer
/// uses for weights digests: tiny, portable, and plenty uniform for
/// placement scoring (this is load-balancing, not cryptography).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The rendezvous score of `key` on `host`. Key and host are hashed
/// with a separator byte that cannot occur in either (neither stems
/// nor socket addresses contain NUL), so `("ab", "c")` and
/// `("a", "bc")` cannot collide structurally.
pub fn score(key: &str, host: &str) -> u64 {
    let mut bytes = Vec::with_capacity(key.len() + host.len() + 1);
    bytes.extend_from_slice(key.as_bytes());
    bytes.push(0);
    bytes.extend_from_slice(host.as_bytes());
    fnv1a(&bytes)
}

/// Index of the highest-scoring host for `key`, or `None` for an
/// empty candidate list. Ties (astronomically unlikely, but the
/// contract must be total) break toward the lexicographically
/// smallest host string so every router agrees.
pub fn rendezvous<S: AsRef<str>>(key: &str, hosts: &[S]) -> Option<usize> {
    let mut best: Option<(u64, &str, usize)> = None;
    for (i, h) in hosts.iter().enumerate() {
        let h = h.as_ref();
        let s = score(key, h);
        let better = match best {
            None => true,
            Some((bs, bh, _)) => s > bs || (s == bs && h < bh),
        };
        if better {
            best = Some((s, h, i));
        }
    }
    best.map(|(_, _, i)| i)
}

/// All candidate indices for `key`, best first — the failover order a
/// router walks when the top choice refuses a submit.
pub fn ranked<S: AsRef<str>>(key: &str, hosts: &[S]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..hosts.len()).collect();
    order.sort_by(|&a, &b| {
        let (sa, sb) = (score(key, hosts[a].as_ref()), score(key, hosts[b].as_ref()));
        sb.cmp(&sa).then_with(|| hosts[a].as_ref().cmp(hosts[b].as_ref()))
    });
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn rendezvous_is_deterministic_and_total() {
        let hosts = ["h1:7931", "h2:7931", "h3:7931"];
        for key in ["job-1", "job-2", "tenant/x-17"] {
            let a = rendezvous(key, &hosts).unwrap();
            let b = rendezvous(key, &hosts).unwrap();
            assert_eq!(a, b);
        }
        let none: [&str; 0] = [];
        assert_eq!(rendezvous("job-1", &none), None);
    }

    #[test]
    fn ranked_leads_with_the_rendezvous_winner() {
        let hosts = ["h1:7931", "h2:7931", "h3:7931"];
        for key in ["a-1", "b-2", "c-3", "d-4"] {
            let order = ranked(key, &hosts);
            assert_eq!(order.len(), 3);
            assert_eq!(order[0], rendezvous(key, &hosts).unwrap());
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2], "a permutation of all hosts");
        }
    }

    #[test]
    fn removing_a_host_only_remaps_its_own_keys() {
        let hosts = ["h1:7931", "h2:7931", "h3:7931", "h4:7931"];
        let keys: Vec<String> = (0..300).map(|i| format!("job{i}-{i}")).collect();
        let before: Vec<usize> =
            keys.iter().map(|k| rendezvous(k, &hosts).unwrap()).collect();
        // Drop h3 (index 2); survivors keep their identity strings.
        let survivors = ["h1:7931", "h2:7931", "h4:7931"];
        for (k, &was) in keys.iter().zip(&before) {
            let now = rendezvous(k, &survivors).unwrap();
            if was != 2 {
                // Map the surviving index back to the original list.
                let now_orig = [0usize, 1, 3][now];
                assert_eq!(now_orig, was, "key {k} moved without its host dying");
            }
        }
    }

    #[test]
    fn placement_is_roughly_balanced() {
        let hosts = ["h1:7931", "h2:7931", "h3:7931"];
        let mut counts = [0usize; 3];
        for i in 0..600 {
            counts[rendezvous(&format!("job{i}-{i}"), &hosts).unwrap()] += 1;
        }
        for &c in &counts {
            // Perfect balance is 200 per host; allow a generous band.
            assert!(c > 120 && c < 280, "skewed placement: {counts:?}");
        }
    }
}
