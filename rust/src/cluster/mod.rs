//! Multi-host serve cluster: a thin router/control plane in front of
//! N backend [`crate::serve`] processes.
//!
//! One serve process hosts many sessions, but `max_sessions` caps the
//! box. This module removes that cap without inventing a new
//! protocol: the router speaks the *same* newline-delimited JSON as
//! every serve host ([`crate::serve::protocol`]), so existing clients
//! point at the router and see one big service.
//!
//! * [`routing`] — deterministic session→host placement by rendezvous
//!   (highest-random-weight) hashing on the checkpoint lineage stem.
//!   Adding or removing one host only remaps the sessions that hashed
//!   to it; everything else stays put.
//! * [`net`] — deadline-bounded request helpers over `std::net`. The
//!   serve-layer [`crate::serve::TcpClient`] waits forever by design;
//!   a router probing possibly-dead hosts cannot, so every connect,
//!   send and receive here carries a timeout.
//! * [`router`] — the control plane: host registry with periodic
//!   health probes (the `stats` command doubles as the probe),
//!   Up → Suspect → Down backoff, transparent proxying of
//!   session-addressed commands, checkpoint-migration rebalancing
//!   (snapshot on the source, `submit` with `lineage: true` on the
//!   target, then cancel the source — in that order, so the bytes are
//!   loaded before any tombstone can land), drain/undrain for rolling
//!   restarts, and cluster-level `stats`/`metrics` aggregation.
//! * [`server`] — the TCP front door, mirroring
//!   [`crate::serve::server`] line framing, with a migration-aware
//!   `watch` proxy: a stream interrupted by a migration ends with a
//!   clean `"event": "end", "status": "migrating"` line (a redirect —
//!   re-issue the watch), never a hang.
//!
//! Migration is exactly "checkpoint here, resume there": the EVACKPT
//! format is host- and ISA-portable and restore-and-continue is
//! bit-identical, so a moved session computes the same weights it
//! would have on its original host. The one requirement is that the
//! router can read each host's `checkpoint_dir` (shared or local
//! filesystem) — that is also how sessions are rescued off a host
//! that died without warning.
//!
//! Run it with `eva router --hosts 10.0.0.1:7931,10.0.0.2:7931`, or
//! embed [`Router`] in-process (the cluster tests run a whole
//! cluster, failures included, inside one test binary).

#![warn(missing_docs)]

pub mod net;
pub mod router;
pub mod routing;
pub mod server;

pub use router::{HostHealth, Placement, Router};
pub use routing::rendezvous;
pub use server::RouterServer;

use crate::jsonx::Json;

/// One backend serve process, as the router sees it.
#[derive(Clone, Debug)]
pub struct HostSpec {
    /// Control-plane address of the serve process (`addr:port`).
    pub addr: String,
    /// The host's `checkpoint_dir`, as a path the *router* can read.
    /// Needed to rescue sessions off a host that died without
    /// warning (live drains go through the wire instead).
    pub checkpoint_dir: String,
}

/// Cluster/router configuration, loadable from a JSON object with
/// the keys `router_addr`, `hosts`, `probe_interval_ms`,
/// `probe_timeout_ms`, `probe_fails_down`, `request_timeout_ms`,
/// `auto_migrate` (all optional; unknown keys are rejected to catch
/// typos, mirroring [`crate::serve::ServeConfig::from_json`]).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// TCP listen address for the router (`router_addr`). Port 0
    /// binds an ephemeral port (tests/CI).
    pub router_addr: String,
    /// Backend hosts (`hosts`: array of `"addr"` strings or
    /// `{"addr": ..., "checkpoint_dir": ...}` objects).
    pub hosts: Vec<HostSpec>,
    /// Milliseconds between health-probe passes (`probe_interval_ms`);
    /// 0 disables the probe thread — callers drive
    /// [`Router::probe_once`] by hand (tests).
    pub probe_interval_ms: u64,
    /// Per-host connect + reply budget for one probe
    /// (`probe_timeout_ms`). A host that accepts TCP but never
    /// answers is just as failed as a refused connection.
    pub probe_timeout_ms: u64,
    /// Consecutive failed probes before a host goes `Down`
    /// (`probe_fails_down`); below that it is `Suspect` — still
    /// routable for existing sessions, excluded from new placements.
    pub probe_fails_down: u32,
    /// Timeout for proxied client requests (`request_timeout_ms`).
    pub request_timeout_ms: u64,
    /// Rescue sessions off a host the moment it goes `Down`
    /// (`auto_migrate`, default true): newest loadable checkpoint in
    /// that host's `checkpoint_dir`, resumed on a live host.
    pub auto_migrate: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            router_addr: "127.0.0.1:7940".into(),
            hosts: Vec::new(),
            probe_interval_ms: 1000,
            probe_timeout_ms: 500,
            probe_fails_down: 3,
            request_timeout_ms: 5000,
            auto_migrate: true,
        }
    }
}

impl ClusterConfig {
    /// Parse from a JSON object (see type docs for the keys).
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = Json::parse(text)?;
        let obj = v.as_obj().ok_or("cluster config must be an object")?;
        let mut c = ClusterConfig::default();
        for (k, val) in obj {
            match k.as_str() {
                "router_addr" => {
                    c.router_addr = val.as_str().ok_or("router_addr: string")?.to_string()
                }
                "hosts" => {
                    let arr = val.as_arr().ok_or("hosts: array")?;
                    c.hosts = arr.iter().map(host_spec).collect::<Result<_, _>>()?;
                }
                "probe_interval_ms" => {
                    c.probe_interval_ms =
                        val.as_usize().ok_or("probe_interval_ms: number")? as u64;
                }
                "probe_timeout_ms" => {
                    let n = val.as_usize().ok_or("probe_timeout_ms: number")?;
                    if n == 0 {
                        return Err("probe_timeout_ms must be ≥ 1".into());
                    }
                    c.probe_timeout_ms = n as u64;
                }
                "probe_fails_down" => {
                    let n = val.as_usize().ok_or("probe_fails_down: number")?;
                    if n == 0 {
                        return Err("probe_fails_down must be ≥ 1".into());
                    }
                    c.probe_fails_down = n as u32;
                }
                "request_timeout_ms" => {
                    let n = val.as_usize().ok_or("request_timeout_ms: number")?;
                    if n == 0 {
                        return Err("request_timeout_ms must be ≥ 1".into());
                    }
                    c.request_timeout_ms = n as u64;
                }
                "auto_migrate" => c.auto_migrate = val.as_bool().ok_or("auto_migrate: bool")?,
                other => return Err(format!("unknown cluster config key '{other}'")),
            }
        }
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&text)
    }
}

fn host_spec(v: &Json) -> Result<HostSpec, String> {
    if let Some(addr) = v.as_str() {
        return Ok(HostSpec { addr: addr.to_string(), checkpoint_dir: String::new() });
    }
    let obj = v.as_obj().ok_or("hosts[]: string or object")?;
    let mut spec = HostSpec { addr: String::new(), checkpoint_dir: String::new() };
    for (k, val) in obj {
        match k.as_str() {
            "addr" => spec.addr = val.as_str().ok_or("hosts[].addr: string")?.to_string(),
            "checkpoint_dir" => {
                spec.checkpoint_dir =
                    val.as_str().ok_or("hosts[].checkpoint_dir: string")?.to_string()
            }
            other => return Err(format!("unknown host key '{other}'")),
        }
    }
    if spec.addr.is_empty() {
        return Err("hosts[] entry needs a non-empty 'addr'".into());
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_config_parses_and_validates() {
        let c = ClusterConfig::from_json(
            r#"{"router_addr": "0.0.0.0:7940",
                "hosts": ["10.0.0.1:7931",
                          {"addr": "10.0.0.2:7931", "checkpoint_dir": "/data/ck2"}],
                "probe_interval_ms": 250, "probe_timeout_ms": 100,
                "probe_fails_down": 2, "request_timeout_ms": 900,
                "auto_migrate": false}"#,
        )
        .unwrap();
        assert_eq!(c.router_addr, "0.0.0.0:7940");
        assert_eq!(c.hosts.len(), 2);
        assert_eq!(c.hosts[0].addr, "10.0.0.1:7931");
        assert_eq!(c.hosts[0].checkpoint_dir, "");
        assert_eq!(c.hosts[1].checkpoint_dir, "/data/ck2");
        assert_eq!(c.probe_interval_ms, 250);
        assert_eq!(c.probe_timeout_ms, 100);
        assert_eq!(c.probe_fails_down, 2);
        assert_eq!(c.request_timeout_ms, 900);
        assert!(!c.auto_migrate);
        let d = ClusterConfig::from_json("{}").unwrap();
        assert!(d.hosts.is_empty());
        assert_eq!(d.probe_fails_down, 3);
        assert!(d.auto_migrate);
        assert!(ClusterConfig::from_json(r#"{"probe_fails_down": 0}"#).is_err());
        assert!(ClusterConfig::from_json(r#"{"probe_timeout_ms": 0}"#).is_err());
        assert!(ClusterConfig::from_json(r#"{"port": 1}"#).is_err());
        assert!(ClusterConfig::from_json(r#"{"hosts": [{"addr": ""}]}"#).is_err());
        assert!(ClusterConfig::from_json(r#"{"hosts": [{"host": "x"}]}"#).is_err());
    }
}
