//! Deadline-bounded ndjson requests over `std::net`.
//!
//! The serve-layer [`crate::serve::TcpClient`] is deliberately
//! patient: it waits as long as the server needs. A router is the
//! opposite — it talks to hosts that may be dead, wedged, or
//! accepting TCP while never replying, and a health probe that can
//! block forever is a health probe that can take the router down
//! with the host. Every operation here carries a deadline: connects
//! use [`std::net::TcpStream::connect_timeout`], reads poll in short
//! slices against a caller-supplied budget, and a missed deadline is
//! an ordinary `Err`, never a hang.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::jsonx::Json;
use crate::serve::server::MAX_LINE_BYTES;

/// Read-poll slice. Short enough that a deadline is honored promptly;
/// long enough that an idle wait costs a handful of syscalls.
const POLL: Duration = Duration::from_millis(20);

/// One timeout-bounded connection to a serve host (or router).
pub struct Conn {
    stream: TcpStream,
    /// Bytes received but not yet consumed as a full line.
    buf: Vec<u8>,
    timeout: Duration,
}

impl Conn {
    /// Connect within `timeout`. Resolution failures, refused
    /// connections and slow handshakes all surface as `Err`.
    pub fn connect(addr: &str, timeout: Duration) -> Result<Conn, String> {
        let mut last = format!("{addr}: no addresses resolved");
        for sa in addr.to_socket_addrs().map_err(|e| format!("{addr}: {e}"))? {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(stream) => {
                    stream
                        .set_read_timeout(Some(POLL))
                        .map_err(|e| format!("{addr}: {e}"))?;
                    stream
                        .set_nodelay(true)
                        .map_err(|e| format!("{addr}: {e}"))?;
                    return Ok(Conn { stream, buf: Vec::new(), timeout });
                }
                Err(e) => last = format!("{addr}: {e}"),
            }
        }
        Err(last)
    }

    /// Send one request line.
    pub fn send(&mut self, req: &Json) -> Result<(), String> {
        let mut line = req.dump();
        line.push('\n');
        self.stream.write_all(line.as_bytes()).map_err(|e| format!("send: {e}"))?;
        self.stream.flush().map_err(|e| format!("send: {e}"))
    }

    /// Receive one response line within this connection's timeout.
    pub fn recv(&mut self) -> Result<Json, String> {
        self.recv_deadline(Instant::now() + self.timeout)
    }

    /// Receive one response line by `deadline`. Partial lines survive
    /// poll slices; a peer that accepts the request but never answers
    /// is reported as a timeout, not waited on.
    pub fn recv_deadline(&mut self, deadline: Instant) -> Result<Json, String> {
        let mut chunk = [0u8; 4096];
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = self.buf.drain(..=pos).collect();
                let text = String::from_utf8_lossy(&line);
                let text = text.trim();
                if text.is_empty() {
                    continue; // blank keep-alive line; keep reading
                }
                return Json::parse(text).map_err(|e| format!("bad response: {e}"));
            }
            if self.buf.len() > MAX_LINE_BYTES {
                return Err(format!("response exceeds {MAX_LINE_BYTES} bytes"));
            }
            if Instant::now() >= deadline {
                return Err("timed out waiting for a reply".into());
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err("peer closed the connection".into()),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(format!("recv: {e}")),
            }
        }
    }

    /// Send one request and read its one-line response.
    pub fn request(&mut self, req: &Json) -> Result<Json, String> {
        self.send(req)?;
        self.recv()
    }
}

/// One-shot request: connect, ask, read one reply — all within
/// `timeout` (connect and reply each get the full budget; a probe
/// that needs both to be slow is failed either way). This is the
/// router's workhorse for probes and proxied commands.
pub fn request(addr: &str, req: &Json, timeout: Duration) -> Result<Json, String> {
    let mut conn = Conn::connect(addr, timeout)?;
    conn.request(req)
}

/// [`request`] that also surfaces protocol-level failures
/// (`ok: false`) as `Err` carrying the server's error string.
pub fn request_ok(addr: &str, req: &Json, timeout: Duration) -> Result<Json, String> {
    let resp = request(addr, req, timeout)?;
    match resp.get("ok") {
        Some(Json::Bool(true)) => Ok(resp),
        _ => Err(resp.get_str("error").unwrap_or("request failed").to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn connect_to_nothing_fails_fast() {
        // Reserve a port, close the listener, connect to the corpse.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = Instant::now();
        let err = Conn::connect(&addr, Duration::from_millis(300));
        assert!(err.is_err());
        assert!(t0.elapsed() < Duration::from_secs(5), "must not hang");
    }

    #[test]
    fn silent_peer_times_out_instead_of_hanging() {
        // A listener that accepts and then says nothing.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        // Named like every other spawn site; joined at the end of the
        // test (after the client gave up) so the accepted socket — and
        // with it the listener — is dropped deterministically.
        let hold = std::thread::Builder::new()
            .name("test-silent-peer".into())
            .spawn(move || listener.accept().map(|(s, _)| s))
            .expect("spawn silent-peer holder");
        let t0 = Instant::now();
        let res = request(
            &addr,
            &Json::obj(vec![("cmd", Json::Str("stats".into()))]),
            Duration::from_millis(200),
        );
        assert!(res.is_err(), "{res:?}");
        assert!(res.unwrap_err().contains("timed out"));
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(180), "honors the budget");
        assert!(waited < Duration::from_secs(5), "must not hang");
        drop(hold.join());
    }
}
