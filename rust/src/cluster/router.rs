//! The cluster control plane: host registry, health probes,
//! placement, proxying, and checkpoint-migration rebalancing.
//!
//! A [`Router`] owns no training state at all. Its entire world view
//! is (a) a host registry refreshed by probing each host's `stats`
//! command, and (b) a placement table mapping *cluster* session ids
//! to `(host, remote id, lineage stem)`. Everything durable lives in
//! the hosts' checkpoints, which is why a router can be restarted (or
//! replaced) without losing a single session — rendezvous hashing
//! recomputes the same placements from the same host list.
//!
//! ## Health state machine
//!
//! ```text
//!            probe ok                probe failed
//!   Up ───────────────▶ Up    Up ────────────────▶ Suspect
//!   Suspect ──ok──────▶ Up    Suspect ──(n-th consecutive fail,
//!   Down ──ok─────────▶ Up              n ≥ probe_fails_down)──▶ Down
//! ```
//!
//! `Suspect` hosts keep serving their existing sessions (one missed
//! probe is usually a GC pause, not a death) but receive no new
//! placements. `Down` hosts trigger a rescue when `auto_migrate` is
//! on: every session placed there is resumed from the newest loadable
//! checkpoint in that host's `checkpoint_dir` onto a live host. The
//! rescue re-runs each probe pass while the host stays `Down`, so a
//! rescue blocked by a full cluster retries instead of giving up.
//!
//! ## Migration ordering
//!
//! A live drain moves a session in three wire calls, in an order that
//! is load-bearing: **checkpoint** on the source, **submit** with
//! `lineage: true` on the target, and only then **cancel** on the
//! source. The target has loaded the snapshot bytes before the source
//! is told to die, so the cancel-side terminal tombstone (which may
//! overwrite the very same `<stem>-step<K>.ckpt` path) can no longer
//! poison the move. Steps the source ran between the snapshot and the
//! cancel are recomputed on the target — checkpoint restore is
//! bit-identical, so the session's trajectory is unchanged, merely
//! replayed.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::{net, routing, ClusterConfig};
use crate::jsonx::Json;
use crate::serve::protocol::forwardable;

/// Probe-derived health of one backend host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HostHealth {
    /// Last probe succeeded; placeable.
    Up,
    /// Missed at least one probe but fewer than `probe_fails_down`
    /// in a row; existing sessions stay, no new placements.
    Suspect,
    /// Missed `probe_fails_down` consecutive probes; rescue target.
    Down,
}

impl HostHealth {
    /// Lowercase wire name (`up` / `suspect` / `down`).
    pub fn as_str(&self) -> &'static str {
        match self {
            HostHealth::Up => "up",
            HostHealth::Suspect => "suspect",
            HostHealth::Down => "down",
        }
    }
}

/// Where one cluster session currently lives.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Index into the configured host list.
    pub host: usize,
    /// The session id *on that host* (hosts mint their own ids; the
    /// router's ids are cluster-wide and stable across migrations).
    pub remote_id: u64,
    /// Checkpoint lineage stem — the placement key and the session's
    /// one identity across hosts.
    pub stem: String,
    /// A migration is in flight; session-addressed commands are
    /// deferred (status reports `"migrating"`) until it lands.
    pub migrating: bool,
}

/// A point-in-time registry view of one host (the `hosts` command).
#[derive(Clone, Debug)]
pub struct HostView {
    /// Control-plane address.
    pub addr: String,
    /// Probe-derived health.
    pub health: HostHealth,
    /// Drained hosts receive no new placements (rolling restarts).
    pub draining: bool,
    /// Consecutive failed probes so far.
    pub consecutive_failures: u32,
    /// Live session count from the last successful probe.
    pub live: u64,
    /// The host's checkpoint directory as the router sees it.
    pub checkpoint_dir: String,
}

struct HostEntry {
    addr: String,
    checkpoint_dir: String,
    health: HostHealth,
    draining: bool,
    consecutive_failures: u32,
    live: u64,
}

struct RouterInner {
    cfg: ClusterConfig,
    hosts: Mutex<Vec<HostEntry>>,
    placements: Mutex<BTreeMap<u64, Placement>>,
    next_id: AtomicU64,
    stop: AtomicBool,
    migrations: AtomicU64,
    failed_probes: AtomicU64,
    probe: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// The cluster router. Cheap to clone (an `Arc` around shared state);
/// every clone talks to the same registry and placement table.
#[derive(Clone)]
pub struct Router {
    inner: Arc<RouterInner>,
}

/// Response fields, keyed by owned strings so proxied host responses
/// can be passed through without re-keying to `'static`.
type Fields = BTreeMap<String, Json>;

fn fields(pairs: Vec<(&str, Json)>) -> Fields {
    pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
}

/// Poison-tolerant lock. A panic on some other thread while it held
/// the registry or placement table must not cascade into every
/// request path — the maps hold plain data that is never left
/// half-updated across an unwind point, so routing on the recovered
/// view is safe. This keeps `.unwrap()` out of the request paths
/// (lint rule L5).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Router {
    /// Build the registry and, when `probe_interval_ms > 0`, start
    /// the background probe thread. Hosts start `Up` (optimistically
    /// placeable before the first probe lands); cluster session ids
    /// start at 1.
    pub fn start(cfg: ClusterConfig) -> Router {
        let hosts = cfg
            .hosts
            .iter()
            .map(|h| HostEntry {
                addr: h.addr.clone(),
                checkpoint_dir: h.checkpoint_dir.clone(),
                health: HostHealth::Up,
                draining: false,
                consecutive_failures: 0,
                live: 0,
            })
            .collect();
        let router = Router {
            inner: Arc::new(RouterInner {
                cfg,
                hosts: Mutex::new(hosts),
                placements: Mutex::new(BTreeMap::new()),
                next_id: AtomicU64::new(1),
                stop: AtomicBool::new(false),
                migrations: AtomicU64::new(0),
                failed_probes: AtomicU64::new(0),
                probe: Mutex::new(None),
            }),
        };
        let interval = router.inner.cfg.probe_interval_ms;
        if interval > 0 {
            let r = router.clone();
            let spawned = std::thread::Builder::new()
                .name("eva-router-probe".into())
                .spawn(move || {
                    while !r.is_stopped() {
                        r.probe_once();
                        // Sleep in short slices so shutdown is prompt.
                        let deadline = Instant::now() + Duration::from_millis(interval);
                        while Instant::now() < deadline && !r.is_stopped() {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                });
            match spawned {
                Ok(handle) => *lock(&router.inner.probe) = Some(handle),
                // A router without probes still routes; degrading to
                // manual `probe_once` beats refusing to start.
                Err(e) => eprintln!(
                    "eva-router: could not start the probe thread ({e}); \
                     background health probing is disabled"
                ),
            }
        }
        router
    }

    /// The cluster configuration this router was started with.
    pub fn config(&self) -> &ClusterConfig {
        &self.inner.cfg
    }

    /// Whether [`Router::shutdown`] has been requested.
    pub fn is_stopped(&self) -> bool {
        self.inner.stop.load(Ordering::Relaxed)
    }

    /// Stop the router (probe thread joined, front door drains).
    /// Backend hosts are *not* shut down — they keep training; the
    /// router is control plane only.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        let handle = lock(&self.inner.probe).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// A session's current placement (tests and the watch proxy).
    pub fn placement(&self, id: u64) -> Option<Placement> {
        lock(&self.inner.placements).get(&id).cloned()
    }

    /// A host's control-plane address by registry index.
    pub fn host_addr(&self, idx: usize) -> Option<String> {
        lock(&self.inner.hosts).get(idx).map(|h| h.addr.clone())
    }

    /// Registry snapshot, configured order.
    pub fn hosts(&self) -> Vec<HostView> {
        lock(&self.inner.hosts)
            .iter()
            .map(|h| HostView {
                addr: h.addr.clone(),
                health: h.health,
                draining: h.draining,
                consecutive_failures: h.consecutive_failures,
                live: h.live,
                checkpoint_dir: h.checkpoint_dir.clone(),
            })
            .collect()
    }

    /// Checkpoint-migrations completed since start.
    pub fn migrations(&self) -> u64 {
        self.inner.migrations.load(Ordering::Relaxed)
    }

    /// One health-probe pass over every host: `stats` with the probe
    /// timeout, Up/Suspect/Down bookkeeping, then (with
    /// `auto_migrate`) a rescue attempt for every host that is
    /// `Down`. Runs on the probe thread when `probe_interval_ms > 0`;
    /// call it directly for deterministic tests.
    pub fn probe_once(&self) {
        let probe_req = Json::obj(vec![("cmd", Json::Str("stats".into()))]);
        let timeout = Duration::from_millis(self.inner.cfg.probe_timeout_ms);
        let addrs: Vec<(usize, String)> = {
            let hosts = lock(&self.inner.hosts);
            hosts.iter().enumerate().map(|(i, h)| (i, h.addr.clone())).collect()
        };
        // Probe off-lock: a wedged host must not freeze the registry.
        let results: Vec<(usize, Result<Json, String>)> = addrs
            .iter()
            .map(|(i, addr)| (*i, net::request_ok(addr, &probe_req, timeout)))
            .collect();
        let mut down_hosts = Vec::new();
        {
            let mut hosts = lock(&self.inner.hosts);
            for (i, res) in results {
                let Some(h) = hosts.get_mut(i) else { continue };
                match res {
                    Ok(resp) => {
                        h.health = HostHealth::Up;
                        h.consecutive_failures = 0;
                        h.live = resp.get_f64("live").unwrap_or(0.0) as u64;
                    }
                    Err(_) => {
                        h.consecutive_failures = h.consecutive_failures.saturating_add(1);
                        self.inner.failed_probes.fetch_add(1, Ordering::Relaxed);
                        crate::telemetry::CLUSTER_PROBE_FAILURES.add(1);
                        h.health = if h.consecutive_failures >= self.inner.cfg.probe_fails_down
                        {
                            HostHealth::Down
                        } else {
                            HostHealth::Suspect
                        };
                    }
                }
                if h.health == HostHealth::Down {
                    down_hosts.push(i);
                }
            }
            let up = hosts.iter().filter(|h| h.health == HostHealth::Up).count();
            crate::telemetry::CLUSTER_HOSTS_UP.set(up as u64);
        }
        if self.inner.cfg.auto_migrate {
            for i in down_hosts {
                self.rescue_host(i);
            }
        }
    }

    /// Probes failed since start (all hosts, all passes).
    pub fn failed_probes(&self) -> u64 {
        self.inner.failed_probes.load(Ordering::Relaxed)
    }

    /// Handle one parsed request, producing the response object —
    /// the router-side counterpart of
    /// [`crate::serve::protocol::dispatch`]; same envelope (`ok`,
    /// `error`, echoed `id`).
    pub fn dispatch(&self, req: &Json) -> Json {
        let mut map = match self.handle(req) {
            Ok(mut m) => {
                m.insert("ok".into(), Json::Bool(true));
                m
            }
            Err(e) => fields(vec![("ok", Json::Bool(false)), ("error", Json::Str(e))]),
        };
        if let Some(id) = req.get("id") {
            map.insert("id".into(), id.clone());
        }
        Json::Obj(map)
    }

    fn handle(&self, req: &Json) -> Result<Fields, String> {
        let cmd = req.get_str("cmd").ok_or("missing 'cmd'")?;
        match cmd {
            "submit" => self.submit(req),
            "watch" => Err(
                "'watch' streams newline-delimited step events and is only \
                 available over the TCP transport"
                    .into(),
            ),
            // Session-addressed `health` is forwardable (the owning
            // host holds the rings); without a `session` it is a
            // fleet aggregate, merged here like `metrics`.
            "health" if req.get("session").is_none() => self.health_aggregate(),
            c if forwardable(c) => self.forward(req),
            "stats" => self.stats(),
            "metrics" => self.metrics(),
            "hosts" => Ok(fields(vec![("hosts", self.hosts_json())])),
            "drain" => {
                let host = req.get_str("host").ok_or("missing 'host' address")?;
                let (migrated, failed) = self.drain(host)?;
                Ok(fields(vec![
                    ("host", Json::Str(host.into())),
                    ("migrated", Json::Num(migrated as f64)),
                    ("failed", Json::Num(failed as f64)),
                ]))
            }
            "undrain" => {
                let host = req.get_str("host").ok_or("missing 'host' address")?;
                self.undrain(host)?;
                Ok(fields(vec![("host", Json::Str(host.into()))]))
            }
            "shutdown" => {
                self.shutdown();
                Ok(fields(vec![("stopping", Json::Bool(true))]))
            }
            other => Err(format!("unknown command '{other}'")),
        }
    }

    /// Hosts new sessions may be placed on: `Up` and not draining.
    fn placeable(&self, exclude: Option<usize>) -> Vec<(usize, String)> {
        lock(&self.inner.hosts)
            .iter()
            .enumerate()
            .filter(|(i, h)| {
                h.health == HostHealth::Up && !h.draining && Some(*i) != exclude
            })
            .map(|(i, h)| (i, h.addr.clone()))
            .collect()
    }

    fn request_timeout(&self) -> Duration {
        Duration::from_millis(self.inner.cfg.request_timeout_ms)
    }

    fn submit(&self, req: &Json) -> Result<Fields, String> {
        // Placement key: the lineage stem when resuming a checkpoint
        // (derived from the file name — `<stem>-step<N>.ckpt`), else
        // the job name. The host then mints the real stem
        // (`<safe-name>-<id>`), which we learn back via `status` so
        // later migrations hash the same identity everywhere.
        let key = req
            .get_str("checkpoint")
            .and_then(stem_of_path)
            .or_else(|| req.get_str("name").map(String::from))
            .unwrap_or_else(|| "job".into());
        let candidates = self.placeable(None);
        if candidates.is_empty() {
            return Err("no live host to place the session on".into());
        }
        let addrs: Vec<&str> = candidates.iter().map(|(_, a)| a.as_str()).collect();
        let mut fwd = req.clone();
        if let Json::Obj(m) = &mut fwd {
            m.remove("id"); // the router echoes the id itself
        }
        let timeout = self.request_timeout();
        let mut last_err = String::new();
        for rank in routing::ranked(&key, &addrs) {
            let (idx, addr) = &candidates[rank];
            match net::request_ok(addr, &fwd, timeout) {
                Ok(resp) => {
                    let remote_id = resp
                        .get_f64("session")
                        .map(|v| v as u64)
                        .ok_or("host response carried no session id")?;
                    // Learn the host-minted lineage stem. Best-effort:
                    // an empty stem just means migrations fall back to
                    // hashing by id (still deterministic).
                    let stem = net::request_ok(
                        addr,
                        &Json::obj(vec![
                            ("cmd", Json::Str("status".into())),
                            ("session", Json::Num(remote_id as f64)),
                        ]),
                        timeout,
                    )
                    .ok()
                    .and_then(|r| {
                        r.get("session")
                            .and_then(|s| s.get_str("lineage"))
                            .map(String::from)
                    })
                    .unwrap_or_default();
                    let cid = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
                    lock(&self.inner.placements).insert(
                        cid,
                        Placement {
                            host: *idx,
                            remote_id,
                            stem,
                            migrating: false,
                        },
                    );
                    let mut out = fields(vec![
                        ("session", Json::Num(cid as f64)),
                        ("host", Json::Str(addr.clone())),
                    ]);
                    if let Some(st) = resp.get_str("status") {
                        out.insert("status".into(), Json::Str(st.into()));
                    }
                    if let Some(qp) = resp.get_f64("queue_position") {
                        out.insert("queue_position".into(), Json::Num(qp));
                    }
                    return Ok(out);
                }
                Err(e) => last_err = format!("{addr}: {e}"),
            }
        }
        Err(format!("submit failed on every live host (last: {last_err})"))
    }

    /// Proxy a session-addressed command to the owning host,
    /// rewriting cluster id → remote id on the way out and back.
    fn forward(&self, req: &Json) -> Result<Fields, String> {
        let cid = req
            .get_f64("session")
            .map(|v| v as u64)
            .ok_or("missing 'session' id")?;
        let p = self
            .placement(cid)
            .ok_or_else(|| format!("unknown session {cid}"))?;
        if p.migrating {
            if req.get_str("cmd") == Some("status") {
                return Ok(fields(vec![("session", migrating_state_json(cid, &p))]));
            }
            return Err(format!("session {cid} is migrating between hosts; retry"));
        }
        let addr = self
            .host_addr(p.host)
            .ok_or_else(|| format!("session {cid}: host index {} gone", p.host))?;
        let mut fwd = req.clone();
        if let Json::Obj(m) = &mut fwd {
            m.insert("session".into(), Json::Num(p.remote_id as f64));
            m.remove("id");
        }
        let resp = net::request_ok(&addr, &fwd, self.request_timeout())
            .map_err(|e| format!("host {addr}: {e}"))?;
        let Json::Obj(mut m) = resp else {
            return Err(format!("host {addr}: malformed response"));
        };
        m.remove("ok");
        m.remove("id");
        if let Some(Json::Obj(sess)) = m.get_mut("session") {
            sess.insert("id".into(), Json::Num(cid as f64));
            sess.insert("host".into(), Json::Str(addr));
        }
        Ok(m)
    }

    /// Stop admitting to `host_addr` and migrate every session placed
    /// there onto live peers. Returns `(migrated, failed)`; failures
    /// leave their sessions where they were (retry the drain). The
    /// host stays registered and draining until [`Router::undrain`] —
    /// the admit-stop / migrate / verify / re-admit loop of a rolling
    /// restart.
    pub fn drain(&self, host_addr: &str) -> Result<(usize, usize), String> {
        let idx = self.host_index(host_addr)?;
        lock(&self.inner.hosts)[idx].draining = true;
        let victims: Vec<u64> = {
            let placements = lock(&self.inner.placements);
            placements
                .iter()
                .filter(|(_, p)| p.host == idx && !p.migrating)
                .map(|(id, _)| *id)
                .collect()
        };
        let mut migrated = 0;
        let mut failed = 0;
        for id in victims {
            match self.migrate(id) {
                Ok(()) => migrated += 1,
                Err(_) => failed += 1,
            }
        }
        Ok((migrated, failed))
    }

    /// Re-admit a drained host to placement.
    pub fn undrain(&self, host_addr: &str) -> Result<(), String> {
        let idx = self.host_index(host_addr)?;
        lock(&self.inner.hosts)[idx].draining = false;
        Ok(())
    }

    fn host_index(&self, addr: &str) -> Result<usize, String> {
        lock(&self.inner.hosts)
            .iter()
            .position(|h| h.addr == addr)
            .ok_or_else(|| format!("unknown host '{addr}'"))
    }

    /// Live-migrate one session off its current host: checkpoint at
    /// the source, resume the lineage on the rendezvous-chosen
    /// target, then cancel the source (strictly in that order — see
    /// the module docs). Steps the source runs between snapshot and
    /// cancel are recomputed, not lost: restore is bit-identical.
    pub fn migrate(&self, cid: u64) -> Result<(), String> {
        let (src_idx, remote_id, stem) = {
            let mut placements = lock(&self.inner.placements);
            let p = placements
                .get_mut(&cid)
                .ok_or_else(|| format!("unknown session {cid}"))?;
            if p.migrating {
                return Err(format!("session {cid} is already migrating"));
            }
            p.migrating = true;
            (p.host, p.remote_id, p.stem.clone())
        };
        let result = self.migrate_live(cid, src_idx, remote_id, &stem);
        if result.is_err() {
            if let Some(p) = lock(&self.inner.placements).get_mut(&cid) {
                p.migrating = false;
            }
        }
        result
    }

    fn migrate_live(
        &self,
        cid: u64,
        src_idx: usize,
        remote_id: u64,
        stem: &str,
    ) -> Result<(), String> {
        let src_addr = self
            .host_addr(src_idx)
            .ok_or_else(|| format!("host index {src_idx} gone"))?;
        let timeout = self.request_timeout();
        let resp = net::request_ok(
            &src_addr,
            &Json::obj(vec![
                ("cmd", Json::Str("checkpoint".into())),
                ("session", Json::Num(remote_id as f64)),
            ]),
            timeout,
        )
        .map_err(|e| format!("checkpoint on {src_addr}: {e}"))?;
        let path = resp
            .get_str("path")
            .ok_or("checkpoint response carried no path")?
            .to_string();
        self.adopt(cid, src_idx, stem, &path, Some((src_addr, remote_id)))
    }

    /// Resume `path` on the best live host excluding `exclude`, then
    /// (for live migrations) cancel the source copy, then repoint the
    /// placement. Shared tail of drains and dead-host rescues.
    fn adopt(
        &self,
        cid: u64,
        exclude: usize,
        stem: &str,
        path: &str,
        cancel_source: Option<(String, u64)>,
    ) -> Result<(), String> {
        let candidates = self.placeable(Some(exclude));
        if candidates.is_empty() {
            return Err("no live host to migrate to".into());
        }
        let addrs: Vec<&str> = candidates.iter().map(|(_, a)| a.as_str()).collect();
        let key = if stem.is_empty() { path } else { stem };
        let submit = Json::obj(vec![
            ("cmd", Json::Str("submit".into())),
            ("checkpoint", Json::Str(path.into())),
            ("lineage", Json::Bool(true)),
        ]);
        let timeout = self.request_timeout();
        let mut last_err = String::new();
        for rank in routing::ranked(key, &addrs) {
            let (tgt_idx, tgt_addr) = &candidates[rank];
            match net::request_ok(tgt_addr, &submit, timeout) {
                Ok(resp) => {
                    let new_remote = resp
                        .get_f64("session")
                        .map(|v| v as u64)
                        .ok_or("target response carried no session id")?;
                    // The target has loaded the bytes; *now* the
                    // source copy may die (its cancel tombstone can
                    // no longer matter). Best-effort — a dead source
                    // has already stopped on its own.
                    if let Some((src_addr, old_remote)) = &cancel_source {
                        let _ = net::request(
                            src_addr,
                            &Json::obj(vec![
                                ("cmd", Json::Str("cancel".into())),
                                ("session", Json::Num(*old_remote as f64)),
                            ]),
                            timeout,
                        );
                    }
                    if let Some(p) = lock(&self.inner.placements).get_mut(&cid) {
                        p.host = *tgt_idx;
                        p.remote_id = new_remote;
                        p.migrating = false;
                    }
                    self.inner.migrations.fetch_add(1, Ordering::Relaxed);
                    crate::telemetry::CLUSTER_MIGRATIONS.add(1);
                    return Ok(());
                }
                Err(e) => last_err = format!("{tgt_addr}: {e}"),
            }
        }
        Err(format!("every migration target refused (last: {last_err})"))
    }

    /// Rescue every session placed on a `Down` host from the newest
    /// loadable checkpoint in its `checkpoint_dir`. Sessions without
    /// a loadable snapshot (or with no rescue target) stay pointed at
    /// the dead host — visible as errors on access, retried next
    /// probe pass, and live again if the host returns.
    fn rescue_host(&self, idx: usize) -> (usize, usize) {
        let dir = {
            let hosts = lock(&self.inner.hosts);
            match hosts.get(idx) {
                Some(h) => h.checkpoint_dir.clone(),
                None => return (0, 0),
            }
        };
        let victims: Vec<(u64, String)> = {
            let mut placements = lock(&self.inner.placements);
            placements
                .iter_mut()
                .filter(|(_, p)| p.host == idx && !p.migrating)
                .map(|(id, p)| {
                    p.migrating = true;
                    (*id, p.stem.clone())
                })
                .collect()
        };
        let mut rescued = 0;
        let mut failed = 0;
        for (cid, stem) in victims {
            let outcome = if dir.is_empty() {
                Err("host is down and has no checkpoint_dir configured".into())
            } else if stem.is_empty() {
                Err("no lineage stem recorded for this session".into())
            } else {
                match crate::serve::checkpoint::newest_loadable(&dir, &stem) {
                    Some((_step, path, _ck)) => self.adopt(cid, idx, &stem, &path, None),
                    None => Err(format!("no loadable checkpoint for '{stem}' in {dir}")),
                }
            };
            match outcome {
                Ok(()) => rescued += 1,
                Err(_) => {
                    failed += 1;
                    if let Some(p) = lock(&self.inner.placements).get_mut(&cid) {
                        p.migrating = false;
                    }
                }
            }
        }
        (rescued, failed)
    }

    fn hosts_json(&self) -> Json {
        Json::Arr(
            self.hosts()
                .into_iter()
                .map(|h| {
                    Json::obj(vec![
                        ("addr", Json::Str(h.addr)),
                        ("health", Json::Str(h.health.as_str().into())),
                        ("draining", Json::Bool(h.draining)),
                        ("consecutive_failures", Json::Num(h.consecutive_failures as f64)),
                        ("live", Json::Num(h.live as f64)),
                        ("checkpoint_dir", Json::Str(h.checkpoint_dir)),
                    ])
                })
                .collect(),
        )
    }

    /// Cluster-level `stats`: per-host capacity and throughput fields
    /// summed over every reachable host, every placed session's state
    /// under its *cluster* id, the host registry, and router-side
    /// counters.
    fn stats(&self) -> Result<Fields, String> {
        let stats_req = Json::obj(vec![("cmd", Json::Str("stats".into()))]);
        let timeout = self.request_timeout();
        let addrs: Vec<(usize, String)> = {
            let hosts = lock(&self.inner.hosts);
            hosts.iter().enumerate().map(|(i, h)| (i, h.addr.clone())).collect()
        };
        const SUMMED: &[&str] = &[
            "queue_depth",
            "running",
            "paused",
            "live",
            "admitted",
            "max_sessions",
            "total_lanes",
            "rounds",
            "scheduler_steps",
            "auto_checkpoints",
            "promotions",
            "evicted",
        ];
        let mut sums: BTreeMap<&str, f64> = SUMMED.iter().map(|k| (*k, 0.0)).collect();
        let mut per_host = Vec::new();
        let mut host_sessions: BTreeMap<usize, Vec<Json>> = BTreeMap::new();
        let mut reachable = 0usize;
        for (i, addr) in &addrs {
            match net::request_ok(addr, &stats_req, timeout) {
                Ok(resp) => {
                    reachable += 1;
                    for key in SUMMED {
                        if let Some(v) = resp.get_f64(key) {
                            if let Some(slot) = sums.get_mut(key) {
                                *slot += v;
                            }
                        }
                    }
                    if let Some(sessions) = resp.get("sessions").and_then(|s| s.as_arr()) {
                        host_sessions.insert(*i, sessions.clone());
                    }
                    per_host.push(Json::obj(vec![
                        ("addr", Json::Str(addr.clone())),
                        ("ok", Json::Bool(true)),
                        ("live", Json::Num(resp.get_f64("live").unwrap_or(0.0))),
                        ("running", Json::Num(resp.get_f64("running").unwrap_or(0.0))),
                        (
                            "queue_depth",
                            Json::Num(resp.get_f64("queue_depth").unwrap_or(0.0)),
                        ),
                    ]));
                }
                Err(e) => per_host.push(Json::obj(vec![
                    ("addr", Json::Str(addr.clone())),
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(e)),
                ])),
            }
        }
        // Re-key each placed session's state under its cluster id.
        let placements = lock(&self.inner.placements).clone();
        let mut sessions = Vec::new();
        for (cid, p) in &placements {
            let found = host_sessions.get(&p.host).and_then(|list| {
                list.iter()
                    .find(|s| s.get_f64("id").map(|v| v as u64) == Some(p.remote_id))
            });
            match found {
                Some(state) => {
                    if let Json::Obj(mut m) = state.clone() {
                        m.insert("id".into(), Json::Num(*cid as f64));
                        if let Some(addr) =
                            addrs.iter().find(|(i, _)| *i == p.host).map(|(_, a)| a)
                        {
                            m.insert("host".into(), Json::Str(addr.clone()));
                        }
                        sessions.push(Json::Obj(m));
                    }
                }
                None if p.migrating => sessions.push(migrating_state_json(*cid, p)),
                None => {} // evicted or unreachable host; omit
            }
        }
        let mut out = fields(vec![
            ("hosts_reachable", Json::Num(reachable as f64)),
            ("hosts_total", Json::Num(addrs.len() as f64)),
            ("sessions", Json::Arr(sessions)),
            ("per_host", Json::Arr(per_host)),
            ("hosts", self.hosts_json()),
            (
                "router",
                Json::obj(vec![
                    (
                        "migrations",
                        Json::Num(self.inner.migrations.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "failed_probes",
                        Json::Num(self.inner.failed_probes.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "placements",
                        Json::Num(placements.len() as f64),
                    ),
                ]),
            ),
        ]);
        for key in SUMMED {
            out.insert((*key).to_string(), Json::Num(sums[key]));
        }
        Ok(out)
    }

    /// Cluster-level `metrics`: counters and gauges summed across the
    /// router's own registry and every reachable host (histograms
    /// cannot be merged across processes, so only the router's own
    /// are reported, with each host's full dump under `per_host`).
    fn metrics(&self) -> Result<Fields, String> {
        let mut out: Fields =
            fields(crate::serve::protocol::metrics_fields());
        let metrics_req = Json::obj(vec![("cmd", Json::Str("metrics".into()))]);
        let timeout = self.request_timeout();
        let addrs: Vec<String> = {
            let hosts = lock(&self.inner.hosts);
            hosts.iter().map(|h| h.addr.clone()).collect()
        };
        let mut per_host = Vec::new();
        for addr in &addrs {
            match net::request_ok(addr, &metrics_req, timeout) {
                Ok(resp) => {
                    for section in ["counters", "gauges"] {
                        let (Some(Json::Obj(acc)), Some(Json::Obj(host_vals))) =
                            (out.get_mut(section), resp.get(section))
                        else {
                            continue;
                        };
                        for (name, v) in host_vals {
                            let add = v.as_f64().unwrap_or(0.0);
                            let cur =
                                acc.get(name).and_then(|x| x.as_f64()).unwrap_or(0.0);
                            acc.insert(name.clone(), Json::Num(cur + add));
                        }
                    }
                    let mut m = match resp {
                        Json::Obj(m) => m,
                        _ => BTreeMap::new(),
                    };
                    m.remove("ok");
                    m.insert("addr".into(), Json::Str(addr.clone()));
                    per_host.push(Json::Obj(m));
                }
                Err(e) => per_host.push(Json::obj(vec![
                    ("addr", Json::Str(addr.clone())),
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(e)),
                ])),
            }
        }
        out.insert("per_host".into(), Json::Arr(per_host));
        Ok(out)
    }

    /// Cluster-level `health` (no `session` field): the router's own
    /// aggregate summary, each reachable host's aggregate under
    /// `per_host`, and every host's anomaly flags concatenated (each
    /// stamped with its `host` address) so one request surfaces every
    /// firing rule in the fleet.
    fn health_aggregate(&self) -> Result<Fields, String> {
        use crate::telemetry::health;
        let own = health::with_global(health::summarize);
        let health_req = Json::obj(vec![("cmd", Json::Str("health".into()))]);
        let timeout = self.request_timeout();
        let addrs: Vec<String> = {
            let hosts = lock(&self.inner.hosts);
            hosts.iter().map(|h| h.addr.clone()).collect()
        };
        let mut anomalies: Vec<Json> =
            own.get("anomalies").and_then(|a| a.as_arr()).map(|a| a.to_vec()).unwrap_or_default();
        let mut per_host = Vec::new();
        let mut reachable = 0usize;
        for addr in &addrs {
            match net::request_ok(addr, &health_req, timeout) {
                Ok(resp) => {
                    reachable += 1;
                    let Some(h) = resp.get("health") else { continue };
                    if let Some(list) = h.get("anomalies").and_then(|a| a.as_arr()) {
                        for f in list {
                            let Json::Obj(mut m) = f.clone() else { continue };
                            m.insert("host".into(), Json::Str(addr.clone()));
                            anomalies.push(Json::Obj(m));
                        }
                    }
                    per_host.push(Json::obj(vec![
                        ("addr", Json::Str(addr.clone())),
                        ("health", h.clone()),
                    ]));
                }
                Err(e) => per_host.push(Json::obj(vec![
                    ("addr", Json::Str(addr.clone())),
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(e)),
                ])),
            }
        }
        let merged = Json::obj(vec![
            ("every", own.get("every").cloned().unwrap_or(Json::Null)),
            ("series", own.get("series").cloned().unwrap_or_else(|| Json::obj(vec![]))),
            ("anomalies", Json::Arr(anomalies)),
            ("hosts_reachable", Json::Num(reachable as f64)),
            ("hosts_total", Json::Num(addrs.len() as f64)),
            ("per_host", Json::Arr(per_host)),
        ]);
        Ok(fields(vec![("health", merged)]))
    }
}

/// The synthesized `status` body while a session is mid-migration:
/// enough identity to keep dashboards honest, with a status no host
/// would ever report.
fn migrating_state_json(cid: u64, p: &Placement) -> Json {
    Json::obj(vec![
        ("id", Json::Num(cid as f64)),
        ("status", Json::Str("migrating".into())),
        ("lineage", Json::Str(p.stem.clone())),
    ])
}

/// Lineage stem from a checkpoint file path
/// (`.../<stem>-step<N>.ckpt` → `<stem>`).
fn stem_of_path(path: &str) -> Option<String> {
    std::path::Path::new(path)
        .file_name()
        .and_then(|s| s.to_str())
        .and_then(|f| f.strip_suffix(".ckpt"))
        .and_then(|b| b.rsplit_once("-step"))
        .map(|(stem, _)| stem.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::HostSpec;

    fn cfg(hosts: Vec<&str>) -> ClusterConfig {
        ClusterConfig {
            hosts: hosts
                .into_iter()
                .map(|a| HostSpec { addr: a.into(), checkpoint_dir: String::new() })
                .collect(),
            probe_interval_ms: 0, // manual probing
            probe_timeout_ms: 100,
            request_timeout_ms: 200,
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn stem_of_path_parses_checkpoint_names() {
        assert_eq!(stem_of_path("/ck/job-3-step40.ckpt").as_deref(), Some("job-3"));
        assert_eq!(stem_of_path("rel/a_b-7-step0.ckpt").as_deref(), Some("a_b-7"));
        assert_eq!(stem_of_path("noext"), None);
        assert_eq!(stem_of_path("plain.ckpt"), None);
    }

    #[test]
    fn unknown_commands_and_sessions_error_cleanly() {
        let r = Router::start(cfg(vec![]));
        let resp = r.dispatch(&Json::obj(vec![("cmd", Json::Str("nope".into()))]));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp.get_str("error").unwrap().contains("unknown command"));
        let resp = r.dispatch(&Json::obj(vec![
            ("cmd", Json::Str("status".into())),
            ("session", Json::Num(7.0)),
            ("id", Json::Num(9.0)),
        ]));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("id"), Some(&Json::Num(9.0)), "id echoed on errors");
        // No hosts → no placement possible.
        let resp = r.dispatch(&Json::obj(vec![
            ("cmd", Json::Str("submit".into())),
            ("checkpoint", Json::Str("/nonexistent-step0.ckpt".into())),
        ]));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp.get_str("error").unwrap().contains("no live host"));
        r.shutdown();
    }

    #[test]
    fn probes_walk_up_suspect_down_and_count_failures() {
        // Two dead addresses (bound then released).
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut c = cfg(vec![dead.as_str()]);
        c.probe_fails_down = 2;
        c.auto_migrate = false;
        let r = Router::start(c);
        assert_eq!(r.hosts()[0].health, HostHealth::Up, "optimistic start");
        r.probe_once();
        assert_eq!(r.hosts()[0].health, HostHealth::Suspect);
        r.probe_once();
        assert_eq!(r.hosts()[0].health, HostHealth::Down);
        assert_eq!(r.failed_probes(), 2);
        assert_eq!(r.hosts()[0].consecutive_failures, 2);
        r.shutdown();
    }

    #[test]
    fn drain_requires_a_known_host() {
        let r = Router::start(cfg(vec!["127.0.0.1:1"]));
        assert!(r.drain("127.0.0.1:2").is_err());
        assert!(r.undrain("127.0.0.1:2").is_err());
        r.drain("127.0.0.1:1").unwrap();
        assert!(r.hosts()[0].draining);
        r.undrain("127.0.0.1:1").unwrap();
        assert!(!r.hosts()[0].draining);
        r.shutdown();
    }
}
