//! The router's TCP front door.
//!
//! Same line framing, limits and lifecycle as the per-host
//! [`crate::serve::server`] (one request per line, one response per
//! line, `MAX_LINE_BYTES` cap, non-blocking accept polled against
//! shutdown) — a client cannot tell a router from a single host by
//! its framing, only by the extra commands it answers.
//!
//! The streaming `watch` command is proxied, not forwarded blindly: a
//! relay that just pipes bytes would hang forever when the upstream
//! host dies or the session migrates away mid-stream. The proxy reads
//! the upstream in short slices and re-checks the placement between
//! slices, so every disruption ends the stream with a clean final
//! line the client can act on:
//!
//! * `"status": "migrating"` — the session moved (or is moving) to
//!   another host; re-issue the watch and the router will stream from
//!   its new home. This is the redirect path; an upstream `end` with
//!   `"cancelled"` caused by our own migration-cancel is rewritten to
//!   it so clients never mistake a rebalance for a user cancel.
//! * `"status": "unreachable"` — the host stopped answering and the
//!   session has (so far) nowhere else to be.
//! * `"status": "stopped"` / `"evicted"` — as in the serve layer.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use crate::cluster::net::Conn;
use crate::cluster::router::{HostHealth, Router};
use crate::jsonx::Json;
use crate::serve::server::MAX_LINE_BYTES;

/// How long one relay read waits before re-checking placement,
/// health and shutdown. Step lines normally arrive much faster; this
/// only bounds how stale the proxy's world view can get.
const RELAY_SLICE: Duration = Duration::from_millis(200);

/// A running router listener.
pub struct RouterServer {
    addr: SocketAddr,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl RouterServer {
    /// Bind `addr` (port 0 for ephemeral) and start accepting. Serves
    /// until the router is shut down.
    pub fn start(router: Router, addr: &str) -> std::io::Result<RouterServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let handle = std::thread::Builder::new()
            .name("eva-router-accept".into())
            .spawn(move || accept_loop(listener, router))?;
        Ok(RouterServer { addr: local, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (i.e. until the router is
    /// shut down) and drain connection handlers.
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, router: Router) {
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !router.is_stopped() {
        match listener.accept() {
            Ok((stream, _)) => {
                let router = router.clone();
                if let Ok(h) = std::thread::Builder::new()
                    .name("eva-router-conn".into())
                    .spawn(move || handle_conn(stream, router))
                {
                    handlers.push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => break,
        }
        handlers.retain(|h| !h.is_finished());
    }
    for h in handlers {
        let _ = h.join();
    }
}

fn handle_conn(stream: TcpStream, router: Router) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let write = stream.try_clone();
    let mut reader = BufReader::new(stream);
    let Ok(mut write) = write else { return };
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                if line.trim().is_empty() {
                    line.clear();
                    continue;
                }
                let resp = if line.len() > MAX_LINE_BYTES {
                    Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        (
                            "error",
                            Json::Str(format!("request exceeds {MAX_LINE_BYTES} bytes")),
                        ),
                    ])
                } else {
                    match Json::parse(line.trim()) {
                        Ok(req) if req.get_str("cmd") == Some("watch") => {
                            line.clear();
                            if stream_watch_proxy(&mut write, &router, &req) {
                                continue;
                            }
                            break; // client gone mid-stream
                        }
                        Ok(req) => router.dispatch(&req),
                        Err(e) => Json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", Json::Str(format!("bad request: {e}"))),
                        ]),
                    }
                };
                let oversized = line.len() > MAX_LINE_BYTES;
                line.clear();
                let mut out = resp.dump();
                out.push('\n');
                if write.write_all(out.as_bytes()).is_err() || write.flush().is_err() {
                    break;
                }
                if oversized {
                    break;
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if router.is_stopped() || line.len() > MAX_LINE_BYTES {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Proxy one `watch` as a step-event stream, ending cleanly on every
/// disruption (see module docs). Returns `true` when the connection
/// is still usable for further requests, `false` when the client
/// vanished mid-stream.
fn stream_watch_proxy(write: &mut TcpStream, router: &Router, req: &Json) -> bool {
    let echo_id = req.get("id").cloned();
    let send = |write: &mut TcpStream, mut pairs: Vec<(&'static str, Json)>| -> bool {
        if let Some(id) = &echo_id {
            pairs.push(("id", id.clone()));
        }
        let mut out = Json::obj(pairs).dump();
        out.push('\n');
        write.write_all(out.as_bytes()).is_ok() && write.flush().is_ok()
    };
    let fail = |write: &mut TcpStream, e: String| -> bool {
        send(write, vec![("ok", Json::Bool(false)), ("error", Json::Str(e))])
    };
    let end = |write: &mut TcpStream, status: &str| -> bool {
        send(
            write,
            vec![
                ("ok", Json::Bool(true)),
                ("event", Json::Str("end".into())),
                ("status", Json::Str(status.into())),
            ],
        )
    };
    let Some(cid) = req.get_f64("session").map(|v| v as u64) else {
        return fail(write, "missing 'session' id".into());
    };
    let Some(p) = router.placement(cid) else {
        return fail(write, format!("unknown session {cid}"));
    };
    let timeout = Duration::from_millis(router.config().request_timeout_ms);
    // Mid-migration at watch start: ack + immediate redirect, so a
    // retrying client needs no special first-line handling.
    if p.migrating {
        if !send(
            write,
            vec![
                ("ok", Json::Bool(true)),
                ("event", Json::Str("watching".into())),
                ("session", Json::Num(cid as f64)),
            ],
        ) {
            return false;
        }
        return end(write, "migrating");
    }
    let Some(addr) = router.host_addr(p.host) else {
        return fail(write, format!("session {cid}: host index {} gone", p.host));
    };
    let mut upstream = match Conn::connect(&addr, timeout) {
        Ok(c) => c,
        Err(e) => return fail(write, format!("host {addr}: {e}")),
    };
    let upstream_req = Json::obj(vec![
        ("cmd", Json::Str("watch".into())),
        ("session", Json::Num(p.remote_id as f64)),
    ]);
    let ack = match upstream.request(&upstream_req) {
        Ok(a) => a,
        Err(e) => return fail(write, format!("host {addr}: {e}")),
    };
    if ack.get("ok") != Some(&Json::Bool(true)) {
        return fail(write, ack.get_str("error").unwrap_or("watch failed").to_string());
    }
    if !send(
        write,
        vec![
            ("ok", Json::Bool(true)),
            ("event", Json::Str("watching".into())),
            ("session", Json::Num(cid as f64)),
        ],
    ) {
        return false;
    }
    // `moved` = the placement no longer points where this stream
    // reads from — the session migrated (or is migrating) away.
    let moved = |router: &Router| -> bool {
        router
            .placement(cid)
            .map(|q| q.migrating || q.host != p.host)
            .unwrap_or(false)
    };
    loop {
        match upstream.recv_deadline(Instant::now() + RELAY_SLICE) {
            Ok(line_obj) => {
                if line_obj.get_str("event") == Some("end") {
                    // Our own migration cancels the source copy; its
                    // stream then ends "cancelled". Report the truth.
                    if line_obj.get_str("status") == Some("cancelled") && moved(router) {
                        return end(write, "migrating");
                    }
                    let mut out = line_obj.dump();
                    if let Some(id) = &echo_id {
                        if let Json::Obj(mut m) = line_obj {
                            m.insert("id".into(), id.clone());
                            out = Json::Obj(m).dump();
                        }
                    }
                    out.push('\n');
                    return write.write_all(out.as_bytes()).is_ok() && write.flush().is_ok();
                }
                // Step line (or future event kind): relay verbatim.
                let mut out = line_obj.dump();
                out.push('\n');
                if write.write_all(out.as_bytes()).is_err() || write.flush().is_err() {
                    return false; // client gone; upstream stream ends with us
                }
            }
            Err(e) if e.contains("timed out") => {
                if router.placement(cid).is_none() {
                    return end(write, "evicted");
                }
                if moved(router) {
                    return end(write, "migrating");
                }
                if router.is_stopped() {
                    return end(write, "stopped");
                }
                // A wedged upstream must not pin this thread forever:
                // once the prober has declared the host down, give up.
                let down = router
                    .hosts()
                    .get(p.host)
                    .map(|h| h.health == HostHealth::Down)
                    .unwrap_or(true);
                if down {
                    return end(write, "unreachable");
                }
            }
            Err(_) => {
                // Upstream closed or broke mid-stream.
                return end(write, if moved(router) { "migrating" } else { "unreachable" });
            }
        }
    }
}
