//! Bounded time-series rings for optimizer-health samples.
//!
//! A [`Ring`] is a fixed-capacity sequence of `(step, value)` points
//! under one dotted metric name; pushing past capacity drops the
//! oldest point. A [`SeriesStore`] owns a bounded set of rings keyed
//! by name — the per-session and service-aggregate containers the
//! health layer records into. Everything here is plain data: no
//! atomics, no clocks, no numerics impact.

use std::collections::{BTreeMap, VecDeque};

use crate::jsonx::Json;

/// Default per-ring point capacity.
pub const DEFAULT_RING_CAP: usize = 256;

/// Upper bound on distinct series names one store will hold; records
/// against new names beyond this are ignored (existing rings keep
/// updating), so a misbehaving producer cannot grow memory without
/// bound.
pub const MAX_SERIES: usize = 512;

/// A fixed-capacity `(step, value)` ring; push drops the oldest point.
#[derive(Clone, Debug)]
pub struct Ring {
    cap: usize,
    data: VecDeque<(u64, f64)>,
}

impl Ring {
    /// An empty ring holding at most `cap` points (`cap` ≥ 1).
    pub fn new(cap: usize) -> Self {
        Ring { cap: cap.max(1), data: VecDeque::new() }
    }

    /// Append a point, dropping the oldest when full.
    pub fn push(&mut self, step: u64, value: f64) {
        if self.data.len() == self.cap {
            self.data.pop_front();
        }
        self.data.push_back((step, value));
    }

    /// Number of points currently held.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when no points have been recorded.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Point capacity.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// The newest point, if any.
    pub fn last(&self) -> Option<(u64, f64)> {
        self.data.back().copied()
    }

    /// Iterate oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        self.data.iter().copied()
    }

    /// Minimum stored value (NaN-tolerant: NaN never wins), 0 if empty.
    pub fn min(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&(_, v)| v).fold(f64::INFINITY, f64::min)
    }

    /// Maximum stored value (NaN-tolerant), 0 if empty.
    pub fn max(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max)
    }

    /// Mean of the stored values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&(_, v)| v).sum::<f64>() / self.data.len() as f64
    }

    /// Population standard deviation of the stored values (0 when
    /// fewer than two points).
    pub fn stddev(&self) -> f64 {
        if self.data.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var =
            self.data.iter().map(|&(_, v)| (v - m) * (v - m)).sum::<f64>() / self.data.len() as f64;
        var.sqrt()
    }

    /// Compact JSON summary: `{n, last_step, last, min, mean, max}`.
    /// Non-finite values serialize as `null` (jsonx contract), so the
    /// anomaly layer carries non-finiteness as explicit flags instead.
    pub fn summary(&self) -> Json {
        match self.last() {
            None => Json::obj(vec![("n", Json::Num(0.0))]),
            Some((step, value)) => Json::obj(vec![
                ("n", Json::Num(self.len() as f64)),
                ("last_step", Json::Num(step as f64)),
                ("last", Json::Num(value)),
                ("min", Json::Num(self.min())),
                ("mean", Json::Num(self.mean())),
                ("max", Json::Num(self.max())),
            ]),
        }
    }
}

/// A bounded map of metric name → [`Ring`].
#[derive(Clone, Debug)]
pub struct SeriesStore {
    ring_cap: usize,
    rings: BTreeMap<String, Ring>,
}

impl Default for SeriesStore {
    fn default() -> Self {
        Self::new()
    }
}

impl SeriesStore {
    /// An empty store whose rings hold [`DEFAULT_RING_CAP`] points.
    pub fn new() -> Self {
        Self::with_ring_cap(DEFAULT_RING_CAP)
    }

    /// An empty store with an explicit per-ring capacity.
    pub fn with_ring_cap(ring_cap: usize) -> Self {
        SeriesStore { ring_cap: ring_cap.max(1), rings: BTreeMap::new() }
    }

    /// Record one point. New names past [`MAX_SERIES`] are dropped.
    pub fn record(&mut self, name: &str, step: u64, value: f64) {
        if let Some(r) = self.rings.get_mut(name) {
            r.push(step, value);
            return;
        }
        if self.rings.len() >= MAX_SERIES {
            return;
        }
        let mut r = Ring::new(self.ring_cap);
        r.push(step, value);
        self.rings.insert(name.to_string(), r);
    }

    /// Look up a ring by exact name.
    pub fn get(&self, name: &str) -> Option<&Ring> {
        self.rings.get(name)
    }

    /// Number of distinct series.
    pub fn len(&self) -> usize {
        self.rings.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.rings.is_empty()
    }

    /// Iterate `(name, ring)` in sorted-name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Ring)> {
        self.rings.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Drop every ring.
    pub fn clear(&mut self) {
        self.rings.clear();
    }

    /// JSON summary object: name → [`Ring::summary`].
    pub fn to_json(&self) -> Json {
        Json::Obj(self.rings.iter().map(|(k, r)| (k.clone(), r.summary())).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest_at_capacity() {
        let mut r = Ring::new(3);
        for s in 0..5u64 {
            r.push(s, s as f64);
        }
        assert_eq!(r.len(), 3);
        let pts: Vec<_> = r.iter().collect();
        assert_eq!(pts, vec![(2, 2.0), (3, 3.0), (4, 4.0)]);
        assert_eq!(r.last(), Some((4, 4.0)));
    }

    #[test]
    fn ring_preserves_step_ordering() {
        let mut r = Ring::new(8);
        for s in [10u64, 20, 30, 40] {
            r.push(s, 1.0);
        }
        let steps: Vec<u64> = r.iter().map(|(s, _)| s).collect();
        let mut sorted = steps.clone();
        sorted.sort_unstable();
        assert_eq!(steps, sorted, "points must stay in insertion (step) order");
    }

    #[test]
    fn ring_stats() {
        let mut r = Ring::new(8);
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.push(0, v);
        }
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 4.0);
        assert!((r.mean() - 2.5).abs() < 1e-12);
        assert!((r.stddev() - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn store_records_and_bounds_series_count() {
        let mut s = SeriesStore::with_ring_cap(4);
        for i in 0..(MAX_SERIES + 10) {
            s.record(&format!("m.{i:04}"), 1, i as f64);
        }
        assert_eq!(s.len(), MAX_SERIES, "store must cap distinct series");
        // Existing rings keep updating past the cap.
        s.record("m.0000", 2, 99.0);
        assert_eq!(s.get("m.0000").unwrap().last(), Some((2, 99.0)));
        // Unknown-over-cap names are dropped silently.
        assert!(s.get(&format!("m.{:04}", MAX_SERIES + 5)).is_none());
    }

    #[test]
    fn store_summary_shape() {
        let mut s = SeriesStore::new();
        s.record("a.b", 7, 1.5);
        let j = s.to_json();
        let ring = j.get("a.b").expect("series present");
        assert_eq!(ring.get_f64("n"), Some(1.0));
        assert_eq!(ring.get_f64("last_step"), Some(7.0));
        assert_eq!(ring.get_f64("last"), Some(1.5));
    }
}
