//! Process-global telemetry: counters, gauges, histograms, and
//! per-step tracing spans.
//!
//! A std-only, dependency-free observability layer. Three pieces:
//!
//! * **Registry** — a fixed catalog of process-global [`Counter`]s,
//!   [`Gauge`]s and fixed-bucket [`Histogram`]s with hierarchical
//!   dotted names (`simd.dot8.calls`, `train.step_us`,
//!   `serve.sched.quantum_us`). Recording is a relaxed atomic add, so
//!   an instrumented hot path costs ~one atomic add when telemetry is
//!   enabled and a single branch on a cached flag when disabled.
//! * **Spans** — [`time_phase`] wraps a code region, records its wall
//!   time into a histogram *and* into a thread-local per-step phase
//!   list that [`take_step_phases`] drains; the serve layer attaches
//!   the drained breakdown to streaming `watch` events.
//! * **Export** — [`counters`]/[`gauges`]/[`histograms`] enumerate the
//!   catalog for the `metrics` protocol command and the bench-snapshot
//!   harness; [`render_text`] is the human-readable dump `eva serve`
//!   prints at shutdown.
//!
//! **Numerics are never touched.** Instrumentation only ever reads
//! clocks and bumps atomics — the determinism contract
//! (`docs/KERNELS.md`) is unaffected, and the simd/backend/serve
//! parity tests pass with telemetry enabled and disabled
//! (`rust/tests/telemetry.rs`). Counter values themselves are *not*
//! deterministic (they depend on scheduling, chunk gates and host
//! ISA) and live explicitly outside that contract.
//!
//! **Selection.** Telemetry defaults to **on**; disable with the CLI
//! flag `--telemetry off`, the config key `"telemetry"`, the
//! `EVA_TELEMETRY` environment variable, or [`install`] — the same
//! resolution surfaces as `--simd`. A misspelled `EVA_TELEMETRY`
//! value is a hard error at first use, never a silent default.

#![warn(missing_docs)]

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::time::Instant;

pub mod export;
pub mod health;
pub mod series;

// ---------------------------------------------------------------------------
// The enabled/disabled knob (threaded like --simd)
// ---------------------------------------------------------------------------

/// Parsed `--telemetry` / `"telemetry"` selection (config/CLI layer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TelemetryChoice {
    /// Record metrics and spans (the default).
    On,
    /// Compile the instrumentation down to a branch on a cached flag.
    Off,
}

impl TelemetryChoice {
    /// Parse `on | off`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "on" => Ok(TelemetryChoice::On),
            "off" => Ok(TelemetryChoice::Off),
            other => Err(format!("unknown telemetry mode '{other}' (use on | off)")),
        }
    }

    /// Canonical config-string (inverse of [`TelemetryChoice::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            TelemetryChoice::On => "on",
            TelemetryChoice::Off => "off",
        }
    }

    fn is_on(self) -> bool {
        matches!(self, TelemetryChoice::On)
    }
}

/// `u8::MAX` = not yet resolved; first read resolves the boot default.
const UNSET: u8 = u8::MAX;

static STATE: AtomicU8 = AtomicU8::new(UNSET);

/// Whether telemetry is recording. Resolved lazily on first use: the
/// `EVA_TELEMETRY` environment variable if set (`on`/`off`, anything
/// else is a hard panic — never a silent default), otherwise **on**;
/// [`install`] overrides it at any time. One relaxed atomic load on
/// the hot path.
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        0 => false,
        _ => boot_default(),
    }
}

#[cold]
fn boot_default() -> bool {
    let on = match std::env::var("EVA_TELEMETRY") {
        Ok(v) => match TelemetryChoice::parse(&v) {
            Ok(choice) => choice.is_on(),
            Err(e) => panic!("EVA_TELEMETRY: {e}"),
        },
        Err(_) => true,
    };
    // First resolution wins, but never clobber a concurrent install().
    let _ = STATE.compare_exchange(UNSET, on as u8, Ordering::Relaxed, Ordering::Relaxed);
    STATE.load(Ordering::Relaxed) == 1
}

/// Make `choice` the process-wide telemetry mode; returns the
/// resolved enabled flag. Because telemetry never touches numerics,
/// this is a pure observability control — switching it never changes
/// a training run (enforced by `rust/tests/telemetry.rs`).
pub fn install(choice: &TelemetryChoice) -> bool {
    STATE.store(choice.is_on() as u8, Ordering::Relaxed);
    choice.is_on()
}

// ---------------------------------------------------------------------------
// Metric primitives
// ---------------------------------------------------------------------------

/// A monotonically increasing process-global counter.
pub struct Counter {
    name: &'static str,
    v: AtomicU64,
}

impl Counter {
    /// A new zeroed counter (const — counters are statics).
    pub const fn new(name: &'static str) -> Self {
        Counter { name, v: AtomicU64::new(0) }
    }

    /// Add `n` (one relaxed atomic add; a branch when disabled).
    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// The dotted metric name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// A process-global last-value gauge.
pub struct Gauge {
    name: &'static str,
    v: AtomicU64,
}

impl Gauge {
    /// A new zeroed gauge (const — gauges are statics).
    pub const fn new(name: &'static str) -> Self {
        Gauge { name, v: AtomicU64::new(0) }
    }

    /// Set the current value (one relaxed store; a branch when disabled).
    #[inline]
    pub fn set(&self, v: u64) {
        if enabled() {
            self.v.store(v, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// The dotted metric name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// Log-linear bucket count: values `< 16` µs get exact buckets, then
/// 8 sub-buckets per power of two up to `2^32` µs (~71 min); larger
/// samples clamp into the last bucket. Relative quantization error is
/// bounded by one sub-bucket width (≤ ~6%).
const NBUCKETS: usize = 16 + 8 * 28;

/// A fixed-bucket latency histogram over microsecond samples.
///
/// Recording is wait-free (three relaxed atomic adds); readers compute
/// the exact `count`/mean and *approximate* percentiles from the
/// log-linear bucket grid — approximation error is bounded by the
/// sub-bucket width, ≤ ~6% of the value.
pub struct Histogram {
    name: &'static str,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
    buckets: [AtomicU64; NBUCKETS],
}

fn bucket_index(us: u64) -> usize {
    if us < 16 {
        return us as usize;
    }
    let m = 63 - us.leading_zeros() as u64; // ≥ 4
    let sub = (us >> (m - 3)) & 7;
    let idx = 16 + ((m - 4) * 8 + sub) as usize;
    idx.min(NBUCKETS - 1)
}

/// Representative (midpoint) microsecond value of a bucket.
fn bucket_value_us(idx: usize) -> f64 {
    if idx < 16 {
        return idx as f64;
    }
    let rel = (idx - 16) as u64;
    let m = rel / 8 + 4;
    let sub = rel % 8;
    let width = 1u64 << (m - 3);
    ((1u64 << m) + sub * width) as f64 + width as f64 / 2.0
}

impl Histogram {
    /// A new empty histogram (const — histograms are statics).
    pub const fn new(name: &'static str) -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Histogram {
            name,
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
            buckets: [Z; NBUCKETS],
        }
    }

    /// Record one microsecond sample (three relaxed adds; a branch
    /// when disabled).
    #[inline]
    pub fn record_us(&self, us: u64) {
        if !enabled() {
            return;
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Exact number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact mean in milliseconds (0 when empty).
    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64 / 1000.0
    }

    /// Approximate p-th percentile in milliseconds (p in [0, 100];
    /// 0 when empty). Bucket-grid resolution: ≤ ~6% relative error.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_value_us(i) / 1000.0;
            }
        }
        bucket_value_us(NBUCKETS - 1) / 1000.0
    }

    /// Exact maximum recorded sample in milliseconds (0 when empty).
    pub fn max_ms(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// The dotted metric name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
        self.max_us.store(0, Ordering::Relaxed);
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------------------------
// Per-step tracing spans
// ---------------------------------------------------------------------------

thread_local! {
    static STEP_PHASES: RefCell<Vec<(&'static str, u64)>> = const { RefCell::new(Vec::new()) };
}

/// Mark the start of a training step on this thread: clears the
/// thread-local phase list so [`take_step_phases`] only ever sees the
/// current step's spans, and the thread-local health-sample buffer so
/// an undrained step never leaks stale probes into the next. Called
/// by `train::LoopState::step_once`.
pub fn begin_step() {
    STEP_PHASES.with(|p| p.borrow_mut().clear());
    health::clear_thread();
}

/// Time a phase of the current step: runs `f`, records its wall time
/// into `hist` and into the thread-local phase list under `label`.
/// When telemetry is disabled this is a single branch around `f` —
/// no clock reads.
#[inline]
pub fn time_phase<R>(label: &'static str, hist: &'static Histogram, f: impl FnOnce() -> R) -> R {
    if !enabled() {
        return f();
    }
    let t0 = Instant::now();
    let out = f();
    let us = t0.elapsed().as_micros() as u64;
    hist.record_us(us);
    STEP_PHASES.with(|p| p.borrow_mut().push((label, us)));
    out
}

/// Drain this thread's per-step phase spans, merging duplicate labels
/// (sum) in first-seen order. The serve session loop calls this right
/// after `step_once` — same thread — to build streaming `watch`
/// events; draining also bounds the list between steps.
pub fn take_step_phases() -> Vec<(&'static str, u64)> {
    let raw = STEP_PHASES.with(|p| std::mem::take(&mut *p.borrow_mut()));
    let mut merged: Vec<(&'static str, u64)> = Vec::with_capacity(raw.len());
    for (label, us) in raw {
        match merged.iter_mut().find(|(l, _)| *l == label) {
            Some((_, total)) => *total += us,
            None => merged.push((label, us)),
        }
    }
    merged
}

// ---------------------------------------------------------------------------
// Metric catalog
// ---------------------------------------------------------------------------
// The full name catalog is documented in docs/ARCHITECTURE.md
// ("Telemetry"). Counters count kernel dispatches and their FLOP
// estimates; histograms hold span wall times in microseconds.

/// `simd.dot8` dispatches.
pub static SIMD_DOT8_CALLS: Counter = Counter::new("simd.dot8.calls");
/// FLOPs through `simd.dot8` (2n per call).
pub static SIMD_DOT8_FLOPS: Counter = Counter::new("simd.dot8.flops");
/// `simd.axpy8` dispatches.
pub static SIMD_AXPY8_CALLS: Counter = Counter::new("simd.axpy8.calls");
/// FLOPs through `simd.axpy8` (2n per call).
pub static SIMD_AXPY8_FLOPS: Counter = Counter::new("simd.axpy8.flops");
/// `simd.scale8` dispatches.
pub static SIMD_SCALE8_CALLS: Counter = Counter::new("simd.scale8.calls");
/// FLOPs through `simd.scale8` (n per call).
pub static SIMD_SCALE8_FLOPS: Counter = Counter::new("simd.scale8.flops");
/// `simd.blend8` dispatches.
pub static SIMD_BLEND8_CALLS: Counter = Counter::new("simd.blend8.calls");
/// FLOPs through `simd.blend8` (3n per call).
pub static SIMD_BLEND8_FLOPS: Counter = Counter::new("simd.blend8.flops");
/// `simd.row_mac8` dispatches (one per matmul output row).
pub static SIMD_ROW_MAC8_CALLS: Counter = Counter::new("simd.row_mac8.calls");
/// FLOPs through `simd.row_mac8` (2·k·n per call).
pub static SIMD_ROW_MAC8_FLOPS: Counter = Counter::new("simd.row_mac8.flops");
/// `simd.row_dots8` dispatches (one per matmul_a_bt output row).
pub static SIMD_ROW_DOTS8_CALLS: Counter = Counter::new("simd.row_dots8.calls");
/// FLOPs through `simd.row_dots8` (2·k·n per call).
pub static SIMD_ROW_DOTS8_FLOPS: Counter = Counter::new("simd.row_dots8.flops");
/// `tensor::matmul` products.
pub static TENSOR_MATMUL_CALLS: Counter = Counter::new("tensor.matmul.calls");
/// FLOPs through `tensor::matmul` (2mnk per product).
pub static TENSOR_MATMUL_FLOPS: Counter = Counter::new("tensor.matmul.flops");
/// `tensor::matmul_at_b` products.
pub static TENSOR_MATMUL_AT_B_CALLS: Counter = Counter::new("tensor.matmul_at_b.calls");
/// FLOPs through `tensor::matmul_at_b` (2mnk per product).
pub static TENSOR_MATMUL_AT_B_FLOPS: Counter = Counter::new("tensor.matmul_at_b.flops");
/// `tensor::matmul_a_bt` products.
pub static TENSOR_MATMUL_A_BT_CALLS: Counter = Counter::new("tensor.matmul_a_bt.calls");
/// FLOPs through `tensor::matmul_a_bt` (2mnk per product).
pub static TENSOR_MATMUL_A_BT_FLOPS: Counter = Counter::new("tensor.matmul_a_bt.flops");
/// `Tensor::tmatvec` products.
pub static TENSOR_TMATVEC_CALLS: Counter = Counter::new("tensor.tmatvec.calls");
/// FLOPs through `Tensor::tmatvec` (2·rows·cols per product).
pub static TENSOR_TMATVEC_FLOPS: Counter = Counter::new("tensor.tmatvec.flops");
/// Optimizer steps completed (any engine, any optimizer).
pub static TRAIN_STEPS: Counter = Counter::new("train.steps");
/// Auto + explicit checkpoints written by the serve layer.
pub static SERVE_CHECKPOINTS: Counter = Counter::new("serve.checkpoints");
/// Stale lineage snapshots deleted by `--retain-snapshots` pruning.
pub static SERVE_CKPT_PRUNED: Counter = Counter::new("serve.ckpt.pruned");
/// Checkpoint-migrations completed by the cluster router (a session
/// moved from one backend host to another).
pub static CLUSTER_MIGRATIONS: Counter = Counter::new("cluster.migrations");
/// Health probes that failed (timeout, refused connection, or a bad
/// response) — each tick counts once per unreachable host.
pub static CLUSTER_PROBE_FAILURES: Counter = Counter::new("cluster.probe.failures");

/// Admitted (live) serve sessions, sampled each scheduler round.
pub static SERVE_SESSIONS_ADMITTED: Gauge = Gauge::new("serve.sessions.admitted");
/// Waiting (queued, unadmitted) serve sessions, sampled each round.
pub static SERVE_QUEUE_DEPTH: Gauge = Gauge::new("serve.queue.depth");
/// Backend hosts the cluster router currently considers up (probed
/// healthy and not yet marked down).
pub static CLUSTER_HOSTS_UP: Gauge = Gauge::new("cluster.hosts.up");

/// Whole optimizer step (`LoopState::step_once`), data to apply.
pub static TRAIN_STEP_US: Histogram = Histogram::new("train.step_us");
/// Batch index + gather phase of a step.
pub static TRAIN_DATA_US: Histogram = Histogram::new("train.data_us");
/// Model forward+backward phase of a step.
pub static TRAIN_FORWARD_BACKWARD_US: Histogram = Histogram::new("train.forward_backward_us");
/// `Optimizer::step` phase of a step (all optimizer-internal spans
/// nest inside this one).
pub static TRAIN_OPTIMIZER_US: Histogram = Histogram::new("train.optimizer_us");
/// Weight-delta application phase of a step.
pub static TRAIN_APPLY_US: Histogram = Histogram::new("train.apply_us");
/// Validation pass on epoch-close steps.
pub static TRAIN_EVAL_US: Histogram = Histogram::new("train.eval_us");
/// Eva KV running-average refresh (Eq. 14–15).
pub static OPTIM_EVA_KV_REFRESH_US: Histogram = Histogram::new("optim.eva.kv_refresh_us");
/// Eva Sherman–Morrison preconditioning sweep (Eq. 13).
pub static OPTIM_EVA_PRECONDITION_US: Histogram = Histogram::new("optim.eva.precondition_us");
/// Eva KL clip + momentum apply (Eq. 16).
pub static OPTIM_EVA_APPLY_US: Histogram = Histogram::new("optim.eva.apply_us");
/// K-FAC factor blend + damped inverse refresh (Eq. 4–5).
pub static OPTIM_KFAC_REFRESH_US: Histogram = Histogram::new("optim.kfac.refresh_us");
/// K-FAC `Q⁻¹ G R⁻¹` preconditioning products (Eq. 5).
pub static OPTIM_KFAC_PRECONDITION_US: Histogram = Histogram::new("optim.kfac.precondition_us");
/// K-FAC KL clip + momentum apply.
pub static OPTIM_KFAC_APPLY_US: Histogram = Histogram::new("optim.kfac.apply_us");
/// Shampoo `M₁ += GGᵀ`, `M₂ += GᵀG` statistics accumulation (Eq. 8).
pub static OPTIM_SHAMPOO_ACCUMULATE_US: Histogram = Histogram::new("optim.shampoo.accumulate_us");
/// Shampoo inverse-fourth-root refresh (`spd_power` per tile).
pub static OPTIM_SHAMPOO_REFRESH_US: Histogram = Histogram::new("optim.shampoo.refresh_us");
/// Shampoo per-tile preconditioning products.
pub static OPTIM_SHAMPOO_PRECONDITION_US: Histogram =
    Histogram::new("optim.shampoo.precondition_us");
/// Shampoo grafting + momentum apply.
pub static OPTIM_SHAMPOO_APPLY_US: Histogram = Histogram::new("optim.shampoo.apply_us");
/// MKOR rank-1 Sherman–Morrison inverse-factor updates.
pub static OPTIM_MKOR_FACTOR_UPDATE_US: Histogram =
    Histogram::new("optim.mkor.factor_update_us");
/// MKOR `B⁻¹ G A⁻¹` preconditioning products.
pub static OPTIM_MKOR_PRECONDITION_US: Histogram = Histogram::new("optim.mkor.precondition_us");
/// MKOR KL clip + momentum apply.
pub static OPTIM_MKOR_APPLY_US: Histogram = Histogram::new("optim.mkor.apply_us");
/// KrADagrad per-step rank-1 inverse downdates.
pub static OPTIM_KRADAGRAD_ACCUMULATE_US: Histogram =
    Histogram::new("optim.kradagrad.accumulate_us");
/// KrADagrad cached-root refresh (`spd_power` of the maintained inverses).
pub static OPTIM_KRADAGRAD_REFRESH_US: Histogram =
    Histogram::new("optim.kradagrad.refresh_us");
/// KrADagrad `(L⁻¹)^½ G (R⁻¹)^½` preconditioning products.
pub static OPTIM_KRADAGRAD_PRECONDITION_US: Histogram =
    Histogram::new("optim.kradagrad.precondition_us");
/// KrADagrad grafting + momentum apply.
pub static OPTIM_KRADAGRAD_APPLY_US: Histogram = Histogram::new("optim.kradagrad.apply_us");
/// Scheduler lane re-carves (`split_weighted` + sub-pool build).
pub static SERVE_SCHED_CARVE_US: Histogram = Histogram::new("serve.sched.carve_us");
/// One scheduler round's fan-out: every runnable session's quantum.
pub static SERVE_SCHED_QUANTUM_US: Histogram = Histogram::new("serve.sched.quantum_us");
/// One checkpoint capture + atomic write (auto or explicit).
pub static SERVE_SCHED_CHECKPOINT_IO_US: Histogram =
    Histogram::new("serve.sched.checkpoint_io_us");

/// Every registered counter, catalog order.
pub fn counters() -> &'static [&'static Counter] {
    &[
        &SIMD_DOT8_CALLS,
        &SIMD_DOT8_FLOPS,
        &SIMD_AXPY8_CALLS,
        &SIMD_AXPY8_FLOPS,
        &SIMD_SCALE8_CALLS,
        &SIMD_SCALE8_FLOPS,
        &SIMD_BLEND8_CALLS,
        &SIMD_BLEND8_FLOPS,
        &SIMD_ROW_MAC8_CALLS,
        &SIMD_ROW_MAC8_FLOPS,
        &SIMD_ROW_DOTS8_CALLS,
        &SIMD_ROW_DOTS8_FLOPS,
        &TENSOR_MATMUL_CALLS,
        &TENSOR_MATMUL_FLOPS,
        &TENSOR_MATMUL_AT_B_CALLS,
        &TENSOR_MATMUL_AT_B_FLOPS,
        &TENSOR_MATMUL_A_BT_CALLS,
        &TENSOR_MATMUL_A_BT_FLOPS,
        &TENSOR_TMATVEC_CALLS,
        &TENSOR_TMATVEC_FLOPS,
        &TRAIN_STEPS,
        &SERVE_CHECKPOINTS,
        &SERVE_CKPT_PRUNED,
        &CLUSTER_MIGRATIONS,
        &CLUSTER_PROBE_FAILURES,
    ]
}

/// Every registered gauge, catalog order.
pub fn gauges() -> &'static [&'static Gauge] {
    &[&SERVE_SESSIONS_ADMITTED, &SERVE_QUEUE_DEPTH, &CLUSTER_HOSTS_UP]
}

/// Every registered histogram, catalog order.
pub fn histograms() -> &'static [&'static Histogram] {
    &[
        &TRAIN_STEP_US,
        &TRAIN_DATA_US,
        &TRAIN_FORWARD_BACKWARD_US,
        &TRAIN_OPTIMIZER_US,
        &TRAIN_APPLY_US,
        &TRAIN_EVAL_US,
        &OPTIM_EVA_KV_REFRESH_US,
        &OPTIM_EVA_PRECONDITION_US,
        &OPTIM_EVA_APPLY_US,
        &OPTIM_KFAC_REFRESH_US,
        &OPTIM_KFAC_PRECONDITION_US,
        &OPTIM_KFAC_APPLY_US,
        &OPTIM_SHAMPOO_ACCUMULATE_US,
        &OPTIM_SHAMPOO_REFRESH_US,
        &OPTIM_SHAMPOO_PRECONDITION_US,
        &OPTIM_SHAMPOO_APPLY_US,
        &OPTIM_MKOR_FACTOR_UPDATE_US,
        &OPTIM_MKOR_PRECONDITION_US,
        &OPTIM_MKOR_APPLY_US,
        &OPTIM_KRADAGRAD_ACCUMULATE_US,
        &OPTIM_KRADAGRAD_REFRESH_US,
        &OPTIM_KRADAGRAD_PRECONDITION_US,
        &OPTIM_KRADAGRAD_APPLY_US,
        &SERVE_SCHED_CARVE_US,
        &SERVE_SCHED_QUANTUM_US,
        &SERVE_SCHED_CHECKPOINT_IO_US,
    ]
}

/// Zero every registered metric. For benches and tests that want a
/// clean window (e.g. per-optimizer phase profiles); the registry is
/// process-global, so concurrent recorders will keep writing.
pub fn reset_all() {
    for c in counters() {
        c.reset();
    }
    for g in gauges() {
        g.reset();
    }
    for h in histograms() {
        h.reset();
    }
}

/// Human-readable registry dump (non-zero metrics only) — what
/// `eva serve` prints at shutdown.
pub fn render_text() -> String {
    let mut out = String::new();
    out.push_str(&format!("telemetry: {}\n", if enabled() { "on" } else { "off" }));
    for c in counters() {
        if c.get() > 0 {
            out.push_str(&format!("  {:<34} {}\n", c.name(), c.get()));
        }
    }
    for g in gauges() {
        if g.get() > 0 {
            out.push_str(&format!("  {:<34} {}\n", g.name(), g.get()));
        }
    }
    for h in histograms() {
        if h.count() > 0 {
            out.push_str(&format!(
                "  {:<34} n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms\n",
                h.name(),
                h.count(),
                h.mean_ms(),
                h.percentile_ms(50.0),
                h.percentile_ms(95.0),
                h.percentile_ms(99.0),
                h.max_ms()
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parses_and_labels() {
        assert_eq!(TelemetryChoice::parse("on").unwrap(), TelemetryChoice::On);
        assert_eq!(TelemetryChoice::parse("off").unwrap(), TelemetryChoice::Off);
        assert_eq!(TelemetryChoice::parse("on").unwrap().label(), "on");
        assert_eq!(TelemetryChoice::parse("off").unwrap().label(), "off");
        assert!(TelemetryChoice::parse("maybe").is_err());
    }

    #[test]
    fn counter_respects_the_knob() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = enabled();
        static C: Counter = Counter::new("test.knob.counter");
        install(&TelemetryChoice::On);
        C.add(3);
        assert_eq!(C.get(), 3);
        install(&TelemetryChoice::Off);
        C.add(5);
        assert_eq!(C.get(), 3, "disabled counter must not move");
        install(if prev { &TelemetryChoice::On } else { &TelemetryChoice::Off });
    }

    #[test]
    fn bucket_grid_is_monotonic_and_tight() {
        let mut last = 0usize;
        for us in [0u64, 1, 7, 15, 16, 17, 100, 1000, 65_536, 1 << 25, u64::MAX] {
            let idx = bucket_index(us);
            assert!(idx >= last || us < 16, "bucket index regressed at {us}");
            last = idx.max(last);
            if us >= 16 && idx < NBUCKETS - 1 {
                let rep = bucket_value_us(idx);
                let rel = (rep - us as f64).abs() / us as f64;
                assert!(rel < 0.07, "bucket rep {rep} too far from {us}");
            }
        }
        assert_eq!(bucket_index(u64::MAX), NBUCKETS - 1);
    }

    #[test]
    fn histogram_stats_and_percentile_bounds() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = enabled();
        install(&TelemetryChoice::On);
        static H: Histogram = Histogram::new("test.hist");
        H.reset();
        assert_eq!(H.count(), 0);
        assert_eq!(H.mean_ms(), 0.0);
        assert_eq!(H.percentile_ms(50.0), 0.0);
        // One sample: every percentile is (approximately) that sample.
        H.record_us(10_000);
        for p in [0.0, 50.0, 100.0] {
            assert!((H.percentile_ms(p) - 10.0).abs() < 1.0, "p{p} = {}", H.percentile_ms(p));
        }
        // Skewed set: p50 near the low mass, p100 near the max.
        H.reset();
        for us in [1000u64, 2000, 3000, 4000, 100_000] {
            H.record_us(us);
        }
        assert_eq!(H.count(), 5);
        assert!((H.mean_ms() - 22.0).abs() < 0.5);
        assert!(H.percentile_ms(50.0) <= 4.5);
        let p100 = H.percentile_ms(100.0);
        assert!((95.0..110.0).contains(&p100), "p100 = {p100}");
        H.reset();
        install(if prev { &TelemetryChoice::On } else { &TelemetryChoice::Off });
    }

    #[test]
    fn step_phases_merge_by_label_in_order() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = enabled();
        install(&TelemetryChoice::On);
        static H: Histogram = Histogram::new("test.phase.hist");
        begin_step();
        time_phase("alpha", &H, || std::thread::sleep(std::time::Duration::from_micros(200)));
        time_phase("beta", &H, || ());
        time_phase("alpha", &H, || ());
        let phases = take_step_phases();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].0, "alpha");
        assert_eq!(phases[1].0, "beta");
        assert!(phases[0].1 >= 200, "alpha span lost its duration: {phases:?}");
        // Drained: a second take is empty.
        assert!(take_step_phases().is_empty());
        H.reset();
        install(if prev { &TelemetryChoice::On } else { &TelemetryChoice::Off });
    }

    #[test]
    fn disabled_time_phase_records_nothing() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = enabled();
        install(&TelemetryChoice::Off);
        static H: Histogram = Histogram::new("test.disabled.hist");
        begin_step();
        let out = time_phase("gone", &H, || 42);
        assert_eq!(out, 42);
        assert_eq!(H.count(), 0);
        assert!(take_step_phases().is_empty());
        install(if prev { &TelemetryChoice::On } else { &TelemetryChoice::Off });
    }
}
