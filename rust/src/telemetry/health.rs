//! Second-order optimizer health probes.
//!
//! Each second-order optimizer records per-layer diagnostics —
//! Sherman–Morrison denominator, update coefficient, Kronecker-vector
//! norms, damping in effect, preconditioned-vs-raw gradient cosine
//! and norm ratio, factor-refresh staleness — at a sampled cadence
//! ([`every`] steps, default [`DEFAULT_EVERY`]; 0 disables). Samples
//! flow through a **thread-local buffer**: the optimizer pushes
//! `(name, value)` pairs on the calling thread during its step, and
//! the owner of that step (the serve session loop, or a standalone
//! consumer) drains them with [`take_samples`] right after
//! `step_once` returns — the same hand-off shape as
//! [`super::take_step_phases`]. Drained samples land in bounded
//! [`SeriesStore`] rings: one per session, plus a process-global
//! aggregate every train step feeds (so `eva train` and the scrape
//! endpoint see health without a serve session).
//!
//! **Numerics are never touched.** Probes only *read* optimizer
//! state and gradients on the calling thread, outside any parallel
//! closure; enabling, disabling, or re-pacing them leaves train
//! digests bit-identical (enforced by `rust/tests/telemetry.rs`).
//!
//! Metric names follow `eva.health.<alg>.<metric>[.l<layer>]`, e.g.
//! `eva.health.eva.sm_denom.l0`; the loss series recorded by the
//! train loop is `eva.health.train.loss`. The [`detect`] pass turns
//! rings into rule-based anomaly flags (non-finite sample, SM
//! denominator within 10× of the damping floor, negative
//! preconditioned-gradient cosine, loss spike beyond k·rolling-σ).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use super::series::SeriesStore;
use crate::jsonx::Json;

/// Default sampling cadence: probe every 10th step.
pub const DEFAULT_EVERY: u64 = 10;

/// Loss-spike rule: flag when the newest loss exceeds the rolling
/// mean by more than this many rolling standard deviations.
pub const LOSS_SPIKE_SIGMA: f64 = 4.0;

/// Denominator-collapse rule: flag when the newest Sherman–Morrison
/// denominator is within this factor of the damping floor γ (the
/// denominator is γ + ‖ā‖²‖b̄‖² ≥ γ, so ≤ 10γ means the curvature
/// term has nearly vanished).
pub const DENOM_COLLAPSE_FACTOR: f64 = 10.0;

static EVERY: AtomicU64 = AtomicU64::new(DEFAULT_EVERY);

/// Set the sampling cadence: probe on steps where `step % n == 0`;
/// `n = 0` disables probing entirely. Purely observational — never
/// changes numerics.
pub fn set_every(n: u64) {
    EVERY.store(n, Ordering::Relaxed);
}

/// Current sampling cadence (0 = disabled).
pub fn every() -> u64 {
    EVERY.load(Ordering::Relaxed)
}

/// Whether health probes should sample on this step. One relaxed
/// load past the telemetry-enabled branch; callers gate the (cheap,
/// read-only) diagnostic recomputation on this.
#[inline]
pub fn due(step: u64) -> bool {
    if !super::enabled() {
        return false;
    }
    let n = every();
    n > 0 && step % n == 0
}

thread_local! {
    static SAMPLES: RefCell<Vec<(String, f64)>> = const { RefCell::new(Vec::new()) };
}

/// Push one raw named sample onto this thread's buffer. Prefer
/// [`sample`] / [`sample_layer`], which build canonical names.
pub fn record(name: String, value: f64) {
    SAMPLES.with(|s| s.borrow_mut().push((name, value)));
}

/// Record a per-algorithm scalar: `eva.health.<alg>.<metric>`.
pub fn sample(alg: &str, metric: &str, value: f64) {
    record(format!("eva.health.{alg}.{metric}"), value);
}

/// Record a per-layer diagnostic: `eva.health.<alg>.<metric>.l<layer>`.
pub fn sample_layer(alg: &str, metric: &str, layer: usize, value: f64) {
    record(format!("eva.health.{alg}.{metric}.l{layer}"), value);
}

/// Drain this thread's buffered samples (empty when probes were not
/// due). The step owner calls this right after `step_once` — same
/// thread — and feeds a [`SeriesStore`].
pub fn take_samples() -> Vec<(String, f64)> {
    SAMPLES.with(|s| std::mem::take(&mut *s.borrow_mut()))
}

/// Clear this thread's buffer; called from [`super::begin_step`] so
/// stale samples from an undrained step never leak into the next.
pub fn clear_thread() {
    SAMPLES.with(|s| s.borrow_mut().clear());
}

fn global() -> &'static Mutex<SeriesStore> {
    static GLOBAL: OnceLock<Mutex<SeriesStore>> = OnceLock::new();
    GLOBAL.get_or_init(|| Mutex::new(SeriesStore::new()))
}

/// Record drained samples into the process-global aggregate store.
pub fn record_global(step: u64, samples: &[(String, f64)]) {
    if samples.is_empty() {
        return;
    }
    let mut store = global().lock().unwrap_or_else(|e| e.into_inner());
    for (name, value) in samples {
        store.record(name, step, *value);
    }
}

/// Run `f` against the process-global aggregate store.
pub fn with_global<R>(f: impl FnOnce(&SeriesStore) -> R) -> R {
    let store = global().lock().unwrap_or_else(|e| e.into_inner());
    f(&store)
}

/// Drop every ring in the process-global aggregate (tests / fresh
/// serve boots).
pub fn reset_global() {
    global().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Rule-based anomaly scan over a store. Returns one flag object per
/// firing rule: `{series, rule, step, detail}`.
///
/// Rules:
/// * `non_finite` — the newest sample of any series is NaN/±Inf.
/// * `denom_near_collapse` — a `sm_denom` series' newest value is
///   within [`DENOM_COLLAPSE_FACTOR`]× of the sibling `damping`
///   series (the γ floor): the rank-one curvature term has collapsed.
/// * `negative_cosine` — a `precond_cosine` series' newest value is
///   negative: the preconditioned step points *against* the gradient.
/// * `loss_spike` — a `.loss` series with ≥ 8 points whose newest
///   value exceeds mean + [`LOSS_SPIKE_SIGMA`]·σ of the ring.
pub fn detect(store: &SeriesStore) -> Vec<Json> {
    let mut flags = Vec::new();
    for (name, ring) in store.iter() {
        let Some((step, last)) = ring.last() else { continue };
        if !last.is_finite() {
            flags.push(flag(name, "non_finite", step, "newest sample is not finite"));
            continue;
        }
        if let Some(prefix) = name.strip_suffix_metric("sm_denom") {
            let gamma = store.get(&format!("{prefix}.damping")).and_then(|r| r.last());
            if let Some((_, g)) = gamma {
                if g.is_finite() && g > 0.0 && last <= DENOM_COLLAPSE_FACTOR * g {
                    flags.push(flag(
                        name,
                        "denom_near_collapse",
                        step,
                        &format!("denominator {last:.3e} within {DENOM_COLLAPSE_FACTOR}x of damping {g:.3e}"),
                    ));
                }
            }
        }
        if name.contains(".precond_cosine") && last < 0.0 {
            flags.push(flag(
                name,
                "negative_cosine",
                step,
                "preconditioned step points against the gradient",
            ));
        }
        if name.ends_with(".loss") && ring.len() >= 8 {
            // Rolling stats over the history *excluding* the newest
            // point — a genuine spike would otherwise inflate σ and
            // mask itself.
            let hist: Vec<f64> = ring.iter().map(|(_, v)| v).collect();
            let hist = &hist[..hist.len() - 1];
            let mean = hist.iter().sum::<f64>() / hist.len() as f64;
            let var = hist.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / hist.len() as f64;
            let sd = var.sqrt();
            if sd > 0.0 && last > mean + LOSS_SPIKE_SIGMA * sd {
                flags.push(flag(
                    name,
                    "loss_spike",
                    step,
                    &format!("loss {last:.3e} > mean {mean:.3e} + {LOSS_SPIKE_SIGMA}*sigma {sd:.3e}"),
                ));
            }
        }
    }
    flags
}

fn flag(series: &str, rule: &str, step: u64, detail: &str) -> Json {
    Json::obj(vec![
        ("series", Json::Str(series.to_string())),
        ("rule", Json::Str(rule.to_string())),
        ("step", Json::Num(step as f64)),
        ("detail", Json::Str(detail.to_string())),
    ])
}

/// `{series: {...}, anomalies: [...], every: n}` — the shape both the
/// per-session and aggregate arms of the `health` protocol command
/// return.
pub fn summarize(store: &SeriesStore) -> Json {
    Json::obj(vec![
        ("every", Json::Num(every() as f64)),
        ("series", store.to_json()),
        ("anomalies", Json::Arr(detect(store))),
    ])
}

/// Strip `".{metric}"` or `".{metric}.l<k>"` from a series name,
/// returning the algorithm prefix (used to find sibling series).
trait MetricSuffix {
    fn strip_suffix_metric(&self, metric: &str) -> Option<&str>;
}

impl MetricSuffix for str {
    fn strip_suffix_metric(&self, metric: &str) -> Option<&str> {
        let pat = format!(".{metric}");
        match self.find(&pat) {
            Some(i) => {
                let rest = &self[i + pat.len()..];
                let is_layer = rest.len() >= 3
                    && rest.as_bytes()[0] == b'.'
                    && rest.as_bytes()[1] == b'l'
                    && rest[2..].bytes().all(|b| b.is_ascii_digit());
                if rest.is_empty() || is_layer {
                    Some(&self[..i])
                } else {
                    None
                }
            }
            None => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadence_gate() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev_tel = super::super::enabled();
        super::super::install(&super::super::TelemetryChoice::On);
        let prev = every();
        set_every(5);
        assert!(due(0) && due(10) && !due(3));
        set_every(0);
        assert!(!due(0) && !due(10));
        set_every(prev);
        super::super::install(if prev_tel {
            &super::super::TelemetryChoice::On
        } else {
            &super::super::TelemetryChoice::Off
        });
    }

    #[test]
    fn thread_buffer_drains_once() {
        clear_thread();
        sample("eva", "damping", 0.03);
        sample_layer("eva", "sm_denom", 0, 1.5);
        let s = take_samples();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].0, "eva.health.eva.damping");
        assert_eq!(s[1].0, "eva.health.eva.sm_denom.l0");
        assert!(take_samples().is_empty(), "second drain must be empty");
    }

    #[test]
    fn detect_flags_nan_and_denom_collapse() {
        let mut store = SeriesStore::new();
        store.record("eva.health.eva.damping", 10, 0.03);
        // Denominator barely above gamma: collapse flag.
        store.record("eva.health.eva.sm_denom.l0", 10, 0.05);
        // Healthy denominator: no flag.
        store.record("eva.health.eva.sm_denom.l1", 10, 5.0);
        // NaN sample: non-finite flag.
        store.record("eva.health.eva.precond_cosine.l0", 10, f64::NAN);
        let flags = detect(&store);
        let rules: Vec<&str> = flags.iter().filter_map(|f| f.get_str("rule")).collect();
        assert!(rules.contains(&"denom_near_collapse"), "flags: {flags:?}");
        assert!(rules.contains(&"non_finite"), "flags: {flags:?}");
        let collapsed: Vec<&str> = flags
            .iter()
            .filter(|f| f.get_str("rule") == Some("denom_near_collapse"))
            .filter_map(|f| f.get_str("series"))
            .collect();
        assert_eq!(collapsed, vec!["eva.health.eva.sm_denom.l0"]);
    }

    #[test]
    fn detect_flags_negative_cosine_and_loss_spike() {
        let mut store = SeriesStore::new();
        store.record("eva.health.kfac.precond_cosine.l2", 4, -0.25);
        for s in 0..9u64 {
            store.record("eva.health.train.loss", s, 1.0 + 0.01 * s as f64);
        }
        store.record("eva.health.train.loss", 9, 50.0);
        let flags = detect(&store);
        let rules: Vec<&str> = flags.iter().filter_map(|f| f.get_str("rule")).collect();
        assert!(rules.contains(&"negative_cosine"), "flags: {flags:?}");
        assert!(rules.contains(&"loss_spike"), "flags: {flags:?}");
    }

    #[test]
    fn metric_suffix_matching() {
        let layered = "eva.health.eva.sm_denom.l3".strip_suffix_metric("sm_denom");
        assert_eq!(layered, Some("eva.health.eva"));
        let flat = "eva.health.eva.sm_denom".strip_suffix_metric("sm_denom");
        assert_eq!(flat, Some("eva.health.eva"));
        assert_eq!("eva.health.eva.sm_denom_min".strip_suffix_metric("sm_denom"), None);
    }
}
