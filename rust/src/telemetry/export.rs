//! Standard export surfaces for the telemetry registry and health
//! series: Prometheus text exposition and Chrome trace-event JSON.
//!
//! * [`render_prometheus`] renders every registered counter, gauge
//!   and histogram plus the newest value of each health series as
//!   Prometheus text exposition format v0.0.4 (`# HELP`/`# TYPE`
//!   preamble per series, dotted names mapped to underscores, no
//!   duplicate series).
//! * [`MetricsServer`] is a std-only HTTP/1.1 GET responder serving
//!   that rendering on a dedicated listener (`--metrics-addr`) —
//!   non-blocking accept loop polling a stop flag, thread-per-conn,
//!   the same shape as `serve/server.rs`. `telemetry` stays free of
//!   any `serve` dependency.
//! * [`TraceSpan`] + [`chrome_trace_json`] / [`write_chrome_trace`]
//!   emit the per-step phase spans the serve session ring already
//!   collects as a Chrome trace-event file (`--trace-out`), loadable
//!   in Perfetto / `chrome://tracing`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::jsonx::Json;

// ---------------------------------------------------------------------------
// Prometheus text exposition v0.0.4
// ---------------------------------------------------------------------------

/// Map a dotted metric name onto the Prometheus grammar:
/// `[a-zA-Z_:][a-zA-Z0-9_:]*` — every other byte becomes `_`.
fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphabetic() || c == '_' || c == ':' || (i > 0 && c.is_ascii_digit());
        out.push(if ok { c } else { '_' });
    }
    out
}

fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

fn emit(
    out: &mut String,
    seen: &mut std::collections::BTreeSet<String>,
    name: &str,
    kind: &str,
    help: &str,
    value: String,
) {
    let pname = sanitize(name);
    if !seen.insert(pname.clone()) {
        return; // never emit a duplicate series
    }
    out.push_str(&format!("# HELP {pname} {help}\n# TYPE {pname} {kind}\n{pname} {value}\n"));
}

/// Render the full registry + health series as Prometheus text
/// exposition format v0.0.4. Histograms surface as derived gauges
/// (`_count`, `_mean_ms`, `_p50_ms`, `_p95_ms`, `_p99_ms`, `_max_ms`)
/// rather than native histogram type — the registry's log-linear
/// buckets are an internal detail. Each health ring contributes its
/// newest value.
pub fn render_prometheus() -> String {
    let mut out = String::new();
    let mut seen = std::collections::BTreeSet::new();
    for c in super::counters() {
        emit(&mut out, &mut seen, c.name(), "counter", "eva counter", format!("{}", c.get()));
    }
    for g in super::gauges() {
        emit(&mut out, &mut seen, g.name(), "gauge", "eva gauge", format!("{}", g.get()));
    }
    for h in super::histograms() {
        let base = h.name();
        emit(
            &mut out,
            &mut seen,
            &format!("{base}.count"),
            "counter",
            "eva histogram sample count",
            format!("{}", h.count()),
        );
        for (suffix, v) in [
            ("mean_ms", h.mean_ms()),
            ("p50_ms", h.percentile_ms(50.0)),
            ("p95_ms", h.percentile_ms(95.0)),
            ("p99_ms", h.percentile_ms(99.0)),
            ("max_ms", h.max_ms()),
        ] {
            emit(
                &mut out,
                &mut seen,
                &format!("{base}.{suffix}"),
                "gauge",
                "eva histogram statistic (milliseconds)",
                fmt_value(v),
            );
        }
    }
    super::health::with_global(|store| {
        for (name, ring) in store.iter() {
            if let Some((_, v)) = ring.last() {
                let help = "eva optimizer-health sample (newest)";
                emit(&mut out, &mut seen, name, "gauge", help, fmt_value(v));
            }
        }
    });
    out
}

// ---------------------------------------------------------------------------
// Chrome trace-event JSON
// ---------------------------------------------------------------------------

/// One complete (`ph: "X"`) trace span.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpan {
    /// Process id column in the trace viewer (serve uses session id).
    pub pid: u64,
    /// Thread id column (serve uses 0).
    pub tid: u64,
    /// Span label (phase name, e.g. `forward_backward`).
    pub name: String,
    /// Start timestamp in microseconds.
    pub ts_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
}

/// Serialize spans as a Chrome trace-event JSON object
/// (`{"traceEvents": [...]}`) that Perfetto and `chrome://tracing`
/// open directly. Every event is a complete (`ph: "X"`) span.
pub fn chrome_trace_json(spans: &[TraceSpan]) -> String {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("cat", Json::Str("step".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(s.ts_us as f64)),
                ("dur", Json::Num(s.dur_us as f64)),
                ("pid", Json::Num(s.pid as f64)),
                ("tid", Json::Num(s.tid as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
    .dump()
}

/// Write [`chrome_trace_json`] to `path`.
pub fn write_chrome_trace(path: &std::path::Path, spans: &[TraceSpan]) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json(spans))
}

// ---------------------------------------------------------------------------
// Scrape endpoint
// ---------------------------------------------------------------------------

/// A std-only HTTP GET responder serving [`render_prometheus`] — the
/// `--metrics-addr` listener. Accept loop is non-blocking and polls a
/// stop flag every 10 ms; each connection gets a short-lived handler
/// thread. [`MetricsServer::stop`] (also run on drop) joins the
/// accept thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`, port 0 for ephemeral) and
    /// start serving scrapes.
    pub fn start(addr: &str) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = thread::Builder::new()
            .name("eva-metrics-accept".to_string())
            .spawn(move || accept_loop(listener, flag))?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolved port when bound to port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread (idempotent).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = thread::Builder::new()
                    .name("eva-metrics-conn".to_string())
                    .spawn(move || handle_conn(stream));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn handle_conn(mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let mut buf = Vec::new();
    let mut tmp = [0u8; 1024];
    loop {
        match stream.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&tmp[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let line = head.lines().next().unwrap_or("");
    let (status, body) = if line.starts_with("GET ") {
        ("200 OK", render_prometheus())
    } else {
        ("405 Method Not Allowed", "only GET is supported\n".to_string())
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(resp.as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_maps_dots_and_dashes() {
        assert_eq!(sanitize("eva.health.eva-f.sm_denom.l0"), "eva_health_eva_f_sm_denom_l0");
        assert_eq!(sanitize("train.step_us"), "train_step_us");
    }

    #[test]
    fn prometheus_rendering_is_well_formed() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = super::super::enabled();
        super::super::install(&super::super::TelemetryChoice::On);
        super::super::TRAIN_STEPS.add(1);
        super::super::health::record_global(0, &[("eva.health.eva.damping".to_string(), 0.03)]);
        let text = render_prometheus();
        assert!(text.contains("# TYPE train_steps counter"), "{text}");
        assert!(text.contains("# TYPE eva_health_eva_damping gauge"), "{text}");
        // Every series line has a HELP+TYPE preamble and appears once.
        let mut names = std::collections::BTreeSet::new();
        for l in text.lines() {
            if l.starts_with('#') {
                continue;
            }
            let name = l.split_whitespace().next().unwrap();
            assert!(names.insert(name.to_string()), "duplicate series {name}");
            assert!(text.contains(&format!("# TYPE {name} ")), "missing TYPE for {name}");
            assert!(text.contains(&format!("# HELP {name} ")), "missing HELP for {name}");
        }
        super::super::health::reset_global();
        super::super::install(if prev {
            &super::super::TelemetryChoice::On
        } else {
            &super::super::TelemetryChoice::Off
        });
    }

    #[test]
    fn chrome_trace_round_trips() {
        let fb = TraceSpan {
            pid: 1,
            tid: 0,
            name: "forward_backward".to_string(),
            ts_us: 0,
            dur_us: 120,
        };
        let ap = TraceSpan { pid: 1, tid: 0, name: "apply".to_string(), ts_us: 120, dur_us: 40 };
        let spans = vec![fb, ap];
        let j = Json::parse(&chrome_trace_json(&spans)).expect("valid json");
        let events = j.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get_str("ph"), Some("X"));
        assert_eq!(events[0].get_str("name"), Some("forward_backward"));
        assert_eq!(events[1].get_f64("ts"), Some(120.0));
        assert_eq!(events[1].get_f64("dur"), Some(40.0));
    }

    #[test]
    fn metrics_server_serves_a_scrape() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = super::super::enabled();
        super::super::install(&super::super::TelemetryChoice::On);
        let mut srv = MetricsServer::start("127.0.0.1:0").expect("bind");
        let mut conn = TcpStream::connect(srv.addr()).expect("connect");
        conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("version=0.0.4"), "{resp}");
        assert!(resp.contains("# TYPE train_steps counter"), "{resp}");
        // Non-GET is rejected.
        let mut conn = TcpStream::connect(srv.addr()).expect("connect");
        conn.write_all(b"POST / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 405"), "{resp}");
        srv.stop();
        super::super::install(if prev {
            &super::super::TelemetryChoice::On
        } else {
            &super::super::TelemetryChoice::Off
        });
    }
}
