//! Minimal JSON parser + emitter (substrate; no serde offline).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null). Used for the artifact manifest written by
//! `python/compile/aot.py`, the typed config system, and experiment
//! result logs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Object keys are sorted (BTreeMap) so emission is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing junk at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `get` chained with string access.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literals; `{n}` would
                    // emit invalid documents (the serve protocol sends
                    // step losses, which can be NaN before the first
                    // step). Standard practice: serialize as null.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[2].get_str("b"), Some("x\ny"));
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("name", Json::Str("eva".into())),
            ("dims", Json::arr_usize(&[784, 1000, 500])),
            ("lr", Json::Num(0.1)),
        ]);
        let p = v.pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
        assert!(p.contains('\n'));
    }

    #[test]
    fn rejects_junk() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn non_finite_numbers_emit_null() {
        // `write!("{n}")` would produce `NaN` / `inf` / `-inf`, none of
        // which is JSON. They must serialize as null — and the result
        // must parse back (round-trip through the serve protocol).
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = Json::obj(vec![("loss", Json::Num(v)), ("step", Json::Num(3.0))]);
            let text = doc.dump();
            let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(back.get("loss"), Some(&Json::Null), "{text}");
            assert_eq!(back.get_f64("step"), Some(3.0));
            let pretty = doc.pretty();
            assert!(Json::parse(&pretty).is_ok(), "{pretty}");
        }
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Arr(vec![Json::Num(f64::INFINITY)]).dump(), "[null]");
    }
}
