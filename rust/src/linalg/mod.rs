//! Dense linear algebra for the second-order baselines (substrate).
//!
//! K-FAC and FOOF need damped SPD inverses; Shampoo needs inverse 2k-th
//! roots of SPD gradient statistics. No LAPACK exists in this offline
//! environment, so the repo ships:
//!
//! * [`cholesky`] / [`cholesky_solve`] / [`spd_inverse`] — `O(d³/3)`
//!   factor + triangular solves for `(M + γI)⁻¹`.
//! * [`eigh_jacobi`] — cyclic Jacobi symmetric eigendecomposition,
//!   quadratically convergent; used for matrix functions.
//! * [`spd_power`] — `M^p` (any real `p`, e.g. `-1/(2k)` for Shampoo)
//!   via the eigendecomposition.
//!
//! These are the exact "expensive inverse" code paths whose cost Eva's
//! Sherman–Morrison identity eliminates — Table 1 / Table 5 benches call
//! them directly.

use std::ops::Range;

use crate::backend::{self, Backend, SendPtr};
use crate::tensor::{matmul, Tensor};

/// `spd_inverse` dispatches its independent column solves through the
/// backend from this dimension up.
const SPD_INV_PAR_MIN: usize = 64;

/// Cholesky factorization `M = L Lᵀ` of a symmetric positive-definite
/// matrix. Returns the lower-triangular factor; fails if a pivot is not
/// strictly positive (matrix not PD).
pub fn cholesky(m: &Tensor) -> Result<Tensor, String> {
    let n = m.rows();
    assert_eq!(n, m.cols(), "cholesky: square matrix required");
    let mut l = Tensor::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // dot of row prefixes — contiguous in row-major layout.
            let s = crate::tensor::dot(&l.row(i)[..j], &l.row(j)[..j]);
            if i == j {
                let d = m.at(i, i) - s;
                if d <= 0.0 || !d.is_finite() {
                    return Err(format!("cholesky: non-PD pivot {d} at {i}"));
                }
                *l.at_mut(i, j) = d.sqrt();
            } else {
                *l.at_mut(i, j) = (m.at(i, j) - s) / l.at(j, j);
            }
        }
    }
    Ok(l)
}

/// Solve `M x = b` given the Cholesky factor `L` of `M`.
pub fn cholesky_solve(l: &Tensor, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    // Forward: L y = b — row prefixes are contiguous.
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let s = crate::tensor::dot(&l.row(i)[..i], &y[..i]);
        y[i] = (b[i] - s) / l.at(i, i);
    }
    // Backward: Lᵀ x = y as a column sweep over the *rows* of L.
    // (Lᵀ)[k,i] = L[i,k], so once x[i] is fixed its contribution to
    // every remaining unknown is x[0..i] -= x[i]·L[i,0..i] — a single
    // contiguous row prefix, instead of walking column i of L with
    // stride n per unknown (the old cache-hostile inner loop).
    let mut x = y;
    for i in (0..n).rev() {
        x[i] /= l.at(i, i);
        let xi = x[i];
        let (head, _) = x.split_at_mut(i);
        crate::tensor::axpy(-xi, &l.row(i)[..i], head);
    }
    x
}

/// Dense inverse of an SPD matrix via Cholesky (column-by-column solve).
pub fn spd_inverse(m: &Tensor) -> Result<Tensor, String> {
    spd_inverse_with(&*backend::global(), m)
}

/// [`spd_inverse`] with an explicit backend. The n column solves
/// `L Lᵀ x = e_j` are independent: each lane solves a block of columns
/// into *rows* of a scratch matrix (contiguous writes), transposed
/// once at the end. Per-column arithmetic is identical for every
/// backend, so results are bit-equal across backends.
pub fn spd_inverse_with(bk: &dyn Backend, m: &Tensor) -> Result<Tensor, String> {
    let n = m.rows();
    let l = cholesky(m)?;
    let mut t = Tensor::zeros(n, n);
    let tp = SendPtr(t.data_mut().as_mut_ptr());
    let lref = &l;
    let body = |r: Range<usize>| {
        let mut e = vec![0.0f32; n];
        for j in r {
            e[j] = 1.0;
            let col = cholesky_solve(lref, &e);
            e[j] = 0.0;
            // SAFETY: row j is written by exactly one chunk.
            let row = unsafe { std::slice::from_raw_parts_mut(tp.0.add(j * n), n) };
            row.copy_from_slice(&col);
        }
    };
    if n >= SPD_INV_PAR_MIN {
        backend::par_ranges(bk, n, 4, &body);
    } else {
        body(0..n);
    }
    Ok(t.transpose())
}

/// Inverse of `M + γI` for symmetric PSD `M` (the damped preconditioner
/// inverse used by K-FAC Eq. 5 and FOOF Eq. 6).
pub fn damped_inverse(m: &Tensor, gamma: f32) -> Result<Tensor, String> {
    let mut d = m.clone();
    d.add_diag(gamma);
    spd_inverse(&d)
}

/// Symmetric eigendecomposition `M = V diag(λ) Vᵀ` by the cyclic Jacobi
/// method. Returns `(eigenvalues, V)` with eigenvectors in the *columns*
/// of `V`, eigenvalues unordered.
///
/// Rotation application stays sequential on purpose: each rotation is
/// only O(n) work, far below the pool's dispatch cost, and rotations
/// are serially dependent. Parallel Jacobi needs round-robin pair
/// scheduling (independent rotation sets per phase) — tracked as a
/// ROADMAP backend follow-on. The O(n³) eigensolve *consumers* do go
/// through the backend (Shampoo fans `spd_power` per tile via
/// `par_map`).
pub fn eigh_jacobi(m: &Tensor, max_sweeps: usize) -> (Vec<f32>, Tensor) {
    let n = m.rows();
    assert_eq!(n, m.cols());
    let mut a = m.clone();
    let mut v = Tensor::eye(n);
    // Relative convergence: off-diagonal mass vs total mass (an
    // absolute 1e-18 made well-scaled matrices sweep to no effect —
    // see EXPERIMENTS.md §Perf L3).
    let total: f64 = a.data().iter().map(|&x| (x as f64) * (x as f64)).sum();
    let tol = (total.max(1e-30)) * 1e-14;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in i + 1..n {
                off += (a.at(i, j) as f64).powi(2);
            }
        }
        if off < tol {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = a.at(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = a.at(p, p);
                let aqq = a.at(q, q);
                let theta = (aqq - app) as f64 / (2.0 * apq as f64);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                let (c, s) = (c as f32, s as f32);
                // Rotate rows/cols p and q of A.
                for k in 0..n {
                    let akp = a.at(k, p);
                    let akq = a.at(k, q);
                    *a.at_mut(k, p) = c * akp - s * akq;
                    *a.at_mut(k, q) = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a.at(p, k);
                    let aqk = a.at(q, k);
                    *a.at_mut(p, k) = c * apk - s * aqk;
                    *a.at_mut(q, k) = s * apk + c * aqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    *v.at_mut(k, p) = c * vkp - s * vkq;
                    *v.at_mut(k, q) = s * vkp + c * vkq;
                }
            }
        }
    }
    let lambda = (0..n).map(|i| a.at(i, i)).collect();
    (lambda, v)
}

/// `(M + γI)^p` for symmetric PSD `M` and real exponent `p` via Jacobi
/// eigendecomposition — Shampoo's inverse 2k-th roots use
/// `p = -1/(2k)`. Negative eigenvalues (numerical noise) are clamped to
/// zero before damping.
pub fn spd_power(m: &Tensor, gamma: f32, p: f32) -> Tensor {
    let n = m.rows();
    let (lambda, v) = eigh_jacobi(m, 30);
    // W = V diag((λ+γ)^p)
    let mut w = Tensor::zeros(n, n);
    for j in 0..n {
        let lj = (lambda[j].max(0.0) + gamma).powf(p);
        for i in 0..n {
            *w.at_mut(i, j) = v.at(i, j) * lj;
        }
    }
    matmul(&w, &v.transpose())
}

/// Largest eigenvalue + eigenvector by power iteration (used by the
/// rank-1 FOOF approximation of Fig. 3 and the PSD-ordering tests).
pub fn power_iteration(m: &Tensor, iters: usize, seed: u64) -> (f32, Vec<f32>) {
    let n = m.rows();
    let mut rng = crate::rng::Pcg64::seeded(seed);
    let mut x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut lambda = 0.0;
    for _ in 0..iters {
        let y = m.matvec(&x);
        let ny = crate::tensor::norm(&y);
        if ny < 1e-30 {
            return (0.0, x);
        }
        x = y.iter().map(|v| v / ny).collect();
        lambda = ny;
    }
    // Rayleigh quotient for the final estimate.
    let y = m.matvec(&x);
    lambda = crate::tensor::dot(&x, &y).max(lambda * 0.0);
    (lambda, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Random SPD matrix `XXᵀ/n + εI`.
    fn random_spd(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let mut x = Tensor::zeros(n, 2 * n);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut m = crate::tensor::matmul_a_bt(&x, &x);
        m.scale(1.0 / (2 * n) as f32);
        m.add_diag(0.05);
        m
    }

    #[test]
    fn cholesky_reconstructs() {
        let m = random_spd(8, 1);
        let l = cholesky(&m).unwrap();
        let rec = crate::tensor::matmul_a_bt(&l, &l);
        assert!(rec.max_abs_diff(&m) < 1e-4);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = Tensor::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eig −1, 3
        assert!(cholesky(&m).is_err());
    }

    #[test]
    fn solve_matches_inverse() {
        let m = random_spd(6, 2);
        let l = cholesky(&m).unwrap();
        let b = [1.0, -2.0, 0.5, 3.0, 0.0, 1.5];
        let x = cholesky_solve(&l, &b);
        let back = m.matvec(&x);
        for (bi, bb) in back.iter().zip(&b) {
            assert!((bi - bb).abs() < 1e-3, "{back:?}");
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let m = random_spd(10, 3);
        let inv = spd_inverse(&m).unwrap();
        let prod = crate::tensor::matmul(&m, &inv);
        assert!(prod.max_abs_diff(&Tensor::eye(10)) < 1e-3);
    }

    #[test]
    fn jacobi_diagonalizes() {
        let m = random_spd(9, 4);
        let (lambda, v) = eigh_jacobi(&m, 30);
        // M V = V diag(λ)
        for j in 0..9 {
            let col: Vec<f32> = (0..9).map(|i| v.at(i, j)).collect();
            let mv = m.matvec(&col);
            for i in 0..9 {
                assert!((mv[i] - lambda[j] * col[i]).abs() < 1e-3);
            }
        }
        // Eigenvalues of SPD matrix are positive.
        assert!(lambda.iter().all(|&l| l > 0.0));
    }

    #[test]
    fn spd_power_inverse_root_squares_back() {
        // (M+γI)^{-1/2} squared == (M+γI)^{-1}.
        let m = random_spd(7, 5);
        let gamma = 0.1;
        let half = spd_power(&m, gamma, -0.5);
        let sq = crate::tensor::matmul(&half, &half);
        let inv = damped_inverse(&m, gamma).unwrap();
        assert!(sq.max_abs_diff(&inv) < 2e-3);
    }

    #[test]
    fn spd_power_identity_exponent() {
        let m = random_spd(5, 6);
        let p1 = spd_power(&m, 0.0, 1.0);
        assert!(p1.max_abs_diff(&m) < 1e-3);
    }

    #[test]
    fn power_iteration_finds_top_eig() {
        let m = random_spd(8, 7);
        let (lmax, _v) = power_iteration(&m, 200, 0);
        let (lambda, _) = eigh_jacobi(&m, 30);
        let top = lambda.iter().cloned().fold(f32::MIN, f32::max);
        assert!((lmax - top).abs() / top < 1e-2, "{lmax} vs {top}");
    }

    /// The new row-streaming backward substitution solves a known
    /// triangular system exactly.
    #[test]
    fn backward_substitution_matches_known_solution() {
        let l = Tensor::from_rows(&[&[2.0, 0.0, 0.0], &[1.0, 3.0, 0.0], &[0.5, -1.0, 1.5]]);
        let m = crate::tensor::matmul_a_bt(&l, &l); // M = L Lᵀ
        let x_true = [0.7f32, -1.2, 2.5];
        let b = m.matvec(&x_true);
        let x = cholesky_solve(&l, &b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-4, "{x:?} vs {x_true:?}");
        }
    }

    /// The eigensolver is backend-independent (serial rotations) —
    /// identical results under a threaded global backend.
    #[test]
    fn eigh_is_backend_invariant() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let m = random_spd(24, 9);
        let (ls, vs) = eigh_jacobi(&m, 30);
        let prev = crate::backend::global();
        crate::backend::set_global(std::sync::Arc::new(crate::backend::Threaded::new(4)));
        let (lp, vp) = eigh_jacobi(&m, 30);
        crate::backend::set_global(prev);
        assert_eq!(ls, lp);
        assert_eq!(vs, vp);
    }

    /// The identity behind Eva: Sherman–Morrison inverse of a damped
    /// rank-one matrix equals the dense inverse.
    #[test]
    fn sherman_morrison_matches_dense() {
        let n = 12;
        let mut rng = Pcg64::seeded(8);
        let u: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let gamma = 0.3f32;
        // C = u uᵀ + γI
        let mut c = Tensor::zeros(n, n);
        c.add_outer(1.0, &u, &u);
        c.add_diag(gamma);
        let dense = spd_inverse(&c).unwrap();
        // SM: (γI + uuᵀ)⁻¹ = (1/γ)(I − uuᵀ/(γ + uᵀu))
        let uu = crate::tensor::dot(&u, &u);
        let mut sm = Tensor::eye(n);
        sm.add_outer(-1.0 / (gamma + uu), &u, &u);
        sm.scale(1.0 / gamma);
        assert!(sm.max_abs_diff(&dense) < 1e-3);
    }
}
