//! Dense linear algebra for the second-order baselines (substrate).
//!
//! K-FAC and FOOF need damped SPD inverses; Shampoo needs inverse 2k-th
//! roots of SPD gradient statistics. No LAPACK exists in this offline
//! environment, so the repo ships:
//!
//! * [`cholesky`] / [`cholesky_solve`] / [`spd_inverse`] — `O(d³/3)`
//!   factor + triangular solves for `(M + γI)⁻¹`.
//! * [`eigh_jacobi`] — Jacobi symmetric eigendecomposition with
//!   round-robin pair scheduling (⌊n/2⌋ independent rotations per
//!   phase through the backend), quadratically convergent; used for
//!   matrix functions.
//! * [`spd_power`] — `M^p` (any real `p`, e.g. `-1/(2k)` for Shampoo)
//!   via the eigendecomposition.
//!
//! These are the exact "expensive inverse" code paths whose cost Eva's
//! Sherman–Morrison identity eliminates — Table 1 / Table 5 benches call
//! them directly.
//!
//! Inner loops (the Cholesky row-prefix dots, the triangular-solve
//! axpys) run on the `f32x8` micro-kernels via [`crate::tensor`], so
//! they inherit the same determinism contract: bit-identical across
//! backends, thread counts, and ISA paths (`docs/KERNELS.md`).

#![warn(missing_docs)]

use std::ops::Range;

use crate::backend::{self, Backend, SendPtr};
use crate::tensor::{matmul, Tensor};

/// `spd_inverse` dispatches its independent column solves through the
/// backend from this dimension up.
const SPD_INV_PAR_MIN: usize = 64;

/// Cholesky factorization `M = L Lᵀ` of a symmetric positive-definite
/// matrix. Returns the lower-triangular factor; fails if a pivot is not
/// strictly positive (matrix not PD).
pub fn cholesky(m: &Tensor) -> Result<Tensor, String> {
    let n = m.rows();
    assert_eq!(n, m.cols(), "cholesky: square matrix required");
    let mut l = Tensor::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // dot of row prefixes — contiguous in row-major layout.
            let s = crate::tensor::dot(&l.row(i)[..j], &l.row(j)[..j]);
            if i == j {
                let d = m.at(i, i) - s;
                if d <= 0.0 || !d.is_finite() {
                    return Err(format!("cholesky: non-PD pivot {d} at {i}"));
                }
                *l.at_mut(i, j) = d.sqrt();
            } else {
                *l.at_mut(i, j) = (m.at(i, j) - s) / l.at(j, j);
            }
        }
    }
    Ok(l)
}

/// Solve `M x = b` given the Cholesky factor `L` of `M`.
pub fn cholesky_solve(l: &Tensor, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    // Forward: L y = b — row prefixes are contiguous.
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let s = crate::tensor::dot(&l.row(i)[..i], &y[..i]);
        y[i] = (b[i] - s) / l.at(i, i);
    }
    // Backward: Lᵀ x = y as a column sweep over the *rows* of L.
    // (Lᵀ)[k,i] = L[i,k], so once x[i] is fixed its contribution to
    // every remaining unknown is x[0..i] -= x[i]·L[i,0..i] — a single
    // contiguous row prefix, instead of walking column i of L with
    // stride n per unknown (the old cache-hostile inner loop).
    let mut x = y;
    for i in (0..n).rev() {
        x[i] /= l.at(i, i);
        let xi = x[i];
        let (head, _) = x.split_at_mut(i);
        crate::tensor::axpy(-xi, &l.row(i)[..i], head);
    }
    x
}

/// Dense inverse of an SPD matrix via Cholesky (column-by-column solve).
pub fn spd_inverse(m: &Tensor) -> Result<Tensor, String> {
    spd_inverse_with(&*backend::current(), m)
}

/// [`spd_inverse`] with an explicit backend. The n column solves
/// `L Lᵀ x = e_j` are independent: each lane solves a block of columns
/// into *rows* of a scratch matrix (contiguous writes), transposed
/// once at the end. Per-column arithmetic is identical for every
/// backend, so results are bit-equal across backends.
pub fn spd_inverse_with(bk: &dyn Backend, m: &Tensor) -> Result<Tensor, String> {
    let n = m.rows();
    let l = cholesky(m)?;
    let mut t = Tensor::zeros(n, n);
    let tp = SendPtr(t.data_mut().as_mut_ptr());
    let lref = &l;
    let body = |r: Range<usize>| {
        let mut e = vec![0.0f32; n];
        for j in r {
            e[j] = 1.0;
            let col = cholesky_solve(lref, &e);
            e[j] = 0.0;
            // SAFETY: row j is written by exactly one chunk.
            let row = unsafe { std::slice::from_raw_parts_mut(tp.0.add(j * n), n) };
            row.copy_from_slice(&col);
        }
    };
    if n >= SPD_INV_PAR_MIN {
        backend::par_ranges(bk, n, 4, &body);
    } else {
        body(0..n);
    }
    Ok(t.transpose())
}

/// Inverse of `M + γI` for symmetric PSD `M` (the damped preconditioner
/// inverse used by K-FAC Eq. 5 and FOOF Eq. 6).
pub fn damped_inverse(m: &Tensor, gamma: f32) -> Result<Tensor, String> {
    let mut d = m.clone();
    d.add_diag(gamma);
    spd_inverse(&d)
}

/// Parallel Jacobi engages from this matrix dimension up: below it a
/// phase carries too little arithmetic (each rotation is O(n)) to pay
/// for pool dispatch, so the round phases run inline — same code, same
/// arithmetic, gate derived from `n` only.
const JACOBI_PAR_MIN: usize = 64;

/// Minimum rotation pairs per parallel chunk in a Jacobi phase.
const JACOBI_PAIR_GRAIN: usize = 8;

/// Tournament (round-robin) schedule over `0..n`: `n-1` rounds for
/// even `n` (`n` rounds with a bye for odd `n`), each round pairing
/// every index with a distinct partner, covering all `n(n-1)/2` pairs
/// exactly once. Pairs are emitted as `(p, q)` with `p < q`.
fn round_robin_rounds(n: usize) -> Vec<Vec<(usize, usize)>> {
    let m = n + n % 2; // pad odd n with a bye slot
    if m < 2 {
        return Vec::new();
    }
    (0..m - 1)
        .map(|r| {
            let mut pairs = Vec::with_capacity(m / 2);
            // The circle method: player m-1 is fixed and meets r; the
            // rest pair off symmetrically around the rotating circle.
            if m - 1 < n && r < n {
                pairs.push((r.min(m - 1), r.max(m - 1)));
            }
            for i in 1..m / 2 {
                let x = (r + i) % (m - 1);
                let y = (r + m - 1 - i) % (m - 1);
                if x < n && y < n {
                    pairs.push((x.min(y), x.max(y)));
                }
            }
            pairs
        })
        .collect()
}

/// Symmetric eigendecomposition `M = V diag(λ) Vᵀ` by the Jacobi
/// method with round-robin pair scheduling, dispatched through the
/// thread's current backend. Returns `(eigenvalues, V)` with
/// eigenvectors in the *columns* of `V`, eigenvalues unordered.
///
/// # Examples
///
/// ```
/// use eva::linalg::eigh_jacobi;
/// use eva::tensor::Tensor;
///
/// let m = Tensor::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
/// let (lambda, v) = eigh_jacobi(&m, 20);
/// // Each eigenpair satisfies M v_j = λ_j v_j.
/// for j in 0..2 {
///     let col: Vec<f32> = (0..2).map(|i| v.at(i, j)).collect();
///     let mv = m.matvec(&col);
///     for i in 0..2 {
///         assert!((mv[i] - lambda[j] * col[i]).abs() < 1e-4);
///     }
/// }
/// ```
pub fn eigh_jacobi(m: &Tensor, max_sweeps: usize) -> (Vec<f32>, Tensor) {
    eigh_jacobi_with(&*backend::current(), m, max_sweeps)
}

/// [`eigh_jacobi`] with an explicit backend.
///
/// One sweep = the `round_robin_rounds` tournament: every round holds
/// `⌊n/2⌋` rotations on disjoint index planes, which commute, so the
/// round equals applying them in any order. A round runs as two
/// barrier-separated phases, each one parallel-for over the pairs
/// (from `JACOBI_PAR_MIN` up; inline below):
///
/// 1. **column phase** — each pair reads its own entries
///    `(p,p), (q,q), (p,q)`, derives the rotation, and updates columns
///    `p`,`q` of `A`;
/// 2. **row phase** — each pair replays the stored rotation onto rows
///    `p`,`q` of `A` and columns `p`,`q` of `V`.
///
/// Every write is pair-owned and every read comes from entries no
/// other pair touches in that phase, so the arithmetic per element is
/// fixed by the schedule alone — `seq` and `threads:N` are
/// **bit-identical**. The cyclic-sweep convergence test is preserved:
/// sweeps stop once off-diagonal mass drops below a relative
/// tolerance.
pub fn eigh_jacobi_with(bk: &dyn Backend, m: &Tensor, max_sweeps: usize) -> (Vec<f32>, Tensor) {
    let n = m.rows();
    assert_eq!(n, m.cols());
    let mut a = m.clone();
    let mut v = Tensor::eye(n);
    if n < 2 {
        return ((0..n).map(|i| a.at(i, i)).collect(), v);
    }
    // Relative convergence: off-diagonal mass vs total mass (an
    // absolute 1e-18 made well-scaled matrices sweep to no effect —
    // see EXPERIMENTS.md §Perf L3).
    let total: f64 = a.data().iter().map(|&x| (x as f64) * (x as f64)).sum();
    let tol = (total.max(1e-30)) * 1e-14;
    let rounds = round_robin_rounds(n);
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in i + 1..n {
                off += (a.at(i, j) as f64).powi(2);
            }
        }
        if off < tol {
            break;
        }
        for pairs in &rounds {
            let np = pairs.len();
            if np == 0 {
                continue;
            }
            // (c, s, active) per pair: written by the column phase,
            // replayed by the row phase after the barrier.
            let mut rot: Vec<(f32, f32, bool)> = vec![(1.0, 0.0, false); np];
            let rp = SendPtr(rot.as_mut_ptr());
            let ap = SendPtr(a.data_mut().as_mut_ptr());
            let vp = SendPtr(v.data_mut().as_mut_ptr());
            let col_phase = |r: Range<usize>| {
                for idx in r {
                    let (p, q) = pairs[idx];
                    // SAFETY: this phase touches only columns p and q
                    // of A (and slot idx of rot), owned by this pair.
                    unsafe {
                        let apq = *ap.0.add(p * n + q);
                        if apq.abs() < 1e-12 {
                            continue;
                        }
                        let app = *ap.0.add(p * n + p);
                        let aqq = *ap.0.add(q * n + q);
                        let theta = (aqq - app) as f64 / (2.0 * apq as f64);
                        let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                        let c = 1.0 / (t * t + 1.0).sqrt();
                        let s = t * c;
                        let (c, s) = (c as f32, s as f32);
                        *rp.0.add(idx) = (c, s, true);
                        for k in 0..n {
                            let akp = *ap.0.add(k * n + p);
                            let akq = *ap.0.add(k * n + q);
                            *ap.0.add(k * n + p) = c * akp - s * akq;
                            *ap.0.add(k * n + q) = s * akp + c * akq;
                        }
                    }
                }
            };
            let row_phase = |r: Range<usize>| {
                for idx in r {
                    let (p, q) = pairs[idx];
                    // SAFETY: this phase touches only rows p and q of A
                    // and columns p and q of V, owned by this pair.
                    unsafe {
                        let (c, s, active) = *rp.0.add(idx);
                        if !active {
                            continue;
                        }
                        for k in 0..n {
                            let apk = *ap.0.add(p * n + k);
                            let aqk = *ap.0.add(q * n + k);
                            *ap.0.add(p * n + k) = c * apk - s * aqk;
                            *ap.0.add(q * n + k) = s * apk + c * aqk;
                        }
                        // Accumulate eigenvectors.
                        for k in 0..n {
                            let vkp = *vp.0.add(k * n + p);
                            let vkq = *vp.0.add(k * n + q);
                            *vp.0.add(k * n + p) = c * vkp - s * vkq;
                            *vp.0.add(k * n + q) = s * vkp + c * vkq;
                        }
                    }
                }
            };
            if n >= JACOBI_PAR_MIN {
                backend::par_ranges(bk, np, JACOBI_PAIR_GRAIN, &col_phase);
                backend::par_ranges(bk, np, JACOBI_PAIR_GRAIN, &row_phase);
            } else {
                col_phase(0..np);
                row_phase(0..np);
            }
        }
    }
    let lambda = (0..n).map(|i| a.at(i, i)).collect();
    (lambda, v)
}

/// `(M + γI)^p` for symmetric PSD `M` and real exponent `p` via Jacobi
/// eigendecomposition — Shampoo's inverse 2k-th roots use
/// `p = -1/(2k)`. Negative eigenvalues (numerical noise) are clamped to
/// zero before damping.
pub fn spd_power(m: &Tensor, gamma: f32, p: f32) -> Tensor {
    let n = m.rows();
    let (lambda, v) = eigh_jacobi(m, 30);
    // W = V diag((λ+γ)^p)
    let mut w = Tensor::zeros(n, n);
    for j in 0..n {
        let lj = (lambda[j].max(0.0) + gamma).powf(p);
        for i in 0..n {
            *w.at_mut(i, j) = v.at(i, j) * lj;
        }
    }
    matmul(&w, &v.transpose())
}

/// Largest eigenvalue + eigenvector by power iteration (used by the
/// rank-1 FOOF approximation of Fig. 3 and the PSD-ordering tests).
pub fn power_iteration(m: &Tensor, iters: usize, seed: u64) -> (f32, Vec<f32>) {
    let n = m.rows();
    let mut rng = crate::rng::Pcg64::seeded(seed);
    let mut x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut lambda = 0.0;
    for _ in 0..iters {
        let y = m.matvec(&x);
        let ny = crate::tensor::norm(&y);
        if ny < 1e-30 {
            return (0.0, x);
        }
        x = y.iter().map(|v| v / ny).collect();
        lambda = ny;
    }
    // Rayleigh quotient for the final estimate.
    let y = m.matvec(&x);
    lambda = crate::tensor::dot(&x, &y).max(lambda * 0.0);
    (lambda, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Random SPD matrix `XXᵀ/n + εI`.
    fn random_spd(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let mut x = Tensor::zeros(n, 2 * n);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut m = crate::tensor::matmul_a_bt(&x, &x);
        m.scale(1.0 / (2 * n) as f32);
        m.add_diag(0.05);
        m
    }

    #[test]
    fn cholesky_reconstructs() {
        let m = random_spd(8, 1);
        let l = cholesky(&m).unwrap();
        let rec = crate::tensor::matmul_a_bt(&l, &l);
        assert!(rec.max_abs_diff(&m) < 1e-4);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = Tensor::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eig −1, 3
        assert!(cholesky(&m).is_err());
    }

    #[test]
    fn solve_matches_inverse() {
        let m = random_spd(6, 2);
        let l = cholesky(&m).unwrap();
        let b = [1.0, -2.0, 0.5, 3.0, 0.0, 1.5];
        let x = cholesky_solve(&l, &b);
        let back = m.matvec(&x);
        for (bi, bb) in back.iter().zip(&b) {
            assert!((bi - bb).abs() < 1e-3, "{back:?}");
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let m = random_spd(10, 3);
        let inv = spd_inverse(&m).unwrap();
        let prod = crate::tensor::matmul(&m, &inv);
        assert!(prod.max_abs_diff(&Tensor::eye(10)) < 1e-3);
    }

    #[test]
    fn jacobi_diagonalizes() {
        let m = random_spd(9, 4);
        let (lambda, v) = eigh_jacobi(&m, 30);
        // M V = V diag(λ)
        for j in 0..9 {
            let col: Vec<f32> = (0..9).map(|i| v.at(i, j)).collect();
            let mv = m.matvec(&col);
            for i in 0..9 {
                assert!((mv[i] - lambda[j] * col[i]).abs() < 1e-3);
            }
        }
        // Eigenvalues of SPD matrix are positive.
        assert!(lambda.iter().all(|&l| l > 0.0));
    }

    #[test]
    fn spd_power_inverse_root_squares_back() {
        // (M+γI)^{-1/2} squared == (M+γI)^{-1}.
        let m = random_spd(7, 5);
        let gamma = 0.1;
        let half = spd_power(&m, gamma, -0.5);
        let sq = crate::tensor::matmul(&half, &half);
        let inv = damped_inverse(&m, gamma).unwrap();
        assert!(sq.max_abs_diff(&inv) < 2e-3);
    }

    #[test]
    fn spd_power_identity_exponent() {
        let m = random_spd(5, 6);
        let p1 = spd_power(&m, 0.0, 1.0);
        assert!(p1.max_abs_diff(&m) < 1e-3);
    }

    #[test]
    fn power_iteration_finds_top_eig() {
        let m = random_spd(8, 7);
        let (lmax, _v) = power_iteration(&m, 200, 0);
        let (lambda, _) = eigh_jacobi(&m, 30);
        let top = lambda.iter().cloned().fold(f32::MIN, f32::max);
        assert!((lmax - top).abs() / top < 1e-2, "{lmax} vs {top}");
    }

    /// The new row-streaming backward substitution solves a known
    /// triangular system exactly.
    #[test]
    fn backward_substitution_matches_known_solution() {
        let l = Tensor::from_rows(&[&[2.0, 0.0, 0.0], &[1.0, 3.0, 0.0], &[0.5, -1.0, 1.5]]);
        let m = crate::tensor::matmul_a_bt(&l, &l); // M = L Lᵀ
        let x_true = [0.7f32, -1.2, 2.5];
        let b = m.matvec(&x_true);
        let x = cholesky_solve(&l, &b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-4, "{x:?} vs {x_true:?}");
        }
    }

    /// Round-robin rounds cover every unordered pair exactly once and
    /// never reuse an index within a round.
    #[test]
    fn round_robin_schedule_is_a_tournament() {
        for n in [0usize, 1, 2, 5, 8, 9, 24] {
            let rounds = round_robin_rounds(n);
            let mut seen = std::collections::BTreeSet::new();
            for pairs in &rounds {
                let mut in_round = std::collections::BTreeSet::new();
                for &(p, q) in pairs {
                    assert!(p < q && q < n, "n={n} pair ({p},{q})");
                    assert!(in_round.insert(p) && in_round.insert(q), "index reuse in round");
                    assert!(seen.insert((p, q)), "duplicate pair ({p},{q})");
                }
            }
            assert_eq!(seen.len(), n * (n.max(1) - 1) / 2, "n={n} coverage");
        }
    }

    /// The eigensolver's phase structure is backend-independent —
    /// identical results under a threaded global backend.
    #[test]
    fn eigh_is_backend_invariant() {
        let _serial = crate::backend::TEST_GLOBAL_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let m = random_spd(24, 9);
        let (ls, vs) = eigh_jacobi(&m, 30);
        let prev = crate::backend::global();
        crate::backend::set_global(std::sync::Arc::new(crate::backend::Threaded::new(4)));
        let (lp, vp) = eigh_jacobi(&m, 30);
        crate::backend::set_global(prev);
        assert_eq!(ls, lp);
        assert_eq!(vs, vp);
    }

    /// The identity behind Eva: Sherman–Morrison inverse of a damped
    /// rank-one matrix equals the dense inverse.
    #[test]
    fn sherman_morrison_matches_dense() {
        let n = 12;
        let mut rng = Pcg64::seeded(8);
        let u: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let gamma = 0.3f32;
        // C = u uᵀ + γI
        let mut c = Tensor::zeros(n, n);
        c.add_outer(1.0, &u, &u);
        c.add_diag(gamma);
        let dense = spd_inverse(&c).unwrap();
        // SM: (γI + uuᵀ)⁻¹ = (1/γ)(I − uuᵀ/(γ + uᵀu))
        let uu = crate::tensor::dot(&u, &u);
        let mut sm = Tensor::eye(n);
        sm.add_outer(-1.0 / (gamma + uu), &u, &u);
        sm.scale(1.0 / gamma);
        assert!(sm.max_abs_diff(&dense) < 1e-3);
    }
}
