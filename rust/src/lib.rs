//! # Eva — vectorized second-order optimization, reproduced end to end
//!
//! This crate is a production-shaped reproduction of *"Eva: A General
//! Vectorized Approximation Framework for Second-order Optimization"*
//! (Zhang, Shi, Li — 2023). It is the L3 (Rust) layer of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for the Eva /
//!   Eva-f / Eva-s rank-one Sherman–Morrison preconditioners.
//! * **L2** (`python/compile/model.py`): JAX model fwd/bwd emitting the
//!   per-layer curvature statistics (KVs `ā, b̄` and KFs `AAᵀ, BBᵀ`),
//!   AOT-lowered to HLO text under `artifacts/`.
//! * **L3** (this crate): training framework — datasets, the optimizer
//!   zoo (Eva + all paper baselines), a PJRT runtime that executes the
//!   AOT artifacts, a data-parallel coordinator, and the experiment
//!   harness that regenerates every table and figure of the paper.
//!
//! ## Quickstart
//!
//! ```no_run
//! use eva::config::TrainConfig;
//! use eva::train::Trainer;
//!
//! let mut cfg = TrainConfig::preset("quickstart");
//! cfg.optim.algorithm = "eva".into();
//! let mut trainer = Trainer::from_config(&cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final loss {:.4}  acc {:.2}%", report.final_loss, 100.0 * report.best_val_acc);
//! ```
//!
//! See `examples/` for runnable scenarios and `eva experiment <id>` for
//! the paper's tables/figures.

// Curated clippy posture for the `-D warnings` CI job. Each allow is
// a deliberate repo-wide idiom, not an unreviewed escape hatch — new
// allows belong here (crate-level, with a reason), never inline.
#![allow(clippy::too_many_arguments)] // kernel entrypoints mirror BLAS-style signatures
#![allow(clippy::type_complexity)] // backend closures carry their full lifetime story
#![allow(clippy::needless_range_loop)] // index loops keep reduction order explicit (KERNELS.md)
#![allow(clippy::manual_memcpy)] // explicit element loops document ordering in hot paths
#![allow(clippy::new_without_default)] // constructors take config; Default would hide it
#![allow(clippy::many_single_char_names)] // math code mirrors the paper's notation (ā, b̄, γ…)
#![allow(clippy::large_enum_variant)] // protocol enums trade size for a flat match surface
#![allow(clippy::comparison_chain)] // three-way numeric branches read better than cmp() here

pub mod backend;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod jsonx;
pub mod linalg;
pub mod lint;
pub mod nn;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod simd;
pub mod telemetry;
pub mod tensor;
pub mod testing;
pub mod train;

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
