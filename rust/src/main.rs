//! `eva` — launcher binary: training runs, experiments, validation.

// The binary shares the library's curated clippy posture (see
// rust/src/lib.rs — crate-level attributes don't cross the lib/bin
// boundary, so the subset that can fire here is restated).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::large_enum_variant)]

use anyhow::{anyhow, Result};

use eva::cli::{Cli, USAGE};
use eva::config::{Engine, LrSchedule, ModelArch, TrainConfig};
use eva::train::Trainer;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args).map_err(|e| anyhow!(e))?;
    // Fail fast on typo'd options instead of silently ignoring them.
    cli.reject_unknown().map_err(|e| anyhow!(e))?;
    // Backend selection applies to every command (train, experiments,
    // validate, serve) — install it before dispatch.
    if let Some(spec) = cli.opt("backend") {
        let choice = eva::backend::BackendChoice::parse(spec).map_err(|e| anyhow!(e))?;
        let b = eva::backend::install(&choice);
        println!("compute backend: {}", b.label());
    }
    // Per-worker lane budget for data-parallel runs (table8, the dp
    // example paths). Like --backend, it applies to every command.
    if let Some(n) = cli.opt_usize("worker-threads").map_err(|e| anyhow!(e))? {
        if n == 0 {
            return Err(anyhow!("--worker-threads must be ≥ 1"));
        }
        eva::coordinator::dp::set_default_worker_threads(Some(n));
        println!("dp worker lanes: {n} per worker");
    }
    // ISA path for the f32x8 micro-kernels. Like --backend, a
    // process-wide knob applying to every command; numerics are
    // bit-identical across paths (docs/KERNELS.md).
    if let Some(spec) = cli.opt("simd") {
        let choice = eva::simd::SimdChoice::parse(spec).map_err(|e| anyhow!(e))?;
        let isa = eva::simd::install(&choice).map_err(|e| anyhow!(e))?;
        println!("simd kernels: {}", isa.name());
    }
    // Telemetry knob (metrics registry + tracing spans). Process-wide
    // like the others; instrumentation never touches numerics.
    if let Some(spec) = cli.opt("telemetry") {
        let choice = eva::telemetry::TelemetryChoice::parse(spec).map_err(|e| anyhow!(e))?;
        eva::telemetry::install(&choice);
        println!("telemetry: {}", choice.label());
    }
    match cli.command.as_str() {
        "" | "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        "train" => train(&cli),
        "serve" => serve(&cli),
        "router" => router(&cli),
        "health" => health(&cli),
        "lint" => lint(&cli),
        "experiment" => {
            let id = cli
                .positional
                .first()
                .ok_or_else(|| anyhow!("usage: eva experiment <id|all>"))?;
            eva::exp::run(id)
        }
        "validate" => eva::exp::validate::run(),
        "list" => list(),
        "info" => info(),
        other => Err(anyhow!("unknown command '{other}'\n\n{USAGE}")),
    }
}

fn train(cli: &Cli) -> Result<()> {
    let mut cfg = if let Some(path) = cli.opt("config") {
        TrainConfig::from_file(path).map_err(|e| anyhow!(e))?
    } else {
        TrainConfig::preset(&cli.opt_or("preset", "quickstart"))
    };
    if let Some(o) = cli.opt("optimizer") {
        cfg.optim.algorithm = o.to_string();
    }
    if let Some(d) = cli.opt("dataset") {
        cfg.dataset = d.to_string();
    }
    if let Some(e) = cli.opt_usize("epochs").map_err(|e| anyhow!(e))? {
        cfg.epochs = e;
    }
    if let Some(l) = cli.opt_f32("lr").map_err(|e| anyhow!(e))? {
        cfg.base_lr = l;
    }
    if let Some(b) = cli.opt_usize("batch").map_err(|e| anyhow!(e))? {
        cfg.batch_size = b;
    }
    if let Some(s) = cli.opt_usize("seed").map_err(|e| anyhow!(e))? {
        cfg.seed = s as u64;
    }
    if let Some(i) = cli.opt_usize("interval").map_err(|e| anyhow!(e))? {
        cfg.optim.hp.update_interval = i;
    }
    if let Some(d) = cli.opt_f32("damping").map_err(|e| anyhow!(e))? {
        cfg.optim.hp.damping = d;
    }
    if let Some(m) = cli.opt_usize("max-steps").map_err(|e| anyhow!(e))? {
        cfg.max_steps = Some(m as u64);
    }
    if let Some(s) = cli.opt("schedule") {
        cfg.lr_schedule = LrSchedule::parse(s).map_err(|e| anyhow!(e))?;
    }
    if let Some(hidden) = cli.opt("hidden") {
        let dims: Vec<usize> = hidden
            .split(',')
            .map(|s| s.trim().parse().map_err(|_| anyhow!("--hidden: bad dims '{hidden}'")))
            .collect::<Result<_>>()?;
        cfg.arch = ModelArch::Classifier { hidden: dims };
    }
    if let Some(e) = cli.opt("engine") {
        cfg.engine = match e {
            "native" => Engine::Native,
            s if s.starts_with("pjrt:") => Engine::Pjrt { model: s[5..].to_string() },
            other => return Err(anyhow!("unknown engine '{other}'")),
        };
    }
    if cli.opt("backend").is_some() {
        // The CLI flag wins over the config file. run() already
        // installed it globally, so clear the config's choice rather
        // than letting Trainer::from_config rebuild a pool.
        cfg.backend = None;
    }
    if cli.opt("worker-threads").is_some() {
        // Same precedence for the dp per-worker lane budget: run()
        // already set the process-wide default from the CLI.
        cfg.worker_threads = None;
    }
    if cli.opt("simd").is_some() {
        // Same precedence for the ISA path: run() already installed it.
        cfg.simd = None;
    }
    if cli.opt("telemetry").is_some() {
        // Same precedence for the telemetry knob.
        cfg.telemetry = None;
    }
    println!(
        "train: dataset={} optimizer={} epochs={} batch={} lr={} engine={:?}",
        cfg.dataset, cfg.optim.algorithm, cfg.epochs, cfg.batch_size, cfg.base_lr, cfg.engine
    );
    let mut trainer = Trainer::from_config(&cfg)?;
    let total = trainer.total_steps();
    println!("total steps: {total}");
    let report = trainer.run()?;
    println!("\nepoch  train_loss  val_metric  step_ms");
    for e in &report.history {
        println!(
            "{:>5}  {:>10.4}  {:>10.4}  {:>7.2}",
            e.epoch, e.train_loss, e.val_metric, e.mean_step_ms
        );
    }
    println!(
        "\nfinal loss {:.4} | best val acc {:.2}% | optimizer state {} KiB | total {:.1}s",
        report.final_loss,
        100.0 * report.best_val_acc,
        report.optimizer_state_bytes / 1024,
        report.total_time_s
    );
    Ok(())
}

/// `eva serve` — the multi-tenant training-session service. Blocks
/// until a client sends `shutdown` or the process receives
/// SIGTERM/SIGINT, which checkpoints every live session first; a
/// restart with `--resume-dir` re-admits them.
fn serve(cli: &Cli) -> Result<()> {
    use eva::serve::{signal, ServeConfig, Server, Service};
    let mut cfg = if let Some(path) = cli.opt("config") {
        ServeConfig::from_file(path).map_err(|e| anyhow!(e))?
    } else {
        ServeConfig::default()
    };
    if let Some(a) = cli.opt("addr") {
        cfg.addr = a.to_string();
    }
    if let Some(n) = cli.opt_usize("max-sessions").map_err(|e| anyhow!(e))? {
        if n == 0 {
            return Err(anyhow!("--max-sessions must be ≥ 1"));
        }
        cfg.max_sessions = n;
    }
    if let Some(n) = cli.opt_usize("max-per-tenant").map_err(|e| anyhow!(e))? {
        cfg.max_sessions_per_tenant = n;
    }
    if let Some(d) = cli.opt("checkpoint-dir") {
        cfg.checkpoint_dir = d.to_string();
    }
    if let Some(n) = cli.opt_usize("checkpoint-every").map_err(|e| anyhow!(e))? {
        cfg.checkpoint_every_steps = n as u64;
    }
    if let Some(n) = cli.opt_usize("retain-terminal").map_err(|e| anyhow!(e))? {
        cfg.retain_terminal = n;
    }
    if let Some(n) = cli.opt_usize("retain-snapshots").map_err(|e| anyhow!(e))? {
        cfg.retain_snapshots = n;
    }
    if let Some(d) = cli.opt("resume-dir") {
        cfg.resume_dir = Some(d.to_string());
    }
    if let Some(q) = cli.opt_usize("quantum").map_err(|e| anyhow!(e))? {
        if q == 0 {
            return Err(anyhow!("--quantum must be ≥ 1"));
        }
        cfg.quantum_steps = q;
    }
    if let Some(a) = cli.opt("metrics-addr") {
        cfg.metrics_addr = Some(a.to_string());
    }
    if let Some(p) = cli.opt("trace-out") {
        cfg.trace_out = Some(p.to_string());
    }
    if let Some(n) = cli.opt_usize("health-every").map_err(|e| anyhow!(e))? {
        cfg.health_every_steps = n as u64;
    }
    // Catch SIGTERM/SIGINT before any session exists so no window is
    // uncovered.
    signal::install_term_handler();
    let addr = cfg.addr.clone();
    // Service::start itself resumes cfg.resume_dir (so library
    // embedders get the same boot semantics as the CLI).
    let svc = Service::start(cfg.clone());
    if let Some(dir) = &cfg.resume_dir {
        let n = svc.stats().sessions.len();
        if n > 0 {
            println!("serve: resumed {n} session(s) from {dir}");
        }
    }
    let server = Server::start(svc.clone(), &addr)?;
    println!(
        "serve: listening on {} | backend {} | simd {} | telemetry {} | max {} sessions | quantum {} steps | checkpoints → {}",
        server.addr(),
        eva::backend::global().label(),
        eva::simd::active().name(),
        if eva::telemetry::enabled() { "on" } else { "off" },
        cfg.max_sessions,
        cfg.quantum_steps,
        cfg.checkpoint_dir,
    );
    if cfg.checkpoint_every_steps > 0 {
        println!("serve: auto-checkpoint every {} steps", cfg.checkpoint_every_steps);
    }
    if cfg.retain_snapshots > 0 {
        println!("serve: retaining {} snapshots per lineage", cfg.retain_snapshots);
    }
    if let Some(ma) = svc.metrics_addr() {
        println!("serve: prometheus scrape endpoint on http://{ma}/metrics");
    }
    if let Some(path) = &cfg.trace_out {
        println!("serve: chrome trace will be written to {path} at shutdown");
    }
    println!("serve: newline-delimited JSON; try {{\"cmd\":\"stats\"}} or {{\"cmd\":\"shutdown\"}}");
    // Serve until a client shuts us down or a termination signal
    // arrives (the atomic-flag shim in eva::serve::signal).
    while !svc.is_stopped() && !signal::term_requested() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    if signal::term_requested() && !svc.is_stopped() {
        println!("serve: termination signal — checkpointing live sessions");
        svc.shutdown();
    }
    server.join();
    // Final registry dump — the service's perf trajectory for the log.
    if eva::telemetry::enabled() {
        println!("\n-- telemetry --\n{}", eva::telemetry::render_text());
    }
    println!("serve: shut down");
    Ok(())
}

/// `eva router` — the cluster front door: places sessions across N
/// backend serve processes, probes their health, and rescues sessions
/// off dead hosts by checkpoint migration. Speaks the same ndjson
/// protocol as `eva serve`, so any serve client works unchanged.
fn router(cli: &Cli) -> Result<()> {
    use eva::cluster::{ClusterConfig, HostSpec, Router, RouterServer};
    use eva::serve::signal;
    let mut cfg = if let Some(path) = cli.opt("config") {
        ClusterConfig::from_file(path).map_err(|e| anyhow!(e))?
    } else {
        ClusterConfig::default()
    };
    if let Some(a) = cli.opt("addr") {
        cfg.router_addr = a.to_string();
    }
    if let Some(hosts) = cli.opt("hosts") {
        cfg.hosts = hosts
            .split(',')
            .map(|a| a.trim())
            .filter(|a| !a.is_empty())
            .map(|a| HostSpec { addr: a.to_string(), checkpoint_dir: String::new() })
            .collect();
    }
    if let Some(dirs) = cli.opt("checkpoint-dirs") {
        let dirs: Vec<&str> = dirs.split(',').map(|d| d.trim()).collect();
        if dirs.len() != cfg.hosts.len() {
            return Err(anyhow!(
                "--checkpoint-dirs lists {} dirs for {} hosts",
                dirs.len(),
                cfg.hosts.len()
            ));
        }
        for (h, d) in cfg.hosts.iter_mut().zip(dirs) {
            h.checkpoint_dir = d.to_string();
        }
    }
    if let Some(n) = cli.opt_usize("probe-interval-ms").map_err(|e| anyhow!(e))? {
        cfg.probe_interval_ms = n as u64;
    }
    if let Some(n) = cli.opt_usize("probe-timeout-ms").map_err(|e| anyhow!(e))? {
        if n == 0 {
            return Err(anyhow!("--probe-timeout-ms must be ≥ 1"));
        }
        cfg.probe_timeout_ms = n as u64;
    }
    if let Some(n) = cli.opt_usize("probe-fails").map_err(|e| anyhow!(e))? {
        if n == 0 {
            return Err(anyhow!("--probe-fails must be ≥ 1"));
        }
        cfg.probe_fails_down = n as u32;
    }
    if let Some(n) = cli.opt_usize("request-timeout-ms").map_err(|e| anyhow!(e))? {
        if n == 0 {
            return Err(anyhow!("--request-timeout-ms must be ≥ 1"));
        }
        cfg.request_timeout_ms = n as u64;
    }
    if let Some(v) = cli.opt("auto-migrate") {
        cfg.auto_migrate = match v {
            "on" | "true" => true,
            "off" | "false" => false,
            other => return Err(anyhow!("--auto-migrate: 'on' or 'off', not '{other}'")),
        };
    }
    if cfg.hosts.is_empty() {
        return Err(anyhow!("router needs at least one backend host (--hosts A1,A2,...)"));
    }
    signal::install_term_handler();
    let addr = cfg.router_addr.clone();
    let router = Router::start(cfg.clone());
    let server = RouterServer::start(router.clone(), &addr)?;
    println!(
        "router: listening on {} | {} host(s) | probe every {}ms ({}x{}ms to down) | auto-migrate {}",
        server.addr(),
        cfg.hosts.len(),
        cfg.probe_interval_ms,
        cfg.probe_fails_down,
        cfg.probe_timeout_ms,
        if cfg.auto_migrate { "on" } else { "off" },
    );
    for h in &cfg.hosts {
        println!(
            "router: host {}{}",
            h.addr,
            if h.checkpoint_dir.is_empty() {
                String::new()
            } else {
                format!(" (checkpoints: {})", h.checkpoint_dir)
            }
        );
    }
    println!("router: newline-delimited JSON; try {{\"cmd\":\"hosts\"}} or {{\"cmd\":\"stats\"}}");
    while !router.is_stopped() && !signal::term_requested() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    if signal::term_requested() && !router.is_stopped() {
        // Control plane only: backend hosts keep training and
        // checkpointing; a restarted router recomputes placements.
        println!("router: termination signal");
        router.shutdown();
    }
    server.join();
    if eva::telemetry::enabled() {
        // Fleet-aggregated registry — counters/gauges summed across
        // every reachable host (mirrors `eva serve`'s exit dump, but
        // cluster-wide). Hosts outlive the router; unreachable ones
        // appear as error entries under per_host.
        let req = eva::jsonx::Json::obj(vec![("cmd", eva::jsonx::Json::Str("metrics".into()))]);
        let dump = router.dispatch(&req);
        println!("\n-- fleet metrics --\n{}", dump.pretty());
        println!("\n-- router telemetry --\n{}", eva::telemetry::render_text());
    }
    println!("router: shut down");
    Ok(())
}

/// `eva health` — query a serve (or router) control plane for the
/// optimizer-health report: per-layer second-order diagnostics and
/// anomaly flags. `--session ID` narrows to one session's rings;
/// without it the service (or fleet) aggregate is reported.
fn health(cli: &Cli) -> Result<()> {
    use eva::serve::{ServeClient, TcpClient};
    let addr = cli.opt_or("addr", "127.0.0.1:7931");
    let session = cli.opt_usize("session").map_err(|e| anyhow!(e))?.map(|n| n as u64);
    let mut client =
        TcpClient::connect(&addr).map_err(|e| anyhow!("connect to {addr}: {e}"))?;
    let report = client.health(session).map_err(|e| anyhow!(e))?;
    println!("{}", report.pretty());
    let n_anomalies =
        report.get("anomalies").and_then(|a| a.as_arr()).map(|a| a.len()).unwrap_or(0);
    if n_anomalies > 0 {
        eprintln!("health: {n_anomalies} anomaly flag(s) raised");
    }
    Ok(())
}

/// `eva lint` — the repo-invariant static-analysis pass (rules
/// L1–L6, `docs/LINTS.md`). Lints the whole `rust/src` tree by
/// default, or the given files/directories; exits nonzero when any
/// violation survives suppression, so CI can run it blocking.
fn lint(cli: &Cli) -> Result<()> {
    use eva::lint::{lint_paths, lint_tree, render_fix_list, render_json, render_text, LintConfig};
    use std::path::PathBuf;

    // Locate the source root and the metric catalog relative to the
    // working directory — works from the repo root and from rust/.
    let src_root = ["rust/src", "src"]
        .iter()
        .map(PathBuf::from)
        .find(|p| p.join("lint").is_dir())
        .ok_or_else(|| {
            anyhow!("cannot find the rust/src tree from {:?}", std::env::current_dir())
        })?;
    let doc_catalog = ["docs/ARCHITECTURE.md", "../docs/ARCHITECTURE.md"]
        .iter()
        .map(PathBuf::from)
        .find(|p| p.is_file());
    if doc_catalog.is_none() {
        eprintln!("lint: docs/ARCHITECTURE.md not found — skipping the L6 metric-catalog rule");
    }
    let cfg = LintConfig { src_root, doc_catalog };
    let diags = if cli.positional.is_empty() {
        lint_tree(&cfg)?
    } else {
        let paths: Vec<PathBuf> = cli.positional.iter().map(PathBuf::from).collect();
        lint_paths(&cfg, &paths)?
    };
    let format = cli.opt_or("format", "text");
    match format.as_str() {
        "json" => print!("{}", render_json(&diags)),
        "text" => {
            print!("{}", render_text(&diags));
            if cli.has_flag("fix-list") && !diags.is_empty() {
                print!("\n{}", render_fix_list(&diags));
            }
        }
        other => return Err(anyhow!("--format: 'text' or 'json', not '{other}'")),
    }
    if diags.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("{} lint violation(s)", diags.len()))
    }
}

fn list() -> Result<()> {
    println!("datasets:    c10-like c100-like c10-small c100-small mnist-like fmnist-like faces-like curves");
    println!("optimizers:  {}", eva::optim::OPTIMIZER_NAMES.join(" "));
    println!(
        "backends:    seq threads threads:N   (current: {}, hardware: {})",
        eva::backend::global().label(),
        eva::backend::default_threads()
    );
    println!(
        "simd:        {}   (active: {}, available: {})",
        "auto avx2 sse2 scalar",
        eva::simd::active().name(),
        eva::simd::available_isas()
            .iter()
            .map(|i| i.name())
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!("experiments: {}", eva::exp::ALL.join(" "));
    match eva::runtime::Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts:   ({} compiled graphs)", rt.manifest().artifacts.len());
            for k in rt.manifest().artifacts.keys() {
                println!("  {k}");
            }
        }
        Err(_) => println!("artifacts:   (none — run `make artifacts`)"),
    }
    Ok(())
}

fn info() -> Result<()> {
    println!(
        "eva {} — three-layer Rust+JAX+Pallas reproduction of Eva (Zhang et al. 2023)",
        eva::VERSION
    );
    match eva::runtime::Runtime::open_default() {
        Ok(rt) => {
            for (name, m) in &rt.manifest().models {
                println!(
                    "model {name}: dims {:?}, {} params, batch {}, loss {}",
                    m.dims, m.num_params, m.batch, m.loss
                );
            }
        }
        Err(e) => println!("runtime unavailable: {e}"),
    }
    Ok(())
}
