//! quickcheck-lite: property-based testing harness (substrate).
//!
//! No proptest/quickcheck offline, so the repo ships a minimal
//! generator + runner. Properties are closures over a [`Gen`]; the
//! runner executes N seeded cases and reports the failing seed so a
//! failure is reproducible by construction (no shrinking — the seed is
//! the witness).

use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// Random-input generator handed to properties.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg64::seeded(seed) }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Standard-normal vector of length n.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v, 1.0);
        v
    }

    /// Standard-normal matrix.
    pub fn normal_tensor(&mut self, rows: usize, cols: usize) -> Tensor {
        let mut t = Tensor::zeros(rows, cols);
        self.rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    /// Random SPD matrix with condition control: `XXᵀ/cols + eps·I`.
    pub fn spd_tensor(&mut self, n: usize, eps: f32) -> Tensor {
        let x = self.normal_tensor(n, n + 4);
        let mut m = crate::tensor::matmul_a_bt(&x, &x);
        m.scale(1.0 / (n + 4) as f32);
        m.add_diag(eps);
        m
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics (failing the enclosing
/// `#[test]`) with the case seed on the first counterexample.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Helper assertion for approximate scalar equality inside properties.
pub fn close(a: f32, b: f32, tol: f32, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Helper assertion for approximate tensor equality inside properties.
pub fn tensors_close(a: &Tensor, b: &Tensor, tol: f32, what: &str) -> Result<(), String> {
    if a.shape() != b.shape() {
        return Err(format!("{what}: shape {:?} vs {:?}", a.shape(), b.shape()));
    }
    let d = a.max_abs_diff(b);
    if d <= tol {
        Ok(())
    } else {
        Err(format!("{what}: max abs diff {d} > {tol}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("add commutes", 50, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            close(a + b, b + a, 1e-6, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures() {
        check("always fails", 3, |_g| Err("nope".into()));
    }

    #[test]
    fn spd_tensor_is_pd() {
        check("spd gen is PD", 10, |g| {
            let n = g.usize_in(2, 12);
            let m = g.spd_tensor(n, 0.01);
            crate::linalg::cholesky(&m).map(|_| ()).map_err(|e| e)
        });
    }
}
