//! Shampoo (Gupta et al.) — full-matrix adaptive baseline (Eq. 7–8),
//! with the **blocked preconditioner** of the scalable variant (Anil et
//! al. [17]): parameter matrices are tiled into ≤ `block × block`
//! sub-blocks, each preconditioned independently.
//!
//! Per tile (matrix case, k = 2) keep gradient statistics
//! `M₁ = Σ GGᵀ`, `M₂ = Σ GᵀG` and precondition with inverse fourth
//! roots: `ΔW = −α (M₁+γI)^{-1/4} G (M₂+γI)^{-1/4}`.
//!
//! The roots are computed via the Jacobi eigensolver ([`spd_power`]) —
//! the "inverse p-th root" cost that makes Shampoo the slowest
//! per-update algorithm in Table 5, refreshed only every
//! `update_interval` steps in the @10/@50 regimes. Blocking caps the
//! root cost at O(d²·block) instead of O(d³), exactly as in the paper's
//! Shampoo implementation (its dimension cap defaults to 1024 on GPU;
//! scaled here via `HyperParams::shampoo_block`). Uses SGD-magnitude
//! grafting per layer, like Eva-s.

use super::{
    decayed_grads, HyperParams, MomentumState, OptState, Optimizer, StateBuf, StateReader,
    StepCtx, Update,
};
use crate::linalg::spd_power;
use crate::nn::StatsMode;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Tensor};

/// One tile's statistics + cached roots.
struct TileState {
    r0: usize,
    r1: usize,
    c0: usize,
    c1: usize,
    m1: Tensor,
    m2: Tensor,
    l_root: Tensor,
    r_root: Tensor,
}

pub struct Shampoo {
    hp: HyperParams,
    /// Per layer, per tile.
    tiles: Vec<Vec<TileState>>,
    momentum: MomentumState,
    initialized: bool,
    roots_ready: bool,
    pub use_grafting: bool,
}

/// Split `n` into chunks of at most `b`, as (start, end) pairs.
fn chunks(n: usize, b: usize) -> Vec<(usize, usize)> {
    let k = n.div_ceil(b).max(1);
    let base = n.div_ceil(k);
    (0..k)
        .map(|i| (i * base, ((i + 1) * base).min(n)))
        .filter(|(a, b)| a < b)
        .collect()
}

impl Shampoo {
    pub fn new(hp: HyperParams) -> Self {
        Shampoo {
            hp,
            tiles: Vec::new(),
            momentum: MomentumState::new(),
            initialized: false,
            roots_ready: false,
            use_grafting: true,
        }
    }

    pub fn is_refresh_step(&self, step: u64) -> bool {
        step % self.hp.update_interval.max(1) as u64 == 0
    }

    fn init_tiles(&mut self, grads: &[Tensor]) {
        let b = self.hp.shampoo_block.max(8);
        self.tiles = grads
            .iter()
            .map(|g| {
                let mut layer = Vec::new();
                for &(r0, r1) in &chunks(g.rows(), b) {
                    for &(c0, c1) in &chunks(g.cols(), b) {
                        layer.push(TileState {
                            r0,
                            r1,
                            c0,
                            c1,
                            m1: Tensor::zeros(r1 - r0, r1 - r0),
                            m2: Tensor::zeros(c1 - c0, c1 - c0),
                            l_root: Tensor::zeros(0, 0),
                            r_root: Tensor::zeros(0, 0),
                        });
                    }
                }
                layer
            })
            .collect();
        self.initialized = true;
    }

    /// Accumulate per-tile gradient statistics `M₁ += GGᵀ`,
    /// `M₂ += GᵀG`. The products and the `axpy` accumulations run on
    /// the `f32x8` micro-kernels via `tensor`, so accumulation is
    /// bit-identical across backends and ISA paths.
    fn accumulate(&mut self, grads: &[Tensor]) {
        for (layer, g) in self.tiles.iter_mut().zip(grads) {
            for t in layer.iter_mut() {
                let blk = g.submatrix(t.r0, t.r1, t.c0, t.c1);
                t.m1.axpy(1.0, &matmul_a_bt(&blk, &blk));
                t.m2.axpy(1.0, &matmul_at_b(&blk, &blk));
            }
        }
    }

    fn refresh_roots(&mut self) {
        let gamma = self.hp.damping;
        // Every tile's inverse fourth roots are independent — flatten
        // (layer, tile) coordinates and fan the Jacobi eigensolves
        // across the compute backend, then write results back. With
        // many tiles the fan-out wins and each eigensolve runs inline
        // on its pool lane; with a single big tile the fan-out is a
        // no-op and the round-robin parallel Jacobi inside spd_power
        // picks up the lanes instead (backend::current resolution).
        let coords: Vec<(usize, usize)> = self
            .tiles
            .iter()
            .enumerate()
            .flat_map(|(li, layer)| (0..layer.len()).map(move |ti| (li, ti)))
            .collect();
        let bk = crate::backend::current();
        let tiles = &self.tiles;
        let roots = crate::backend::par_map(&*bk, coords.len(), |i| {
            let t = &tiles[coords[i].0][coords[i].1];
            (spd_power(&t.m1, gamma, -0.25), spd_power(&t.m2, gamma, -0.25))
        });
        for ((li, ti), (l_root, r_root)) in coords.into_iter().zip(roots) {
            self.tiles[li][ti].l_root = l_root;
            self.tiles[li][ti].r_root = r_root;
        }
        self.roots_ready = true;
    }
}

impl Optimizer for Shampoo {
    fn name(&self) -> &'static str {
        "shampoo"
    }

    fn stats_mode(&self) -> StatsMode {
        StatsMode::None // statistics come from G itself.
    }

    fn step(&mut self, ctx: &StepCtx) -> Update {
        use crate::telemetry as tm;
        let grads = decayed_grads(ctx, self.hp.weight_decay);
        if !self.initialized {
            self.init_tiles(&grads);
        }
        // Statistics accumulate every step (cheap matmuls); the
        // expensive inverse roots refresh on the interval.
        tm::time_phase("accumulate", &tm::OPTIM_SHAMPOO_ACCUMULATE_US, || {
            self.accumulate(&grads)
        });
        if self.is_refresh_step(ctx.step) || !self.roots_ready {
            tm::time_phase("refresh", &tm::OPTIM_SHAMPOO_REFRESH_US, || self.refresh_roots());
        }
        let pre: Vec<Tensor> =
            tm::time_phase("precondition", &tm::OPTIM_SHAMPOO_PRECONDITION_US, || {
                grads
                    .iter()
                    .zip(&self.tiles)
                    .map(|(g, layer)| {
                        let mut p = Tensor::zeros(g.rows(), g.cols());
                        for t in layer {
                            let blk = g.submatrix(t.r0, t.r1, t.c0, t.c1);
                            let pb = matmul(&matmul(&t.l_root, &blk), &t.r_root);
                            p.paste(t.r0, t.c0, &pb);
                        }
                        p
                    })
                    .collect()
            });
        if tm::health::due(ctx.step) {
            // Read-only sampled health probe (never changes numerics).
            tm::health::sample("shampoo", "damping", self.hp.damping as f64);
            tm::health::sample(
                "shampoo",
                "root_staleness",
                (ctx.step % self.hp.update_interval.max(1) as u64) as f64,
            );
            for (l, g) in grads.iter().enumerate() {
                tm::health::sample_layer("shampoo", "tiles", l, self.tiles[l].len() as f64);
                let (pn, gn) = (pre[l].norm(), g.norm());
                if pn > 0.0 && gn > 0.0 {
                    let cos = pre[l].dot(g) / (pn * gn);
                    tm::health::sample_layer("shampoo", "precond_cosine", l, cos as f64);
                    tm::health::sample_layer("shampoo", "precond_norm_ratio", l, (pn / gn) as f64);
                }
            }
        }
        tm::time_phase("apply", &tm::OPTIM_SHAMPOO_APPLY_US, || {
            let mut pre = pre;
            if self.use_grafting {
                for (p, g) in pre.iter_mut().zip(&grads) {
                    let pn = p.norm_sq();
                    if pn > 1e-24 {
                        p.scale((g.norm_sq() / pn).sqrt());
                    }
                }
            }
            self.momentum.apply(self.hp.momentum, ctx.lr, pre, ctx.bias_grads.to_vec())
        })
    }

    fn state_bytes(&self) -> usize {
        let f: usize = self
            .tiles
            .iter()
            .flatten()
            .map(|t| t.m1.len() + t.m2.len() + t.l_root.len() + t.r_root.len())
            .sum();
        4 * f + self.momentum.state_bytes()
    }

    fn export_state(&self) -> OptState {
        let mut st = OptState::new(self.name());
        st.scalars.push(self.initialized as u64);
        st.scalars.push(self.roots_ready as u64);
        st.scalars.push(self.tiles.len() as u64);
        for layer in &self.tiles {
            st.scalars.push(layer.len() as u64);
            for t in layer {
                st.scalars.push(t.r0 as u64);
                st.scalars.push(t.r1 as u64);
                st.scalars.push(t.c0 as u64);
                st.scalars.push(t.c1 as u64);
            }
        }
        for (li, layer) in self.tiles.iter().enumerate() {
            for (ti, t) in layer.iter().enumerate() {
                st.bufs.push(StateBuf::tensor(format!("t{li}.{ti}.m1"), &t.m1));
                st.bufs.push(StateBuf::tensor(format!("t{li}.{ti}.m2"), &t.m2));
                st.bufs.push(StateBuf::tensor(format!("t{li}.{ti}.lr"), &t.l_root));
                st.bufs.push(StateBuf::tensor(format!("t{li}.{ti}.rr"), &t.r_root));
            }
        }
        self.momentum.export_into(&mut st);
        st
    }

    fn import_state(&mut self, st: &OptState) -> Result<(), String> {
        let mut r = StateReader::open(st, self.name())?;
        self.initialized = r.flag()?;
        self.roots_ready = r.flag()?;
        let nlayers = r.scalar()? as usize;
        let mut coords: Vec<Vec<(usize, usize, usize, usize)>> = Vec::with_capacity(nlayers);
        for _ in 0..nlayers {
            let ntiles = r.scalar()? as usize;
            let mut layer = Vec::with_capacity(ntiles);
            for _ in 0..ntiles {
                let r0 = r.scalar()? as usize;
                let r1 = r.scalar()? as usize;
                let c0 = r.scalar()? as usize;
                let c1 = r.scalar()? as usize;
                layer.push((r0, r1, c0, c1));
            }
            coords.push(layer);
        }
        let mut tiles = Vec::with_capacity(nlayers);
        for (li, layer) in coords.into_iter().enumerate() {
            let mut out = Vec::with_capacity(layer.len());
            for (ti, (r0, r1, c0, c1)) in layer.into_iter().enumerate() {
                out.push(TileState {
                    r0,
                    r1,
                    c0,
                    c1,
                    m1: r.tensor(&format!("t{li}.{ti}.m1"))?,
                    m2: r.tensor(&format!("t{li}.{ti}.m2"))?,
                    l_root: r.tensor(&format!("t{li}.{ti}.lr"))?,
                    r_root: r.tensor(&format!("t{li}.{ti}.rr"))?,
                });
            }
            tiles.push(out);
        }
        self.tiles = tiles;
        self.momentum = MomentumState::import_from(&mut r)?;
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};

    fn plain_hp() -> HyperParams {
        HyperParams { momentum: 0.0, weight_decay: 0.0, ..HyperParams::default() }
    }

    #[test]
    fn chunking_covers_range() {
        for (n, b) in [(10usize, 4usize), (784, 256), (5, 8), (256, 256)] {
            let cs = chunks(n, b);
            assert_eq!(cs[0].0, 0);
            assert_eq!(cs.last().unwrap().1, n);
            for w in cs.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            assert!(cs.iter().all(|(a, b2)| b2 - a <= b));
        }
    }

    /// Diagonal sanity: for a diagonal gradient, Shampoo whitens the
    /// large entries more than the small ones (adaptive behaviour).
    #[test]
    fn whitens_anisotropic_gradients() {
        let mut opt = Shampoo::new(plain_hp());
        opt.use_grafting = false;
        let params = vec![Tensor::zeros(2, 2)];
        let grads = vec![Tensor::from_rows(&[&[10.0, 0.0], &[0.0, 0.1]])];
        let bias = vec![vec![]];
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &[],
            lr: 1.0,
            step: 0,
        };
        let u = opt.step(&ctx);
        let d = &u.deltas[0];
        // Ratio of update magnitudes must be far below the 100× of raw g.
        let ratio = d.at(0, 0).abs() / d.at(1, 1).abs().max(1e-9);
        assert!(ratio < 30.0, "ratio {ratio} (raw would be 100)");
    }

    /// pᵀg > 0 — the preconditioner keeps descent directions.
    #[test]
    fn prop_positive_definite() {
        check("shampoo pᵀg > 0", 10, |g: &mut Gen| {
            let mut opt = Shampoo::new(plain_hp());
            opt.use_grafting = false;
            let (r, c) = (g.usize_in(2, 6), g.usize_in(2, 6));
            let grads = vec![g.normal_tensor(r, c)];
            let params = vec![Tensor::zeros(r, c)];
            let bias = vec![vec![]];
            let ctx = StepCtx {
                params: &params,
                grads: &grads,
                bias_grads: &bias,
                stats: &[],
                lr: 1.0,
                step: 0,
            };
            let u = opt.step(&ctx);
            let pg = -u.deltas[0].dot(&grads[0]);
            if pg > 0.0 {
                Ok(())
            } else {
                Err(format!("pᵀg = {pg}"))
            }
        });
    }

    /// Blocked == unblocked when the tile budget covers the matrix.
    #[test]
    fn blocking_is_transparent_for_small_layers() {
        let mut g = Gen::new(3);
        let grad = g.normal_tensor(6, 5);
        let run = |block: usize| {
            let mut hp = plain_hp();
            hp.shampoo_block = block;
            let mut opt = Shampoo::new(hp);
            opt.use_grafting = false;
            let params = vec![Tensor::zeros(6, 5)];
            let grads = vec![grad.clone()];
            let bias = vec![vec![]];
            let ctx = StepCtx {
                params: &params,
                grads: &grads,
                bias_grads: &bias,
                stats: &[],
                lr: 1.0,
                step: 0,
            };
            opt.step(&ctx).deltas[0].clone()
        };
        // One big tile vs an even bigger budget — identical.
        assert!(run(64).max_abs_diff(&run(1024)) < 1e-6);
        // Tiled run still yields a descent direction.
        let tiled = run(3);
        assert!(tiled.dot(&grad) < 0.0);
    }

    #[test]
    fn interval_skips_root_recomputation() {
        let mut hp = plain_hp();
        hp.update_interval = 10;
        let mut opt = Shampoo::new(hp);
        let params = vec![Tensor::zeros(2, 2)];
        let grads = vec![Tensor::from_rows(&[&[1.0, 0.5], &[0.25, 2.0]])];
        let bias = vec![vec![]];
        let mk = |step| StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &[],
            lr: 1.0,
            step,
        };
        let _ = opt.step(&mk(0));
        let roots_after_0 = opt.tiles[0][0].l_root.clone();
        let _ = opt.step(&mk(1)); // accumulates stats but keeps roots
        assert_eq!(opt.tiles[0][0].l_root, roots_after_0);
        let _ = opt.step(&mk(10)); // refresh step
        assert_ne!(opt.tiles[0][0].l_root, roots_after_0);
    }
}
