//! Adagrad (Duchi et al.) — diagonal adaptive baseline of Table 7 /
//! Fig. 4. Shampoo is its full-matrix generalization, which is the
//! paper's framing for the Eva-s comparison.

use super::{decayed_grads, HyperParams, OptState, Optimizer, StateBuf, StateReader, StepCtx, Update};
use crate::nn::StatsMode;
use crate::tensor::Tensor;

pub struct Adagrad {
    hp: HyperParams,
    accum_w: Vec<Tensor>,
    accum_b: Vec<Vec<f32>>,
    initialized: bool,
}

impl Adagrad {
    pub fn new(hp: HyperParams) -> Self {
        Adagrad { hp, accum_w: Vec::new(), accum_b: Vec::new(), initialized: false }
    }
}

impl Optimizer for Adagrad {
    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn stats_mode(&self) -> StatsMode {
        StatsMode::None
    }

    fn step(&mut self, ctx: &StepCtx) -> Update {
        let grads = decayed_grads(ctx, self.hp.weight_decay);
        if !self.initialized {
            self.accum_w = grads.iter().map(|g| Tensor::zeros(g.rows(), g.cols())).collect();
            self.accum_b = ctx.bias_grads.iter().map(|b| vec![0.0; b.len()]).collect();
            self.initialized = true;
        }
        let eps = self.hp.eps.max(1e-10);
        let mut deltas = Vec::with_capacity(grads.len());
        for (acc, g) in self.accum_w.iter_mut().zip(&grads) {
            let mut d = g.clone();
            for (av, (dv, &gv)) in
                acc.data_mut().iter_mut().zip(d.data_mut().iter_mut().zip(g.data()))
            {
                *av += gv * gv;
                *dv = -ctx.lr * gv / (av.sqrt() + eps);
            }
            deltas.push(d);
        }
        let mut bias_deltas = Vec::with_capacity(ctx.bias_grads.len());
        for (acc, g) in self.accum_b.iter_mut().zip(ctx.bias_grads) {
            let mut d = Vec::with_capacity(g.len());
            for (av, &gv) in acc.iter_mut().zip(g) {
                *av += gv * gv;
                d.push(-ctx.lr * gv / (av.sqrt() + eps));
            }
            bias_deltas.push(d);
        }
        Update { deltas, bias_deltas }
    }

    fn state_bytes(&self) -> usize {
        let w: usize = self.accum_w.iter().map(|t| t.len()).sum();
        let b: usize = self.accum_b.iter().map(|v| v.len()).sum();
        4 * (w + b)
    }

    fn export_state(&self) -> OptState {
        let mut st = OptState::new(self.name());
        st.scalars.push(self.initialized as u64);
        st.scalars.push(self.accum_w.len() as u64);
        st.scalars.push(self.accum_b.len() as u64);
        for (i, t) in self.accum_w.iter().enumerate() {
            st.bufs.push(StateBuf::tensor(format!("acc.w{i}"), t));
        }
        for (i, v) in self.accum_b.iter().enumerate() {
            st.bufs.push(StateBuf::vecf(format!("acc.b{i}"), v));
        }
        st
    }

    fn import_state(&mut self, st: &OptState) -> Result<(), String> {
        let mut r = StateReader::open(st, self.name())?;
        self.initialized = r.flag()?;
        let nw = r.scalar()? as usize;
        let nb = r.scalar()? as usize;
        self.accum_w = (0..nw)
            .map(|i| r.tensor(&format!("acc.w{i}")))
            .collect::<Result<_, _>>()?;
        self.accum_b = (0..nb)
            .map(|i| r.vecf(&format!("acc.b{i}")))
            .collect::<Result<_, _>>()?;
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_size_shrinks_over_time() {
        let mut hp = HyperParams::default();
        hp.weight_decay = 0.0;
        let mut opt = Adagrad::new(hp);
        let params = vec![Tensor::full(1, 1, 0.0)];
        let grads = vec![Tensor::full(1, 1, 1.0)];
        let bias_grads = vec![vec![]];
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias_grads,
            stats: &[],
            lr: 1.0,
            step: 0,
        };
        let d1 = opt.step(&ctx).deltas[0].data()[0].abs();
        let d2 = opt.step(&ctx).deltas[0].data()[0].abs();
        let d3 = opt.step(&ctx).deltas[0].data()[0].abs();
        assert!(d1 > d2 && d2 > d3, "{d1} {d2} {d3}");
        // First step ≈ lr (accumulator = g²).
        assert!((d1 - 1.0).abs() < 1e-3);
    }
}
