//! **Eva** — the paper's core contribution (Eq. 9–16).
//!
//! Per layer, keep running-average Kronecker vectors
//! `ā = mean-col(A)`, `b̄ = mean-col(B)` (Eq. 10, 14–15) and precondition
//! with the damped rank-one curvature
//! `C = (b̄b̄ᵀ) ⊗ (āāᵀ)` via Sherman–Morrison (Eq. 12), giving the
//! closed-form update
//!
//! ```text
//! ΔW = −(α/γ) ( G − (b̄ᵀ G ā)/(γ + (āᵀā)(b̄ᵀb̄)) · b̄ āᵀ )      (Eq. 13)
//! ```
//!
//! O(d²L) time (a matvec + an outer product per layer — same order as
//! reading the gradient) and O(2dL) state. Stabilized by KL clipping
//! (Eq. 16) and momentum on the preconditioned gradient, exactly like
//! the paper's K-FAC practice.
//!
//! Ablation switches (`use_momentum`, `use_kl_clip`, `use_kvs`)
//! reproduce Table 9; `use_kvs = false` replaces the KV Kronecker
//! structure with a rank-one curvature built from the normalized
//! gradient itself (the paper's "w/o KVs" control: same computation
//! shape, no activation information).

use super::{
    decayed_grads, kl_clip_factor, HyperParams, MomentumState, OptState, Optimizer, StateBuf,
    StateReader, StepCtx, Update,
};
use crate::nn::StatsMode;
use crate::tensor::{dot, Tensor};

pub struct Eva {
    hp: HyperParams,
    /// Ablation: momentum on the preconditioned gradient (Table 9 "w/o m.").
    pub use_momentum: bool,
    /// Ablation: KL clipping (Table 9 "w/o KL clip").
    pub use_kl_clip: bool,
    /// Ablation: Kronecker vectors (Table 9 "w/o KVs").
    pub use_kvs: bool,
    /// Running-average KV state per layer.
    a_bar: Vec<Vec<f32>>,
    b_bar: Vec<Vec<f32>>,
    momentum: MomentumState,
    initialized: bool,
}

impl Eva {
    pub fn new(hp: HyperParams) -> Self {
        Eva {
            hp,
            use_momentum: true,
            use_kl_clip: true,
            use_kvs: true,
            a_bar: Vec::new(),
            b_bar: Vec::new(),
            momentum: MomentumState::new(),
            initialized: false,
        }
    }

    /// Update the running-average KVs (Eq. 14–15); first step copies.
    /// The per-layer blends run on the `f32x8` elementwise kernel
    /// (`ā ← (1−ξ)·ā + ξ·ā_new`, same arithmetic as the plain loop —
    /// IEEE addition is commutative — on every ISA path).
    fn update_kvs(&mut self, ctx: &StepCtx) {
        let xi = self.hp.running_avg;
        if !self.initialized {
            self.a_bar = ctx.stats.iter().map(|s| s.a_mean.clone()).collect();
            self.b_bar = ctx.stats.iter().map(|s| s.b_mean.clone()).collect();
            self.initialized = true;
            return;
        }
        for (state, s) in self.a_bar.iter_mut().zip(ctx.stats) {
            crate::simd::blend8(state, 1.0 - xi, xi, &s.a_mean);
        }
        for (state, s) in self.b_bar.iter_mut().zip(ctx.stats) {
            crate::simd::blend8(state, 1.0 - xi, xi, &s.b_mean);
        }
    }

    /// Eq. 13 on one layer: p = (1/γ)(G − coeff · b̄āᵀ).
    fn precondition_layer(g: &Tensor, a_bar: &[f32], b_bar: &[f32], gamma: f32) -> Tensor {
        // b̄ᵀ G ā: one matvec + one dot — O(d²).
        let ga = g.matvec(a_bar); // (d_out)
        let num = dot(&ga, b_bar);
        let denom = gamma + dot(a_bar, a_bar) * dot(b_bar, b_bar);
        let coeff = num / denom;
        let mut p = g.clone();
        p.add_outer(-coeff, b_bar, a_bar);
        p.scale(1.0 / gamma);
        p
    }

    /// "w/o KVs" control: rank-one curvature from the normalized
    /// gradient, v = g/‖g‖ → p = (1/γ)(G − (vᵀg)/(γ+1)·V).
    fn precondition_layer_gradonly(g: &Tensor, gamma: f32) -> Tensor {
        let gn = g.norm();
        if gn < 1e-12 {
            let mut p = g.clone();
            p.scale(1.0 / gamma);
            return p;
        }
        // v = g/‖g‖ (flattened); vᵀ g = ‖g‖; vᵀv = 1.
        let coeff = gn / (gamma + 1.0);
        let mut p = g.clone();
        p.axpy(-coeff / gn, g);
        p.scale(1.0 / gamma);
        p
    }

    /// Sampled per-layer health probe: Sherman–Morrison denominator /
    /// coefficient, KV norms, preconditioned-vs-raw cosine and norm
    /// ratio. Read-only (recomputes one matvec per layer on the
    /// calling thread) — never touches optimizer state or numerics.
    fn record_health(&self, grads: &[Tensor], pre: &[Tensor], gamma: f32) {
        use crate::telemetry::health;
        health::sample("eva", "damping", gamma as f64);
        for l in 0..grads.len() {
            if self.use_kvs {
                let (a, b) = (&self.a_bar[l], &self.b_bar[l]);
                let (na2, nb2) = (dot(a, a), dot(b, b));
                let denom = gamma + na2 * nb2;
                let coeff = dot(&grads[l].matvec(a), b) / denom;
                health::sample_layer("eva", "sm_denom", l, denom as f64);
                health::sample_layer("eva", "sm_coeff", l, coeff as f64);
                health::sample_layer("eva", "kv_a_norm", l, (na2 as f64).sqrt());
                health::sample_layer("eva", "kv_b_norm", l, (nb2 as f64).sqrt());
            }
            let (pn, gn) = (pre[l].norm(), grads[l].norm());
            if pn > 0.0 && gn > 0.0 {
                let cos = pre[l].dot(&grads[l]) / (pn * gn);
                health::sample_layer("eva", "precond_cosine", l, cos as f64);
                health::sample_layer("eva", "precond_norm_ratio", l, (pn / gn) as f64);
            }
        }
    }
}

impl Optimizer for Eva {
    fn name(&self) -> &'static str {
        "eva"
    }

    fn stats_mode(&self) -> StatsMode {
        if self.use_kvs {
            StatsMode::KvOnly
        } else {
            StatsMode::None
        }
    }

    fn step(&mut self, ctx: &StepCtx) -> Update {
        use crate::telemetry as tm;
        let gamma = self.hp.damping;
        let grads = decayed_grads(ctx, self.hp.weight_decay);
        // Layers are independent; fan the rank-one preconditioning
        // across the compute backend (identical per-layer arithmetic).
        let bk = crate::backend::current();
        let pre: Vec<Tensor> = if self.use_kvs {
            tm::time_phase("kv_refresh", &tm::OPTIM_EVA_KV_REFRESH_US, || self.update_kvs(ctx));
            let (a_bar, b_bar) = (&self.a_bar, &self.b_bar);
            tm::time_phase("precondition", &tm::OPTIM_EVA_PRECONDITION_US, || {
                crate::backend::par_map(&*bk, grads.len(), |l| {
                    Self::precondition_layer(&grads[l], &a_bar[l], &b_bar[l], gamma)
                })
            })
        } else {
            tm::time_phase("precondition", &tm::OPTIM_EVA_PRECONDITION_US, || {
                crate::backend::par_map(&*bk, grads.len(), |l| {
                    Self::precondition_layer_gradonly(&grads[l], gamma)
                })
            })
        };
        if tm::health::due(ctx.step) {
            self.record_health(&grads, &pre, gamma);
        }
        tm::time_phase("apply", &tm::OPTIM_EVA_APPLY_US, || {
            // KL clipping over weight tensors (Eq. 16).
            let mut pre = pre;
            if self.use_kl_clip {
                let pg = super::pg_inner(&pre, &grads);
                let nu = kl_clip_factor(self.hp.kl_clip, ctx.lr, pg);
                if nu < 1.0 {
                    for p in &mut pre {
                        p.scale(nu);
                    }
                }
            }
            // Biases follow SGD (paper: non-supported params update by
            // SGD).
            let mu = if self.use_momentum { self.hp.momentum } else { 0.0 };
            self.momentum.apply(mu, ctx.lr, pre, ctx.bias_grads.to_vec())
        })
    }

    fn state_bytes(&self) -> usize {
        let kv: usize = self.a_bar.iter().chain(&self.b_bar).map(|v| v.len()).sum();
        4 * kv + self.momentum.state_bytes()
    }

    fn export_state(&self) -> OptState {
        let mut st = OptState::new(self.name());
        st.scalars.push(self.initialized as u64);
        st.scalars.push(self.a_bar.len() as u64);
        for (i, v) in self.a_bar.iter().enumerate() {
            st.bufs.push(StateBuf::vecf(format!("kv.a{i}"), v));
        }
        for (i, v) in self.b_bar.iter().enumerate() {
            st.bufs.push(StateBuf::vecf(format!("kv.b{i}"), v));
        }
        self.momentum.export_into(&mut st);
        st
    }

    fn import_state(&mut self, st: &OptState) -> Result<(), String> {
        let mut r = StateReader::open(st, self.name())?;
        self.initialized = r.flag()?;
        let n = r.scalar()? as usize;
        self.a_bar = (0..n).map(|i| r.vecf(&format!("kv.a{i}"))).collect::<Result<_, _>>()?;
        self.b_bar = (0..n).map(|i| r.vecf(&format!("kv.b{i}"))).collect::<Result<_, _>>()?;
        self.momentum = MomentumState::import_from(&mut r)?;
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::spd_inverse;
    use crate::nn::LayerStats;
    use crate::testing::{check, tensors_close, Gen};

    fn hp_plain() -> HyperParams {
        HyperParams {
            momentum: 0.0,
            weight_decay: 0.0,
            kl_clip: 1e9, // effectively off
            running_avg: 1.0,
            ..HyperParams::default()
        }
    }

    fn stats_for(a: &[f32], b: &[f32]) -> LayerStats {
        LayerStats { a_mean: a.to_vec(), b_mean: b.to_vec(), aat: None, bbt: None }
    }

    /// Eq. 13 equals the dense preconditioner (C+γI)⁻¹ g where
    /// C = (b̄⊗ā)(b̄⊗ā)ᵀ — the Sherman–Morrison identity end to end.
    #[test]
    fn prop_matches_dense_kronecker_inverse() {
        check("eva == dense (C+γI)⁻¹g", 20, |g: &mut Gen| {
            let d_out = g.usize_in(2, 6);
            let d_in = g.usize_in(2, 6);
            let gamma = g.f32_in(0.05, 0.5);
            let grad = g.normal_tensor(d_out, d_in);
            let a = g.normal_vec(d_in);
            let b = g.normal_vec(d_out);
            // Fast path.
            let p = Eva::precondition_layer(&grad, &a, &b, gamma);
            // Dense path: v = b ⊗ a (row-major flatten of b aᵀ).
            let n = d_out * d_in;
            let mut v = vec![0.0f32; n];
            for i in 0..d_out {
                for j in 0..d_in {
                    v[i * d_in + j] = b[i] * a[j];
                }
            }
            let mut c = Tensor::zeros(n, n);
            c.add_outer(1.0, &v, &v);
            c.add_diag(gamma);
            let cinv = spd_inverse(&c).map_err(|e| e)?;
            let pg = cinv.matvec(grad.data());
            let dense = Tensor::from_vec(d_out, d_in, pg);
            tensors_close(&p, &dense, 2e-2, "eva vs dense")
        });
    }

    /// γ→∞ makes Eva converge to (1/γ)·SGD direction.
    #[test]
    fn large_damping_recovers_sgd_direction() {
        let grad = Tensor::from_rows(&[&[1.0, -2.0], &[0.5, 0.25]]);
        let p = Eva::precondition_layer(&grad, &[0.3, -0.1], &[0.2, 0.9], 1e6);
        let mut expect = grad.clone();
        expect.scale(1e-6);
        assert!(p.max_abs_diff(&expect) < 1e-9);
    }

    /// The preconditioner is positive definite: pᵀg > 0 for g ≠ 0.
    #[test]
    fn prop_preconditioner_positive_definite() {
        check("pᵀg > 0", 30, |g: &mut Gen| {
            let (r, c) = (g.usize_in(1, 8), g.usize_in(1, 8));
            let grad = g.normal_tensor(r, c);
            let a = g.normal_vec(grad.cols());
            let b = g.normal_vec(grad.rows());
            let p = Eva::precondition_layer(&grad, &a, &b, g.f32_in(0.01, 1.0));
            if p.dot(&grad) > 0.0 {
                Ok(())
            } else {
                Err(format!("pᵀg = {}", p.dot(&grad)))
            }
        });
    }

    #[test]
    fn full_step_runs_and_reports_state() {
        let mut opt = Eva::new(hp_plain());
        let params = vec![Tensor::zeros(3, 4)];
        let grads = vec![Tensor::full(3, 4, 0.1)];
        let bias = vec![vec![0.0; 3]];
        let stats = vec![stats_for(&[0.1, 0.2, 0.3, 0.4], &[0.5, 0.1, -0.2])];
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &stats,
            lr: 0.1,
            step: 0,
        };
        let u = opt.step(&ctx);
        assert_eq!(u.deltas[0].shape(), (3, 4));
        // KV state: 4 + 3 floats, plus momentum buffers.
        assert!(opt.state_bytes() >= 4 * 7);
        // KV memory is sublinear vs the 12-float gradient.
        assert!(opt.state_bytes() <= 4 * (7 + 12 + 3));
    }

    #[test]
    fn running_average_tracks_new_kvs() {
        let mut hp = hp_plain();
        hp.running_avg = 0.5;
        let mut opt = Eva::new(hp);
        let params = vec![Tensor::zeros(1, 2)];
        let grads = vec![Tensor::full(1, 2, 0.1)];
        let bias = vec![vec![]];
        let s1 = vec![stats_for(&[1.0, 1.0], &[1.0])];
        let ctx1 = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &s1,
            lr: 0.1,
            step: 0,
        };
        let _ = opt.step(&ctx1);
        assert_eq!(opt.a_bar[0], vec![1.0, 1.0]);
        let s2 = vec![stats_for(&[3.0, 3.0], &[1.0])];
        let ctx2 = StepCtx { stats: &s2, step: 1, ..ctx1 };
        let _ = opt.step(&ctx2);
        // 0.5*new + 0.5*old = 2.0
        assert_eq!(opt.a_bar[0], vec![2.0, 2.0]);
    }

    #[test]
    fn kl_clip_bounds_update_size() {
        let mut hp = HyperParams::default();
        hp.momentum = 0.0;
        hp.weight_decay = 0.0;
        hp.kl_clip = 1e-4;
        hp.damping = 0.001; // aggressive 1/γ scale → clip must engage
        let mut opt = Eva::new(hp.clone());
        let params = vec![Tensor::zeros(2, 2)];
        let grads = vec![Tensor::full(2, 2, 1.0)];
        let bias = vec![vec![]];
        let stats = vec![stats_for(&[0.1, 0.1], &[0.1, 0.1])];
        let lr = 0.1;
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &stats,
            lr,
            step: 0,
        };
        let u = opt.step(&ctx);
        // Reference: the same step without clipping gives p_orig; the
        // clipped delta must equal ν·p_orig with ν from Eq. 16, so the
        // quadratic KL proxy α²ν²p_origᵀg is capped at κ.
        let mut unclipped = Eva::new(HyperParams { kl_clip: f32::MAX, ..hp.clone() });
        let u0 = unclipped.step(&ctx);
        let p_orig_g: f32 = u0.deltas[0]
            .data()
            .iter()
            .zip(grads[0].data())
            .map(|(d, g)| (-d / lr) * g)
            .sum();
        let nu = kl_clip_factor(hp.kl_clip, lr, p_orig_g);
        assert!(nu < 1.0, "clip must engage (ν = {nu})");
        let mut expect = u0.deltas[0].clone();
        expect.scale(nu);
        assert!(u.deltas[0].max_abs_diff(&expect) < 1e-6);
        // Quadratic KL after clipping: α²·ν²·p_origᵀg == κ.
        let kl = lr * lr * nu * nu * p_orig_g;
        assert!((kl - hp.kl_clip).abs() < 1e-6, "KL after clip {kl}");
    }

    #[test]
    fn without_kvs_uses_gradient_direction() {
        let mut opt = Eva::new(hp_plain());
        opt.use_kvs = false;
        assert_eq!(opt.stats_mode(), StatsMode::None);
        let params = vec![Tensor::zeros(2, 2)];
        let grads = vec![Tensor::full(2, 2, 0.5)];
        let bias = vec![vec![]];
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &[],
            lr: 1.0,
            step: 0,
        };
        let u = opt.step(&ctx);
        // Direction must stay parallel to g (rank-one built from g).
        let d = &u.deltas[0];
        let cos = -d.dot(&grads[0]) / (d.norm() * grads[0].norm());
        assert!((cos - 1.0).abs() < 1e-5, "cos {cos}");
    }
}
