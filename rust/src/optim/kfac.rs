//! K-FAC (Martens & Grosse) — the primary second-order baseline (Eq. 4–5).
//!
//! Keeps running-average Kronecker factors `Q = BBᵀ/n`, `R = AAᵀ/n` per
//! layer and preconditions `ΔW = −α (Q+γ_L I)⁻¹ G (R+γ_R I)⁻¹` with the
//! factored Tikhonov damping split `γ_L = √γ/π`, `γ_R = π√γ`,
//! `π = √((tr(R)/d_R)/(tr(Q)/d_Q))`.
//!
//! The `update_interval` hyper-parameter reproduces the paper's
//! K-FAC@10 / K-FAC@50 regimes (Table 5, Fig. 6): factors and their
//! inverses are refreshed only every T steps and the *stale* inverses
//! precondition the fresh gradient in between — exactly the staleness
//! Eva avoids. On refresh steps the backward pass must capture full
//! KFs (`StatsMode::Full`, the O(d²) cost); on other steps no
//! statistics are needed.

use super::{
    decayed_grads, kl_clip_factor, HyperParams, MomentumState, OptState, Optimizer, StateBuf,
    StateReader, StepCtx, Update,
};
use crate::linalg::damped_inverse;
use crate::nn::StatsMode;
use crate::tensor::{matmul, Tensor};

pub struct Kfac {
    hp: HyperParams,
    /// Running factors.
    q: Vec<Tensor>,
    r: Vec<Tensor>,
    /// Cached damped inverses (refreshed every `update_interval`).
    q_inv: Vec<Tensor>,
    r_inv: Vec<Tensor>,
    momentum: MomentumState,
    initialized: bool,
}

impl Kfac {
    pub fn new(hp: HyperParams) -> Self {
        Kfac {
            hp,
            q: Vec::new(),
            r: Vec::new(),
            q_inv: Vec::new(),
            r_inv: Vec::new(),
            momentum: MomentumState::new(),
            initialized: false,
        }
    }

    /// True on steps where factors + inverses are recomputed.
    pub fn is_refresh_step(&self, step: u64) -> bool {
        step % self.hp.update_interval.max(1) as u64 == 0
    }

    /// Refresh the running KFs and their damped inverses. The factor
    /// blends (`Q ← (1−ξ)Q + ξ·BBᵀ/n`, likewise `R`) and the Cholesky
    /// solves inside `damped_inverse` stream through the `f32x8`
    /// micro-kernels ([`crate::simd`] via `tensor`/`linalg`), so a
    /// refresh is bit-identical across backends and ISA paths.
    fn refresh(&mut self, ctx: &StepCtx) {
        let xi = self.hp.running_avg;
        if !self.initialized {
            self.q = ctx.stats.iter().map(|s| s.bbt.clone().expect("kfac needs Full stats")).collect();
            self.r = ctx.stats.iter().map(|s| s.aat.clone().unwrap()).collect();
            self.initialized = true;
        } else {
            for (state, s) in self.q.iter_mut().zip(ctx.stats) {
                state.blend(1.0 - xi, xi, s.bbt.as_ref().unwrap());
            }
            for (state, s) in self.r.iter_mut().zip(ctx.stats) {
                state.blend(1.0 - xi, xi, s.aat.as_ref().unwrap());
            }
        }
        let gamma = self.hp.damping;
        // Per-layer factorizations are independent — fan the damped
        // Cholesky inverses (the O(d³) cost Eva eliminates) across the
        // compute backend; each layer's arithmetic is unchanged. On a
        // single-layer model the fan-out is a no-op and the column
        // solves inside spd_inverse parallelize instead.
        let bk = crate::backend::current();
        let (q, r) = (&self.q, &self.r);
        let inverses = crate::backend::par_map(&*bk, q.len(), |l| {
            let (q, r) = (&q[l], &r[l]);
            let tq = (trace(q) / q.rows() as f32).max(1e-8);
            let tr = (trace(r) / r.rows() as f32).max(1e-8);
            let pi = (tr / tq).sqrt();
            let gamma_l = (gamma.sqrt() / pi).max(1e-8);
            let gamma_r = (pi * gamma.sqrt()).max(1e-8);
            (
                damped_inverse(q, gamma_l).expect("Q+γI must be PD"),
                damped_inverse(r, gamma_r).expect("R+γI must be PD"),
            )
        });
        let (q_inv, r_inv): (Vec<Tensor>, Vec<Tensor>) = inverses.into_iter().unzip();
        self.q_inv = q_inv;
        self.r_inv = r_inv;
    }
}

fn trace(m: &Tensor) -> f32 {
    (0..m.rows()).map(|i| m.at(i, i)).sum()
}

impl Optimizer for Kfac {
    fn name(&self) -> &'static str {
        "kfac"
    }

    /// Worst-case requirement (refresh steps). The trainer should use
    /// [`Optimizer::stats_mode_at`] for per-step precision.
    fn stats_mode(&self) -> StatsMode {
        StatsMode::Full
    }

    fn step(&mut self, ctx: &StepCtx) -> Update {
        use crate::telemetry as tm;
        if self.is_refresh_step(ctx.step) {
            tm::time_phase("refresh", &tm::OPTIM_KFAC_REFRESH_US, || self.refresh(ctx));
        }
        assert!(self.initialized, "first K-FAC step must be a refresh step");
        let grads = decayed_grads(ctx, self.hp.weight_decay);
        let bk = crate::backend::current();
        let (q_inv, r_inv) = (&self.q_inv, &self.r_inv);
        let pre: Vec<Tensor> = tm::time_phase("precondition", &tm::OPTIM_KFAC_PRECONDITION_US, || {
            crate::backend::par_map(&*bk, grads.len(), |l| {
                matmul(&matmul(&q_inv[l], &grads[l]), &r_inv[l])
            })
        });
        if tm::health::due(ctx.step) {
            // Read-only sampled health probe: factored-damping split,
            // factor staleness and preconditioned-vs-raw geometry.
            let gamma = self.hp.damping;
            tm::health::sample("kfac", "damping", gamma as f64);
            tm::health::sample(
                "kfac",
                "factor_staleness",
                (ctx.step % self.hp.update_interval.max(1) as u64) as f64,
            );
            for l in 0..grads.len() {
                let (q, r) = (&self.q[l], &self.r[l]);
                let tq = (trace(q) / q.rows() as f32).max(1e-8);
                let tr = (trace(r) / r.rows() as f32).max(1e-8);
                let pi = (tr / tq).sqrt();
                tm::health::sample_layer("kfac", "pi", l, pi as f64);
                let (gl, gr) = ((gamma.sqrt() / pi).max(1e-8), (pi * gamma.sqrt()).max(1e-8));
                tm::health::sample_layer("kfac", "gamma_l", l, gl as f64);
                tm::health::sample_layer("kfac", "gamma_r", l, gr as f64);
                let (pn, gn) = (pre[l].norm(), grads[l].norm());
                if pn > 0.0 && gn > 0.0 {
                    let cos = pre[l].dot(&grads[l]) / (pn * gn);
                    tm::health::sample_layer("kfac", "precond_cosine", l, cos as f64);
                    tm::health::sample_layer("kfac", "precond_norm_ratio", l, (pn / gn) as f64);
                }
            }
        }
        tm::time_phase("apply", &tm::OPTIM_KFAC_APPLY_US, || {
            let mut pre = pre;
            let pg = super::pg_inner(&pre, &grads);
            let nu = kl_clip_factor(self.hp.kl_clip, ctx.lr, pg);
            if nu < 1.0 {
                for p in &mut pre {
                    p.scale(nu);
                }
            }
            self.momentum.apply(self.hp.momentum, ctx.lr, pre, ctx.bias_grads.to_vec())
        })
    }

    fn state_bytes(&self) -> usize {
        let f: usize = self
            .q
            .iter()
            .chain(&self.r)
            .chain(&self.q_inv)
            .chain(&self.r_inv)
            .map(|t| t.len())
            .sum();
        4 * f + self.momentum.state_bytes()
    }

    /// Full KFs only on refresh steps.
    fn stats_mode_at(&self, step: u64) -> StatsMode {
        if self.is_refresh_step(step) {
            StatsMode::Full
        } else {
            StatsMode::None
        }
    }

    fn export_state(&self) -> OptState {
        let mut st = OptState::new(self.name());
        st.scalars.push(self.initialized as u64);
        st.scalars.push(self.q.len() as u64);
        st.scalars.push(self.q_inv.len() as u64);
        for (i, t) in self.q.iter().enumerate() {
            st.bufs.push(StateBuf::tensor(format!("kf.q{i}"), t));
        }
        for (i, t) in self.r.iter().enumerate() {
            st.bufs.push(StateBuf::tensor(format!("kf.r{i}"), t));
        }
        for (i, t) in self.q_inv.iter().enumerate() {
            st.bufs.push(StateBuf::tensor(format!("kf.qinv{i}"), t));
        }
        for (i, t) in self.r_inv.iter().enumerate() {
            st.bufs.push(StateBuf::tensor(format!("kf.rinv{i}"), t));
        }
        self.momentum.export_into(&mut st);
        st
    }

    fn import_state(&mut self, st: &OptState) -> Result<(), String> {
        let mut r = StateReader::open(st, self.name())?;
        self.initialized = r.flag()?;
        let n = r.scalar()? as usize;
        let ninv = r.scalar()? as usize;
        self.q = (0..n).map(|i| r.tensor(&format!("kf.q{i}"))).collect::<Result<_, _>>()?;
        self.r = (0..n).map(|i| r.tensor(&format!("kf.r{i}"))).collect::<Result<_, _>>()?;
        self.q_inv =
            (0..ninv).map(|i| r.tensor(&format!("kf.qinv{i}"))).collect::<Result<_, _>>()?;
        self.r_inv =
            (0..ninv).map(|i| r.tensor(&format!("kf.rinv{i}"))).collect::<Result<_, _>>()?;
        self.momentum = MomentumState::import_from(&mut r)?;
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerStats;
    use crate::testing::{check, tensors_close, Gen};

    fn full_stats(g: &mut Gen, d_in: usize, d_out: usize) -> LayerStats {
        LayerStats {
            a_mean: g.normal_vec(d_in),
            b_mean: g.normal_vec(d_out),
            aat: Some(g.spd_tensor(d_in, 0.01)),
            bbt: Some(g.spd_tensor(d_out, 0.01)),
        }
    }

    fn plain_hp() -> HyperParams {
        HyperParams {
            momentum: 0.0,
            weight_decay: 0.0,
            kl_clip: 1e9,
            running_avg: 1.0,
            ..HyperParams::default()
        }
    }

    /// With Q = I and R = I the K-FAC step reduces to scaled SGD.
    #[test]
    fn identity_factors_give_sgd_direction() {
        let mut opt = Kfac::new(plain_hp());
        let params = vec![Tensor::zeros(3, 3)];
        let grads = vec![Tensor::from_rows(&[&[1.0, 0.0, 0.0], &[0.0, 2.0, 0.0], &[0.0, 0.0, 3.0]])];
        let bias = vec![vec![]];
        let stats = vec![LayerStats {
            a_mean: vec![0.0; 3],
            b_mean: vec![0.0; 3],
            aat: Some(Tensor::eye(3)),
            bbt: Some(Tensor::eye(3)),
        }];
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &stats,
            lr: 1.0,
            step: 0,
        };
        let u = opt.step(&ctx);
        // Q=R=I, π=1 → scale 1/(1+√γ)² uniformly: direction == −g dir.
        let d = &u.deltas[0];
        let cos = -d.dot(&grads[0]) / (d.norm() * grads[0].norm());
        assert!((cos - 1.0).abs() < 1e-5, "cos {cos}");
    }

    /// Preconditioner is PD: pᵀg > 0.
    #[test]
    fn prop_positive_definite() {
        check("kfac pᵀg > 0", 10, |g: &mut Gen| {
            let d_in = g.usize_in(2, 6);
            let d_out = g.usize_in(2, 6);
            let mut opt = Kfac::new(plain_hp());
            let params = vec![Tensor::zeros(d_out, d_in)];
            let grads = vec![g.normal_tensor(d_out, d_in)];
            let bias = vec![vec![]];
            let stats = vec![full_stats(g, d_in, d_out)];
            let ctx = StepCtx {
                params: &params,
                grads: &grads,
                bias_grads: &bias,
                stats: &stats,
                lr: 1.0,
                step: 0,
            };
            let u = opt.step(&ctx);
            let pg = -u.deltas[0].dot(&grads[0]);
            if pg > 0.0 {
                Ok(())
            } else {
                Err(format!("pᵀg = {pg}"))
            }
        });
    }

    /// Interval > 1 reuses stale inverses — steps 1..T-1 need no stats
    /// and must produce identical preconditioning to step 0's factors.
    #[test]
    fn stale_inverses_reused_between_refreshes() {
        let mut g = Gen::new(42);
        let mut hp = plain_hp();
        hp.update_interval = 5;
        let mut opt = Kfac::new(hp);
        let params = vec![Tensor::zeros(4, 4)];
        let grads = vec![g.normal_tensor(4, 4)];
        let bias = vec![vec![]];
        let stats = vec![full_stats(&mut g, 4, 4)];
        let ctx0 = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &stats,
            lr: 1.0,
            step: 0,
        };
        assert_eq!(opt.stats_mode_at(0), StatsMode::Full);
        assert_eq!(opt.stats_mode_at(3), StatsMode::None);
        let u0 = opt.step(&ctx0);
        // Step 1: no stats provided; same gradient → same delta (no
        // momentum), because inverses are cached.
        let ctx1 = StepCtx { stats: &[], step: 1, ..ctx0 };
        let u1 = opt.step(&ctx1);
        tensors_close(&u0.deltas[0], &u1.deltas[0], 1e-6, "stale reuse").unwrap();
    }

    #[test]
    fn state_accounts_factors_and_inverses() {
        let mut g = Gen::new(1);
        let mut opt = Kfac::new(plain_hp());
        let params = vec![Tensor::zeros(3, 5)];
        let grads = vec![g.normal_tensor(3, 5)];
        let bias = vec![vec![]];
        let stats = vec![full_stats(&mut g, 5, 3)];
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &stats,
            lr: 0.1,
            step: 0,
        };
        let _ = opt.step(&ctx);
        // Q,Qinv: 9 each; R,Rinv: 25 each; momentum: 15 (+0 bias).
        assert_eq!(opt.state_bytes(), 4 * (2 * 9 + 2 * 25 + 15));
    }
}
