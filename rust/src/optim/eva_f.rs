//! **Eva-f** — vectorized FOOF (§4.1, Eq. 20–21).
//!
//! Replaces FOOF's Kronecker factor `R = AAᵀ` with the rank-one
//! `āāᵀ`, so the damped inverse is closed-form:
//!
//! ```text
//! ΔW = −(α/γ) ( G − (G ā) āᵀ / (γ + āᵀā) )                    (Eq. 21)
//! ```
//!
//! Stabilized by **KL normalization** instead of clipping (§4.1): the
//! preconditioned gradients are scaled by `1/√(Σ_l p_lᵀ g_l)`, removing
//! the κ hyper-parameter entirely.

use super::{
    decayed_grads, HyperParams, MomentumState, OptState, Optimizer, StateBuf, StateReader,
    StepCtx, Update,
};
use crate::nn::StatsMode;
use crate::tensor::{dot, Tensor};

pub struct EvaF {
    hp: HyperParams,
    a_bar: Vec<Vec<f32>>,
    momentum: MomentumState,
    initialized: bool,
    /// KL normalization (on by default; off recovers raw Eq. 21).
    pub use_kl_norm: bool,
}

impl EvaF {
    pub fn new(hp: HyperParams) -> Self {
        EvaF {
            hp,
            a_bar: Vec::new(),
            momentum: MomentumState::new(),
            initialized: false,
            use_kl_norm: true,
        }
    }

    /// Eq. 21 on one layer.
    fn precondition_layer(g: &Tensor, a_bar: &[f32], gamma: f32) -> Tensor {
        let ga = g.matvec(a_bar); // (d_out)
        let denom = gamma + dot(a_bar, a_bar);
        let mut p = g.clone();
        p.add_outer(-1.0 / denom, &ga, a_bar);
        p.scale(1.0 / gamma);
        p
    }
}

impl Optimizer for EvaF {
    fn name(&self) -> &'static str {
        "eva-f"
    }

    fn stats_mode(&self) -> StatsMode {
        StatsMode::KvOnly
    }

    fn step(&mut self, ctx: &StepCtx) -> Update {
        let xi = self.hp.running_avg;
        if !self.initialized {
            self.a_bar = ctx.stats.iter().map(|s| s.a_mean.clone()).collect();
            self.initialized = true;
        } else {
            // KV running average on the f32x8 blend kernel (same
            // arithmetic as the plain loop on every ISA path).
            for (state, s) in self.a_bar.iter_mut().zip(ctx.stats) {
                crate::simd::blend8(state, 1.0 - xi, xi, &s.a_mean);
            }
        }
        let gamma = self.hp.damping;
        let grads = decayed_grads(ctx, self.hp.weight_decay);
        let mut pre: Vec<Tensor> = grads
            .iter()
            .enumerate()
            .map(|(l, g)| Self::precondition_layer(g, &self.a_bar[l], gamma))
            .collect();
        if crate::telemetry::health::due(ctx.step) {
            // Read-only sampled health probe (never changes numerics).
            use crate::telemetry::health;
            health::sample("eva-f", "damping", gamma as f64);
            for (l, g) in grads.iter().enumerate() {
                let a = &self.a_bar[l];
                let na2 = dot(a, a);
                health::sample_layer("eva-f", "sm_denom", l, (gamma + na2) as f64);
                health::sample_layer("eva-f", "kv_a_norm", l, (na2 as f64).sqrt());
                let (pn, gn) = (pre[l].norm(), g.norm());
                if pn > 0.0 && gn > 0.0 {
                    let cos = pre[l].dot(g) / (pn * gn);
                    health::sample_layer("eva-f", "precond_cosine", l, cos as f64);
                    health::sample_layer("eva-f", "precond_norm_ratio", l, (pn / gn) as f64);
                }
            }
        }
        if self.use_kl_norm {
            // KL normalization: p ← p/√(Σ pᵀg). pᵀg ≥ 0 (PD preconditioner).
            let pg = super::pg_inner(&pre, &grads).max(1e-12);
            let inv = 1.0 / pg.sqrt();
            for p in &mut pre {
                p.scale(inv);
            }
        }
        self.momentum.apply(self.hp.momentum, ctx.lr, pre, ctx.bias_grads.to_vec())
    }

    fn state_bytes(&self) -> usize {
        let kv: usize = self.a_bar.iter().map(|v| v.len()).sum();
        4 * kv + self.momentum.state_bytes()
    }

    fn export_state(&self) -> OptState {
        let mut st = OptState::new(self.name());
        st.scalars.push(self.initialized as u64);
        st.scalars.push(self.a_bar.len() as u64);
        for (i, v) in self.a_bar.iter().enumerate() {
            st.bufs.push(StateBuf::vecf(format!("kv.a{i}"), v));
        }
        self.momentum.export_into(&mut st);
        st
    }

    fn import_state(&mut self, st: &OptState) -> Result<(), String> {
        let mut r = StateReader::open(st, self.name())?;
        self.initialized = r.flag()?;
        let n = r.scalar()? as usize;
        self.a_bar = (0..n).map(|i| r.vecf(&format!("kv.a{i}"))).collect::<Result<_, _>>()?;
        self.momentum = MomentumState::import_from(&mut r)?;
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::damped_inverse;
    use crate::testing::{check, tensors_close, Gen};

    /// Eq. 21 equals G·(āāᵀ+γI)⁻¹ computed densely.
    #[test]
    fn prop_matches_dense_right_inverse() {
        check("eva-f == G(āāᵀ+γI)⁻¹", 20, |g: &mut Gen| {
            let d_out = g.usize_in(1, 7);
            let d_in = g.usize_in(2, 7);
            let gamma = g.f32_in(0.05, 0.5);
            let grad = g.normal_tensor(d_out, d_in);
            let a = g.normal_vec(d_in);
            let fast = EvaF::precondition_layer(&grad, &a, gamma);
            let mut aat = Tensor::zeros(d_in, d_in);
            aat.add_outer(1.0, &a, &a);
            let inv = damped_inverse(&aat, gamma).map_err(|e| e)?;
            let mut dense = crate::tensor::matmul(&grad, &inv);
            // precondition_layer includes the 1/γ? No: Eq.21 already is
            // (1/γ)(G − …) == G(āāᵀ+γI)⁻¹. Dense path needs no scaling.
            tensors_close(&fast, &mut dense, 2e-2, "eva-f vs dense")
        });
    }

    /// Eva-f solves the "gradient descent on neurons" least squares
    /// (Eq. 27–28): ΔW minimizes ‖ΔW ā āᵀ − G‖² + γ‖ΔW‖².
    #[test]
    fn prop_least_squares_stationarity() {
        check("eva-f normal equations", 15, |g: &mut Gen| {
            let d_out = g.usize_in(1, 5);
            let d_in = g.usize_in(2, 5);
            let gamma = g.f32_in(0.1, 0.6);
            let grad = g.normal_tensor(d_out, d_in);
            let a = g.normal_vec(d_in);
            let p = EvaF::precondition_layer(&grad, &a, gamma);
            // Stationarity: P(āāᵀ + γI) = G.
            let mut aat = Tensor::zeros(d_in, d_in);
            aat.add_outer(1.0, &a, &a);
            aat.add_diag(gamma);
            let back = crate::tensor::matmul(&p, &aat);
            tensors_close(&back, &grad, 2e-2, "P(āāᵀ+γI) vs G")
        });
    }

    #[test]
    fn kl_norm_makes_update_scale_invariant() {
        // Scaling the gradient by c scales p by c too; KL-normalized
        // update scales by c/√(c²) = 1 in direction · magnitude ∝ √(pᵀg).
        let mut hp = HyperParams::default();
        hp.momentum = 0.0;
        hp.weight_decay = 0.0;
        let mut opt1 = EvaF::new(hp.clone());
        let mut opt2 = EvaF::new(hp);
        let params = vec![Tensor::zeros(2, 3)];
        let g1 = vec![Tensor::full(2, 3, 0.2)];
        let mut g2 = g1.clone();
        g2[0].scale(10.0);
        let bias = vec![vec![]];
        let stats = vec![crate::nn::LayerStats {
            a_mean: vec![0.3, -0.2, 0.5],
            b_mean: vec![],
            aat: None,
            bbt: None,
        }];
        fn mk<'a>(
            params: &'a [Tensor],
            grads: &'a [Tensor],
            bias: &'a [Vec<f32>],
            stats: &'a [crate::nn::LayerStats],
        ) -> StepCtx<'a> {
            StepCtx { params, grads, bias_grads: bias, stats, lr: 1.0, step: 0 }
        }
        let u1 = opt1.step(&mk(&params, &g1, &bias, &stats));
        let u2 = opt2.step(&mk(&params, &g2, &bias, &stats));
        // ‖Δ2‖/‖Δ1‖ == 10/√100 = 1 exactly under KL normalization.
        let r = u2.deltas[0].norm() / u1.deltas[0].norm();
        assert!((r - 1.0).abs() < 1e-4, "ratio {r}");
    }
}
