//! FOOF (Benzing 2022) — gradient descent on neurons (Eq. 6), plus the
//! rank-1 eigen-approximation of Fig. 3 / Eq. 24–26.
//!
//! `ΔW = −α G (R + γI)⁻¹`, `R = AAᵀ/n` with a running average. In
//! `rank1` mode the damped inverse is replaced by the paper's rank-one
//! eigendecomposition approximation
//! `p ≈ (1/γ)(G − λ₁/(γ+λ₁) · G u₁u₁ᵀ)` (Eq. 26) — the observation
//! that motivates Eva-f.
//!
//! Both variants use KL normalization like Eva-f so the Fig. 8
//! convergence pairing is apples-to-apples (the FOOF paper's own
//! step-size control is learning-rate based; see DESIGN.md).

use super::{
    decayed_grads, HyperParams, MomentumState, OptState, Optimizer, StateBuf, StateReader,
    StepCtx, Update,
};
use crate::linalg::{damped_inverse, power_iteration};
use crate::nn::StatsMode;
use crate::tensor::{matmul, Tensor};

pub struct Foof {
    hp: HyperParams,
    rank1: bool,
    r: Vec<Tensor>,
    r_inv: Vec<Tensor>,
    /// Rank-1 mode cache: (λ₁, u₁) per layer.
    eig: Vec<(f32, Vec<f32>)>,
    momentum: MomentumState,
    initialized: bool,
    pub use_kl_norm: bool,
}

impl Foof {
    pub fn new(hp: HyperParams, rank1: bool) -> Self {
        Foof {
            hp,
            rank1,
            r: Vec::new(),
            r_inv: Vec::new(),
            eig: Vec::new(),
            momentum: MomentumState::new(),
            initialized: false,
            use_kl_norm: true,
        }
    }

    pub fn is_refresh_step(&self, step: u64) -> bool {
        step % self.hp.update_interval.max(1) as u64 == 0
    }

    /// Refresh the running factor `R` and its inverse (or rank-1
    /// eigenpair). The blends and the power-iteration matvecs run on
    /// the `f32x8` micro-kernels via `tensor`, so a refresh is
    /// bit-identical across backends and ISA paths.
    fn refresh(&mut self, ctx: &StepCtx) {
        let xi = self.hp.running_avg;
        if !self.initialized {
            self.r = ctx
                .stats
                .iter()
                .map(|s| s.aat.clone().expect("foof needs Full stats"))
                .collect();
            self.initialized = true;
        } else {
            for (state, s) in self.r.iter_mut().zip(ctx.stats) {
                state.blend(1.0 - xi, xi, s.aat.as_ref().unwrap());
            }
        }
        let gamma = self.hp.damping;
        // Per-layer factorizations are independent — fan them across
        // the compute backend (same arithmetic per layer either way).
        let bk = crate::backend::current();
        let r = &self.r;
        if self.rank1 {
            self.eig =
                crate::backend::par_map(&*bk, r.len(), |l| power_iteration(&r[l], 50, 0x0f00));
        } else {
            self.r_inv = crate::backend::par_map(&*bk, r.len(), |l| {
                damped_inverse(&r[l], gamma).expect("R+γI must be PD")
            });
        }
    }
}

impl Optimizer for Foof {
    fn name(&self) -> &'static str {
        if self.rank1 {
            "foof-rank1"
        } else {
            "foof"
        }
    }

    fn stats_mode(&self) -> StatsMode {
        StatsMode::Full
    }

    fn step(&mut self, ctx: &StepCtx) -> Update {
        if self.is_refresh_step(ctx.step) {
            self.refresh(ctx);
        }
        assert!(self.initialized, "first FOOF step must be a refresh step");
        let gamma = self.hp.damping;
        let grads = decayed_grads(ctx, self.hp.weight_decay);
        let mut pre: Vec<Tensor> = grads
            .iter()
            .enumerate()
            .map(|(l, g)| {
                if self.rank1 {
                    // Eq. 26: (1/γ)(G − λ₁/(γ+λ₁)·(G u₁)u₁ᵀ)
                    let (l1, u1) = &self.eig[l];
                    let gu = g.matvec(u1);
                    let mut p = g.clone();
                    p.add_outer(-l1 / (gamma + l1), &gu, u1);
                    p.scale(1.0 / gamma);
                    p
                } else {
                    matmul(g, &self.r_inv[l])
                }
            })
            .collect();
        if crate::telemetry::health::due(ctx.step) {
            // Read-only sampled health probe (never changes numerics).
            use crate::telemetry::health;
            let alg = self.name();
            health::sample(alg, "damping", gamma as f64);
            health::sample(
                alg,
                "factor_staleness",
                (ctx.step % self.hp.update_interval.max(1) as u64) as f64,
            );
            for (l, g) in grads.iter().enumerate() {
                if self.rank1 {
                    let (l1, _) = &self.eig[l];
                    health::sample_layer(alg, "lambda1", l, *l1 as f64);
                    health::sample_layer(alg, "rank1_coeff", l, (l1 / (gamma + l1)) as f64);
                }
                let (pn, gn) = (pre[l].norm(), g.norm());
                if pn > 0.0 && gn > 0.0 {
                    let cos = pre[l].dot(g) / (pn * gn);
                    health::sample_layer(alg, "precond_cosine", l, cos as f64);
                    health::sample_layer(alg, "precond_norm_ratio", l, (pn / gn) as f64);
                }
            }
        }
        if self.use_kl_norm {
            let pg = super::pg_inner(&pre, &grads).max(1e-12);
            let inv = 1.0 / pg.sqrt();
            for p in &mut pre {
                p.scale(inv);
            }
        }
        self.momentum.apply(self.hp.momentum, ctx.lr, pre, ctx.bias_grads.to_vec())
    }

    fn state_bytes(&self) -> usize {
        let f: usize = self.r.iter().chain(&self.r_inv).map(|t| t.len()).sum();
        let e: usize = self.eig.iter().map(|(_, u)| u.len() + 1).sum();
        4 * (f + e) + self.momentum.state_bytes()
    }

    /// Full KFs only on refresh steps.
    fn stats_mode_at(&self, step: u64) -> StatsMode {
        if self.is_refresh_step(step) {
            StatsMode::Full
        } else {
            StatsMode::None
        }
    }

    fn export_state(&self) -> OptState {
        let mut st = OptState::new(self.name());
        st.scalars.push(self.initialized as u64);
        st.scalars.push(self.r.len() as u64);
        st.scalars.push(self.r_inv.len() as u64);
        st.scalars.push(self.eig.len() as u64);
        for (i, t) in self.r.iter().enumerate() {
            st.bufs.push(StateBuf::tensor(format!("kf.r{i}"), t));
        }
        for (i, t) in self.r_inv.iter().enumerate() {
            st.bufs.push(StateBuf::tensor(format!("kf.rinv{i}"), t));
        }
        // (λ₁, u₁) packed as one vector [λ₁, u₁…] per layer.
        for (i, (l1, u1)) in self.eig.iter().enumerate() {
            let mut packed = Vec::with_capacity(u1.len() + 1);
            packed.push(*l1);
            packed.extend_from_slice(u1);
            st.bufs.push(StateBuf::vecf(format!("eig{i}"), &packed));
        }
        self.momentum.export_into(&mut st);
        st
    }

    fn import_state(&mut self, st: &OptState) -> Result<(), String> {
        let mut r = StateReader::open(st, self.name())?;
        self.initialized = r.flag()?;
        let n = r.scalar()? as usize;
        let ninv = r.scalar()? as usize;
        let neig = r.scalar()? as usize;
        self.r = (0..n).map(|i| r.tensor(&format!("kf.r{i}"))).collect::<Result<_, _>>()?;
        self.r_inv =
            (0..ninv).map(|i| r.tensor(&format!("kf.rinv{i}"))).collect::<Result<_, _>>()?;
        self.eig = (0..neig)
            .map(|i| {
                let packed = r.vecf(&format!("eig{i}"))?;
                if packed.is_empty() {
                    return Err(format!("foof: eig{i} empty"));
                }
                Ok((packed[0], packed[1..].to_vec()))
            })
            .collect::<Result<_, _>>()?;
        self.momentum = MomentumState::import_from(&mut r)?;
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerStats;
    use crate::testing::{check, Gen};

    fn plain_hp() -> HyperParams {
        HyperParams {
            momentum: 0.0,
            weight_decay: 0.0,
            running_avg: 1.0,
            ..HyperParams::default()
        }
    }

    fn rank1_dominant_stats(g: &mut Gen, d: usize) -> LayerStats {
        // R with one dominant direction, like real activations with a
        // large mean component.
        let u = g.normal_vec(d);
        let mut r = g.spd_tensor(d, 0.001);
        r.scale(0.005);
        r.add_outer(4.0, &u, &u);
        LayerStats { a_mean: vec![0.0; d], b_mean: vec![], aat: Some(r), bbt: None }
    }

    /// Rank-1 FOOF approximates full FOOF when R is near rank-one — the
    /// Fig. 3 observation.
    #[test]
    fn prop_rank1_close_to_full_on_lowrank_r() {
        check("foof-rank1 ≈ foof", 10, |g: &mut Gen| {
            let d = g.usize_in(3, 8);
            let stats = vec![rank1_dominant_stats(g, d)];
            let grads = vec![g.normal_tensor(2, d)];
            let params = vec![Tensor::zeros(2, d)];
            let bias = vec![vec![]];
            let ctx = StepCtx {
                params: &params,
                grads: &grads,
                bias_grads: &bias,
                stats: &stats,
                lr: 1.0,
                step: 0,
            };
            let mut full = Foof::new(plain_hp(), false);
            full.use_kl_norm = false;
            let mut r1 = Foof::new(plain_hp(), true);
            r1.use_kl_norm = false;
            let uf = full.step(&ctx);
            let ur = r1.step(&ctx);
            // Cosine similarity of the two updates should be high.
            let (a, b) = (&uf.deltas[0], &ur.deltas[0]);
            let cos = a.dot(b) / (a.norm() * b.norm());
            if cos > 0.95 {
                Ok(())
            } else {
                Err(format!("cos {cos}"))
            }
        });
    }

    #[test]
    fn foof_matches_manual_right_preconditioning() {
        let mut opt = Foof::new(plain_hp(), false);
        opt.use_kl_norm = false;
        let params = vec![Tensor::zeros(2, 2)];
        let grads = vec![Tensor::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]])];
        let bias = vec![vec![]];
        let r = Tensor::from_rows(&[&[2.0, 0.0], &[0.0, 4.0]]);
        let stats = vec![LayerStats {
            a_mean: vec![0.0; 2],
            b_mean: vec![],
            aat: Some(r),
            bbt: None,
        }];
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias,
            stats: &stats,
            lr: 1.0,
            step: 0,
        };
        let gamma = HyperParams::default().damping;
        let u = opt.step(&ctx);
        assert!((u.deltas[0].at(0, 0) + 1.0 / (2.0 + gamma)).abs() < 1e-4);
        assert!((u.deltas[0].at(1, 1) + 1.0 / (4.0 + gamma)).abs() < 1e-4);
        assert!(u.deltas[0].at(0, 1).abs() < 1e-6);
    }
}
