//! M-FAC (Frantar et al.) — matrix-free FIM baseline (§2.2).
//!
//! Estimates the empirical Fisher from the last `m` *whole-model*
//! gradients, `F = λI + (1/m) Σᵢ gᵢgᵢᵀ`, and computes `F⁻¹g` by chained
//! Sherman–Morrison over the gradient history (the recursive
//! Woodbury scheme). No matrix is ever materialized, but the history
//! costs `O(m·d)` memory and `O(m²·d)` time per step — the paper's
//! point about M-FAC being memory-hungry (m = 1024 suggested; scaled to
//! `hp.mfac_history` here, see DESIGN.md).

use super::{
    decayed_grads, HyperParams, MomentumState, OptState, Optimizer, StateBuf, StateReader,
    StepCtx, Update,
};
use crate::nn::StatsMode;
use crate::tensor::{axpy, dot, Tensor};

pub struct MFac {
    hp: HyperParams,
    /// Ring buffer of the last m flattened whole-model gradients.
    history: Vec<Vec<f32>>,
    next_slot: usize,
    momentum: MomentumState,
    /// Layer shapes for unflattening.
    shapes: Vec<(usize, usize)>,
}

impl MFac {
    pub fn new(hp: HyperParams) -> Self {
        MFac {
            hp,
            history: Vec::new(),
            next_slot: 0,
            momentum: MomentumState::new(),
            shapes: Vec::new(),
        }
    }

    fn flatten(grads: &[Tensor]) -> Vec<f32> {
        let mut out = Vec::with_capacity(grads.iter().map(|g| g.len()).sum());
        for g in grads {
            out.extend_from_slice(g.data());
        }
        out
    }

    fn unflatten(&self, flat: &[f32]) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(self.shapes.len());
        let mut off = 0;
        for &(r, c) in &self.shapes {
            out.push(Tensor::from_vec(r, c, flat[off..off + r * c].to_vec()));
            off += r * c;
        }
        out
    }

    /// `F⁻¹ v` via chained Sherman–Morrison over the history.
    ///
    /// With `F_0 = λI`, `F_k = F_{k-1} + (1/m) g_k g_kᵀ`:
    /// `F_k⁻¹v = F_{k-1}⁻¹v − c_k (g_kᵀ F_{k-1}⁻¹ v) / d_k` where
    /// `c_k = F_{k-1}⁻¹ g_k`, `d_k = m + g_kᵀ c_k`. The `c_k` are built
    /// by running the length-(k−1) chain on `g_k` itself.
    #[cfg(test)]
    fn inv_apply(&self, v: &[f32], lambda: f32) -> Vec<f32> {
        self.inv_apply_full(v, lambda).0
    }

    /// [`Self::inv_apply`] plus the chain denominators `d_k` — the
    /// Sherman–Morrison health quantities, returned at zero extra
    /// compute for the sampled health probe.
    fn inv_apply_full(&self, v: &[f32], lambda: f32) -> (Vec<f32>, Vec<f32>) {
        let m = self.history.len();
        let inv_l = 1.0 / lambda;
        // Pass 1: compute c_k and denominators d_k.
        let mut cs: Vec<Vec<f32>> = Vec::with_capacity(m);
        let mut ds: Vec<f32> = Vec::with_capacity(m);
        for k in 0..m {
            let gk = &self.history[k];
            let mut w: Vec<f32> = gk.iter().map(|x| x * inv_l).collect();
            for j in 0..k {
                let coeff = dot(&self.history[j], &w) / ds[j];
                axpy(-coeff, &cs[j], &mut w);
            }
            let d = m as f32 + dot(gk, &w);
            cs.push(w);
            ds.push(d);
        }
        // Pass 2: run the full chain on v.
        let mut w: Vec<f32> = v.iter().map(|x| x * inv_l).collect();
        for j in 0..m {
            let coeff = dot(&self.history[j], &w) / ds[j];
            axpy(-coeff, &cs[j], &mut w);
        }
        (w, ds)
    }
}

impl Optimizer for MFac {
    fn name(&self) -> &'static str {
        "mfac"
    }

    fn stats_mode(&self) -> StatsMode {
        StatsMode::None
    }

    fn step(&mut self, ctx: &StepCtx) -> Update {
        if self.shapes.is_empty() {
            self.shapes = ctx.grads.iter().map(|g| g.shape()).collect();
        }
        let grads = decayed_grads(ctx, self.hp.weight_decay);
        let flat = Self::flatten(&grads);
        // Insert into the ring buffer.
        let m = self.hp.mfac_history.max(1);
        if self.history.len() < m {
            self.history.push(flat.clone());
        } else {
            self.history[self.next_slot] = flat.clone();
            self.next_slot = (self.next_slot + 1) % m;
        }
        let (pre_flat, ds) = self.inv_apply_full(&flat, self.hp.damping);
        if crate::telemetry::health::due(ctx.step) {
            // Read-only sampled health probe: the chain denominators
            // d_k are the SM health quantities, already computed.
            use crate::telemetry::health;
            health::sample("mfac", "damping", self.hp.damping as f64);
            health::sample("mfac", "history_len", ds.len() as f64);
            if !ds.is_empty() {
                let min = ds.iter().copied().fold(f32::INFINITY, f32::min);
                let mean = ds.iter().sum::<f32>() / ds.len() as f32;
                health::sample("mfac", "sm_denom_min", min as f64);
                health::sample("mfac", "sm_denom_mean", mean as f64);
            }
            let (pn, gn) = (crate::tensor::norm(&pre_flat), crate::tensor::norm(&flat));
            if pn > 0.0 && gn > 0.0 {
                let cos = dot(&pre_flat, &flat) / (pn * gn);
                health::sample("mfac", "precond_cosine", cos as f64);
                health::sample("mfac", "precond_norm_ratio", (pn / gn) as f64);
            }
        }
        let pre = self.unflatten(&pre_flat);
        self.momentum.apply(self.hp.momentum, ctx.lr, pre, ctx.bias_grads.to_vec())
    }

    fn state_bytes(&self) -> usize {
        let h: usize = self.history.iter().map(|g| g.len()).sum();
        4 * h + self.momentum.state_bytes()
    }

    fn export_state(&self) -> OptState {
        let mut st = OptState::new(self.name());
        st.scalars.push(self.next_slot as u64);
        st.scalars.push(self.shapes.len() as u64);
        for &(rows, cols) in &self.shapes {
            st.scalars.push(rows as u64);
            st.scalars.push(cols as u64);
        }
        st.scalars.push(self.history.len() as u64);
        for (i, g) in self.history.iter().enumerate() {
            st.bufs.push(StateBuf::vecf(format!("hist{i}"), g));
        }
        self.momentum.export_into(&mut st);
        st
    }

    fn import_state(&mut self, st: &OptState) -> Result<(), String> {
        let mut r = StateReader::open(st, self.name())?;
        self.next_slot = r.scalar()? as usize;
        let nshapes = r.scalar()? as usize;
        let mut shapes = Vec::with_capacity(nshapes);
        for _ in 0..nshapes {
            let rows = r.scalar()? as usize;
            let cols = r.scalar()? as usize;
            shapes.push((rows, cols));
        }
        self.shapes = shapes;
        let nh = r.scalar()? as usize;
        self.history = (0..nh).map(|i| r.vecf(&format!("hist{i}"))).collect::<Result<_, _>>()?;
        self.momentum = MomentumState::import_from(&mut r)?;
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::spd_inverse;
    use crate::testing::{check, Gen};

    /// inv_apply equals a dense (λI + (1/m)Σggᵀ)⁻¹ solve.
    #[test]
    fn prop_inv_apply_matches_dense() {
        check("mfac woodbury == dense", 12, |g: &mut Gen| {
            let d = g.usize_in(2, 10);
            let m = g.usize_in(1, 6);
            let lambda = g.f32_in(0.1, 1.0);
            let mut opt = MFac::new(HyperParams::default());
            let mut f = Tensor::zeros(d, d);
            for _ in 0..m {
                let gi = g.normal_vec(d);
                f.add_outer(1.0 / m as f32, &gi, &gi);
                opt.history.push(gi);
            }
            f.add_diag(lambda);
            let dense = spd_inverse(&f).map_err(|e| e)?;
            let v = g.normal_vec(d);
            let fast = opt.inv_apply(&v, lambda);
            let slow = dense.matvec(&v);
            for (a, b) in fast.iter().zip(&slow) {
                if (a - b).abs() > 2e-2 * (1.0 + b.abs()) {
                    return Err(format!("{a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ring_buffer_caps_history() {
        let mut hp = HyperParams::default();
        hp.mfac_history = 3;
        hp.momentum = 0.0;
        hp.weight_decay = 0.0;
        let mut opt = MFac::new(hp);
        let params = vec![Tensor::zeros(2, 2)];
        let bias = vec![vec![]];
        for step in 0..5 {
            let grads = vec![Tensor::full(2, 2, step as f32 + 1.0)];
            let ctx = StepCtx {
                params: &params,
                grads: &grads,
                bias_grads: &bias,
                stats: &[],
                lr: 0.1,
                step,
            };
            let _ = opt.step(&ctx);
        }
        assert_eq!(opt.history.len(), 3);
        // Memory accounting: 3 grads × 4 floats each.
        assert_eq!(opt.state_bytes(), 4 * (3 * 4 + 4));
    }

    #[test]
    fn empty_history_is_scaled_identity() {
        let opt = MFac::new(HyperParams::default());
        let out = opt.inv_apply(&[2.0, -4.0], 0.5);
        assert_eq!(out, vec![4.0, -8.0]);
    }
}
