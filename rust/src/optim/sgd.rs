//! SGD with momentum and L2 weight decay (paper Eq. 2; the first-order
//! baseline every table normalizes against).

use super::{
    decayed_grads, HyperParams, MomentumState, OptState, Optimizer, StateReader, StepCtx, Update,
};
use crate::nn::StatsMode;

pub struct Sgd {
    hp: HyperParams,
    momentum: MomentumState,
}

impl Sgd {
    pub fn new(hp: HyperParams) -> Self {
        Sgd { hp, momentum: MomentumState::new() }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn stats_mode(&self) -> StatsMode {
        StatsMode::None
    }

    fn step(&mut self, ctx: &StepCtx) -> Update {
        let g = decayed_grads(ctx, self.hp.weight_decay);
        self.momentum.apply(self.hp.momentum, ctx.lr, g, ctx.bias_grads.to_vec())
    }

    fn state_bytes(&self) -> usize {
        self.momentum.state_bytes()
    }

    fn export_state(&self) -> OptState {
        let mut st = OptState::new(self.name());
        self.momentum.export_into(&mut st);
        st
    }

    fn import_state(&mut self, st: &OptState) -> Result<(), String> {
        let mut r = StateReader::open(st, self.name())?;
        self.momentum = MomentumState::import_from(&mut r)?;
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn plain_step_is_negative_gradient() {
        let mut hp = HyperParams::default();
        hp.momentum = 0.0;
        hp.weight_decay = 0.0;
        let mut opt = Sgd::new(hp);
        let params = vec![Tensor::full(2, 2, 1.0)];
        let grads = vec![Tensor::full(2, 2, 2.0)];
        let bias_grads = vec![vec![1.0, 1.0]];
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias_grads,
            stats: &[],
            lr: 0.5,
            step: 0,
        };
        let u = opt.step(&ctx);
        assert_eq!(u.deltas[0].data(), &[-1.0; 4]);
        assert_eq!(u.bias_deltas[0], vec![-0.5, -0.5]);
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let mut hp = HyperParams::default();
        hp.momentum = 0.0;
        hp.weight_decay = 0.1;
        let mut opt = Sgd::new(hp);
        let params = vec![Tensor::full(1, 1, 10.0)];
        let grads = vec![Tensor::zeros(1, 1)];
        let bias_grads = vec![vec![]];
        let ctx = StepCtx {
            params: &params,
            grads: &grads,
            bias_grads: &bias_grads,
            stats: &[],
            lr: 1.0,
            step: 0,
        };
        let u = opt.step(&ctx);
        assert!((u.deltas[0].data()[0] + 1.0).abs() < 1e-6);
    }
}
